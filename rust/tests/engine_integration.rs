//! End-to-end engine integration: generate a synthetic NanoAOD-like
//! file, skim it through every engine configuration, and cross-check
//! results (PJRT kernel ≡ interpreter, two-phase ≡ legacy, output file
//! contents ≡ an independent reference selection).

use skimroot::compress::Codec;
use skimroot::engine::{DecompMode, EngineOpts, SkimEngine};
use skimroot::gen::{self, GenConfig};
use skimroot::metrics::{Node, Stage, Timeline};
use skimroot::net::{DiskModel, LinkModel};
use skimroot::query::SkimQuery;
use skimroot::runtime::SkimRuntime;
use skimroot::troot::{ColumnData, ColumnValues, LocalFile, ReadAt, TRootReader};
use skimroot::xrootd::{LoopbackWire, XrdClient, XrdServer};
use std::sync::{Arc, OnceLock};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Option<&'static SkimRuntime> {
    static RT: OnceLock<Option<SkimRuntime>> = OnceLock::new();
    RT.get_or_init(|| SkimRuntime::load(artifacts_dir()).ok()).as_ref()
}

fn workdir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("skim_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Shared small dataset (full pipeline shape, 1200 events).
fn dataset() -> std::path::PathBuf {
    static PATH: OnceLock<std::path::PathBuf> = OnceLock::new();
    PATH.get_or_init(|| {
        let dir = workdir();
        let path = dir.join("events.troot");
        let cfg = GenConfig {
            n_events: 1200,
            target_branches: 220,
            n_hlt: 40,
            basket_events: 256,
            codec: Codec::Lz4,
            seed: 42,
        };
        gen::generate(&cfg, &path).unwrap();
        path
    })
    .clone()
}

fn query(outname: &str) -> SkimQuery {
    gen::higgs_query("events.troot", outname)
}

fn local_store() -> Arc<dyn ReadAt> {
    Arc::new(LocalFile::open(dataset()).unwrap())
}

fn run_with(opts: &EngineOpts, outname: &str) -> (skimroot::engine::SkimResult, Timeline) {
    let tl = Timeline::new();
    let engine = SkimEngine::new(runtime());
    let out = workdir().join(outname);
    let res = engine
        .run(local_store(), &query(outname), &tl, opts, &out)
        .unwrap();
    (res, tl)
}

#[test]
fn pjrt_and_interpreter_agree() {
    if runtime().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let vec_opts = EngineOpts { use_pjrt: true, max_objects: 16, ..Default::default() };
    let int_opts = EngineOpts { use_pjrt: false, max_objects: 16, ..Default::default() };
    let (res_v, _) = run_with(&vec_opts, "out_vec.troot");
    let (res_i, _) = run_with(&int_opts, "out_int.troot");
    assert!(res_v.vectorized);
    assert!(!res_i.vectorized);
    assert_eq!(res_v.n_pass, res_i.n_pass);
    assert_eq!(res_v.stage_funnel, res_i.stage_funnel);
    // Byte-identical filtered files.
    let a = std::fs::read(workdir().join("out_vec.troot")).unwrap();
    let b = std::fs::read(workdir().join("out_int.troot")).unwrap();
    assert_eq!(a, b);
}

#[test]
fn two_phase_and_legacy_produce_identical_output() {
    let two = EngineOpts { two_phase: true, use_pjrt: false, ..Default::default() };
    let legacy = EngineOpts { two_phase: false, use_pjrt: false, ..Default::default() };
    let (res2, _) = run_with(&two, "out_two.troot");
    let (res1, _) = run_with(&legacy, "out_legacy.troot");
    assert_eq!(res2.n_pass, res1.n_pass);
    let a = std::fs::read(workdir().join("out_two.troot")).unwrap();
    let b = std::fs::read(workdir().join("out_legacy.troot")).unwrap();
    assert_eq!(a, b);
    // Legacy fetches every output branch for every cluster; two-phase
    // fetches at most that (equal when every cluster has a passer).
    assert!(res2.baskets_fetched <= res1.baskets_fetched);
    assert!(res2.fetched_bytes <= res1.fetched_bytes);
}

#[test]
fn two_phase_skips_output_fetch_for_rejected_clusters() {
    // A selection nothing passes: phase 2 never runs, so only the
    // criteria baskets are fetched — the core two-phase saving.
    let tight = SkimQuery::from_json_text(
        r#"{"input": "events.troot", "output": "none.troot",
            "branches": ["Electron_*", "MET_pt", "run"],
            "selection": {"preselection": [
                {"branch": "MET_pt", "op": ">", "value": 100000.0}]}}"#,
    )
    .unwrap();
    let engine = SkimEngine::new(None);
    let opts2 = EngineOpts { two_phase: true, use_pjrt: false, ..Default::default() };
    let opts1 = EngineOpts { two_phase: false, use_pjrt: false, ..Default::default() };
    let tl = Timeline::new();
    let res2 = engine
        .run(local_store(), &tight, &tl, &opts2, workdir().join("none2.troot"))
        .unwrap();
    let res1 = engine
        .run(local_store(), &tight, &tl, &opts1, workdir().join("none1.troot"))
        .unwrap();
    assert_eq!(res2.n_pass, 0);
    assert_eq!(res1.n_pass, 0);
    // Two-phase only touched the single criteria branch (MET_pt).
    assert!(
        res2.fetched_bytes * 4 < res1.fetched_bytes,
        "two-phase {} vs legacy {}",
        res2.fetched_bytes,
        res1.fetched_bytes
    );
}

#[test]
fn output_matches_independent_reference_selection() {
    // Skim with the engine, then recompute the selection directly from
    // full columns and compare passing MET values.
    let opts = EngineOpts { use_pjrt: false, ..Default::default() };
    let (res, _) = run_with(&opts, "out_ref.troot");

    let reader = TRootReader::open(LocalFile::open(dataset()).unwrap()).unwrap();
    let q = query("x");
    let plan = skimroot::query::plan::SkimPlan::build(&q, reader.meta()).unwrap();

    // Reference: per-event evaluation straight from whole columns.
    let met = match reader.read_branch_all("MET_pt").unwrap() {
        ColumnData::Scalar(v) => v,
        _ => unreachable!(),
    };
    let n = reader.n_events() as usize;

    // Load all criteria columns.
    let mut jagged: std::collections::HashMap<String, (Vec<u32>, Vec<f32>)> = Default::default();
    let mut scalar: std::collections::HashMap<String, Vec<f64>> = Default::default();
    for name in &plan.criteria_branches {
        match reader.read_branch_all(name).unwrap() {
            ColumnData::Jagged { offsets, values } => {
                let v = match values {
                    ColumnValues::F32(v) => v,
                    _ => unreachable!(),
                };
                jagged.insert(name.clone(), (offsets, v));
            }
            ColumnData::Scalar(v) => {
                scalar.insert(name.clone(), (0..n).map(|i| v.get_as_f64(i)).collect());
            }
        }
    }

    let max_m = 16usize;
    let mut expected_pass = Vec::new();
    for ev in 0..n {
        let p = &plan.program;
        let mut ok = p.scalar_cuts.iter().all(|c| {
            let x = scalar[&p.scalar_columns[c.col]][ev] as f32;
            cmp(x, c.op, c.abs, c.value)
        });
        for g in &p.groups {
            let mut count = 0;
            for slot in 0..max_m {
                let mut pass = !g.cut_range.is_empty();
                for k in g.cut_range.clone() {
                    let cut = &p.obj_cuts[k];
                    let (offs, vals) = &jagged[&p.obj_columns[cut.col]];
                    let lo = offs[ev] as usize;
                    let hi = offs[ev + 1] as usize;
                    let m = (hi - lo).min(max_m);
                    if slot >= m {
                        pass = false;
                        break;
                    }
                    if !cmp(vals[lo + slot], cut.op, cut.abs, cut.value) {
                        pass = false;
                        break;
                    }
                }
                if pass {
                    count += 1;
                }
            }
            ok &= count >= g.min_count;
        }
        if let Some(ht) = &p.ht {
            let (offs, vals) = &jagged[&p.obj_columns[ht.col]];
            let lo = offs[ev] as usize;
            let hi = offs[ev + 1] as usize;
            let m = (hi - lo).min(max_m);
            let total: f32 = vals[lo..lo + m].iter().filter(|&&x| x > ht.object_pt_min).sum();
            ok &= total >= ht.min_ht;
        }
        if !p.triggers.is_empty() {
            ok &= p
                .triggers
                .iter()
                .any(|&s| scalar[&p.scalar_columns[s]][ev] > 0.5);
        }
        if ok {
            expected_pass.push(ev);
        }
    }

    assert_eq!(res.n_pass as usize, expected_pass.len());

    // Check the output file's MET_pt column equals the passers' values.
    let out_reader =
        TRootReader::open(LocalFile::open(workdir().join("out_ref.troot")).unwrap()).unwrap();
    assert_eq!(out_reader.n_events() as usize, expected_pass.len());
    let out_met = match out_reader.read_branch_all("MET_pt").unwrap() {
        ColumnData::Scalar(v) => v,
        _ => unreachable!(),
    };
    for (i, &ev) in expected_pass.iter().enumerate() {
        assert_eq!(out_met.get_as_f64(i), met.get_as_f64(ev), "passer {i} (event {ev})");
    }
    // Output keeps all 89 branches.
    assert_eq!(out_reader.meta().branches.len(), 89);
}

fn cmp(x: f32, op: u8, abs: bool, v: f32) -> bool {
    let x = if abs { x.abs() } else { x };
    match op {
        0 => x > v,
        1 => x >= v,
        2 => x < v,
        3 => x <= v,
        4 => x == v,
        5 => x != v,
        _ => false,
    }
}

#[test]
fn remote_skim_over_loopback_wire_charges_stages() {
    // Serve the dataset over the XRootD-like protocol on a 1 Gbps link
    // model and skim remotely (the paper's client-side setup).
    let dir = dataset().parent().unwrap().to_path_buf();
    let server = XrdServer::new(&dir, DiskModel::disk_pool());
    let tl = Timeline::new();
    server.set_timeline(Some(tl.clone()));
    let wire = Arc::new(LoopbackWire::new(server, LinkModel::wan_1g(), tl.clone()));
    let client = XrdClient::new(wire);
    let remote = Arc::new(client.open("events.troot").unwrap());

    let engine = SkimEngine::new(runtime());
    let opts = EngineOpts { use_pjrt: false, ..Default::default() };
    let out = workdir().join("out_remote.troot");
    let res = engine
        .run(remote, &query("out_remote.troot"), &tl, &opts, &out)
        .unwrap();

    assert!(res.n_pass > 0);
    // Network fetch time accrued (RTTs + bytes over 1 Gbps).
    assert!(tl.stage_total(Stage::BasketFetch) > 0.01);
    assert!(tl.stage_total(Stage::Decompress) > 0.0);
    assert!(tl.stage_total(Stage::Filter) > 0.0);
    assert!(tl.node_busy(Node::Client) > 0.0);
    // Identical selection to the local run.
    let (local, _) = run_with(&opts, "out_local_cmp.troot");
    assert_eq!(res.n_pass, local.n_pass);

    // Cache should have batched round-trips: hits >> misses.
    let cache = res.cache.unwrap();
    assert!(cache.hits > cache.misses, "cache: {cache:?}");
    assert!(cache.prefetch_batches < res.baskets_fetched / 4);
}

#[test]
fn no_cache_means_per_basket_round_trips() {
    let dir = dataset().parent().unwrap().to_path_buf();
    let server = XrdServer::new(&dir, DiskModel::ideal());
    let tl = Timeline::new();
    let wire = Arc::new(LoopbackWire::new(server, LinkModel::wan_1g(), tl.clone()));
    let client = XrdClient::new(wire);
    let remote = Arc::new(client.open("events.troot").unwrap());
    let engine = SkimEngine::new(None);
    let opts = EngineOpts { use_pjrt: false, cache_bytes: None, ..Default::default() };
    let out = workdir().join("out_nocache.troot");
    let res = engine
        .run(remote, &query("out_nocache.troot"), &tl, &opts, &out)
        .unwrap();
    // Every basket fetch is its own round-trip: ≥ baskets_fetched RTTs.
    assert!(tl.counter("link_round_trips") >= res.baskets_fetched);
}

#[test]
fn hw_engine_decompression_attributes_to_engine_not_cpu() {
    let tl = Timeline::new();
    let engine = SkimEngine::new(None);
    let speedup = 1.4;
    let opts = EngineOpts {
        use_pjrt: false,
        compute_node: Node::Dpu,
        decomp: DecompMode::HwEngine { speedup },
        ..Default::default()
    };
    let out = workdir().join("out_hw.troot");
    engine
        .run(local_store(), &query("out_hw.troot"), &tl, &opts, &out)
        .unwrap();
    // All decompression time sits on the engine, none on the ARM cores.
    let engine_busy = tl.node_busy(Node::DpuEngine);
    assert!(engine_busy > 0.0);
    assert!((tl.stage_total(Stage::Decompress) - engine_busy).abs() < 1e-9);
    // The DPU cores still did deserialize/filter/output work.
    assert!(tl.node_busy(Node::Dpu) > 0.0);
}

#[test]
fn copy_all_query_keeps_every_event() {
    let q = SkimQuery::from_json_text(
        r#"{"input": "events.troot", "output": "copy.troot",
            "branches": ["MET_pt", "nJet"]}"#,
    )
    .unwrap();
    let tl = Timeline::new();
    let engine = SkimEngine::new(None);
    let out = workdir().join("copy.troot");
    let opts = EngineOpts { use_pjrt: false, ..Default::default() };
    let res = engine.run(local_store(), &q, &tl, &opts, &out).unwrap();
    assert_eq!(res.n_pass, res.n_events);
    let r = TRootReader::open(LocalFile::open(&out).unwrap()).unwrap();
    assert_eq!(r.n_events(), res.n_events);
    assert_eq!(r.meta().branches.len(), 2);
}

#[test]
fn output_codec_override_and_funnel_monotone() {
    let opts = EngineOpts {
        use_pjrt: false,
        output_codec: Some(Codec::XzLike),
        ..Default::default()
    };
    let (res, _) = run_with(&opts, "out_xz.troot");
    let r = TRootReader::open(LocalFile::open(workdir().join("out_xz.troot")).unwrap()).unwrap();
    assert_eq!(r.meta().codec, Codec::XzLike);
    // The §3.2 funnel is monotone non-increasing.
    let f = res.stage_funnel;
    assert!(f[0] >= f[1] && f[1] >= f[2] && f[2] >= f[3]);
    assert_eq!(f[3], res.n_pass);
}
