//! Chaos matrix: every fault kind × engine placement × serving
//! surface, with fixed seeds so each cell is deterministic.
//!
//! Cell contract:
//! * **transient faults** (active on the first attempt only) must
//!   recover through the retry policy and produce output bytes
//!   identical to a fault-free run — the fault is invisible except in
//!   the `retries`/`faults_injected` counters;
//! * **stalled reads under a deadline** must end in the
//!   `deadline-exceeded` terminal state, and the worker slot they held
//!   must be released (a follow-up job on the same service completes);
//! * **hopeless faults** (active on every attempt) must exhaust the
//!   retry budget and surface a terminal failure with error detail;
//! * nothing anywhere may panic — every cell ends in an asserted
//!   terminal state.

use skimroot::compress::Codec;
use skimroot::coordinator::{Deployment, FaultKind, FaultPlan};
use skimroot::dpu::http::{http_request, http_request_with_headers, DpuHttpServer};
use skimroot::gen::{self, GenConfig};
use skimroot::metrics::Timeline;
use skimroot::net::{DiskModel, LinkModel};
use skimroot::query::SkimQuery;
use skimroot::serve::{ServeConfig, SkimScheduler, SkimService, SkimServiceClient};
use skimroot::{CancelToken, Error, JobCtl, SkimJob};
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

/// The corruption-flavored kinds that abort an attempt. StallRead is
/// exercised separately (it never errors — it charges virtual time and
/// is only terminal through a deadline).
const FAILING_KINDS: [FaultKind; 4] = [
    FaultKind::ReadError,
    FaultKind::CorruptFrame,
    FaultKind::DecompressCorrupt,
    FaultKind::FailAtRead,
];

const PLACEMENTS: [&str; 2] = ["client", "dpu"];

fn workdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("chaos_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn dataset() -> PathBuf {
    static PATH: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    PATH.get_or_init(|| {
        let storage = workdir("storage");
        let cfg = GenConfig {
            n_events: 400,
            target_branches: 60,
            n_hlt: 10,
            basket_events: 100,
            codec: Codec::Lz4,
            seed: 71,
        };
        gen::generate(&cfg, &storage.join("events.troot")).unwrap();
        storage
    })
    .clone()
}

fn query(out: &str) -> SkimQuery {
    SkimQuery::new("events.troot", out)
        .keep(&["MET_pt", "nJet", "Jet_pt"])
        .with_cut_str("MET_pt > 25 && nJet >= 1")
        .unwrap()
}

/// Deployment for one matrix cell: the named placement with an ideal
/// disk (all timing comes from the fault plan) and the given faults.
fn deployment(placement: &str, fault: FaultPlan) -> Deployment {
    let mut dep = match placement {
        "client" => Deployment::client_opt(LinkModel::dedicated_100g()),
        _ => Deployment::skim_root(LinkModel::local()),
    };
    dep.disk = DiskModel::ideal();
    dep.fault = fault;
    dep
}

/// Fault active on the first attempt only: the retry must recover.
fn transient(kind: FaultKind, seed: u64) -> FaultPlan {
    FaultPlan {
        kind,
        fail_prob: 1.0,
        fail_at_read: 2,
        fail_attempts: 1,
        max_retries: 3,
        seed,
        ..Default::default()
    }
}

/// Fault active on every attempt: the retry budget must exhaust.
fn hopeless(kind: FaultKind, seed: u64) -> FaultPlan {
    FaultPlan {
        kind,
        fail_prob: 1.0,
        fail_at_read: 2,
        max_retries: 2,
        seed,
        ..Default::default()
    }
}

/// Every read stalls 120 virtual seconds — harmless without a
/// deadline, deterministically fatal with one.
fn stall(seed: u64) -> FaultPlan {
    FaultPlan {
        kind: FaultKind::StallRead,
        fail_prob: 1.0,
        stall_s: 120.0,
        seed,
        ..Default::default()
    }
}

/// Uniform result of one matrix cell, whatever surface produced it.
struct Outcome {
    /// Terminal [`skimroot::serve::JobState`] name.
    state: String,
    /// Output bytes (`done` cells only).
    bytes: Option<Vec<u8>>,
    retries: u64,
    faults: u64,
    error: String,
}

// ---------------- surface drivers ------------------------------------

/// Surface 1: the one-shot in-process `SkimJob` facade.
fn run_facade(dep: Deployment, deadline_ms: u64, tag: &str) -> Outcome {
    let mut job = SkimJob::new(query(&format!("{tag}.troot")))
        .storage(dataset())
        .client_dir(workdir(tag))
        .deployment(dep);
    if deadline_ms > 0 {
        job = job.deadline_ms(deadline_ms);
    }
    match job.run() {
        Ok(report) => Outcome {
            state: "done".into(),
            bytes: Some(std::fs::read(&report.result.output_path).unwrap()),
            retries: report.timeline.counter("retries"),
            faults: report.timeline.counter("faults_injected"),
            error: String::new(),
        },
        Err(e) => Outcome {
            state: match e {
                Error::DeadlineExceeded(_) => "deadline-exceeded".into(),
                Error::Cancelled(_) => "cancelled".into(),
                _ => "failed".into(),
            },
            bytes: None,
            retries: 0,
            faults: 0,
            error: e.to_string(),
        },
    }
}

/// Surface 2: the multi-tenant TCP service. Runs the cell job, then a
/// follow-up job without a deadline to prove the single worker slot
/// was released.
fn run_tcp(dep: Deployment, deadline_ms: u64, tag: &str) -> (Outcome, Outcome) {
    let mut cfg = ServeConfig::new(dataset());
    cfg.work_dir = workdir(&format!("{tag}_work"));
    cfg.workers = 1;
    cfg.deployment = dep;
    let service = SkimService::new(cfg).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = service.serve_tcp(listener, stop.clone());
    let client = SkimServiceClient::connect(&addr).unwrap();

    let run_one = |out: &str, deadline_ms: u64| -> Outcome {
        let job = client
            .submit_with_deadline(&query(out), deadline_ms)
            .unwrap();
        let wait = client.wait_result(job);
        let status = client.status(job).unwrap();
        Outcome {
            state: status.state.name().into(),
            bytes: wait.ok().map(|(_, bytes)| bytes),
            retries: status.retries,
            faults: status.faults_injected,
            error: status.error.unwrap_or_default(),
        }
    };
    let cell = run_one(&format!("{tag}.troot"), deadline_ms);
    let followup = run_one(&format!("{tag}_free.troot"), 0);

    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    service.shutdown();
    (cell, followup)
}

/// Pull the integer value of `key` out of a flat status JSON body.
fn json_u64(text: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let start = text.find(&pat).unwrap_or_else(|| panic!("{key} missing in {text}"));
    let rest = &text[start + pat.len()..];
    let end = rest.find([',', '}']).unwrap();
    rest[..end].trim().parse().unwrap()
}

/// Pull the string value of `key` out of a flat status JSON body.
fn json_str(text: &str, key: &str) -> String {
    let pat = format!("\"{key}\":\"");
    let start = text.find(&pat).unwrap_or_else(|| panic!("{key} missing in {text}"));
    let rest = &text[start + pat.len()..];
    rest[..rest.find('"').unwrap()].to_string()
}

/// Surface 3: the DPU HTTP jobs API. Same shape as [`run_tcp`]:
/// the cell job, then an undeadlined follow-up on the freed worker.
fn run_http(dep: Deployment, deadline_ms: u64, tag: &str) -> (Outcome, Outcome) {
    let mut cfg = ServeConfig::new(dataset());
    cfg.work_dir = workdir(&format!("{tag}_work"));
    cfg.workers = 1;
    cfg.deployment = dep;
    let sched = SkimScheduler::new(cfg).unwrap();
    let server = DpuHttpServer::new(|_q: &SkimQuery, _tl: &Timeline| {
        Err(skimroot::Error::Engine("sync path unused".into()))
    })
    .with_scheduler(sched.clone());
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = server.serve(listener, stop.clone());

    let run_one = |out: &str, deadline_ms: u64| -> Outcome {
        let payload = query(out).to_json().to_string();
        let value = format!("{deadline_ms}");
        let header = [("X-Skim-Deadline-Ms", value.as_str())];
        let extra: &[(&str, &str)] = if deadline_ms > 0 { &header } else { &[] };
        let (code, _, body) =
            http_request_with_headers(&addr, "POST", "/jobs", extra, payload.as_bytes())
                .unwrap();
        assert_eq!(code, 202, "{}", String::from_utf8_lossy(&body));
        let text = String::from_utf8(body).unwrap();
        let id: u64 =
            text.trim_start_matches("{\"job\":").trim_end_matches('}').parse().unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        let text = loop {
            let (code, _, body) =
                http_request(&addr, "GET", &format!("/jobs/{id}"), b"").unwrap();
            assert_eq!(code, 200);
            let text = String::from_utf8(body).unwrap();
            let state = json_str(&text, "state");
            if state != "queued" && state != "running" {
                break text;
            }
            assert!(std::time::Instant::now() < deadline, "cell never terminal: {text}");
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        let state = json_str(&text, "state");
        let bytes = if state == "done" {
            let (code, _, bytes) =
                http_request(&addr, "GET", &format!("/jobs/{id}/result"), b"").unwrap();
            assert_eq!(code, 200);
            Some(bytes)
        } else {
            None
        };
        Outcome {
            state,
            bytes,
            retries: json_u64(&text, "retries"),
            faults: json_u64(&text, "faults_injected"),
            error: if text.contains("\"error\":\"") {
                json_str(&text, "error")
            } else {
                String::new()
            },
        }
    };
    let cell = run_one(&format!("{tag}.troot"), deadline_ms);
    let followup = run_one(&format!("{tag}_free.troot"), 0);

    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    sched.shutdown();
    (cell, followup)
}

// ---------------- the matrix -----------------------------------------

/// Fault-free reference bytes per placement, via the facade.
fn clean_reference(placement: &str) -> Vec<u8> {
    let out = run_facade(
        deployment(placement, FaultPlan::default()),
        0,
        &format!("clean_{placement}"),
    );
    assert_eq!(out.state, "done", "clean {placement} run failed: {}", out.error);
    assert_eq!(out.faults, 0);
    out.bytes.unwrap()
}

fn assert_recovered(cell: &Outcome, reference: &[u8], label: &str) {
    assert_eq!(cell.state, "done", "{label}: {}", cell.error);
    assert!(cell.retries >= 1, "{label}: fault did not cost a retry");
    assert!(cell.faults >= 1, "{label}: no fault was injected");
    assert_eq!(
        cell.bytes.as_deref().unwrap(),
        reference,
        "{label}: recovered bytes diverged from the clean run"
    );
}

fn assert_expired(cell: &Outcome, label: &str) {
    assert_eq!(cell.state, "deadline-exceeded", "{label}: {}", cell.error);
    assert!(
        cell.error.contains("deadline"),
        "{label}: error detail must name the deadline, got '{}'",
        cell.error
    );
}

fn assert_slot_released(followup: &Outcome, reference: &[u8], label: &str) {
    assert_eq!(
        followup.state, "done",
        "{label}: follow-up job never ran — worker slot leaked ({})",
        followup.error
    );
    assert_eq!(
        followup.bytes.as_deref().unwrap(),
        reference,
        "{label}: follow-up bytes diverged"
    );
}

#[test]
fn transient_faults_recover_byte_identical_on_every_surface() {
    for placement in PLACEMENTS {
        let reference = clean_reference(placement);
        for (i, kind) in FAILING_KINDS.into_iter().enumerate() {
            let seed = 100 + i as u64;
            let tag = format!("t_{placement}_{}", kind.name().replace('-', "_"));

            let cell = run_facade(deployment(placement, transient(kind, seed)), 0, &tag);
            assert_recovered(&cell, &reference, &format!("facade/{placement}/{kind:?}"));

            let (cell, follow) =
                run_tcp(deployment(placement, transient(kind, seed)), 0, &format!("{tag}_tcp"));
            assert_recovered(&cell, &reference, &format!("tcp/{placement}/{kind:?}"));
            assert_slot_released(&follow, &reference, &format!("tcp/{placement}/{kind:?}"));

            let (cell, follow) =
                run_http(deployment(placement, transient(kind, seed)), 0, &format!("{tag}_http"));
            assert_recovered(&cell, &reference, &format!("http/{placement}/{kind:?}"));
            assert_slot_released(&follow, &reference, &format!("http/{placement}/{kind:?}"));
        }
    }
}

#[test]
fn stalled_reads_expire_deadlines_and_release_worker_slots() {
    for placement in PLACEMENTS {
        let tag = format!("s_{placement}");

        // Facade: the deadline surfaces as Error::DeadlineExceeded.
        let cell = run_facade(deployment(placement, stall(7)), 2_000, &tag);
        assert_expired(&cell, &format!("facade/{placement}/stall"));

        // Serve surfaces: terminal state + counters cross the wire,
        // and the follow-up job (same stalling service, no deadline —
        // stalls charge virtual time, they do not block real time)
        // proves the worker slot came back.
        let (cell, follow) =
            run_tcp(deployment(placement, stall(7)), 2_000, &format!("{tag}_tcp"));
        assert_expired(&cell, &format!("tcp/{placement}/stall"));
        assert!(cell.faults >= 1, "tcp/{placement}/stall: no stall was injected");
        assert_eq!(follow.state, "done", "tcp/{placement}/stall: slot leaked");

        let (cell, follow) =
            run_http(deployment(placement, stall(7)), 2_000, &format!("{tag}_http"));
        assert_expired(&cell, &format!("http/{placement}/stall"));
        assert!(cell.faults >= 1, "http/{placement}/stall: no stall was injected");
        assert_eq!(follow.state, "done", "http/{placement}/stall: slot leaked");
    }
}

// ---------------- adaptive execution cells ---------------------------

/// Adaptive execution riding a chaos cell: warm up after one group,
/// re-plan every group. The 400-event / 100-per-basket dataset gives
/// four basket groups, so re-plans happen mid-job — racing the retry,
/// cancel and deadline machinery.
fn adaptive(mut dep: Deployment) -> Deployment {
    dep.adaptive = skimroot::engine::AdaptiveOpts {
        enabled: true,
        warmup_groups: 1,
        replan_every: 1,
        seed: None,
    };
    dep
}

/// A fault-free fixed-order client run with a caller-chosen tag (the
/// shared [`clean_reference`] uses one fixed tag; these tests run in
/// parallel threads and need their own output paths).
fn clean_reference_tagged(tag: &str) -> Vec<u8> {
    let out = run_facade(deployment("client", FaultPlan::default()), 0, tag);
    assert_eq!(out.state, "done", "clean run '{tag}' failed: {}", out.error);
    out.bytes.unwrap()
}

/// Files with the given suffix left in a service work dir.
fn leftovers(dir: &std::path::Path, suffix: &str) -> Vec<String> {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| n.ends_with(suffix))
                .collect()
        })
        .unwrap_or_default()
}

#[test]
fn adaptive_transient_faults_recover_byte_identical() {
    // Client placement: the one that threads AdaptiveOpts into the
    // engine (the DPU placement prefers its fixed-order kernel).
    let reference = clean_reference_tagged("a_ref_transient");

    // Fault-free adaptive run first: reordering must be invisible in
    // the output bytes before any fault is layered on top.
    let clean =
        run_facade(adaptive(deployment("client", FaultPlan::default())), 0, "a_clean");
    assert_eq!(clean.state, "done", "adaptive clean run failed: {}", clean.error);
    assert_eq!(
        clean.bytes.as_deref().unwrap(),
        &reference[..],
        "adaptive clean run diverged from the fixed-order reference"
    );

    for (i, kind) in FAILING_KINDS.into_iter().enumerate() {
        let seed = 500 + i as u64;
        let tag = format!("a_t_{}", kind.name().replace('-', "_"));

        let cell =
            run_facade(adaptive(deployment("client", transient(kind, seed))), 0, &tag);
        assert_recovered(&cell, &reference, &format!("adaptive facade/{kind:?}"));

        let (cell, follow) = run_tcp(
            adaptive(deployment("client", transient(kind, seed))),
            0,
            &format!("{tag}_tcp"),
        );
        assert_recovered(&cell, &reference, &format!("adaptive tcp/{kind:?}"));
        assert_slot_released(&follow, &reference, &format!("adaptive tcp/{kind:?}"));
    }
}

#[test]
fn adaptive_replans_race_cancel_and_deadline_to_clean_terminal_states() {
    let reference = clean_reference_tagged("a_ref_race");

    // Deadline mid-job: every read stalls 120 virtual seconds, so the
    // 2-second deadline expires during the first groups — while the
    // adaptive state is mid-warm-up / mid-re-plan.
    let tag = "a_stall";
    let cell = run_facade(adaptive(deployment("client", stall(7))), 2_000, tag);
    assert_expired(&cell, "adaptive facade/stall");
    assert!(
        !workdir(tag).join(format!("{tag}.troot")).exists(),
        "deadline-exceeded adaptive job left a partial output"
    );

    let (cell, follow) =
        run_tcp(adaptive(deployment("client", stall(7))), 2_000, "a_stall_tcp");
    assert_expired(&cell, "adaptive tcp/stall");
    assert_eq!(follow.state, "done", "adaptive tcp/stall: slot leaked");
    let parts = leftovers(&workdir("a_stall_tcp_work"), ".part");
    assert!(parts.is_empty(), "staged partial outputs not deleted: {parts:?}");

    // Pre-cancelled token: the adaptive job dies at its first group
    // boundary — the cancel is observed between warm-up bookkeeping
    // steps — always in the `cancelled` terminal state, never with an
    // output file on disk.
    let token = CancelToken::new();
    token.cancel();
    let out = SkimJob::new(query("a_cancel.troot"))
        .storage(dataset())
        .client_dir(workdir("a_cancel"))
        .deployment(adaptive(deployment("client", FaultPlan::default())))
        .ctl(JobCtl { cancel: Some(token), deadline_s: None })
        .run();
    match out {
        Err(Error::Cancelled(_)) => {}
        Err(e) => panic!("pre-cancelled adaptive job must end Cancelled, got: {e}"),
        Ok(_) => panic!("pre-cancelled adaptive job must not complete"),
    }
    assert!(
        !workdir("a_cancel").join("a_cancel.troot").exists(),
        "cancelled adaptive job left a partial output"
    );

    // Cancel racing a live adaptive job over TCP: whichever side wins,
    // the terminal state is clean, the worker slot comes back, and no
    // staged partial output survives.
    let mut cfg = ServeConfig::new(dataset());
    cfg.work_dir = workdir("a_cancel_tcp_work");
    cfg.workers = 1;
    cfg.deployment = adaptive(deployment("client", stall(11)));
    let service = SkimService::new(cfg).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = service.serve_tcp(listener, stop.clone());
    let client = SkimServiceClient::connect(&addr).unwrap();
    let job = client
        .submit_with_deadline(&query("a_cancel_tcp.troot"), 0)
        .unwrap();
    let _ = client.cancel(job);
    let status = loop {
        let s = client.status(job).unwrap();
        let name = s.state.name();
        if name != "queued" && name != "running" {
            break s;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    };
    let name = status.state.name();
    assert!(
        name == "cancelled" || name == "done",
        "cancel race must end in a clean terminal state, got {name} ({:?})",
        status.error
    );
    if name == "done" {
        let (_, bytes) = client.wait_result(job).unwrap();
        assert_eq!(bytes, reference, "cancel-survivor bytes diverged");
    }
    // The slot is free either way.
    let follow = client
        .submit_with_deadline(&query("a_cancel_free.troot"), 0)
        .unwrap();
    let (_, bytes) = client.wait_result(follow).unwrap();
    assert_eq!(bytes, reference, "follow-up after a cancel race diverged");
    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    service.shutdown();
    let parts = leftovers(&workdir("a_cancel_tcp_work"), ".part");
    assert!(parts.is_empty(), "cancel race left staged partial outputs: {parts:?}");
}

#[test]
fn hopeless_faults_exhaust_retries_with_error_detail() {
    for placement in PLACEMENTS {
        for (i, kind) in FAILING_KINDS.into_iter().enumerate() {
            let seed = 300 + i as u64;
            let tag = format!("h_{placement}_{}", kind.name().replace('-', "_"));
            let cell = run_facade(deployment(placement, hopeless(kind, seed)), 0, &tag);
            assert_eq!(cell.state, "failed", "facade/{placement}/{kind:?}");
            assert!(
                !cell.error.is_empty(),
                "facade/{placement}/{kind:?}: terminal failure must carry error detail"
            );
        }
    }
}
