//! Integration coverage for the multi-tenant serving layer: N
//! parallel clients with overlapping branch sets against one TCP
//! server must produce byte-identical outputs to serial one-shot
//! runs, and the shared basket cache must report a nonzero hit rate
//! on the overlap.

use skimroot::compress::Codec;
use skimroot::gen::{self, GenConfig};
use skimroot::serve::{JobState, ServeConfig, SkimService, SkimServiceClient};
use skimroot::{SkimJob, SkimQuery};
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn workdir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("serve_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn dataset() -> PathBuf {
    static PATH: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    PATH.get_or_init(|| {
        let storage = workdir().join("storage");
        std::fs::create_dir_all(&storage).unwrap();
        let path = storage.join("events.troot");
        let cfg = GenConfig {
            n_events: 1_000,
            target_branches: 170,
            n_hlt: 40,
            basket_events: 200,
            codec: Codec::Lz4,
            seed: 97,
        };
        gen::generate(&cfg, &path).unwrap();
        storage
    })
    .clone()
}

/// Distinct cuts, all overlapping on the same hot criteria branches.
const CUTS: [&str; 6] = [
    "MET_pt > 20",
    "MET_pt > 40 && nJet >= 2",
    "max(Muon_pt) > 25 || MET_pt > 60",
    "sum(Jet_pt[Jet_pt > 20]) > 100",
    "nMuon >= 1 && MET_pt > 10",
    "count(Jet_pt > 35) >= 1",
];

fn query_for(i: usize) -> SkimQuery {
    SkimQuery::new("events.troot", format!("conc{i}.troot"))
        .keep(&["MET_pt", "nJet", "Jet_pt", "Muon_pt", "nMuon"])
        .with_cut_str(CUTS[i % CUTS.len()])
        .unwrap()
}

#[test]
fn concurrent_clients_match_serial_and_share_baskets() {
    let storage = dataset();
    let mut cfg = ServeConfig::new(&storage);
    cfg.workers = 4;
    cfg.work_dir = workdir().join("serve_work");
    let deployment = cfg.deployment.clone();
    let service = SkimService::new(cfg).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = service.serve_tcp(listener, stop.clone());

    // N parallel TCP clients against the one server.
    let n = CUTS.len();
    let served: Vec<(u64, Vec<u8>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let client = SkimServiceClient::connect(&addr).unwrap();
                    let job = client.submit(&query_for(i)).unwrap();
                    let (status, bytes) = client.wait_result(job).unwrap();
                    assert_eq!(status.state, JobState::Done);
                    (status.n_pass, bytes)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Each concurrent output is byte-identical to a serial, uncached,
    // one-shot run of the same query.
    let mut distinct_pass_counts = std::collections::BTreeSet::new();
    for (i, (n_pass, bytes)) in served.iter().enumerate() {
        let report = SkimJob::new(query_for(i))
            .storage(&storage)
            .client_dir(workdir().join(format!("serial{i}")))
            .deployment(deployment.clone())
            .run()
            .unwrap();
        assert_eq!(report.result.n_pass, *n_pass, "cut {i}: selection diverged");
        assert!(*n_pass > 0, "cut {i} selects nothing — weak test");
        let serial = std::fs::read(&report.result.output_path).unwrap();
        assert_eq!(&serial, bytes, "cut {i}: output bytes diverged");
        distinct_pass_counts.insert(*n_pass);
    }
    // The cuts are genuinely distinct queries, not one query repeated.
    assert!(distinct_pass_counts.len() > 1);

    // The overlap was served from the shared cache.
    let stats = service.scheduler().cache_stats();
    assert!(stats.misses > 0);
    assert!(stats.hits > 0, "overlapping branch sets must hit: {stats:?}");

    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    service.shutdown();
}

#[test]
fn batched_adaptive_jobs_match_solo_and_reconcile_profiles() {
    // Shared-scan batching × adaptive execution: N same-file jobs
    // merged into one scan, each member reordering its own funnel
    // independently, must still produce outputs byte-identical to
    // solo adaptive runs — and every member's selectivity profile and
    // scan_shared counter must cross the wire and reconcile.
    let storage = dataset();
    let mut cfg = ServeConfig::new(&storage);
    cfg.workers = 4;
    cfg.batch_window_ms = 300;
    cfg.work_dir = workdir().join("serve_adaptive");
    cfg.deployment.adaptive = skimroot::engine::AdaptiveOpts {
        enabled: true,
        warmup_groups: 1,
        replan_every: 1,
        seed: None,
    };
    let deployment = cfg.deployment.clone();
    let service = SkimService::new(cfg).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = service.serve_tcp(listener, stop.clone());

    let query_adaptive = |i: usize| {
        SkimQuery::new("events.troot", format!("adco{i}.troot"))
            .keep(&["MET_pt", "nJet", "Jet_pt", "Muon_pt", "nMuon"])
            .with_cut_str(CUTS[i % CUTS.len()])
            .unwrap()
    };

    let n = CUTS.len();
    let served: Vec<(skimroot::serve::JobStatus, Vec<u8>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let addr = addr.clone();
                let query = query_adaptive(i);
                scope.spawn(move || {
                    let client = SkimServiceClient::connect(&addr).unwrap();
                    let job = client.submit(&query).unwrap();
                    let (status, bytes) = client.wait_result(job).unwrap();
                    assert_eq!(status.state, JobState::Done);
                    (status, bytes)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    assert!(
        served.iter().any(|(s, _)| s.batch_members >= 2),
        "no job was batched — the window never formed a shared scan"
    );
    for (i, (status, bytes)) in served.iter().enumerate() {
        // Solo adaptive run of the same query (no batching window).
        let solo = SkimJob::new(query_adaptive(i))
            .storage(&storage)
            .client_dir(workdir().join(format!("adsolo{i}")))
            .deployment(deployment.clone())
            .run()
            .unwrap();
        assert_eq!(solo.result.n_pass, status.n_pass, "cut {i}: selection diverged");
        let solo_bytes = std::fs::read(&solo.result.output_path).unwrap();
        assert_eq!(&solo_bytes, bytes, "cut {i}: batched bytes diverge from solo");

        // The per-conjunct profile crossed the scheduler and the wire.
        assert!(!status.profile.is_empty(), "cut {i}: profile missing from status");
        for p in &status.profile {
            assert!(
                p.passed <= p.visited,
                "cut {i}: profile entry '{}' passed {} of {} visited",
                p.key,
                p.passed,
                p.visited
            );
            assert!(
                p.visited <= status.n_events,
                "cut {i}: profile entry '{}' visited {} of {} events",
                p.key,
                p.visited,
                status.n_events
            );
        }
        // Batched members were served by the shared union scan.
        if status.batch_members >= 2 {
            assert!(
                status.scan_shared > 0,
                "cut {i}: batched member fetched every basket itself"
            );
        }
    }

    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    service.shutdown();
}

#[test]
fn queue_depth_backpressure_over_tcp() {
    let storage = dataset();
    let mut cfg = ServeConfig::new(&storage);
    // Accept-only service: submissions beyond the depth are rejected
    // deterministically because no worker drains the queue.
    cfg.workers = 0;
    cfg.queue_depth = 3;
    cfg.work_dir = workdir().join("serve_bp");
    let service = SkimService::new(cfg).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = service.serve_tcp(listener, stop.clone());

    let client = SkimServiceClient::connect(&addr).unwrap();
    for i in 0..3 {
        client.submit(&query_for(i)).unwrap();
    }
    let err = client.submit(&query_for(3)).unwrap_err();
    assert!(format!("{err}").contains("queue full"), "{err}");

    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    service.shutdown();
}
