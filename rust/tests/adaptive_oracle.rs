//! Differential-oracle harness for selectivity-adaptive **and fused**
//! execution.
//!
//! Two layers keep the adaptive and fused evaluators honest:
//!
//! 1. **Evaluator-level fuzzing** — hundreds of randomized
//!    `CutProgram`s × randomized batches × randomized conjunct orders,
//!    every one compared bit-for-bit against the fixed-order scalar
//!    oracle (`interp::eval`). The fused arm additionally compiles a
//!    `FusePlan` for each order and demands `eval_fused` reproduce
//!    `eval_adaptive` exactly — mask, stage rows and visited/passed
//!    tallies. Any failing case prints a `SKIM_TEST_SEED=<n>` line;
//!    exporting that variable replays exactly that case.
//!
//! 2. **End-to-end engine matrix** — a generated dataset skimmed under
//!    every combination of parallelism {1, 2, 4} × adaptive {off, on}
//!    × zone-map {off, on}, asserting `n_pass`, `n_events` and the
//!    output **bytes** match the fixed-order reference run; plus a
//!    fused sweep covering `--fuse` × {solo, fan-out-merge,
//!    zone-map-pruned, adaptive} cells against the same references.
//!
//! The invariant under test (see `eval_adaptive` / `eval_fused`):
//! conjunct reordering, kernel fusion and common-subexpression sharing
//! may change *per-stage* funnel tallies, but the final event mask,
//! kept columns and output bytes must be identical to the fixed order.

use skimroot::compress::Codec;
use skimroot::engine::fused::eval_fused;
use skimroot::engine::interp::{eval, eval_adaptive};
use skimroot::engine::{AdaptiveOpts, EngineOpts, SkimEngine};
use skimroot::gen::{self, GenConfig};
use skimroot::index::FileIndex;
use skimroot::metrics::Timeline;
use skimroot::query::fuse::fuse_plan;
use skimroot::query::plan::{CExpr, CutProgram, HtParam, ObjCutParam, ObjGroup, ScalarCutParam};
use skimroot::query::stats::{conjuncts_of, rank_order, ConjunctStats};
use skimroot::query::{AggOp, BinOp, SkimQuery, UnaryOp};
use skimroot::runtime::{Batch, Capacities, MaskResult};
use skimroot::troot::{LocalFile, ReadAt};
use skimroot::util::Pcg32;
use std::sync::{Arc, OnceLock};

// =====================================================================
// Layer 1: randomized program/batch/order fuzzing vs the scalar oracle
// =====================================================================

/// Randomized cases in the sweep (each tries several conjunct orders).
const EVAL_CASES: u64 = 520;
/// Seed base: case `i` runs with `Pcg32::new(SEED_BASE + i)`, so a
/// failing case number doubles as its replay seed.
const SEED_BASE: u64 = 0xada9_7100;

fn gen_value(rng: &mut Pcg32) -> f32 {
    // Quarter-step grid: exact floats so `==`/`!=` cuts have real hit
    // probability (mirrors the in-crate interpreter prop tests).
    (rng.below(200) as f32 - 100.0) / 4.0
}

fn gen_obj_expr(rng: &mut Pcg32, depth: usize, n_obj: usize, n_sc: usize) -> CExpr {
    if depth == 0 {
        return CExpr::Jagged(rng.below(n_obj as u32) as usize);
    }
    match rng.below(6) {
        0 => CExpr::Jagged(rng.below(n_obj as u32) as usize),
        1 => CExpr::Num(gen_value(rng)),
        2 => CExpr::Scalar(rng.below(n_sc as u32) as usize),
        3 => CExpr::Unary(
            [UnaryOp::Neg, UnaryOp::Not, UnaryOp::Abs][rng.below(3) as usize],
            Box::new(gen_obj_expr(rng, depth - 1, n_obj, n_sc)),
        ),
        _ => {
            let ops = [
                BinOp::Add,
                BinOp::Sub,
                BinOp::Mul,
                BinOp::Div,
                BinOp::Lt,
                BinOp::Le,
                BinOp::Gt,
                BinOp::Ge,
                BinOp::Eq,
                BinOp::Ne,
                BinOp::And,
                BinOp::Or,
                BinOp::Min,
                BinOp::Max,
            ];
            CExpr::Binary(
                ops[rng.below(ops.len() as u32) as usize],
                Box::new(gen_obj_expr(rng, depth - 1, n_obj, n_sc)),
                Box::new(gen_obj_expr(rng, depth - 1, n_obj, n_sc)),
            )
        }
    }
}

fn gen_event_expr(rng: &mut Pcg32, depth: usize, n_obj: usize, n_sc: usize) -> CExpr {
    let aggs = [AggOp::Count, AggOp::Any, AggOp::All, AggOp::Sum, AggOp::Max, AggOp::Min];
    if depth == 0 || rng.chance(0.3) {
        return CExpr::Agg {
            op: aggs[rng.below(aggs.len() as u32) as usize],
            nobj: rng.below(n_obj as u32) as usize,
            arg: Box::new(gen_obj_expr(rng, depth.min(2), n_obj, n_sc)),
            pred: if rng.chance(0.4) {
                Some(Box::new(gen_obj_expr(rng, 1, n_obj, n_sc)))
            } else {
                None
            },
        };
    }
    match rng.below(5) {
        0 => CExpr::Num(gen_value(rng)),
        1 => CExpr::Scalar(rng.below(n_sc as u32) as usize),
        2 => CExpr::Unary(
            [UnaryOp::Neg, UnaryOp::Not, UnaryOp::Abs][rng.below(3) as usize],
            Box::new(gen_event_expr(rng, depth - 1, n_obj, n_sc)),
        ),
        _ => {
            let ops = [
                BinOp::Add,
                BinOp::Mul,
                BinOp::Gt,
                BinOp::Ge,
                BinOp::Lt,
                BinOp::And,
                BinOp::Or,
                BinOp::Min,
                BinOp::Max,
            ];
            CExpr::Binary(
                ops[rng.below(ops.len() as u32) as usize],
                Box::new(gen_event_expr(rng, depth - 1, n_obj, n_sc)),
                Box::new(gen_event_expr(rng, depth - 1, n_obj, n_sc)),
            )
        }
    }
}

fn gen_program(rng: &mut Pcg32, n_obj: usize, n_sc: usize) -> CutProgram {
    let mut p = CutProgram::default();
    for c in 0..n_obj {
        p.obj_columns.push(format!("o{c}"));
    }
    for s in 0..n_sc {
        p.scalar_columns.push(format!("s{s}"));
    }
    for _ in 0..rng.below(3) {
        p.scalar_cuts.push(ScalarCutParam {
            col: rng.below(n_sc as u32) as usize,
            op: rng.below(6) as u8,
            abs: rng.chance(0.3),
            value: gen_value(rng),
        });
    }
    for g in 0..rng.below(3) {
        let start = p.obj_cuts.len();
        for _ in 0..1 + rng.below(2) {
            p.obj_cuts.push(ObjCutParam {
                col: rng.below(n_obj as u32) as usize,
                op: rng.below(6) as u8,
                abs: rng.chance(0.3),
                value: gen_value(rng),
            });
        }
        p.groups.push(ObjGroup {
            collection: format!("G{g}"),
            cut_range: start..p.obj_cuts.len(),
            min_count: rng.below(3),
        });
    }
    if rng.chance(0.5) {
        p.ht = Some(HtParam {
            col: rng.below(n_obj as u32) as usize,
            object_pt_min: gen_value(rng),
            min_ht: gen_value(rng),
        });
    }
    if rng.chance(0.5) {
        for s in 0..n_sc {
            if rng.chance(0.5) {
                p.triggers.push(s);
            }
        }
    }
    for _ in 0..rng.below(3) {
        p.exprs.push(gen_event_expr(rng, 1 + rng.below(3) as usize, n_obj, n_sc));
    }
    p
}

fn gen_batch(rng: &mut Pcg32, n_obj: usize, n_sc: usize) -> Batch {
    let m = 1 + rng.below(6) as usize;
    let n = 1 + rng.below(48) as usize;
    let b = n + rng.below(8) as usize;
    let caps = Capacities { c: n_obj, s: n_sc, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 };
    let mut batch = Batch::zeroed(&caps, b, m);
    batch.n_valid = n;
    for c in 0..n_obj {
        for ev in 0..n {
            let mut nobj = rng.below(m as u32 + 3) as f32;
            if rng.chance(0.1) {
                nobj += 0.5;
            }
            batch.nobj[c * b + ev] = nobj;
            for slot in 0..m {
                batch.cols[(c * b + ev) * m + slot] = gen_value(rng);
            }
        }
    }
    for s in 0..n_sc {
        for ev in 0..n {
            batch.scalars[s * b + ev] =
                if rng.chance(0.5) { rng.below(2) as f32 } else { gen_value(rng) };
        }
    }
    batch
}

/// Cumulative-funnel counts: `stages` is multiplicative per event, so
/// the product across stages must reconstruct the final mask exactly.
fn funnel_of(r: &MaskResult) -> [u64; 4] {
    let n = r.mask.len();
    let mut f = [0u64; 4];
    for ev in 0..n {
        let mut cum = 1.0f32;
        for (s, fs) in f.iter_mut().enumerate() {
            cum *= r.stages[s][ev];
            *fs += cum as u64;
        }
    }
    f
}

fn check_against_oracle(
    program: &CutProgram,
    batch: &Batch,
    order: &[usize],
    oracle: &MaskResult,
    stats: &mut [ConjunctStats],
    what: &str,
) -> MaskResult {
    let conjuncts = conjuncts_of(program);
    let out = eval_adaptive(program, batch, &conjuncts, order, stats);
    assert_eq!(out.mask, oracle.mask, "{what}: mask diverges under order {order:?}");
    // Cumulative funnel must reconstruct the mask (the per-stage
    // tallies themselves may legitimately differ from the fixed order).
    let f = funnel_of(&out);
    let n_pass = oracle.mask.iter().filter(|&&x| x > 0.5).count() as u64;
    assert_eq!(f[3], n_pass, "{what}: cumulative funnel does not reconstruct the mask");
    for w in f.windows(2) {
        assert!(w[1] <= w[0], "{what}: funnel is not monotone: {f:?}");
    }
    for (i, s) in stats.iter().enumerate() {
        assert!(
            s.passed <= s.visited,
            "{what}: conjunct {i} passed {} of only {} visited",
            s.passed,
            s.visited
        );
    }
    out
}

/// One randomized differential case: a program and a batch, evaluated
/// under the identity, reversed, randomly-shuffled and selectivity-
/// ranked orders, each compared bit-for-bit against the scalar oracle.
fn run_eval_case(seed: u64) {
    let mut rng = Pcg32::new(SEED_BASE + seed);
    let n_obj = 1 + rng.below(3) as usize;
    let n_sc = 1 + rng.below(4) as usize;
    let program = gen_program(&mut rng, n_obj, n_sc);
    let batch = gen_batch(&mut rng, n_obj, n_sc);
    let oracle = eval(&program, &batch);
    let conjuncts = conjuncts_of(&program);
    let k = conjuncts.len();
    let mut stats = vec![ConjunctStats::default(); k];

    // Identity order (the warm-up configuration) fills `stats`.
    let identity: Vec<usize> = (0..k).collect();
    check_against_oracle(&program, &batch, &identity, &oracle, &mut stats, "identity");

    // Selectivity-ranked order from the measured stats — exactly what
    // a post-warm-up re-plan would choose.
    let ranked = rank_order(&conjuncts, &stats);
    let mut ranked_stats = vec![ConjunctStats::default(); k];
    check_against_oracle(&program, &batch, &ranked, &oracle, &mut ranked_stats, "ranked");

    // Reversed and randomly-shuffled orders: ANDed conjuncts commute,
    // so *any* permutation must reproduce the oracle mask.
    let reversed: Vec<usize> = (0..k).rev().collect();
    let mut rev_stats = vec![ConjunctStats::default(); k];
    check_against_oracle(&program, &batch, &reversed, &oracle, &mut rev_stats, "reversed");

    let mut shuffled = identity.clone();
    for i in (1..k).rev() {
        shuffled.swap(i, rng.below(i as u32 + 1) as usize);
    }
    let mut shuf_stats = vec![ConjunctStats::default(); k];
    check_against_oracle(&program, &batch, &shuffled, &oracle, &mut shuf_stats, "shuffled");
}

#[test]
fn prop_adaptive_orders_match_the_scalar_oracle() {
    // Replay mode: SKIM_TEST_SEED=<n> runs exactly one failing case.
    if let Ok(s) = std::env::var("SKIM_TEST_SEED") {
        let seed: u64 = s
            .trim()
            .parse()
            .expect("SKIM_TEST_SEED must be the integer printed by a failing run");
        eprintln!("replaying adaptive oracle case {seed}");
        run_eval_case(seed);
        return;
    }
    for seed in 0..EVAL_CASES {
        if let Err(payload) = std::panic::catch_unwind(|| run_eval_case(seed)) {
            eprintln!(
                "adaptive oracle case {seed} failed — replay with:\n  \
                 SKIM_TEST_SEED={seed} cargo test --test adaptive_oracle \
                 prop_adaptive_orders_match_the_scalar_oracle -- --nocapture"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// One randomized fused differential case: the same program/batch
/// generator as the adaptive arm, but every order is compiled into a
/// [`fuse_plan`] and run through `eval_fused`, which must be
/// **bit-identical** to `eval_adaptive` under the same order — mask,
/// every stage row, and per-conjunct visited/passed tallies — and
/// therefore to the scalar oracle's mask.
fn run_fused_case(seed: u64) {
    let mut rng = Pcg32::new(SEED_BASE + 20_000 + seed);
    let n_obj = 1 + rng.below(3) as usize;
    let n_sc = 1 + rng.below(4) as usize;
    let program = gen_program(&mut rng, n_obj, n_sc);
    let batch = gen_batch(&mut rng, n_obj, n_sc);
    let oracle = eval(&program, &batch);
    let conjuncts = conjuncts_of(&program);
    let k = conjuncts.len();

    // Identity order with default stats — exactly what a fuse-only
    // (no --adaptive) run compiles on its first group.
    let identity: Vec<usize> = (0..k).collect();
    let zeros = vec![ConjunctStats::default(); k];
    let warm = compare_fused_to_adaptive(&program, &batch, &identity, &oracle, &zeros, "identity");

    // Ranked order, plan rebuilt against the measured tallies — what a
    // replan checkpoint compiles (this is where the all-pass gate can
    // pull a conjunct back to the interpreter) — plus reversed and a
    // random shuffle (fused kernels must commute like conjuncts do).
    let ranked = rank_order(&conjuncts, &warm);
    compare_fused_to_adaptive(&program, &batch, &ranked, &oracle, &warm, "ranked");

    let reversed: Vec<usize> = (0..k).rev().collect();
    compare_fused_to_adaptive(&program, &batch, &reversed, &oracle, &zeros, "reversed");

    let mut shuffled = identity;
    for i in (1..k).rev() {
        shuffled.swap(i, rng.below(i as u32 + 1) as usize);
    }
    compare_fused_to_adaptive(&program, &batch, &shuffled, &oracle, &zeros, "shuffled");
}

/// Compile a plan against `profile`, run the order through both
/// evaluators and demand bit-identity; returns the adaptive run's
/// measured tallies so the caller can rank-and-replan from them.
fn compare_fused_to_adaptive(
    program: &CutProgram,
    batch: &Batch,
    order: &[usize],
    oracle: &MaskResult,
    profile: &[ConjunctStats],
    what: &str,
) -> Vec<ConjunctStats> {
    let conjuncts = conjuncts_of(program);
    let plan = fuse_plan(program, &conjuncts, order, profile);
    let mut fused_stats = vec![ConjunctStats::default(); conjuncts.len()];
    let fused = eval_fused(program, batch, &conjuncts, &plan, &mut fused_stats);
    let mut adaptive_stats = vec![ConjunctStats::default(); conjuncts.len()];
    let adaptive = eval_adaptive(program, batch, &conjuncts, order, &mut adaptive_stats);

    assert_eq!(fused.mask, oracle.mask, "{what}: fused mask diverges under order {order:?}");
    assert_eq!(
        fused.stages, adaptive.stages,
        "{what}: fused stage rows diverge under order {order:?}"
    );
    for (i, (f, a)) in fused_stats.iter().zip(adaptive_stats.iter()).enumerate() {
        assert_eq!(
            (f.visited, f.passed),
            (a.visited, a.passed),
            "{what}: conjunct {i} tallies diverge under order {order:?}"
        );
    }
    // Cumulative funnel still reconstructs the mask and stays monotone.
    let f = funnel_of(&fused);
    let n_pass = oracle.mask.iter().filter(|&&x| x > 0.5).count() as u64;
    assert_eq!(f[3], n_pass, "{what}: fused funnel does not reconstruct the mask");
    for w in f.windows(2) {
        assert!(w[1] <= w[0], "{what}: fused funnel is not monotone: {f:?}");
    }
    adaptive_stats
}

#[test]
fn prop_fused_kernels_match_the_scalar_oracle() {
    // Replay mode: SKIM_TEST_SEED=<n> runs exactly one failing case.
    if let Ok(s) = std::env::var("SKIM_TEST_SEED") {
        let seed: u64 = s
            .trim()
            .parse()
            .expect("SKIM_TEST_SEED must be the integer printed by a failing run");
        eprintln!("replaying fused oracle case {seed}");
        run_fused_case(seed);
        return;
    }
    for seed in 0..EVAL_CASES {
        if let Err(payload) = std::panic::catch_unwind(|| run_fused_case(seed)) {
            eprintln!(
                "fused oracle case {seed} failed — replay with:\n  \
                 SKIM_TEST_SEED={seed} cargo test --test adaptive_oracle \
                 prop_fused_kernels_match_the_scalar_oracle -- --nocapture"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[test]
fn adaptive_stats_account_for_every_visited_event() {
    // Focused property: under the identity order the first conjunct
    // sees every valid event, and each later conjunct sees exactly the
    // survivors of the previous one (early-exit on a dead funnel is
    // the one allowed shortfall).
    for seed in 0..40 {
        let mut rng = Pcg32::new(SEED_BASE + 10_000 + seed);
        let n_obj = 1 + rng.below(3) as usize;
        let n_sc = 1 + rng.below(4) as usize;
        let program = gen_program(&mut rng, n_obj, n_sc);
        let batch = gen_batch(&mut rng, n_obj, n_sc);
        let conjuncts = conjuncts_of(&program);
        let k = conjuncts.len();
        let mut stats = vec![ConjunctStats::default(); k];
        let order: Vec<usize> = (0..k).collect();
        eval_adaptive(&program, &batch, &conjuncts, &order, &mut stats);
        let mut expect = batch.n_valid as u64;
        for (i, s) in stats.iter().enumerate() {
            if s.visited == 0 {
                // Funnel died before this conjunct ran.
                assert_eq!(expect, 0, "conjunct {i} skipped with {expect} events alive");
                continue;
            }
            assert_eq!(s.visited, expect, "conjunct {i} visited wrong event count");
            expect = s.passed;
        }
    }
}

// =====================================================================
// Layer 2: end-to-end engine matrix — parallelism × adaptive × zone map
// =====================================================================

fn workdir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("adaptive_oracle_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Shared dataset: enough basket groups (256-event clusters) that the
/// adaptive path warms up *and* re-plans mid-job.
fn dataset() -> std::path::PathBuf {
    static PATH: OnceLock<std::path::PathBuf> = OnceLock::new();
    PATH.get_or_init(|| {
        let path = workdir().join("events.troot");
        let cfg = GenConfig {
            n_events: 1500,
            target_branches: 200,
            n_hlt: 40,
            basket_events: 256,
            codec: Codec::Lz4,
            seed: 77,
        };
        gen::generate(&cfg, &path).unwrap();
        path
    })
    .clone()
}

fn zone_index() -> Arc<FileIndex> {
    static IDX: OnceLock<Arc<FileIndex>> = OnceLock::new();
    IDX.get_or_init(|| Arc::new(FileIndex::build_from_file(dataset()).unwrap())).clone()
}

fn local_store() -> Arc<dyn ReadAt> {
    Arc::new(LocalFile::open(dataset()).unwrap())
}

/// The cut inventory: scalar-only, scalar+group+trigger, OR-of-trigger,
/// residual-IR, zone-prunable counter, group-first, and a pathological
/// all-pass cut (adaptive must not perturb it).
const CUTS: [&str; 7] = [
    "MET_pt > 25",
    "MET_pt > 25 && nJet >= 1 && HLT_IsoMu24 > 0.5",
    "nMuon >= 2 && (HLT_Mu50 || max(Muon_pt) > 100)",
    "MET_pt > 100 || sum(Jet_pt[Jet_pt > 30]) > 250",
    "event >= 1000750 && MET_pt > 20",
    "count(Electron_pt > 25) >= 1 && MET_pt > 25",
    "MET_pt > -1",
];

fn query_for(cut: &str, outname: &str) -> SkimQuery {
    SkimQuery::new("events.troot", outname)
        .keep(&["MET_pt", "nJet", "Jet_pt", "Muon_pt", "nMuon", "event"])
        .with_cut_str(cut)
        .unwrap()
}

fn matrix_opts(par: f64, adaptive: bool, zone: bool) -> EngineOpts {
    EngineOpts {
        use_pjrt: false,
        parallelism: par,
        zone_map: if zone { Some(zone_index()) } else { None },
        adaptive: AdaptiveOpts {
            enabled: adaptive,
            // Aggressive cadence so a ~6-group job re-plans mid-run.
            warmup_groups: 1,
            replan_every: 1,
            seed: None,
        },
        ..Default::default()
    }
}

fn run_matrix_cell(
    cut: &str,
    outname: &str,
    opts: &EngineOpts,
) -> (skimroot::engine::SkimResult, Timeline, Vec<u8>) {
    let tl = Timeline::new();
    let engine = SkimEngine::new(None);
    let out = workdir().join(outname);
    let res = engine.run(local_store(), &query_for(cut, outname), &tl, opts, &out).unwrap();
    let bytes = std::fs::read(&out).unwrap();
    (res, tl, bytes)
}

#[test]
fn engine_matrix_adaptive_zone_parallelism_is_byte_identical() {
    for (ci, cut) in CUTS.iter().enumerate() {
        // Fixed-order scalar reference: parallelism 1, no zone map.
        let (ref_res, _, ref_bytes) =
            run_matrix_cell(cut, &format!("m{ci}_ref.troot"), &matrix_opts(1.0, false, false));
        for par in [1.0f64, 2.0, 4.0] {
            for adaptive in [false, true] {
                for zone in [false, true] {
                    let name = format!(
                        "m{ci}_p{}_a{}_z{}.troot",
                        par as u32, adaptive as u8, zone as u8
                    );
                    let opts = matrix_opts(par, adaptive, zone);
                    let (res, tl, bytes) = run_matrix_cell(cut, &name, &opts);
                    let what = format!("cut '{cut}' par={par} adaptive={adaptive} zone={zone}");
                    assert_eq!(res.n_events, ref_res.n_events, "{what}: n_events");
                    assert_eq!(res.n_pass, ref_res.n_pass, "{what}: n_pass");
                    assert_eq!(bytes, ref_bytes, "{what}: output bytes diverge");
                    // The adaptive run must actually have profiled the
                    // funnel; a fixed-order run must not.
                    assert_eq!(
                        !tl.profile().is_empty(),
                        adaptive,
                        "{what}: unexpected profile presence"
                    );
                }
            }
        }
    }
}

#[test]
fn fused_execution_is_byte_identical_across_engine_paths() {
    // `--fuse` × {solo, fan-out-merge, zone-map-pruned, adaptive}:
    // every cell must reproduce the unfused fixed-order reference
    // bytes exactly, for every cut shape in the inventory (the
    // shared-scan × fuse cell lives with the shared-scan executor's
    // own tests). Fuse-only runs must not grow a selectivity profile.
    for (ci, cut) in CUTS.iter().enumerate() {
        let (ref_res, _, ref_bytes) =
            run_matrix_cell(cut, &format!("f{ci}_ref.troot"), &matrix_opts(1.0, false, false));
        let cells: [(f64, bool, bool); 4] =
            [(1.0, false, false), (4.0, false, false), (1.0, false, true), (4.0, true, true)];
        for (par, adaptive, zone) in cells {
            let mut opts = matrix_opts(par, adaptive, zone);
            opts.fuse = true;
            let name =
                format!("f{ci}_p{}_a{}_z{}.troot", par as u32, adaptive as u8, zone as u8);
            let (res, tl, bytes) = run_matrix_cell(cut, &name, &opts);
            let what = format!("cut '{cut}' fuse par={par} adaptive={adaptive} zone={zone}");
            assert_eq!(res.n_events, ref_res.n_events, "{what}: n_events");
            assert_eq!(res.n_pass, ref_res.n_pass, "{what}: n_pass");
            assert_eq!(bytes, ref_bytes, "{what}: output bytes diverge");
            // Fusion alone must not change the reporting surfaces:
            // only --adaptive dumps a profile.
            assert_eq!(
                !tl.profile().is_empty(),
                adaptive,
                "{what}: unexpected profile presence"
            );
        }
    }
}

#[test]
fn adaptive_seed_profile_never_changes_engine_output() {
    // Warm-started adaptive runs (seed profile claims the *first*
    // conjunct passes everything, inverting the natural order) still
    // produce the reference bytes.
    let cut = "MET_pt > 25 && nJet >= 1 && HLT_IsoMu24 > 0.5";
    let (_, _, ref_bytes) =
        run_matrix_cell(cut, "seed_ref.troot", &matrix_opts(1.0, false, false));
    let mut seed = skimroot::query::SelectivityProfile::default();
    seed.record("MET_pt > 25", 100_000, 100_000, 5);
    seed.record("nJet >= 1", 100_000, 50, 5);
    let mut opts = matrix_opts(1.0, true, false);
    opts.adaptive.seed = Some(seed);
    let (res, tl, bytes) = run_matrix_cell(cut, "seed_warm.troot", &opts);
    assert!(res.n_pass > 0);
    assert_eq!(bytes, ref_bytes, "seeded adaptive run diverged from the reference");
    // The reported profile counts only this job's events, not the seed.
    for p in tl.profile() {
        assert!(
            p.visited <= res.n_events,
            "profile entry '{}' double-counts the seed: visited {}",
            p.key,
            p.visited
        );
    }
}
