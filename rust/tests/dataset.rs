//! Dataset-layer integration tests: merge determinism, fault
//! isolation, and byte-identity of the dataset path against a serial
//! single-file loop — across the SkimJob facade (CLI surface), the
//! TCP service and the HTTP jobs API, under fan-out 1 and 4 and
//! engine parallelism 1/2/4.

use skimroot::compress::Codec;
use skimroot::coordinator::{Deployment, Placement};
use skimroot::dpu::http::{http_request, DpuHttpServer};
use skimroot::dpu::DpuConfig;
use skimroot::gen::{self, GenConfig};
use skimroot::net::LinkModel;
use skimroot::query::DatasetSpec;
use skimroot::serve::{ServeConfig, SkimScheduler, SkimServiceClient};
use skimroot::{SkimJob, SkimQuery};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

const N_FILES: usize = 4;

/// A fresh 4-file dataset under its own storage root.
fn setup(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ds_it_{}_{tag}", std::process::id()));
    let store = dir.join("storage/store");
    if !store.join("part003.troot").exists() {
        let cfg = GenConfig {
            n_events: 500,
            target_branches: 160,
            n_hlt: 40,
            basket_events: 200,
            codec: Codec::Lz4,
            seed: 41,
        };
        gen::generate_dataset(&cfg, &store, N_FILES, "all").unwrap();
    }
    dir
}

fn query(output: &str) -> SkimQuery {
    gen::higgs_query("store/part*.troot", output)
}

/// Reference bytes: skim each file alone through single-file jobs
/// (the pre-dataset code path) and merge the outputs serially, in
/// resolved dataset order.
fn serial_reference(dir: &std::path::Path, dep: &Deployment, tag: &str) -> Vec<u8> {
    let storage = dir.join("storage");
    let files =
        skimroot::catalog::resolve(&DatasetSpec::parse("store/part*.troot"), &storage).unwrap();
    assert_eq!(files.len(), N_FILES);
    let mut parts = Vec::new();
    for (i, file) in files.iter().enumerate() {
        let q = query("unused.troot").for_file(file, format!("ref{tag}{i}.troot"));
        let report = SkimJob::new(q)
            .storage(&storage)
            .client_dir(dir.join(format!("ref_client_{tag}")))
            .deployment(dep.clone())
            .run()
            .unwrap();
        assert!(report.files.is_empty(), "single-file jobs keep the legacy report");
        parts.push(std::fs::read(&report.result.output_path).unwrap());
    }
    let out = dir.join(format!("ref_{tag}_merged.troot"));
    skimroot::troot::merge::concat_buffers(parts, &out).unwrap();
    std::fs::read(&out).unwrap()
}

#[test]
fn dataset_equals_serial_concat_under_fan_out_1_and_4() {
    let dir = setup("fanout");
    let storage = dir.join("storage");
    let reference = serial_reference(&dir, &Deployment::skim_root(LinkModel::wan_1g()), "dpu");
    for fan_out in [1usize, 4] {
        let dep = Deployment::builder()
            .name("dpu-ds")
            .placement(Placement::Dpu(DpuConfig::default()))
            .link(LinkModel::wan_1g())
            .fan_out(fan_out)
            .build()
            .unwrap();
        let report = SkimJob::new(query(&format!("out_x{fan_out}.troot")))
            .storage(&storage)
            .client_dir(dir.join(format!("client_x{fan_out}")))
            .deployment(dep)
            .run()
            .unwrap();
        assert_eq!(report.files_total(), N_FILES);
        assert_eq!(report.files_done(), N_FILES);
        let bytes = std::fs::read(&report.result.output_path).unwrap();
        assert_eq!(bytes, reference, "fan_out={fan_out} diverged from serial loop");
    }
}

#[test]
fn dataset_equals_serial_concat_on_client_and_server_placements() {
    let dir = setup("placements");
    let storage = dir.join("storage");
    for (tag, dep) in [
        ("copt", Deployment::client_opt(LinkModel::dedicated_100g())),
        ("srv", Deployment::server_side(LinkModel::dedicated_100g())),
    ] {
        let reference = serial_reference(&dir, &dep, tag);
        let report = SkimJob::new(query(&format!("out_{tag}.troot")))
            .storage(&storage)
            .client_dir(dir.join(format!("client_{tag}")))
            .deployment(dep)
            .run()
            .unwrap();
        let bytes = std::fs::read(&report.result.output_path).unwrap();
        assert_eq!(bytes, reference, "{tag} placement diverged from serial loop");
    }
}

#[test]
fn dataset_bytes_invariant_under_engine_parallelism() {
    let dir = setup("par");
    let storage = dir.join("storage");
    let mut outputs = Vec::new();
    for par in [1.0f64, 2.0, 4.0] {
        let dep = Deployment::builder()
            .name("dpu-par")
            .placement(Placement::Dpu(DpuConfig { parallelism: par, ..DpuConfig::default() }))
            .link(LinkModel::wan_1g())
            .build()
            .unwrap();
        let report = SkimJob::new(query(&format!("out_p{par}.troot")))
            .storage(&storage)
            .client_dir(dir.join(format!("client_p{par}")))
            .deployment(dep)
            .run()
            .unwrap();
        outputs.push(std::fs::read(&report.result.output_path).unwrap());
    }
    assert_eq!(outputs[0], outputs[1], "parallelism 2 changed the merged bytes");
    assert_eq!(outputs[0], outputs[2], "parallelism 4 changed the merged bytes");
}

#[test]
fn truncated_file_is_fault_isolated() {
    let dir = setup("trunc");
    let storage = dir.join("storage");
    // Truncate one part mid-file.
    let victim = storage.join("store/part001.troot");
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() / 4]).unwrap();

    let mut dep = Deployment::client_opt(LinkModel::dedicated_100g());
    dep.fault.max_retries = 1;
    let report = SkimJob::new(query("out_trunc.troot"))
        .storage(&storage)
        .client_dir(dir.join("client_trunc"))
        .deployment(dep.clone())
        .run()
        .unwrap();
    assert_eq!(report.files_total(), N_FILES);
    assert_eq!(report.files_done(), N_FILES - 1);
    assert_eq!(report.files_failed(), 1);
    let failed = report.files.iter().find(|f| f.error.is_some()).unwrap();
    assert_eq!(failed.path, "store/part001.troot");
    assert!(failed.attempts >= 2, "failed file must have been retried");
    assert!(report
        .result
        .warnings
        .iter()
        .any(|w| w.contains("part001.troot")));
    // The surviving files merged: the output equals the serial merge
    // of the other three parts.
    let files = skimroot::catalog::resolve(
        &DatasetSpec::parse("store/part*.troot"),
        &storage,
    )
    .unwrap();
    let mut parts = Vec::new();
    for (i, file) in files.iter().enumerate() {
        if file.ends_with("part001.troot") {
            continue;
        }
        let q = query("unused.troot").for_file(file, format!("tr{i}.troot"));
        let r = SkimJob::new(q)
            .storage(&storage)
            .client_dir(dir.join("client_trunc_ref"))
            .deployment(dep.clone())
            .run()
            .unwrap();
        parts.push(std::fs::read(&r.result.output_path).unwrap());
    }
    let ref_path = dir.join("trunc_ref.troot");
    skimroot::troot::merge::concat_buffers(parts, &ref_path).unwrap();
    assert_eq!(
        std::fs::read(&report.result.output_path).unwrap(),
        std::fs::read(&ref_path).unwrap()
    );
}

#[test]
fn dataset_over_tcp_service_matches_serial_concat() {
    let dir = setup("tcp");
    let storage = dir.join("storage");
    let reference =
        serial_reference(&dir, &Deployment::server_side(LinkModel::local()), "tcp");

    let mut cfg = ServeConfig::new(&storage);
    cfg.deployment.disk = skimroot::net::DiskModel::ideal();
    cfg.workers = 3; // file tasks complete out of order
    let service = skimroot::SkimService::new(cfg).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = service.serve_tcp(listener, stop.clone());

    let client = SkimServiceClient::connect(&addr).unwrap();
    // Dataset submission by name over the wire: list, then query.
    // (`generate_dataset` wrote a self-contained store/all.catalog.)
    let listed = client.list_dataset("catalog:store/all").unwrap();
    assert_eq!(listed.len(), N_FILES);
    assert_eq!(listed[0], "store/part000.troot");
    let job = client.submit(&query("tcp_ds.troot")).unwrap();
    let (status, bytes) = client.wait_result(job).unwrap();
    assert_eq!(status.files_total, N_FILES as u64);
    assert_eq!(status.files_done, N_FILES as u64);
    assert!(status.file_errors.is_empty());
    assert_eq!(bytes, reference, "TCP service diverged from serial loop");

    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    service.shutdown();
}

#[test]
fn dataset_over_http_jobs_api_matches_serial_concat() {
    let dir = setup("http");
    let storage = dir.join("storage");
    let reference =
        serial_reference(&dir, &Deployment::server_side(LinkModel::local()), "http");

    let mut cfg = ServeConfig::new(&storage);
    cfg.deployment.disk = skimroot::net::DiskModel::ideal();
    cfg.workers = 2;
    let sched = SkimScheduler::new(cfg).unwrap();
    let server = DpuHttpServer::new(|_q: &SkimQuery, _tl: &skimroot::metrics::Timeline| {
        Err(skimroot::Error::Engine("sync path unused".into()))
    })
    .with_scheduler(sched.clone());
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = server.serve(listener, stop.clone());

    let payload = query("http_ds.troot").to_json().to_string();
    let (status, _, body) = http_request(&addr, "POST", "/jobs", payload.as_bytes()).unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let text = String::from_utf8(body).unwrap();
    let id: u64 = text
        .trim_start_matches("{\"job\":")
        .trim_end_matches('}')
        .parse()
        .unwrap();

    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let (code, _, body) = http_request(&addr, "GET", &format!("/jobs/{id}"), b"").unwrap();
        assert_eq!(code, 200);
        let text = String::from_utf8(body).unwrap();
        if text.contains("\"state\":\"done\"") {
            assert!(text.contains(&format!("\"files_total\":{N_FILES}")), "{text}");
            assert!(text.contains(&format!("\"files_done\":{N_FILES}")), "{text}");
            break;
        }
        assert!(std::time::Instant::now() < deadline, "job never finished: {text}");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let (code, _, bytes) =
        http_request(&addr, "GET", &format!("/jobs/{id}/result"), b"").unwrap();
    assert_eq!(code, 200);
    assert_eq!(bytes, reference, "HTTP jobs API diverged from serial loop");

    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    sched.shutdown();
}

#[test]
fn traversal_rejected_across_surfaces() {
    let dir = setup("trav");
    let storage = dir.join("storage");
    // SkimJob facade.
    let q = SkimQuery::new("../../etc/passwd", "out.troot");
    let err = SkimJob::new(q)
        .storage(&storage)
        .client_dir(dir.join("client_trav"))
        .deployment(Deployment::client_opt(LinkModel::dedicated_100g()))
        .run()
        .unwrap_err();
    assert!(matches!(err, skimroot::Error::Config(_)), "{err}");

    // TCP wire: submission rejected before enqueue.
    let mut cfg = ServeConfig::new(&storage);
    cfg.workers = 0;
    let service = skimroot::SkimService::new(cfg).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = service.serve_tcp(listener, stop.clone());
    let client = SkimServiceClient::connect(&addr).unwrap();
    let err = client
        .submit(&SkimQuery::new("../secret.troot", "o.troot"))
        .unwrap_err();
    assert!(format!("{err}").contains("escapes the storage root"), "{err}");
    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    service.shutdown();
}
