//! Query-IR integration: the legacy structured schema and the open
//! expression IR must select identical event sets, and cut strings
//! beyond the legacy schema must run end-to-end on the interpreter
//! with reference-checked semantics.

use skimroot::engine::{EngineOpts, SkimEngine};
use skimroot::gen::{self, GenConfig};
use skimroot::metrics::Timeline;
use skimroot::query::plan::SkimPlan;
use skimroot::query::SkimQuery;
use skimroot::troot::{ColumnData, ColumnValues, LocalFile, ReadAt, TRootReader};
use std::sync::{Arc, OnceLock};

fn workdir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("skim_ir_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Shared quickstart-sized dataset (full pipeline shape).
fn dataset() -> std::path::PathBuf {
    static PATH: OnceLock<std::path::PathBuf> = OnceLock::new();
    PATH.get_or_init(|| {
        let path = workdir().join("events.troot");
        let cfg = GenConfig {
            n_events: 1500,
            target_branches: 220,
            n_hlt: 40,
            basket_events: 256,
            codec: skimroot::compress::Codec::Lz4,
            seed: 77,
        };
        gen::generate(&cfg, &path).unwrap();
        path
    })
    .clone()
}

fn local_store() -> Arc<dyn ReadAt> {
    Arc::new(LocalFile::open(dataset()).unwrap())
}

fn run(query: &SkimQuery, outname: &str) -> skimroot::engine::SkimResult {
    let tl = Timeline::new();
    let engine = SkimEngine::new(None);
    let opts = EngineOpts { use_pjrt: false, ..Default::default() };
    engine
        .run(local_store(), query, &tl, &opts, workdir().join(outname))
        .unwrap()
}

/// The acceptance invariant: every legacy Figure-2c JSON query lowers
/// to the IR and selects the *identical* event set — compared here as
/// compiled programs, pass counts, funnels and byte-identical output
/// files on the quickstart dataset.
#[test]
fn legacy_schema_and_lowered_ir_select_identical_events() {
    let q_legacy = gen::higgs_query("events.troot", "ir_legacy.troot");

    // Same query expressed purely as its lowered IR cut.
    let mut q_ir = q_legacy.clone();
    q_ir.cut = q_legacy.selection.to_expr();
    q_ir.selection = Default::default();
    q_ir.output = "ir_expr.troot".to_string();

    // Plans compile to the identical cut program (classification
    // reverses the lowering), with the same branch split.
    let reader = TRootReader::open(LocalFile::open(dataset()).unwrap()).unwrap();
    let plan_legacy = SkimPlan::build(&q_legacy, reader.meta()).unwrap();
    let plan_ir = SkimPlan::build(&q_ir, reader.meta()).unwrap();
    assert_eq!(plan_legacy.program, plan_ir.program);
    assert_eq!(plan_legacy.criteria_branches, plan_ir.criteria_branches);
    assert!(plan_ir.program.fits_kernel(), "lowered legacy query must stay kernel-eligible");

    // And the engine selects the same events (funnel + masks via the
    // byte-identical filtered files).
    let res_legacy = run(&q_legacy, "ir_legacy.troot");
    let res_ir = run(&q_ir, "ir_expr.troot");
    assert!(res_legacy.n_pass > 0);
    assert_eq!(res_legacy.n_pass, res_ir.n_pass);
    assert_eq!(res_legacy.stage_funnel, res_ir.stage_funnel);
    let a = std::fs::read(workdir().join("ir_legacy.troot")).unwrap();
    let b = std::fs::read(workdir().join("ir_expr.troot")).unwrap();
    assert_eq!(a, b, "filtered outputs must be byte-identical");
}

/// A TCut-style string that *is* kernel-expressible compiles onto the
/// fixed-function stages and matches the equivalent structured query.
#[test]
fn kernel_expressible_cut_string_matches_structured_query() {
    let structured = SkimQuery::from_json_text(
        r#"{"input": "events.troot", "output": "ir_struct.troot",
            "branches": ["Electron_pt", "MET_pt"],
            "selection": {
                "preselection": [ {"branch": "MET_pt", "op": ">", "value": 25} ],
                "objects": [
                    { "collection": "Electron", "min_count": 1, "cuts": [
                        {"var": "Electron_pt",  "op": ">",   "value": 25.0},
                        {"var": "Electron_eta", "op": "|<|", "value": 2.4} ] }
                ]
            }}"#,
    )
    .unwrap();
    let cut_string = SkimQuery::new("events.troot", "ir_cutstr.troot")
        .keep(&["Electron_pt", "MET_pt"])
        .with_cut_str("MET_pt > 25 && count(Electron_pt > 25 && |Electron_eta| < 2.4) >= 1")
        .unwrap();

    let reader = TRootReader::open(LocalFile::open(dataset()).unwrap()).unwrap();
    let p1 = SkimPlan::build(&structured, reader.meta()).unwrap();
    let p2 = SkimPlan::build(&cut_string, reader.meta()).unwrap();
    assert_eq!(p1.program, p2.program);
    assert!(p2.program.fits_kernel());

    let r1 = run(&structured, "ir_struct.troot");
    let r2 = run(&cut_string, "ir_cutstr.troot");
    assert!(r1.n_pass > 0);
    assert_eq!(r1.n_pass, r2.n_pass);
    assert_eq!(r1.stage_funnel, r2.stage_funnel);
}

/// A cut inexpressible in the legacy schema (`||` across trigger and
/// kinematics, plus a `max` aggregation) runs on the interpreter and
/// matches an independent per-event reference evaluation from whole
/// columns.
#[test]
fn inexpressible_cut_runs_and_matches_reference() {
    let query = SkimQuery::new("events.troot", "ir_free.troot")
        .keep(&["Muon_pt", "nMuon", "MET_pt"])
        .with_cut_str("nMuon >= 1 && (MET_pt > 40 || max(Muon_pt) > 30)")
        .unwrap();

    let reader = TRootReader::open(LocalFile::open(dataset()).unwrap()).unwrap();
    let plan = SkimPlan::build(&query, reader.meta()).unwrap();
    assert!(!plan.program.fits_kernel());
    assert!(plan
        .program
        .kernel_unfit_reasons()
        .iter()
        .any(|r| r.contains("residual")));

    let res = run(&query, "ir_free.troot");
    assert!(!res.vectorized);

    // Independent reference: evaluate the cut per event from fully
    // decoded columns (first 16 object slots, like the engine).
    let n = reader.n_events() as usize;
    let n_muon: Vec<f64> = match reader.read_branch_all("nMuon").unwrap() {
        ColumnData::Scalar(v) => (0..n).map(|i| v.get_as_f64(i)).collect(),
        _ => unreachable!(),
    };
    let met: Vec<f64> = match reader.read_branch_all("MET_pt").unwrap() {
        ColumnData::Scalar(v) => (0..n).map(|i| v.get_as_f64(i)).collect(),
        _ => unreachable!(),
    };
    let (mu_offs, mu_vals) = match reader.read_branch_all("Muon_pt").unwrap() {
        ColumnData::Jagged { offsets, values: ColumnValues::F32(v) } => (offsets, v),
        _ => unreachable!(),
    };
    let max_m = 16usize;
    let mut expected = 0u64;
    for ev in 0..n {
        let lo = mu_offs[ev] as usize;
        let hi = mu_offs[ev + 1] as usize;
        let m = (hi - lo).min(max_m);
        let mut mu_max = f32::NEG_INFINITY;
        for x in &mu_vals[lo..lo + m] {
            mu_max = mu_max.max(*x);
        }
        if n_muon[ev] >= 1.0 && (met[ev] > 40.0 || mu_max > 30.0) {
            expected += 1;
        }
    }
    assert!(expected > 0);
    assert_eq!(res.n_pass, expected);
}

/// An object-shaped cut gets the TCut implicit-`any`, classifies to
/// the same compiled program as the equivalent explicit object group,
/// and selects the same events.
#[test]
fn implicit_any_matches_structured_object_group() {
    let bare = SkimQuery::new("events.troot", "ir_bare.troot")
        .keep(&["MET_pt"])
        .with_cut_str("Muon_pt > 25")
        .unwrap();
    let structured = SkimQuery::from_json_text(
        r#"{"input": "events.troot", "output": "ir_grp.troot",
            "branches": ["MET_pt"],
            "selection": {"objects": [
                {"collection": "Muon", "min_count": 1, "cuts": [
                    {"var": "Muon_pt", "op": ">", "value": 25.0}]}]}}"#,
    )
    .unwrap();
    let reader = TRootReader::open(LocalFile::open(dataset()).unwrap()).unwrap();
    let p_bare = SkimPlan::build(&bare, reader.meta()).unwrap();
    let p_struct = SkimPlan::build(&structured, reader.meta()).unwrap();
    assert_eq!(p_bare.program, p_struct.program);
    let r_bare = run(&bare, "ir_bare.troot");
    let r_struct = run(&structured, "ir_grp.troot");
    assert!(r_bare.n_pass > 0);
    assert_eq!(r_bare.n_pass, r_struct.n_pass);
}

/// A program wider than the kernel's fixed banks (17 ORed trigger
/// flags → 17 scalar columns > 16) must run on the interpreter with a
/// correctly-sized batch, not warn-then-panic.
#[test]
fn over_capacity_program_runs_on_interpreter() {
    let flags = [
        "HLT_IsoMu24",
        "HLT_IsoMu27",
        "HLT_Mu50",
        "HLT_Ele27_WPTight",
        "HLT_Ele32_WPTight",
        "HLT_Ele35_WPTight",
        "HLT_Photon200",
        "HLT_PFMET120_PFMHT120",
        "HLT_PFMETNoMu120_PFMHTNoMu120",
        "HLT_PFHT1050",
        "HLT_PFJet500",
        "HLT_AK8PFJet400_TrimMass30",
        "HLT_DoubleEle25_CaloIdL_MW",
        "HLT_Mu17_TrkIsoVVL_Mu8_TrkIsoVVL_DZ_Mass3p8",
        "HLT_Mu23_TrkIsoVVL_Ele12_CaloIdL_TrackIdL_IsoVL",
        "HLT_Mu8_TrkIsoVVL_Ele23_CaloIdL_TrackIdL_IsoVL_DZ",
        "HLT_DoublePFJets40_CaloBTagDeepCSV",
    ];
    let query = SkimQuery::new("events.troot", "ir_wide.troot")
        .keep(&["MET_pt"])
        .with_cut_str(&flags.join(" || "))
        .unwrap();
    let reader = TRootReader::open(LocalFile::open(dataset()).unwrap()).unwrap();
    let plan = SkimPlan::build(&query, reader.meta()).unwrap();
    assert_eq!(plan.program.scalar_columns.len(), 17);
    assert!(!plan.program.fits_kernel());
    assert!(plan
        .program
        .kernel_unfit_reasons()
        .iter()
        .any(|r| r.contains("scalar columns")));
    let res = run(&query, "ir_wide.troot");
    assert!(!res.vectorized);
    assert!(res.n_pass > 0, "some of 17 ORed triggers should fire");
    assert!(res.n_pass < res.n_events);
}
