//! Shared bench harness (criterion is unavailable offline): measured
//! runs with warmup, median/min/max reporting, and the common setup for
//! the paper-figure benches.
//!
//! Each `[[bench]]` target is a `harness = false` binary; `cargo bench`
//! runs them all.

#![allow(dead_code)]

use std::time::Instant;

/// Measure `f` `iters` times after `warmup` runs; prints median/min/max.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    println!(
        "{name:<44} median {:>12} (min {:>12}, max {:>12}, n={iters})",
        skimroot::util::human_secs(median),
        skimroot::util::human_secs(times[0]),
        skimroot::util::human_secs(*times.last().unwrap()),
    );
}

/// Throughput variant: reports MB/s over `bytes` processed per iter.
pub fn bench_throughput<T>(
    name: &str,
    bytes: usize,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    println!(
        "{name:<44} {:>10.1} MB/s (median {:>12}, n={iters})",
        bytes as f64 / median / 1e6,
        skimroot::util::human_secs(median),
    );
}

/// The figure benches run the eval suite at `SKIM_BENCH_SCALE`
/// (small|standard; default small so `cargo bench` stays quick).
pub fn bench_scale() -> skimroot::coordinator::eval::EvalScale {
    match std::env::var("SKIM_BENCH_SCALE").as_deref() {
        Ok("standard") => skimroot::coordinator::eval::EvalScale::standard(),
        _ => skimroot::coordinator::eval::EvalScale::small(),
    }
}

pub fn bench_env() -> skimroot::coordinator::eval::EvalEnv {
    let dir = std::env::temp_dir().join("skimroot_bench");
    skimroot::coordinator::eval::prepare(dir, bench_scale()).expect("prepare bench dataset")
}

pub fn bench_runtime() -> Option<skimroot::runtime::SkimRuntime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    skimroot::runtime::SkimRuntime::load(dir).ok()
}
