//! Shared bench harness (criterion is unavailable offline): measured
//! runs with warmup, median/min/max reporting, and the common setup for
//! the paper-figure benches.
//!
//! Each `[[bench]]` target is a `harness = false` binary; `cargo bench`
//! runs them all.

#![allow(dead_code)]

use std::io::Write as _;
use std::time::Instant;

/// Quick-iteration mode for CI smoke runs: `SKIM_BENCH_QUICK=1` caps
/// warmup at 1 and measured iterations at 3 for every bench call, so
/// the bench binaries *execute* in seconds instead of minutes.
pub fn quick() -> bool {
    std::env::var("SKIM_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Machine-readable results: when `BENCH_JSON=path` is set, every
/// `bench`/`bench_throughput` call appends one JSON record
/// `{name, median, min, max, n}` (seconds) to that file — this is what
/// populates the repo's `BENCH_*.json` perf trajectory.
fn record_json(name: &str, median: f64, min: f64, max: f64, n: usize) {
    let Ok(path) = std::env::var("BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    let esc = name.replace('\\', "\\\\").replace('"', "\\\"");
    let line = format!(
        "{{\"name\":\"{esc}\",\"median\":{median:.9},\"min\":{min:.9},\"max\":{max:.9},\"n\":{n}}}\n"
    );
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            let _ = f.write_all(line.as_bytes());
        }
        Err(e) => eprintln!("BENCH_JSON: cannot open {path}: {e}"),
    }
}

fn measure<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    let (warmup, iters) = if quick() { (warmup.min(1), iters.min(3)) } else { (warmup, iters) };
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times
}

/// Measure `f` `iters` times after `warmup` runs; prints median/min/max.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, f: impl FnMut() -> T) {
    let times = measure(warmup, iters, f);
    let median = times[times.len() / 2];
    let (min, max) = (times[0], *times.last().unwrap());
    println!(
        "{name:<44} median {:>12} (min {:>12}, max {:>12}, n={})",
        skimroot::util::human_secs(median),
        skimroot::util::human_secs(min),
        skimroot::util::human_secs(max),
        times.len(),
    );
    record_json(name, median, min, max, times.len());
}

/// Record a single deterministic measurement — modeled (virtual-time)
/// latencies from the deployment cost model don't jitter, so they need
/// no warmup/iteration statistics and make stable CI gates.
pub fn record_model(name: &str, seconds: f64) {
    println!("{name:<44} model  {:>12}", skimroot::util::human_secs(seconds));
    record_json(name, seconds, seconds, seconds, 1);
}

/// Throughput variant: reports MB/s over `bytes` processed per iter.
pub fn bench_throughput<T>(
    name: &str,
    bytes: usize,
    warmup: usize,
    iters: usize,
    f: impl FnMut() -> T,
) {
    let times = measure(warmup, iters, f);
    let median = times[times.len() / 2];
    println!(
        "{name:<44} {:>10.1} MB/s (median {:>12}, n={})",
        bytes as f64 / median / 1e6,
        skimroot::util::human_secs(median),
        times.len(),
    );
    record_json(name, median, times[0], *times.last().unwrap(), times.len());
}

/// The figure benches run the eval suite at `SKIM_BENCH_SCALE`
/// (small|standard; default small so `cargo bench` stays quick).
pub fn bench_scale() -> skimroot::coordinator::eval::EvalScale {
    match std::env::var("SKIM_BENCH_SCALE").as_deref() {
        Ok("standard") => skimroot::coordinator::eval::EvalScale::standard(),
        _ => skimroot::coordinator::eval::EvalScale::small(),
    }
}

pub fn bench_env() -> skimroot::coordinator::eval::EvalEnv {
    let dir = std::env::temp_dir().join("skimroot_bench");
    skimroot::coordinator::eval::prepare(dir, bench_scale()).expect("prepare bench dataset")
}

pub fn bench_runtime() -> Option<skimroot::runtime::SkimRuntime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    skimroot::runtime::SkimRuntime::load(dir).ok()
}
