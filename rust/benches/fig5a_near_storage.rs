//! Figure 5a: server-side filtering vs SkimROOT (DPU over PCIe).
//! Regenerates the paper's table (shape comparison; dataset and
//! bandwidths are scaled — see DESIGN.md §Execution-time model).
//!
//! `SKIM_BENCH_SCALE=standard cargo bench --bench fig5a_near_storage` runs the
//! full-census (1749-branch) dataset.

mod harness;

fn main() {
    let env = harness::bench_env();
    let runtime = harness::bench_runtime();
    if runtime.is_none() {
        eprintln!("[bench] artifacts not built: vectorized path disabled");
    }
    let table = skimroot::coordinator::eval::fig5a(&env, runtime.as_ref()).expect("eval");
    println!("{table}");
}
