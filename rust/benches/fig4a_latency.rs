//! Figure 4a: end-to-end skim latency, four methods × three network speeds.
//! Regenerates the paper's table (shape comparison; dataset and
//! bandwidths are scaled — see DESIGN.md §Execution-time model).
//!
//! `SKIM_BENCH_SCALE=standard cargo bench --bench fig4a_latency` runs the
//! full-census (1749-branch) dataset.

mod harness;

fn main() {
    let env = harness::bench_env();
    let runtime = harness::bench_runtime();
    if runtime.is_none() {
        eprintln!("[bench] artifacts not built: vectorized path disabled");
    }
    let table = skimroot::coordinator::eval::fig4a(&env, runtime.as_ref()).expect("eval");
    println!("{table}");
}
