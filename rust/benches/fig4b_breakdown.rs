//! Figure 4b: per-operation breakdown over the 1 Gbps link.
//! Regenerates the paper's table (shape comparison; dataset and
//! bandwidths are scaled — see DESIGN.md §Execution-time model).
//!
//! `SKIM_BENCH_SCALE=standard cargo bench --bench fig4b_breakdown` runs the
//! full-census (1749-branch) dataset.

mod harness;

fn main() {
    let env = harness::bench_env();
    let runtime = harness::bench_runtime();
    if runtime.is_none() {
        eprintln!("[bench] artifacts not built: vectorized path disabled");
    }
    let table = skimroot::coordinator::eval::fig4b(&env, runtime.as_ref()).expect("eval");
    println!("{table}");
}
