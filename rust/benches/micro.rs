//! Micro benchmarks for the hot paths behind the figures (and the
//! §Perf iteration log in EXPERIMENTS.md):
//!
//! * codec compress/decompress throughput (LZ4 vs zlib vs xz-like —
//!   the Figure 4b decompression asymmetry);
//! * cut evaluation: scalar interpreter vs the batch-vectorized
//!   columnar interpreter vs the PJRT kernel;
//! * basket decode (deserialization substrate);
//! * decompress+deserialize fan-out across 1/2/4 worker threads, plus
//!   end-to-end group processing at `parallelism` 1/2/4 — the
//!   threaded-engine tentpole, measured not asserted;
//! * zone-map pruning: the same cut run end-to-end with and without
//!   the `.tridx` basket index, at high and low selectivity;
//! * shared-scan batching: four overlapping cuts run as one batched
//!   shared scan vs four independent jobs — wall-clock measured, and
//!   the deterministic modeled latencies recorded for the CI gate;
//! * JSON query parsing.
//!
//! `BENCH_JSON=path` appends machine-readable records (see
//! `harness.rs`); `SKIM_BENCH_QUICK=1` runs everything at smoke scale.

mod harness;

use skimroot::compress::{self, Codec};
use skimroot::engine::{interp, EngineOpts, SkimEngine};
use skimroot::gen;
use skimroot::metrics::Timeline;
use skimroot::query::plan::SkimPlan;
use skimroot::query::stats::{conjuncts_of, rank_order, ConjunctStats};
use skimroot::runtime::{Batch, CutParams};
use skimroot::troot::{basket, BranchDesc, ColumnData, DType, LocalFile, ReadAt, TRootReader};
use skimroot::util::Pcg32;
use std::sync::Arc;

fn main() {
    codec_benches();
    filter_benches();
    fused_eval_benches();
    decode_benches();
    zero_copy_decode_benches();
    thread_scaling_benches();
    engine_parallelism_benches();
    dataset_benches();
    zone_map_benches();
    shared_scan_benches();
    adaptive_funnel_benches();
    json_benches();
}

fn bench_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("skimroot_bench_micro");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn codec_benches() {
    println!("== codecs (4 MiB physics-shaped payload) ==");
    let mut rng = Pcg32::new(1);
    let data = rng.compressible_bytes(4 << 20, 0.6);
    for codec in [Codec::Lz4, Codec::Zlib, Codec::XzLike] {
        let frame = compress::compress(codec, &data);
        println!(
            "{:<10} ratio {:.2}",
            codec.name(),
            data.len() as f64 / frame.len() as f64
        );
        harness::bench_throughput(
            &format!("{} compress", codec.name()),
            data.len(),
            1,
            3,
            || compress::compress(codec, &data),
        );
        harness::bench_throughput(
            &format!("{} decompress", codec.name()),
            data.len(),
            1,
            5,
            || compress::decompress(&frame).unwrap(),
        );
    }
}

/// Generate (once) the shared micro dataset and assemble one full
/// batch of its criteria columns for `query`.
fn assemble_batch(query: &skimroot::query::SkimQuery) -> (SkimPlan, Batch) {
    let path = bench_dir().join("micro.troot");
    if !path.exists() {
        let cfg = gen::GenConfig {
            n_events: 2048,
            target_branches: 180,
            n_hlt: 40,
            basket_events: 2048,
            codec: Codec::Lz4,
            seed: 5,
        };
        gen::generate(&cfg, &path).unwrap();
    }
    let reader = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
    let plan = SkimPlan::build(query, reader.meta()).unwrap();

    let caps = skimroot::runtime::Capacities {
        c: plan.program.obj_columns.len().max(12),
        s: plan.program.scalar_columns.len().max(16),
        k_obj: 12,
        k_sc: 6,
        g: 4,
        n_stages: 4,
    };
    // Decoded baskets indexed by the plan's dense BranchIds
    // (= criteria order).
    let decoded: Vec<skimroot::troot::DecodedBasket> = plan
        .criteria_branches
        .iter()
        .map(|name| {
            let bm = reader.branch(name).unwrap().clone();
            reader.read_basket(&bm, 0).unwrap()
        })
        .collect();
    let (b, m) = (2048, 16);
    let mut batch = Batch::zeroed(&caps, b, m);
    skimroot::engine::batch::append(
        &plan.program,
        &decoded,
        &plan.obj_col_branch,
        &plan.scalar_col_branch,
        0,
        2048,
        &mut batch,
        0,
    )
    .unwrap();
    batch.n_valid = 2048;
    (plan, batch)
}

fn filter_benches() {
    println!("\n== cut evaluation (2048-event batch, Higgs program) ==");
    let (plan, batch) = assemble_batch(&gen::higgs_query("micro.troot", "o.troot"));

    harness::bench("interp eval scalar (2048 events)", 2, 10, || {
        interp::eval(&plan.program, &batch)
    });
    harness::bench("interp eval columnar (2048 events)", 2, 10, || {
        interp::eval_columnar(&plan.program, &batch)
    });

    let runtime = harness::bench_runtime();
    if let Some(rt) = &runtime {
        let variant = rt.variant("large").unwrap();
        let params = CutParams::pack(&plan.program, &rt.caps).unwrap();
        harness::bench("PJRT kernel eval (2048 events)", 2, 10, || {
            rt.eval(variant, &batch, &params).unwrap()
        });
    } else {
        println!("(PJRT runtime unavailable: build artifacts first)");
    }

    // A residual-IR cut (inexpressible in the kernel's fixed-function
    // stages): the columnar path's whole-column expression sweeps vs
    // per-event tree dispatch.
    println!("\n== cut evaluation (2048-event batch, residual-IR cut) ==");
    let q = skimroot::query::SkimQuery::new("micro.troot", "o.troot")
        .keep(&["MET_pt"])
        .with_cut_str("MET_pt > 20 || sum(Jet_pt[Jet_pt > 25]) > 150")
        .unwrap();
    let (rplan, rbatch) = assemble_batch(&q);
    assert!(!rplan.program.exprs.is_empty(), "cut must compile to residual IR");
    harness::bench("interp eval scalar (residual IR)", 2, 10, || {
        interp::eval(&rplan.program, &rbatch)
    });
    harness::bench("interp eval columnar (residual IR)", 2, 10, || {
        interp::eval_columnar(&rplan.program, &rbatch)
    });
}

/// Fused cut kernels vs the per-conjunct adaptive interpreter on the
/// same batch and evaluation order. Wall-clock is measured for both;
/// the **modeled** funnel costs are recorded via `record_model` for
/// the CI gate: each conjunct costs events-visited × structural cost,
/// divided by the 8-wide lane factor when the planner fused it. The
/// gate (`fused <= 0.75x interpreted`) therefore fails exactly when
/// the planner stops fusing the hot early conjuncts — a planning
/// regression — independent of machine jitter.
fn fused_eval_benches() {
    use skimroot::engine::fused::eval_fused;
    use skimroot::query::fuse::fuse_plan;

    println!("\n== fused cut kernels (2048-event batch, scalar chain + group) ==");
    let query = skimroot::query::SkimQuery::new("micro.troot", "o.troot")
        .keep(&["MET_pt"])
        .with_cut_str("MET_pt > 25 && MET_sumEt > 60 && nJet >= 2")
        .unwrap();
    let (plan, batch) = assemble_batch(&query);
    let conjuncts = conjuncts_of(&plan.program);
    let identity: Vec<usize> = (0..conjuncts.len()).collect();
    // The plan a fuse-only run compiles on its first group: identity
    // order, unmeasured (0.5-prior) profile.
    let zeros = vec![ConjunctStats::default(); conjuncts.len()];
    let fplan = fuse_plan(&plan.program, &conjuncts, &identity, &zeros);
    assert!(fplan.fused_count() > 0, "bench cut must fuse at least one conjunct");

    harness::bench("cut eval interpreted (2048 events)", 2, 10, || {
        let mut s = vec![ConjunctStats::default(); conjuncts.len()];
        interp::eval_adaptive(&plan.program, &batch, &conjuncts, &identity, &mut s)
    });
    harness::bench("cut eval fused (2048 events)", 2, 10, || {
        let mut s = vec![ConjunctStats::default(); conjuncts.len()];
        eval_fused(&plan.program, &batch, &conjuncts, &fplan, &mut s)
    });

    // Deterministic virtual-cost records for the CI gate, driven by
    // the fused run's actual tallies and the plan's actual decisions.
    let mut stats = vec![ConjunctStats::default(); conjuncts.len()];
    let fused_mask = eval_fused(&plan.program, &batch, &conjuncts, &fplan, &mut stats);
    let interp_mask = interp::eval(&plan.program, &batch);
    assert_eq!(fused_mask.mask, interp_mask.mask, "fused bench diverged from the oracle");
    const LANE_FACTOR: f64 = 8.0; // fused sweeps evaluate 8 lanes per step
    let cost = |fused: bool| -> f64 {
        stats
            .iter()
            .zip(&conjuncts)
            .enumerate()
            .map(|(i, (s, c))| {
                let lanes =
                    if fused && fplan.decisions[i].fused.is_some() { LANE_FACTOR } else { 1.0 };
                s.visited as f64 * c.cost / lanes
            })
            .sum::<f64>()
            * 1e-6
    };
    let (interp_cost, fused_cost) = (cost(false), cost(true));
    println!(
        "fused/interpreted modeled ratio {:.3} ({} of {} conjuncts fused)",
        fused_cost / interp_cost,
        fplan.fused_count(),
        conjuncts.len()
    );
    harness::record_model("cut eval interpreted (virtual)", interp_cost);
    harness::record_model("cut eval fused (virtual)", fused_cost);
}

fn decode_benches() {
    println!("\n== basket decode (deserialization substrate) ==");
    let per_event: Vec<Vec<f32>> = {
        let mut rng = Pcg32::new(9);
        (0..10_000)
            .map(|_| (0..rng.poisson(5.5) as usize).map(|_| rng.exp(35.0) as f32).collect())
            .collect()
    };
    let col = ColumnData::jagged_f32(&per_event);
    let desc = BranchDesc::jagged("Jet_pt", DType::F32, "Jet");
    let raw = basket::encode(&col, 0, per_event.len());
    harness::bench_throughput("jagged decode (10k events)", raw.len(), 2, 10, || {
        basket::decode(&desc, &raw, 0, per_event.len(), 0).unwrap()
    });
    harness::bench("selective decode (100 of 10k events)", 2, 10, || {
        let mut offsets = vec![0u32];
        let mut values = skimroot::troot::ColumnValues::F32(Vec::new());
        for ev in (0..10_000).step_by(100) {
            basket::append_event(&desc, &raw, per_event.len(), ev, &mut offsets, &mut values)
                .unwrap();
        }
        values
    });
}

/// The decode-only quartet behind the zero-copy tentpole: the copying
/// scalar decoder vs the borrowing `decode_shared` view path, on a
/// narrow (512-event, 2 KiB) and a wide (64k-event, 256 KiB) flat f32
/// basket. Wall-clock is measured for all four; the **modeled** costs
/// recorded via `record_model` charge each decode a fixed validation
/// overhead plus 1 ns per value byte actually *moved* — zero when the
/// decode really returned a borrowed view. The CI gate
/// (`zerocopy <= 0.9x copy`) therefore fails exactly when the
/// zero-copy path silently degrades to copying.
fn zero_copy_decode_benches() {
    println!("\n== zero-copy basket decode (flat f32 baskets) ==");
    let mut model_copy = 0.0f64;
    let mut model_view = 0.0f64;
    for (label, n_events) in
        [("narrow 512-event basket", 512usize), ("wide 64k-event basket", 65_536)]
    {
        let mut rng = Pcg32::new(n_events as u64);
        let desc = BranchDesc::scalar("MET_pt", DType::F32);
        let col = ColumnData::scalar_f32((0..n_events).map(|_| rng.exp(35.0) as f32).collect());
        let raw = basket::encode(&col, 0, n_events);
        let shared: skimroot::troot::SharedBytes = Arc::new(raw.clone());
        harness::bench_throughput(&format!("scalar copy decode ({label})"), raw.len(), 2, 10, || {
            basket::decode(&desc, &raw, 0, n_events, 0).unwrap()
        });
        harness::bench_throughput(&format!("zero-copy decode ({label})"), raw.len(), 2, 10, || {
            basket::decode_shared(&desc, &shared, 0, 0, n_events, 0).unwrap()
        });

        // Deterministic model records: bytes moved come from the actual
        // decode results, so an alignment regression shows up here.
        const PER_BYTE: f64 = 1e-9; // 1 GB/s virtual memcpy
        const PER_BASKET: f64 = 2e-6; // header validation overhead
        let moved = |dec: &skimroot::troot::DecodedBasket| {
            if dec.values.is_borrowed() { 0 } else { raw.len() }
        };
        let owned = basket::decode(&desc, &raw, 0, n_events, 0).unwrap();
        let viewed = basket::decode_shared(&desc, &shared, 0, 0, n_events, 0).unwrap();
        assert_eq!(owned.values.as_f32(), viewed.values.as_f32(), "view decode diverged");
        model_copy += PER_BASKET + moved(&owned) as f64 * PER_BYTE;
        model_view += PER_BASKET + moved(&viewed) as f64 * PER_BYTE;
    }
    harness::record_model("decode copy (virtual)", model_copy);
    harness::record_model("decode zerocopy (virtual)", model_view);
}

/// The fan-out primitive in isolation: decompress + deserialize a set
/// of LZ4 basket frames round-robin across 1/2/4 scoped threads —
/// exactly the shape of the engine's threaded group stages.
fn thread_scaling_benches() {
    println!("\n== threaded decompress+deserialize (64 jagged baskets) ==");
    let mut rng = Pcg32::new(17);
    let desc = BranchDesc::jagged("Jet_pt", DType::F32, "Jet");
    let n_events = 2_000usize;
    let frames: Vec<Vec<u8>> = (0..64)
        .map(|_| {
            let per_event: Vec<Vec<f32>> = (0..n_events)
                .map(|_| {
                    (0..rng.poisson(5.5) as usize).map(|_| rng.exp(35.0) as f32).collect()
                })
                .collect();
            let col = ColumnData::jagged_f32(&per_event);
            compress::compress(Codec::Lz4, &basket::encode(&col, 0, n_events))
        })
        .collect();
    let total: usize = frames.iter().map(|f| f.len()).sum();
    for workers in [1usize, 2, 4] {
        harness::bench_throughput(
            &format!("decompress+deserialize ({workers} thread)"),
            total,
            1,
            5,
            || {
                let mut shards: Vec<Vec<&[u8]>> = vec![Vec::new(); workers];
                for (i, f) in frames.iter().enumerate() {
                    shards[i % workers].push(f);
                }
                std::thread::scope(|scope| {
                    let handles: Vec<_> = shards
                        .into_iter()
                        .map(|shard| {
                            scope.spawn(|| {
                                let mut decoded = 0usize;
                                for frame in shard {
                                    let raw = compress::decompress(frame).unwrap();
                                    let dec =
                                        basket::decode(&desc, &raw, 0, n_events, 0).unwrap();
                                    decoded += dec.values.len();
                                }
                                decoded
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
                })
            },
        );
    }
}

/// End-to-end group processing through the real engine at
/// `parallelism` 1/2/4: legacy fetch-all mode so the threaded
/// decompress/deserialize stages carry the full branch census.
fn engine_parallelism_benches() {
    println!("\n== engine group processing (legacy mode, 180 branches) ==");
    let path = bench_dir().join("micro_engine.troot");
    if !path.exists() {
        let cfg = gen::GenConfig {
            n_events: 4096,
            target_branches: 180,
            n_hlt: 40,
            basket_events: 512,
            codec: Codec::Lz4,
            seed: 11,
        };
        gen::generate(&cfg, &path).unwrap();
    }
    let query = gen::higgs_query("micro_engine.troot", "micro_engine_out.troot");
    let out = bench_dir().join("micro_engine_out.troot");
    for par in [1.0f64, 2.0, 4.0] {
        let opts = EngineOpts {
            use_pjrt: false,
            two_phase: false,
            parallelism: par,
            cache_bytes: None,
            ..Default::default()
        };
        harness::bench(&format!("engine run (parallelism={par})"), 1, 5, || {
            let store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&path).unwrap());
            let tl = Timeline::new();
            SkimEngine::new(None).run(store, &query, &tl, &opts, &out).unwrap()
        });
    }
}

/// End-to-end dataset skims: the same 4096 events skimmed as one file
/// vs as a 4-file dataset (per-file jobs + deterministic merge) —
/// what the catalog layer costs/saves at job granularity.
fn dataset_benches() {
    println!("\n== dataset skims (one file vs 4-file dataset, end-to-end) ==");
    let root = bench_dir().join("dataset_root");
    let single = root.join("single.troot");
    if !single.exists() {
        let cfg = gen::GenConfig {
            n_events: 4096,
            target_branches: 180,
            n_hlt: 40,
            basket_events: 512,
            codec: Codec::Lz4,
            seed: 23,
        };
        gen::generate(&cfg, &single).unwrap();
        let part_cfg = gen::GenConfig { n_events: 1024, ..cfg };
        gen::generate_dataset(&part_cfg, root.join("store"), 4, "bench").unwrap();
    }
    let dep = skimroot::coordinator::Deployment::server_side(skimroot::net::LinkModel::local());
    let run = |input: &str, output: &str| {
        let report = skimroot::SkimJob::new(gen::higgs_query(input, output))
            .storage(&root)
            .client_dir(bench_dir().join("dataset_client"))
            .deployment(dep.clone())
            .run()
            .unwrap();
        report.result.n_pass
    };
    harness::bench("e2e skim one file (4096 events)", 1, 5, || {
        run("single.troot", "bench_single.troot")
    });
    harness::bench("e2e skim 4-file dataset (4x1024 events)", 1, 5, || {
        run("store/part*.troot", "bench_ds.troot")
    });
}

/// Zone-map pruning end-to-end: the identical query run with and
/// without the basket index installed. The high-selectivity cut on the
/// `event` counter branch provably kills 7 of 8 baskets (the pruned run
/// skips their read + decompress + deserialize); the low-selectivity
/// cut prunes nothing, measuring the index's overhead when it cannot
/// help. Output bytes are identical either way — that invariant is
/// property-tested in the engine, not here.
fn zone_map_benches() {
    println!("\n== zone-map pruning (8x512-event baskets, end-to-end) ==");
    let path = bench_dir().join("micro_engine.troot");
    if !path.exists() {
        let cfg = gen::GenConfig {
            n_events: 4096,
            target_branches: 180,
            n_hlt: 40,
            basket_events: 512,
            codec: Codec::Lz4,
            seed: 11,
        };
        gen::generate(&cfg, &path).unwrap();
    }
    let index = Arc::new(skimroot::index::FileIndex::build_from_file(&path).unwrap());
    let out = bench_dir().join("micro_zone_out.troot");
    for (label, cut) in [
        ("selective cut", "event >= 1003584"),
        ("broad cut", "MET_pt > 1.0"),
    ] {
        let query = skimroot::query::SkimQuery::new("micro_engine.troot", "zone_out.troot")
            .keep(&["MET_pt", "event", "nJet"])
            .with_cut_str(cut)
            .unwrap();
        for (mode, zone_map) in [("full scan", None), ("pruned", Some(index.clone()))] {
            let opts = EngineOpts { use_pjrt: false, zone_map, ..Default::default() };
            harness::bench(&format!("e2e {label} {mode} (4096 events)"), 1, 5, || {
                let store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&path).unwrap());
                let tl = Timeline::new();
                SkimEngine::new(None).run(store, &query, &tl, &opts, &out).unwrap()
            });
        }
    }
}

/// Shared-scan quartet: four overlapping cuts on one file run as one
/// batched shared scan (`Coordinator::run_shared`) vs four independent
/// solo jobs. Wall-clock is measured for both; the **modeled**
/// (virtual-time) latencies are recorded via `record_model` — those are
/// deterministic cost-model outputs, so CI gates the batched/independent
/// ratio on them without run-to-run jitter. Member virtual time under
/// sharing is the `1/N` fold of the batch scan plus the member's own
/// phase 2, so the sums compared here are directly meaningful.
fn shared_scan_benches() {
    println!("\n== shared-scan batch (4 overlapping cuts, one file) ==");
    let path = bench_dir().join("micro_engine.troot");
    if !path.exists() {
        let cfg = gen::GenConfig {
            n_events: 4096,
            target_branches: 180,
            n_hlt: 40,
            basket_events: 512,
            codec: Codec::Lz4,
            seed: 11,
        };
        gen::generate(&cfg, &path).unwrap();
    }
    let cuts = [
        "MET_pt > 20",
        "MET_pt > 35",
        "MET_pt > 20 && nJet >= 2",
        "MET_pt > 50 || nJet >= 4",
    ];
    let mk = |i: usize, out: String| {
        skimroot::query::SkimQuery::new("micro_engine.troot", out)
            .keep(&["MET_pt", "nJet"])
            .with_cut_str(cuts[i])
            .unwrap()
    };
    let dep = skimroot::coordinator::Deployment::server_side(skimroot::net::LinkModel::local());
    let client = bench_dir().join("shared_client");
    let batch: Vec<_> = (0..cuts.len()).map(|i| mk(i, format!("quartet{i}.troot"))).collect();

    harness::bench("shared-scan quartet batched e2e", 1, 5, || {
        skimroot::coordinator::Coordinator::new(bench_dir(), &client, None)
            .run_shared(&batch, &dep, 1)
            .unwrap()
    });
    harness::bench("shared-scan quartet independent e2e", 1, 5, || {
        (0..cuts.len())
            .map(|i| {
                skimroot::SkimJob::new(mk(i, format!("solo{i}.troot")))
                    .storage(bench_dir())
                    .client_dir(&client)
                    .deployment(dep.clone())
                    .run()
                    .unwrap()
            })
            .count()
    });

    // Deterministic virtual-time records for the CI gate.
    let reports = skimroot::coordinator::Coordinator::new(bench_dir(), &client, None)
        .run_shared(&batch, &dep, 1)
        .unwrap();
    let batched: f64 = reports.iter().map(|r| r.timeline.elapsed()).sum();
    let independent: f64 = (0..cuts.len())
        .map(|i| {
            skimroot::SkimJob::new(mk(i, format!("solo{i}.troot")))
                .storage(bench_dir())
                .client_dir(&client)
                .deployment(dep.clone())
                .run()
                .unwrap()
                .timeline
                .elapsed()
        })
        .sum();
    harness::record_model("shared-scan quartet batched (virtual)", batched);
    harness::record_model("shared-scan quartet independent (virtual)", independent);
}

/// Fixed-vs-adaptive funnel ordering on three canonical cut shapes:
///
/// * **selective-first** — the fixed stage order already runs the
///   cheap, selective cut first; adaptive re-ranking must not make it
///   worse (the `<= 1.05x` CI gate);
/// * **selective-last** — the fixed order runs an expensive, permissive
///   conjunct before the selective one; adaptive re-ranking should win
///   decisively (the `<= 0.7x` CI gate);
/// * **pathological** — every conjunct passes every event, so no order
///   helps; the rank's tie-break must fall back to the fixed order and
///   cost exactly the same.
///
/// Wall-clock is measured for the interpreter runs; the **modeled**
/// funnel costs (Σ over conjuncts of events-visited × structural cost,
/// amortized over an 8-group job with a 1-group warm-up — exactly the
/// engine's `warmup_groups = 1` schedule) are recorded via
/// `record_model`, so CI gates the adaptive/fixed ratio without
/// run-to-run jitter.
fn adaptive_funnel_benches() {
    println!("\n== adaptive funnel ordering (2048-event batch, modeled 8-group job) ==");
    let scenarios: [(&str, &str); 3] = [
        // Scalar cut (stage 0, cost 1, ~5% pass) already leads; the
        // permissive group (cost 6) trails. Fixed order is optimal.
        ("selective-first", "MET_pt > 120 && count(Jet_pt > 0) >= 1"),
        // Fixed order runs the permissive group (cost 6, ~99% pass)
        // before the selective residual; adaptive hoists the residual.
        ("selective-last", "count(Jet_pt > 0) >= 1 && max(Muon_pt) > 150"),
        // All-pass conjuncts: every rank is infinite, the tie-break
        // keeps the fixed stage order, and the ratio is exactly 1.0.
        ("pathological", "MET_pt > -1 && MET_sumEt > -1 && nJet >= 0"),
    ];
    const GROUPS: f64 = 8.0;
    for (label, cut) in scenarios {
        let query = skimroot::query::SkimQuery::new("micro.troot", "o.troot")
            .keep(&["MET_pt"])
            .with_cut_str(cut)
            .unwrap();
        let (plan, batch) = assemble_batch(&query);
        let conjuncts = conjuncts_of(&plan.program);
        assert!(conjuncts.len() >= 2, "{label}: cut must compile to >= 2 conjuncts");
        let identity: Vec<usize> = (0..conjuncts.len()).collect();

        // Warm-up group: fixed order, measuring per-conjunct tallies.
        let mut warm = vec![ConjunctStats::default(); conjuncts.len()];
        let fixed_mask =
            interp::eval_adaptive(&plan.program, &batch, &conjuncts, &identity, &mut warm);
        let ranked = rank_order(&conjuncts, &warm);
        let mut steady = vec![ConjunctStats::default(); conjuncts.len()];
        let ranked_mask =
            interp::eval_adaptive(&plan.program, &batch, &conjuncts, &ranked, &mut steady);
        // The invariant the oracle harness property-tests, spot-checked
        // here: reordering never changes the final event mask.
        assert_eq!(fixed_mask.mask, ranked_mask.mask, "{label}: reorder changed the mask");

        harness::bench(&format!("adaptive funnel fixed ({label})"), 2, 10, || {
            let mut s = vec![ConjunctStats::default(); conjuncts.len()];
            interp::eval_adaptive(&plan.program, &batch, &conjuncts, &identity, &mut s)
        });
        harness::bench(&format!("adaptive funnel ranked ({label})"), 2, 10, || {
            let mut s = vec![ConjunctStats::default(); conjuncts.len()];
            interp::eval_adaptive(&plan.program, &batch, &conjuncts, &ranked, &mut s)
        });

        // Modeled funnel cost of one group under an order: events each
        // conjunct actually visited × its structural cost estimate.
        let group_cost = |stats: &[ConjunctStats]| -> f64 {
            stats
                .iter()
                .zip(&conjuncts)
                .map(|(s, c)| s.visited as f64 * c.cost)
                .sum::<f64>()
                * 1e-6
        };
        let fixed_total = GROUPS * group_cost(&warm);
        let adaptive_total = group_cost(&warm) + (GROUPS - 1.0) * group_cost(&steady);
        println!(
            "{label}: adaptive/fixed modeled ratio {:.3} (ranked order {ranked:?})",
            adaptive_total / fixed_total
        );
        harness::record_model(
            &format!("adaptive funnel fixed ({label}) (virtual)"),
            fixed_total,
        );
        harness::record_model(
            &format!("adaptive funnel adaptive ({label}) (virtual)"),
            adaptive_total,
        );
    }
}

fn json_benches() {
    println!("\n== query front-end ==");
    let query = gen::higgs_query("f.troot", "o.troot");
    let text = query.to_json().to_string();
    println!("higgs query payload: {} bytes", text.len());
    harness::bench("JSON parse + validate (higgs query)", 5, 50, || {
        skimroot::query::SkimQuery::from_json_text(&text).unwrap()
    });
}
