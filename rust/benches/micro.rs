//! Micro benchmarks for the hot paths behind the figures (and the
//! §Perf iteration log in EXPERIMENTS.md):
//!
//! * codec compress/decompress throughput (LZ4 vs zlib vs xz-like —
//!   the Figure 4b decompression asymmetry);
//! * vectorized PJRT cut evaluation vs the scalar interpreter;
//! * basket decode (deserialization substrate);
//! * TTreeCache round-trip reduction;
//! * JSON query parsing.

mod harness;

use skimroot::compress::{self, Codec};
use skimroot::engine::interp;
use skimroot::gen;
use skimroot::query::plan::SkimPlan;
use skimroot::runtime::{Batch, CutParams};
use skimroot::troot::{basket, BranchDesc, ColumnData, DType};
use skimroot::util::Pcg32;

fn main() {
    codec_benches();
    filter_benches();
    decode_benches();
    json_benches();
}

fn codec_benches() {
    println!("== codecs (4 MiB physics-shaped payload) ==");
    let mut rng = Pcg32::new(1);
    let data = rng.compressible_bytes(4 << 20, 0.6);
    for codec in [Codec::Lz4, Codec::Zlib, Codec::XzLike] {
        let frame = compress::compress(codec, &data);
        println!(
            "{:<10} ratio {:.2}",
            codec.name(),
            data.len() as f64 / frame.len() as f64
        );
        harness::bench_throughput(
            &format!("{} compress", codec.name()),
            data.len(),
            1,
            3,
            || compress::compress(codec, &data),
        );
        harness::bench_throughput(
            &format!("{} decompress", codec.name()),
            data.len(),
            1,
            5,
            || compress::decompress(&frame).unwrap(),
        );
    }
}

fn filter_benches() {
    println!("\n== cut evaluation (2048-event batch, Higgs program) ==");
    // Build the Higgs cut program against the generated schema.
    let dir = std::env::temp_dir().join("skimroot_bench_micro");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("micro.troot");
    if !path.exists() {
        let cfg = gen::GenConfig {
            n_events: 2048,
            target_branches: 180,
            n_hlt: 40,
            basket_events: 2048,
            codec: Codec::Lz4,
            seed: 5,
        };
        gen::generate(&cfg, &path).unwrap();
    }
    let reader =
        skimroot::troot::TRootReader::open(skimroot::troot::LocalFile::open(&path).unwrap())
            .unwrap();
    let query = gen::higgs_query("micro.troot", "o.troot");
    let plan = SkimPlan::build(&query, reader.meta()).unwrap();

    let runtime = harness::bench_runtime();
    let caps = runtime
        .as_ref()
        .map(|r| r.caps)
        .unwrap_or(skimroot::runtime::Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 });

    // Assemble a real batch from the file.
    let mut decoded = std::collections::HashMap::new();
    for name in &plan.criteria_branches {
        let bm = reader.branch(name).unwrap().clone();
        decoded.insert(name.clone(), reader.read_basket(&bm, 0).unwrap());
    }
    let (b, m) = (2048, 16);
    let mut batch = Batch::zeroed(&caps, b, m);
    skimroot::engine::batch::append(&plan.program, &decoded, 0, 2048, &mut batch, 0).unwrap();
    batch.n_valid = 2048;

    harness::bench("interpreter eval (2048 events)", 2, 10, || {
        interp::eval(&plan.program, &batch)
    });
    if let Some(rt) = &runtime {
        let variant = rt.variant("large").unwrap();
        let params = CutParams::pack(&plan.program, &rt.caps).unwrap();
        harness::bench("PJRT kernel eval (2048 events)", 2, 10, || {
            rt.eval(variant, &batch, &params).unwrap()
        });
    } else {
        println!("(PJRT runtime unavailable: build artifacts first)");
    }
}

fn decode_benches() {
    println!("\n== basket decode (deserialization substrate) ==");
    let per_event: Vec<Vec<f32>> = {
        let mut rng = Pcg32::new(9);
        (0..10_000)
            .map(|_| (0..rng.poisson(5.5) as usize).map(|_| rng.exp(35.0) as f32).collect())
            .collect()
    };
    let col = ColumnData::jagged_f32(&per_event);
    let desc = BranchDesc::jagged("Jet_pt", DType::F32, "Jet");
    let raw = basket::encode(&col, 0, per_event.len());
    harness::bench_throughput("jagged decode (10k events)", raw.len(), 2, 10, || {
        basket::decode(&desc, &raw, 0, per_event.len()).unwrap()
    });
    harness::bench("selective decode (100 of 10k events)", 2, 10, || {
        let mut offsets = vec![0u32];
        let mut values = skimroot::troot::ColumnValues::F32(Vec::new());
        for ev in (0..10_000).step_by(100) {
            basket::append_event(&desc, &raw, per_event.len(), ev, &mut offsets, &mut values)
                .unwrap();
        }
        values
    });
}

fn json_benches() {
    println!("\n== query front-end ==");
    let query = gen::higgs_query("f.troot", "o.troot");
    let text = query.to_json().to_string();
    println!("higgs query payload: {} bytes", text.len());
    harness::bench("JSON parse + validate (higgs query)", 5, 50, || {
        skimroot::query::SkimQuery::from_json_text(&text).unwrap()
    });
}
