//! The catalog layer: resolving a [`DatasetSpec`] against a storage
//! root into an ordered, validated list of catalog-relative files.
//!
//! This is the boundary where a query's *lexical* dataset spec
//! ([`crate::query::DatasetSpec`]) meets the exported file catalog:
//!
//! * **validation** — every resolved entry must stay inside the
//!   storage root. Paths that could escape it (absolute paths, any
//!   `..`, backslashes) are rejected with a [`crate::Error::Config`]
//!   *before* anything is opened — this is the wire-level
//!   path-traversal gate for remotely submitted queries;
//! * **glob expansion** — patterns are matched against a recursive
//!   walk of the storage export and returned **sorted**, so a glob
//!   dataset has one deterministic file order everywhere (CLI, TCP
//!   service, HTTP jobs API);
//! * **named catalogs** — `catalog:NAME` reads `NAME.catalog` in the
//!   storage root (one file per line, `#` comments), preserving the
//!   catalog's listed order;
//! * **striping** — [`lane_of`] is the shared file → DPU-lane
//!   placement rule used by the coordinator's fan-out.
//!
//! Resolution is lexical beyond globs: explicit files and catalog
//! entries are *not* checked for existence here (a missing file fails
//! that file at open time, with per-file fault isolation), matching
//! the single-file job contract where a bad path fails at open.

use crate::query::wildcard::glob_match;
use crate::query::DatasetSpec;
use crate::{Error, Result};
use std::path::Path;

/// Maximum directory depth a glob walk descends below the storage
/// root (defensive bound against pathological or cyclic exports).
const MAX_WALK_DEPTH: usize = 16;

/// Validate one catalog-relative path: non-empty, relative, forward
/// slashes only, and free of `..` — the same rule the XRootD-like
/// file server enforces, applied *before* any job work happens.
pub fn validate_entry(path: &str) -> Result<()> {
    if path.is_empty() {
        return Err(Error::Config("dataset entry must not be empty".into()));
    }
    if path.starts_with('/') || path.contains('\\') || path.contains("..") {
        return Err(Error::Config(format!(
            "dataset entry '{path}' escapes the storage root (absolute \
             paths, '..' and backslashes are rejected)"
        )));
    }
    Ok(())
}

/// Resolve a dataset spec against `root` into an ordered list of
/// validated catalog-relative files. See the module docs for the
/// per-variant rules.
pub fn resolve(spec: &DatasetSpec, root: &Path) -> Result<Vec<String>> {
    match spec {
        DatasetSpec::File(path) => {
            validate_entry(path)?;
            Ok(vec![path.clone()])
        }
        DatasetSpec::Files(files) => {
            if files.is_empty() {
                return Err(Error::Config("dataset file list is empty".into()));
            }
            for f in files {
                validate_entry(f)?;
            }
            Ok(files.clone())
        }
        DatasetSpec::Glob(pattern) => {
            validate_entry(pattern)?;
            let files = list_glob(root, pattern)?;
            if files.is_empty() {
                return Err(Error::Config(format!(
                    "dataset glob '{pattern}' matched no files under the storage root"
                )));
            }
            Ok(files)
        }
        DatasetSpec::Catalog(name) => {
            validate_entry(name)?;
            read_catalog(root, name)
        }
    }
}

/// Expand a glob pattern against a recursive walk of `root`: every
/// regular file whose root-relative path (forward slashes) matches is
/// returned, sorted lexicographically.
pub fn list_glob(root: &Path, pattern: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    walk(root, "", pattern, 0, &mut out)?;
    out.sort_unstable();
    Ok(out)
}

fn walk(
    dir: &Path,
    prefix: &str,
    pattern: &str,
    depth: usize,
    out: &mut Vec<String>,
) -> Result<()> {
    if depth > MAX_WALK_DEPTH {
        return Ok(());
    }
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        // A missing/unreadable root yields an empty listing; the
        // caller turns that into a "matched no files" config error.
        Err(_) => return Ok(()),
    };
    for entry in entries {
        let entry = entry?;
        let Ok(name) = entry.file_name().into_string() else {
            continue; // non-UTF-8 names cannot be catalog entries
        };
        let rel = if prefix.is_empty() { name.clone() } else { format!("{prefix}/{name}") };
        let ft = entry.file_type()?;
        if ft.is_dir() {
            walk(&entry.path(), &rel, pattern, depth + 1, out)?;
        } else if ft.is_file() && glob_match(pattern, &rel) {
            out.push(rel);
        }
    }
    Ok(())
}

/// Read a named catalog: `NAME.catalog` (the suffix is appended
/// unless already present), itself a catalog-relative path under the
/// storage root; one file per line in listed order, blank lines and
/// `#` comments skipped. Entries are resolved **relative to the
/// catalog file's own directory** (so a dataset generated under
/// `store/` carries a self-contained `store/NAME.catalog`), and every
/// resulting path is validated.
pub fn read_catalog(root: &Path, name: &str) -> Result<Vec<String>> {
    let file = if name.ends_with(".catalog") {
        name.to_string()
    } else {
        format!("{name}.catalog")
    };
    let text = std::fs::read_to_string(root.join(&file))
        .map_err(|e| Error::Config(format!("catalog '{name}': cannot read {file}: {e}")))?;
    let prefix = match std::path::Path::new(&file).parent() {
        Some(p) if !p.as_os_str().is_empty() => {
            format!("{}/", p.to_string_lossy())
        }
        _ => String::new(),
    };
    let mut files = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let entry = format!("{prefix}{line}");
        validate_entry(&entry)?;
        files.push(entry);
    }
    if files.is_empty() {
        return Err(Error::Config(format!("catalog '{name}' lists no files")));
    }
    Ok(files)
}

/// The file → lane placement rule for striping a dataset across
/// `lanes` DPU nodes: files go round-robin, so consecutive files land
/// on different nodes and every lane's share differs by at most one.
pub fn lane_of(file_index: usize, lanes: usize) -> usize {
    file_index % lanes.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("catalog_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("store")).unwrap();
        for name in ["store/b.troot", "store/a.troot", "store/c.troot", "top.troot"] {
            std::fs::write(dir.join(name), b"x").unwrap();
        }
        std::fs::write(dir.join("notes.txt"), b"x").unwrap();
        dir
    }

    #[test]
    fn validate_rejects_escapes() {
        for bad in ["", "/etc/passwd", "../secret", "a/../b", "a\\b", "a..b"] {
            assert!(validate_entry(bad).is_err(), "should reject {bad:?}");
        }
        for ok in ["a.troot", "store/a.troot", "deep/er/f.troot"] {
            assert!(validate_entry(ok).is_ok(), "should accept {ok:?}");
        }
    }

    #[test]
    fn glob_lists_sorted_matches() {
        let root = setup("glob");
        let spec = DatasetSpec::parse("store/*.troot");
        let files = resolve(&spec, &root).unwrap();
        assert_eq!(files, vec!["store/a.troot", "store/b.troot", "store/c.troot"]);
        // Pattern touching every .troot, including the top-level one.
        let all = resolve(&DatasetSpec::parse("*.troot"), &root).unwrap();
        assert!(all.contains(&"top.troot".to_string()));
        // Non-matching glob is a config error.
        let err = resolve(&DatasetSpec::parse("nope/*.troot"), &root).unwrap_err();
        assert!(format!("{err}").contains("matched no files"), "{err}");
    }

    #[test]
    fn explicit_files_keep_order_without_existence_check() {
        let root = setup("files");
        let spec = DatasetSpec::Files(vec!["store/c.troot".into(), "missing.troot".into()]);
        assert_eq!(resolve(&spec, &root).unwrap(), vec!["store/c.troot", "missing.troot"]);
        assert!(resolve(&DatasetSpec::Files(Vec::new()), &root).is_err());
    }

    #[test]
    fn named_catalog_reads_listed_order() {
        let root = setup("named");
        std::fs::write(
            root.join("run.catalog"),
            "# run-2018 files\nstore/c.troot\n\nstore/a.troot\n",
        )
        .unwrap();
        let files = resolve(&DatasetSpec::Catalog("run".into()), &root).unwrap();
        assert_eq!(files, vec!["store/c.troot", "store/a.troot"]);
        assert!(resolve(&DatasetSpec::Catalog("absent".into()), &root).is_err());
        std::fs::write(root.join("bad.catalog"), "../oops\n").unwrap();
        let err = resolve(&DatasetSpec::Catalog("bad".into()), &root).unwrap_err();
        assert!(format!("{err}").contains("escapes the storage root"), "{err}");
    }

    #[test]
    fn nested_catalog_entries_resolve_relative_to_the_catalog() {
        let root = setup("nested");
        // A self-contained dataset directory: catalog next to its
        // files, entries without the directory prefix.
        std::fs::write(root.join("store/set.catalog"), "a.troot\nb.troot\n").unwrap();
        let files = resolve(&DatasetSpec::Catalog("store/set".into()), &root).unwrap();
        assert_eq!(files, vec!["store/a.troot", "store/b.troot"]);
    }

    #[test]
    fn traversal_rejected_for_every_variant() {
        let root = setup("trav");
        for spec in [
            DatasetSpec::File("../../secret".into()),
            DatasetSpec::Files(vec!["ok.troot".into(), "/abs.troot".into()]),
            DatasetSpec::Glob("../*.troot".into()),
            DatasetSpec::Catalog("../cat".into()),
        ] {
            let err = resolve(&spec, &root).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{spec:?}: {err}");
        }
    }

    #[test]
    fn lane_striping_is_round_robin() {
        assert_eq!(lane_of(0, 4), 0);
        assert_eq!(lane_of(5, 4), 1);
        assert_eq!(lane_of(3, 1), 0);
        assert_eq!(lane_of(7, 0), 0); // degenerate lanes clamp to 1
    }
}
