//! The catalog layer: resolving a [`DatasetSpec`] against a storage
//! root into an ordered, validated list of catalog-relative files.
//!
//! This is the boundary where a query's *lexical* dataset spec
//! ([`crate::query::DatasetSpec`]) meets the exported file catalog:
//!
//! * **validation** — every resolved entry must stay inside the
//!   storage root. Paths that could escape it (absolute paths, any
//!   `..`, backslashes) are rejected with a [`crate::Error::Config`]
//!   *before* anything is opened — this is the wire-level
//!   path-traversal gate for remotely submitted queries;
//! * **glob expansion** — patterns are matched against a recursive
//!   walk of the storage export and returned **sorted**, so a glob
//!   dataset has one deterministic file order everywhere (CLI, TCP
//!   service, HTTP jobs API);
//! * **named catalogs** — `catalog:NAME` reads `NAME.catalog` in the
//!   storage root (one file per line, `#` comments), preserving the
//!   catalog's listed order;
//! * **striping** — [`lane_of`] is the shared file → DPU-lane
//!   placement rule used by the coordinator's fan-out;
//! * **materialized skims** — [`register_materialized`] copies a skim
//!   output (plus a freshly derived `.tridx` zone-map sidecar) under
//!   `skims/` and writes a `NAME.catalog` carrying the skim's
//!   [`Lineage`] as structured comments, so the result is itself an
//!   ordinary `catalog:NAME` input to later queries.
//!
//! Zone-map sidecars (`*.tridx`, [`crate::index`]) live next to their
//! data files but are **never** catalog entries: the glob walk skips
//! them, so `store/part*` cannot accidentally skim an index file.
//!
//! Resolution is lexical beyond globs: explicit files and catalog
//! entries are *not* checked for existence here (a missing file fails
//! that file at open time, with per-file fault isolation), matching
//! the single-file job contract where a bad path fails at open.

use crate::query::wildcard::glob_match;
use crate::query::DatasetSpec;
use crate::{Error, Result};
use std::path::Path;

/// Maximum directory depth a glob walk descends below the storage
/// root (defensive bound against pathological or cyclic exports).
const MAX_WALK_DEPTH: usize = 16;

/// Validate one catalog-relative path: non-empty, relative, forward
/// slashes only, and free of `..` — the same rule the XRootD-like
/// file server enforces, applied *before* any job work happens.
pub fn validate_entry(path: &str) -> Result<()> {
    if path.is_empty() {
        return Err(Error::Config("dataset entry must not be empty".into()));
    }
    if path.starts_with('/') || path.contains('\\') || path.contains("..") {
        return Err(Error::Config(format!(
            "dataset entry '{path}' escapes the storage root (absolute \
             paths, '..' and backslashes are rejected)"
        )));
    }
    Ok(())
}

/// Resolve a dataset spec against `root` into an ordered list of
/// validated catalog-relative files. See the module docs for the
/// per-variant rules.
pub fn resolve(spec: &DatasetSpec, root: &Path) -> Result<Vec<String>> {
    match spec {
        DatasetSpec::File(path) => {
            validate_entry(path)?;
            Ok(vec![path.clone()])
        }
        DatasetSpec::Files(files) => {
            if files.is_empty() {
                return Err(Error::Config("dataset file list is empty".into()));
            }
            for f in files {
                validate_entry(f)?;
            }
            Ok(files.clone())
        }
        DatasetSpec::Glob(pattern) => {
            validate_entry(pattern)?;
            let files = list_glob(root, pattern)?;
            if files.is_empty() {
                return Err(Error::Config(format!(
                    "dataset glob '{pattern}' matched no files under the storage root"
                )));
            }
            Ok(files)
        }
        DatasetSpec::Catalog(name) => {
            validate_entry(name)?;
            read_catalog(root, name)
        }
    }
}

/// Expand a glob pattern against a recursive walk of `root`: every
/// regular file whose root-relative path (forward slashes) matches is
/// returned, sorted lexicographically.
pub fn list_glob(root: &Path, pattern: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    walk(root, "", pattern, 0, &mut out)?;
    out.sort_unstable();
    Ok(out)
}

fn walk(
    dir: &Path,
    prefix: &str,
    pattern: &str,
    depth: usize,
    out: &mut Vec<String>,
) -> Result<()> {
    if depth > MAX_WALK_DEPTH {
        return Ok(());
    }
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        // A missing/unreadable root yields an empty listing; the
        // caller turns that into a "matched no files" config error.
        Err(_) => return Ok(()),
    };
    for entry in entries {
        let entry = entry?;
        let Ok(name) = entry.file_name().into_string() else {
            continue; // non-UTF-8 names cannot be catalog entries
        };
        let rel = if prefix.is_empty() { name.clone() } else { format!("{prefix}/{name}") };
        let ft = entry.file_type()?;
        if ft.is_dir() {
            walk(&entry.path(), &rel, pattern, depth + 1, out)?;
        } else if ft.is_file()
            && !crate::index::is_sidecar_name(&name)
            && !is_tmp_name(&name)
            && glob_match(pattern, &rel)
        {
            out.push(rel);
        }
    }
    Ok(())
}

/// Read a named catalog: `NAME.catalog` (the suffix is appended
/// unless already present), itself a catalog-relative path under the
/// storage root; one file per line in listed order, blank lines and
/// `#` comments skipped. Entries are resolved **relative to the
/// catalog file's own directory** (so a dataset generated under
/// `store/` carries a self-contained `store/NAME.catalog`), and every
/// resulting path is validated.
pub fn read_catalog(root: &Path, name: &str) -> Result<Vec<String>> {
    let file = if name.ends_with(".catalog") {
        name.to_string()
    } else {
        format!("{name}.catalog")
    };
    let text = std::fs::read_to_string(root.join(&file))
        .map_err(|e| Error::Config(format!("catalog '{name}': cannot read {file}: {e}")))?;
    let prefix = match std::path::Path::new(&file).parent() {
        Some(p) if !p.as_os_str().is_empty() => {
            format!("{}/", p.to_string_lossy())
        }
        _ => String::new(),
    };
    let mut files = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let entry = format!("{prefix}{line}");
        validate_entry(&entry)?;
        files.push(entry);
    }
    if files.is_empty() {
        return Err(Error::Config(format!("catalog '{name}' lists no files")));
    }
    Ok(files)
}

/// The file → lane placement rule for striping a dataset across
/// `lanes` DPU nodes: files go round-robin, so consecutive files land
/// on different nodes and every lane's share differs by at most one.
pub fn lane_of(file_index: usize, lanes: usize) -> usize {
    file_index % lanes.max(1)
}

// ---------------- materialized skims ---------------------------------

/// Directory under the storage root where materialized skim outputs
/// are copied.
pub const SKIMS_DIR: &str = "skims";

/// Marker comment on the first line of a catalog written by
/// [`register_materialized`].
const MATERIALIZED_MARKER: &str = "# skimroot:materialized";

/// Prefix of staging files written by [`register_materialized`] before
/// their rename into place. Names carrying it never resolve as catalog
/// entries and are swept by [`clean_orphans`] at startup.
const TMP_PREFIX: &str = ".tmp.";

/// Whether a file name is a materialization staging temporary.
pub fn is_tmp_name(name: &str) -> bool {
    name.starts_with(TMP_PREFIX)
}

/// Provenance of a materialized skim, recorded as structured comments
/// in its catalog file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lineage {
    /// Display form of the source [`DatasetSpec`] the skim ran over.
    pub source: String,
    /// Canonical display of the skim's combined cut expression, or
    /// `"(none)"` for a copy-all skim.
    pub cut: String,
}

/// Register a finished skim output as a first-class catalog entry:
/// copy `output_path` to `<root>/skims/<name>.troot`, derive and save
/// its `.tridx` zone-map sidecar (so re-skimming the skim prunes too),
/// and write `<root>/<name>.catalog` carrying the [`Lineage`] as
/// structured comments. The result resolves as `catalog:<name>` like
/// any dataset. Returns the catalog-relative path of the copied file.
///
/// `name` must be a plain filesystem-safe identifier (letters, digits,
/// `.`/`-`/`_`): the catalog is written at the storage root, so a
/// nested name would silently shift its entry prefix.
pub fn register_materialized(
    root: &Path,
    name: &str,
    output_path: &Path,
    source: &DatasetSpec,
    cut: Option<&crate::query::Expr>,
) -> Result<String> {
    if name.is_empty()
        || !name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '.' || c == '-' || c == '_')
    {
        return Err(Error::Config(format!(
            "materialized skim name '{name}' must be non-empty and use only \
             letters, digits, '.', '-' and '_'"
        )));
    }
    let skims = root.join(SKIMS_DIR);
    std::fs::create_dir_all(&skims)?;
    let rel = format!("{SKIMS_DIR}/{name}.troot");
    let data = skims.join(format!("{name}.troot"));
    // Crash-safe commit protocol: every file is staged under a
    // [`TMP_PREFIX`] name and renamed into place, and the root
    // `NAME.catalog` is renamed *last* — the catalog is the commit
    // record. A crash at any point leaves either staging temporaries
    // or skim files without their catalog; both are swept by
    // [`clean_orphans`] before the next process serves.
    let tmp_data = skims.join(format!("{TMP_PREFIX}{name}.troot"));
    std::fs::copy(output_path, &tmp_data)?;
    std::fs::rename(&tmp_data, &data)?;
    // Derive the skim's own zone map after the fact (the generic
    // `skimroot index` path); later skims over this entry prune too.
    let tmp_sidecar = skims.join(format!("{TMP_PREFIX}{name}.troot.tridx"));
    crate::index::FileIndex::build_from_file(&data)?.save(&tmp_sidecar)?;
    std::fs::rename(&tmp_sidecar, crate::index::sidecar_path(&data))?;
    let cut_text = cut.map_or_else(|| "(none)".to_string(), |e| e.to_string());
    let listing = format!(
        "{MATERIALIZED_MARKER}\n# source: {source}\n# cut: {cut_text}\n{rel}\n"
    );
    let tmp_catalog = root.join(format!("{TMP_PREFIX}{name}.catalog"));
    std::fs::write(&tmp_catalog, listing)?;
    std::fs::rename(&tmp_catalog, root.join(format!("{name}.catalog")))?;
    Ok(rel)
}

/// Startup crash recovery for [`register_materialized`]: sweep
/// (a) staging temporaries left at the storage root and under
/// `skims/`, and (b) skim data/sidecar files whose `NAME.catalog`
/// commit record never appeared — a crash between the data rename and
/// the catalog rename orphans them. `skims/` is written exclusively by
/// the materialization path, so an uncatalogued file there is always
/// an orphan, never user data.
///
/// Best-effort by design: the sweep must never stop a service from
/// starting, so unreadable directories and failed removals are
/// silently skipped (the next startup retries them).
pub fn clean_orphans(root: &Path) {
    let skims = root.join(SKIMS_DIR);
    for dir in [root, skims.as_path()] {
        let Ok(entries) = std::fs::read_dir(dir) else { continue };
        for entry in entries.flatten() {
            let Ok(name) = entry.file_name().into_string() else { continue };
            if is_tmp_name(&name) {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
    let Ok(entries) = std::fs::read_dir(&skims) else { return };
    for entry in entries.flatten() {
        let Ok(name) = entry.file_name().into_string() else { continue };
        let Some(stem) = name.strip_suffix(".troot") else {
            continue; // sidecars ride along with their data file below
        };
        if !root.join(format!("{stem}.catalog")).is_file() {
            let _ = std::fs::remove_file(entry.path());
            let _ = std::fs::remove_file(crate::index::sidecar_path(&entry.path()));
        }
    }
}

/// Read back the [`Lineage`] of `catalog:<name>`. Returns `Ok(None)`
/// for a catalog that exists but was not written by
/// [`register_materialized`]; errors only if the catalog file itself
/// cannot be read.
pub fn read_lineage(root: &Path, name: &str) -> Result<Option<Lineage>> {
    let file = if name.ends_with(".catalog") {
        name.to_string()
    } else {
        format!("{name}.catalog")
    };
    let text = std::fs::read_to_string(root.join(&file))
        .map_err(|e| Error::Config(format!("catalog '{name}': cannot read {file}: {e}")))?;
    let mut lines = text.lines();
    if lines.next().map(str::trim) != Some(MATERIALIZED_MARKER) {
        return Ok(None);
    }
    let mut source = None;
    let mut cut = None;
    for line in lines {
        let line = line.trim();
        if let Some(s) = line.strip_prefix("# source: ") {
            source = Some(s.to_string());
        } else if let Some(c) = line.strip_prefix("# cut: ") {
            cut = Some(c.to_string());
        }
    }
    match (source, cut) {
        (Some(source), Some(cut)) => Ok(Some(Lineage { source, cut })),
        _ => Err(Error::Config(format!(
            "catalog '{name}' carries the materialized marker but its \
             lineage comments are incomplete"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("catalog_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("store")).unwrap();
        for name in ["store/b.troot", "store/a.troot", "store/c.troot", "top.troot"] {
            std::fs::write(dir.join(name), b"x").unwrap();
        }
        std::fs::write(dir.join("notes.txt"), b"x").unwrap();
        dir
    }

    #[test]
    fn validate_rejects_escapes() {
        for bad in ["", "/etc/passwd", "../secret", "a/../b", "a\\b", "a..b"] {
            assert!(validate_entry(bad).is_err(), "should reject {bad:?}");
        }
        for ok in ["a.troot", "store/a.troot", "deep/er/f.troot"] {
            assert!(validate_entry(ok).is_ok(), "should accept {ok:?}");
        }
    }

    #[test]
    fn glob_lists_sorted_matches() {
        let root = setup("glob");
        let spec = DatasetSpec::parse("store/*.troot");
        let files = resolve(&spec, &root).unwrap();
        assert_eq!(files, vec!["store/a.troot", "store/b.troot", "store/c.troot"]);
        // Pattern touching every .troot, including the top-level one.
        let all = resolve(&DatasetSpec::parse("*.troot"), &root).unwrap();
        assert!(all.contains(&"top.troot".to_string()));
        // Non-matching glob is a config error.
        let err = resolve(&DatasetSpec::parse("nope/*.troot"), &root).unwrap_err();
        assert!(format!("{err}").contains("matched no files"), "{err}");
    }

    #[test]
    fn explicit_files_keep_order_without_existence_check() {
        let root = setup("files");
        let spec = DatasetSpec::Files(vec!["store/c.troot".into(), "missing.troot".into()]);
        assert_eq!(resolve(&spec, &root).unwrap(), vec!["store/c.troot", "missing.troot"]);
        assert!(resolve(&DatasetSpec::Files(Vec::new()), &root).is_err());
    }

    #[test]
    fn named_catalog_reads_listed_order() {
        let root = setup("named");
        std::fs::write(
            root.join("run.catalog"),
            "# run-2018 files\nstore/c.troot\n\nstore/a.troot\n",
        )
        .unwrap();
        let files = resolve(&DatasetSpec::Catalog("run".into()), &root).unwrap();
        assert_eq!(files, vec!["store/c.troot", "store/a.troot"]);
        assert!(resolve(&DatasetSpec::Catalog("absent".into()), &root).is_err());
        std::fs::write(root.join("bad.catalog"), "../oops\n").unwrap();
        let err = resolve(&DatasetSpec::Catalog("bad".into()), &root).unwrap_err();
        assert!(format!("{err}").contains("escapes the storage root"), "{err}");
    }

    #[test]
    fn nested_catalog_entries_resolve_relative_to_the_catalog() {
        let root = setup("nested");
        // A self-contained dataset directory: catalog next to its
        // files, entries without the directory prefix.
        std::fs::write(root.join("store/set.catalog"), "a.troot\nb.troot\n").unwrap();
        let files = resolve(&DatasetSpec::Catalog("store/set".into()), &root).unwrap();
        assert_eq!(files, vec!["store/a.troot", "store/b.troot"]);
    }

    #[test]
    fn traversal_rejected_for_every_variant() {
        let root = setup("trav");
        for spec in [
            DatasetSpec::File("../../secret".into()),
            DatasetSpec::Files(vec!["ok.troot".into(), "/abs.troot".into()]),
            DatasetSpec::Glob("../*.troot".into()),
            DatasetSpec::Catalog("../cat".into()),
        ] {
            let err = resolve(&spec, &root).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{spec:?}: {err}");
        }
    }

    #[test]
    fn lane_striping_is_round_robin() {
        assert_eq!(lane_of(0, 4), 0);
        assert_eq!(lane_of(5, 4), 1);
        assert_eq!(lane_of(3, 1), 0);
        assert_eq!(lane_of(7, 0), 0); // degenerate lanes clamp to 1
    }

    #[test]
    fn empty_glob_is_a_config_error_not_an_empty_job() {
        let root = setup("emptyglob");
        let err = resolve(&DatasetSpec::parse("store/*.parquet"), &root).unwrap_err();
        assert!(matches!(err, Error::Config(_)));
        assert!(format!("{err}").contains("matched no files"), "{err}");
        // A glob over a nonexistent root behaves the same (no panic).
        let err = resolve(
            &DatasetSpec::parse("store/*.troot"),
            &root.join("does_not_exist"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("matched no files"), "{err}");
    }

    #[test]
    fn nested_directories_sort_into_one_deterministic_order() {
        let root = setup("nestsort");
        std::fs::create_dir_all(root.join("store/run2/deep")).unwrap();
        std::fs::create_dir_all(root.join("store/run1")).unwrap();
        for name in [
            "store/run2/z.troot",
            "store/run2/deep/m.troot",
            "store/run1/k.troot",
        ] {
            std::fs::write(root.join(name), b"x").unwrap();
        }
        let files = resolve(&DatasetSpec::parse("store/*"), &root).unwrap();
        assert_eq!(
            files,
            vec![
                "store/a.troot",
                "store/b.troot",
                "store/c.troot",
                "store/run1/k.troot",
                "store/run2/deep/m.troot",
                "store/run2/z.troot",
            ]
        );
    }

    #[test]
    fn sidecars_never_resolve_as_data_files() {
        let root = setup("sidecars");
        std::fs::write(root.join("store/a.troot.tridx"), b"idx").unwrap();
        // Even a glob that would lexically match the sidecar skips it.
        let files = resolve(&DatasetSpec::parse("store/*"), &root).unwrap();
        assert_eq!(files, vec!["store/a.troot", "store/b.troot", "store/c.troot"]);
        let files = resolve(&DatasetSpec::parse("store/a.troot*"), &root).unwrap();
        assert_eq!(files, vec!["store/a.troot"]);

        // An orphaned sidecar (data file deleted, index left behind)
        // stays invisible rather than resurfacing as a bogus entry.
        std::fs::remove_file(root.join("store/a.troot")).unwrap();
        let files = resolve(&DatasetSpec::parse("store/*"), &root).unwrap();
        assert_eq!(files, vec!["store/b.troot", "store/c.troot"]);
    }

    #[test]
    fn materialized_skim_registers_and_reads_lineage() {
        let root = setup("mat");
        // A real troot file to materialize (content matters: the
        // register path derives a sidecar from it).
        let src = crate::gen::GenConfig::tiny(60);
        let out = root.join("job_out.troot");
        crate::gen::generate(&src, &out).unwrap();

        let spec = DatasetSpec::parse("store/*.troot");
        let cut = crate::query::parse_cut("MET_pt > 20").unwrap();
        let rel = register_materialized(&root, "hot_met", &out, &spec, Some(&cut)).unwrap();
        assert_eq!(rel, "skims/hot_met.troot");
        assert!(root.join("skims/hot_met.troot").is_file());
        assert!(root.join("skims/hot_met.troot.tridx").is_file());

        // Resolves like any named catalog.
        let files = resolve(&DatasetSpec::Catalog("hot_met".into()), &root).unwrap();
        assert_eq!(files, vec!["skims/hot_met.troot"]);

        // Lineage roundtrips; the source spec is re-parseable.
        let lin = read_lineage(&root, "hot_met").unwrap().expect("materialized");
        assert_eq!(DatasetSpec::parse(&lin.source), spec);
        assert_eq!(lin.cut, cut.to_string());

        // A hand-written catalog has no lineage.
        std::fs::write(root.join("plain.catalog"), "store/a.troot\n").unwrap();
        assert_eq!(read_lineage(&root, "plain").unwrap(), None);

        // Unsafe names are rejected before anything is written.
        assert!(register_materialized(&root, "../evil", &out, &spec, None).is_err());
        assert!(register_materialized(&root, "a/b", &out, &spec, None).is_err());
        assert!(register_materialized(&root, "", &out, &spec, None).is_err());
    }

    #[test]
    fn clean_orphans_sweeps_staging_and_uncatalogued_skims() {
        let root = setup("orphans");
        let src = crate::gen::GenConfig::tiny(60);
        let out = root.join("job_out.troot");
        crate::gen::generate(&src, &out).unwrap();
        let spec = DatasetSpec::parse("store/*.troot");

        // A committed skim: catalog present, must survive the sweep.
        register_materialized(&root, "keeper", &out, &spec, None).unwrap();

        // Crash debris: staging temporaries at both levels, and a
        // data/sidecar pair whose catalog commit never happened.
        std::fs::write(root.join(".tmp.half.catalog"), b"x").unwrap();
        std::fs::write(root.join("skims/.tmp.half.troot"), b"x").unwrap();
        std::fs::copy(&out, root.join("skims/lost.troot")).unwrap();
        std::fs::write(root.join("skims/lost.troot.tridx"), b"idx").unwrap();

        // The staging temporary is already invisible to resolution.
        let files = resolve(&DatasetSpec::parse("skims/*"), &root).unwrap();
        assert!(!files.iter().any(|f| f.contains(".tmp.")), "{files:?}");

        clean_orphans(&root);
        assert!(!root.join(".tmp.half.catalog").exists());
        assert!(!root.join("skims/.tmp.half.troot").exists());
        assert!(!root.join("skims/lost.troot").exists());
        assert!(!root.join("skims/lost.troot.tridx").exists());
        assert!(root.join("skims/keeper.troot").is_file(), "committed skim survives");
        assert!(root.join("skims/keeper.troot.tridx").is_file());
        assert!(root.join("keeper.catalog").is_file());

        // Idempotent, and harmless on a root with no skims dir at all.
        clean_orphans(&root);
        clean_orphans(&root.join("does_not_exist"));
        assert!(root.join("skims/keeper.troot").is_file());
    }
}
