//! The multi-tenant job scheduler: a bounded worker pool over
//! [`SkimJob`]s with admission control and per-job status / result
//! retrieval.
//!
//! Lifecycle of one job (see `ARCHITECTURE.md` § "Serving layer"):
//!
//! 1. **submit** — [`SkimScheduler::submit`] parses nothing (it takes a
//!    validated [`SkimQuery`]) but *resolves the dataset*: the query's
//!    input spec is expanded against the service's storage root
//!    ([`crate::catalog::resolve`]), which is also the wire-level
//!    path-traversal gate — entries escaping the catalog are rejected
//!    with a config error before anything is enqueued. Admission
//!    control applies per job: if [`ServeConfig::queue_depth`] jobs
//!    are already waiting, the submission is rejected immediately
//!    (WLCG-style back-pressure: resubmission is the client's job,
//!    not a hidden unbounded queue's).
//! 2. **decompose / schedule** — a single-file job enqueues one task;
//!    a dataset job enqueues **one task per file**, so concurrent
//!    tenants interleave at file granularity on the shared worker
//!    pool (a thousand-file dataset cannot monopolize the service
//!    between one small job's files). Each task drives the ordinary
//!    [`SkimJob`] facade under the service's [`Deployment`] template.
//! 3. **batch formation** (optional) — with
//!    [`ServeConfig::batch_window_ms`] nonzero, single-file jobs first
//!    land in a short **batching window keyed by their resolved
//!    file**: compatible jobs that arrive within the window merge into
//!    one shared-scan batch task ([`crate::mqo`]), so N concurrent
//!    cuts over one hot file pay one phase-1 fetch → decompress →
//!    deserialize pass instead of N. Jobs stay [`JobState::Queued`]
//!    (and count against admission control) while the window is open;
//!    a batch flushes when the window expires or it reaches
//!    [`MAX_BATCH_MEMBERS`]. Batch execution is panic-isolated and
//!    falls back to independent solo runs on any shared-scan error, so
//!    batching can change performance but never outcomes.
//! 4. **shared-cache scan** — every task runs with the service's
//!    shared [`BasketCache`] installed, so concurrent (and
//!    successive) jobs over the same dataset decompress each basket
//!    once.
//! 5. **merge** — per-file outputs are staged as files under the
//!    service's work dir (not pinned in the job table). When a
//!    dataset job's last file task completes, the finishing worker
//!    merges them **in dataset order** through
//!    [`crate::troot::merge`]: the merged bytes are independent of
//!    which worker finished which file first. Failed
//!    files are fault-isolated: they are reported per file
//!    ([`JobStatus::file_errors`]) while the remaining files merge;
//!    the job fails only if every file failed.
//! 6. **stream result** — the filtered file's bytes are held in the
//!    job table until fetched ([`SkimScheduler::fetch_result`]) or
//!    dropped ([`SkimScheduler::forget`]).
//!
//! **Lifecycle control** (see `ARCHITECTURE.md` § "Failure semantics &
//! job lifecycle"): every job carries a [`JobCtl`] — a cancel token
//! plus an optional virtual-time deadline set at submission
//! ([`SkimScheduler::submit_with_deadline`]). [`SkimScheduler::cancel`]
//! flips a queued job straight to [`JobState::Cancelled`] (pulling it
//! out of any open batching window) and trips a running job's token,
//! which the engines observe at the next basket-group boundary; both
//! are idempotent on terminal jobs. Deadline overruns surface as
//! [`JobState::DeadlineExceeded`]. Either way the worker slot is
//! released immediately — a cancelled or expired job never wedges the
//! pool. [`SkimScheduler::drain`] stops admission (submissions get a
//! retriable error) and then finishes or cancels in-flight work by
//! [`DrainPolicy`] before stopping the workers.

use super::cache::BasketCache;
use crate::coordinator::{Coordinator, Deployment};
use crate::job::SkimJob;
use crate::lifecycle::JobCtl;
use crate::net::LinkModel;
use crate::query::SkimQuery;
use crate::{Error, Result};
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Job identifier handed out by [`SkimScheduler::submit`].
pub type JobId = u64;

/// Default worker-pool size for a skim service.
pub const DEFAULT_WORKERS: usize = 4;
/// Default admission-control queue depth.
pub const DEFAULT_QUEUE_DEPTH: usize = 32;
/// Default shared basket-cache capacity (decompressed bytes).
pub const DEFAULT_CACHE_BYTES: u64 = 256 * 1000 * 1000;
/// Default cap on completed job entries retained for status/result
/// pickup (abandoned results must not leak forever).
pub const DEFAULT_RETAINED_JOBS: usize = 256;
/// A pending shared-scan batch flushes as soon as it reaches this many
/// members, even before its window expires.
pub const MAX_BATCH_MEMBERS: usize = 8;

/// Configuration of one multi-tenant skim service.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory the service's file catalog exports (job inputs are
    /// catalog-relative, exactly as for one-shot jobs; dataset specs
    /// resolve against this root at submission).
    pub storage_root: PathBuf,
    /// Scratch directory for per-task outputs (one subdirectory per
    /// task, removed once the result bytes are captured). Defaults to a
    /// unique directory under the system temp dir — deliberately
    /// **outside** the exported catalog, so staged tenant outputs are
    /// never readable through the service's file-serving frames.
    pub work_dir: PathBuf,
    /// Worker threads draining the queue. `0` accepts submissions but
    /// never runs them — useful for tests of admission control.
    pub workers: usize,
    /// Admission control: submissions beyond this many *queued* jobs
    /// are rejected (running jobs do not count; a dataset job counts
    /// once however many file tasks it decomposes into).
    pub queue_depth: usize,
    /// Topology template every job runs under (placement, links,
    /// disk, retries). The default is server-side filtering over a
    /// free local link — the real TCP/HTTP response is the output
    /// transfer, so no virtual transfer time should be charged.
    pub deployment: Deployment,
    /// Shared decompressed-basket cache capacity; `0` disables the
    /// cache (every job re-reads and re-decompresses, as before).
    pub cache_bytes: u64,
    /// Cap on *completed* (done/failed) job entries kept in the table
    /// for status/result pickup; beyond it the oldest completed
    /// entries — result bytes included — are dropped, so clients that
    /// abandon jobs cannot leak memory forever.
    pub retained_jobs: usize,
    /// Shared-scan batching window in milliseconds; `0` (the default)
    /// disables batching entirely. When nonzero, single-file jobs wait
    /// up to this long for same-file companions and are then executed
    /// as **one** shared scan ([`crate::mqo`]): per-member outputs stay
    /// byte-identical to solo runs, but the batch pays one phase-1
    /// basket pass instead of one per member. Requires at least one
    /// worker (the worker pool flushes expired windows) and a
    /// deployment that passes
    /// [`crate::mqo::deployment_incompatibility`] — scheduler
    /// construction rejects the combination otherwise.
    pub batch_window_ms: u64,
}

impl ServeConfig {
    /// Defaults for serving `storage_root`: [`DEFAULT_WORKERS`]
    /// workers, [`DEFAULT_QUEUE_DEPTH`] queue slots, a
    /// [`DEFAULT_CACHE_BYTES`] shared cache,
    /// [`DEFAULT_RETAINED_JOBS`] retained completions, and server-side
    /// placement over a local link.
    pub fn new(storage_root: impl Into<PathBuf>) -> Self {
        // Per-service-instance scratch, outside the exported catalog.
        static INSTANCE: AtomicU64 = AtomicU64::new(0);
        let instance = INSTANCE.fetch_add(1, Ordering::Relaxed);
        let work_dir = std::env::temp_dir()
            .join(format!("skimroot_serve_{}_{instance}", std::process::id()));
        ServeConfig {
            storage_root: storage_root.into(),
            work_dir,
            workers: DEFAULT_WORKERS,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            deployment: Deployment::server_side(LinkModel::local()),
            cache_bytes: DEFAULT_CACHE_BYTES,
            retained_jobs: DEFAULT_RETAINED_JOBS,
            batch_window_ms: 0,
        }
    }
}

/// Coarse job state, as reported over the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for a worker.
    Queued,
    /// A worker is executing the skim (for dataset jobs: at least one
    /// file task has started).
    Running,
    /// Finished; the filtered bytes await [`SkimScheduler::fetch_result`].
    Done,
    /// The job errored (status carries the message).
    Failed,
    /// The client cancelled the job ([`SkimScheduler::cancel`]) before
    /// it finished. Terminal like [`JobState::Failed`], but
    /// distinguishable: the client asked for it.
    Cancelled,
    /// The job's virtual-time deadline passed before it finished.
    /// Terminal; the status carries the overrun detail.
    DeadlineExceeded,
}

impl JobState {
    /// Stable wire code (used by the protocol's `JobState` frame).
    pub fn code(self) -> u8 {
        match self {
            JobState::Queued => 0,
            JobState::Running => 1,
            JobState::Done => 2,
            JobState::Failed => 3,
            JobState::Cancelled => 4,
            JobState::DeadlineExceeded => 5,
        }
    }

    /// Inverse of [`JobState::code`].
    pub fn from_code(code: u8) -> Result<JobState> {
        Ok(match code {
            0 => JobState::Queued,
            1 => JobState::Running,
            2 => JobState::Done,
            3 => JobState::Failed,
            4 => JobState::Cancelled,
            5 => JobState::DeadlineExceeded,
            other => return Err(Error::protocol(format!("bad job state code {other}"))),
        })
    }

    /// Human-readable name.
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::DeadlineExceeded => "deadline-exceeded",
        }
    }

    /// Whether the state is final (the job will never change again).
    pub fn is_terminal(self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

/// Point-in-time view of one job.
#[derive(Debug, Clone)]
pub struct JobStatus {
    /// The id [`SkimScheduler::submit`] returned.
    pub id: JobId,
    /// Current coarse state.
    pub state: JobState,
    /// Events covered so far (accumulates per finished file for
    /// dataset jobs).
    pub n_events: u64,
    /// Events passing the selection so far.
    pub n_pass: u64,
    /// Modeled latency in seconds (summed per-file for dataset jobs —
    /// the serial-equivalent virtual time).
    pub latency: f64,
    /// Shared-basket-cache hits this job scored.
    pub cache_hits: u64,
    /// Shared-basket-cache misses this job paid for.
    pub cache_misses: u64,
    /// Criteria baskets skipped by zone-map pruning (0 when the input
    /// had no `.tridx` sidecar or the cut compiled no zone predicates).
    pub baskets_pruned: u64,
    /// Criteria baskets actually read; `baskets_pruned +
    /// baskets_scanned` is the full criteria scan the job would have
    /// paid without the index.
    pub baskets_scanned: u64,
    /// Decoded-basket views this job received from a shared scan
    /// instead of fetching itself (0 for solo runs): clusters
    /// evaluated × the member's phase-1 branch count.
    pub scan_shared: u64,
    /// Identity of the shared-scan batch this job ran in (0 = solo:
    /// batch ids start at 1).
    pub batch_id: u64,
    /// Member jobs the batch's one scan served (0 = solo).
    pub batch_members: u64,
    /// Resubmission attempts beyond the first, summed across the job's
    /// retry loops (0 when every read succeeded first try).
    pub retries: u64,
    /// Faults the deployment's [`crate::lifecycle::FaultPlan`]
    /// injected into this job's reads (0 outside chaos runs).
    pub faults_injected: u64,
    /// Retry backoff charged to the job's virtual time, microseconds.
    pub backoff_us: u64,
    /// 1 when the job ended [`JobState::Cancelled`].
    pub cancelled: u64,
    /// 1 when the job ended [`JobState::DeadlineExceeded`].
    pub deadline_exceeded: u64,
    /// Failure message when `state` is terminal-with-error
    /// ([`JobState::Failed`], [`JobState::Cancelled`],
    /// [`JobState::DeadlineExceeded`]).
    pub error: Option<String>,
    /// Files in the job's dataset (0 for single-file jobs, whose
    /// status shape is unchanged).
    pub files_total: u64,
    /// Dataset files completed successfully so far.
    pub files_done: u64,
    /// Per-file failure detail, formatted `"<path>: <error>"` —
    /// fault-isolated failures that did *not* fail the whole job.
    pub file_errors: Vec<String>,
    /// Per-conjunct selectivity tallies (empty unless the deployment
    /// ran the adaptive evaluator; accumulates key-wise per finished
    /// file for dataset jobs).
    pub profile: Vec<crate::metrics::ConjunctProfile>,
}

/// One unit of queued work: a whole single-file job, one file of a
/// decomposed dataset job, or a formed shared-scan batch.
#[derive(Debug, Clone)]
enum Task {
    /// A legacy single-file job, executed in one piece.
    Whole(JobId),
    /// One file of a dataset job (index into the job's resolved list).
    File { job: JobId, index: usize },
    /// A flushed batching window: these jobs run as one shared scan.
    Batch(Vec<JobId>),
}

/// An open batching window: same-file jobs accumulate here until the
/// deadline passes (or [`MAX_BATCH_MEMBERS`] is reached), then flush to
/// the queue as one [`Task::Batch`].
struct PendingBatch {
    /// The members' shared resolved file (catalog-relative).
    key: String,
    jobs: Vec<JobId>,
    deadline: Instant,
}

struct JobEntry {
    query: SkimQuery,
    state: JobState,
    /// Cancel token + deadline for this job; the token is shared with
    /// every engine the job spins up.
    ctl: JobCtl,
    output: Option<Vec<u8>>,
    n_events: u64,
    n_pass: u64,
    latency: f64,
    cache_hits: u64,
    cache_misses: u64,
    baskets_pruned: u64,
    baskets_scanned: u64,
    scan_shared: u64,
    batch_id: u64,
    batch_members: u64,
    retries: u64,
    faults_injected: u64,
    backoff_us: u64,
    cancelled: u64,
    deadline_exceeded: u64,
    error: Option<String>,
    /// Resolved dataset files (empty for single-file jobs).
    files: Vec<String>,
    /// Per-file outputs awaiting the deterministic merge, staged as
    /// files under [`ServeConfig::work_dir`] — a thousand-file
    /// dataset must not pin every part's bytes in the job table while
    /// the worker pool trickles through it.
    parts: Vec<Option<PathBuf>>,
    /// Files finished successfully.
    files_done: u64,
    /// Fault-isolated per-file failures: `(index, message)`.
    file_errors: Vec<(usize, String)>,
    /// Guard so exactly one worker runs the final merge.
    merging: bool,
    /// Per-conjunct selectivity tallies from the adaptive evaluator.
    profile: Vec<crate::metrics::ConjunctProfile>,
}

impl JobEntry {
    fn new(query: SkimQuery, files: Vec<String>, ctl: JobCtl) -> JobEntry {
        let n = files.len();
        JobEntry {
            query,
            state: JobState::Queued,
            ctl,
            output: None,
            n_events: 0,
            n_pass: 0,
            latency: 0.0,
            cache_hits: 0,
            cache_misses: 0,
            baskets_pruned: 0,
            baskets_scanned: 0,
            scan_shared: 0,
            batch_id: 0,
            batch_members: 0,
            retries: 0,
            faults_injected: 0,
            backoff_us: 0,
            cancelled: 0,
            deadline_exceeded: 0,
            error: None,
            files,
            parts: (0..n).map(|_| None).collect(),
            files_done: 0,
            file_errors: Vec::new(),
            merging: false,
            profile: Vec::new(),
        }
    }

    /// Fold a finished run's selectivity profile into this entry,
    /// key-wise (dataset jobs accumulate one run per file).
    fn merge_profile(&mut self, prof: &[crate::metrics::ConjunctProfile]) {
        for p in prof {
            match self.profile.iter_mut().find(|e| e.key == p.key) {
                Some(e) => {
                    e.visited += p.visited;
                    e.passed += p.passed;
                    e.cost_us += p.cost_us;
                }
                None => self.profile.push(p.clone()),
            }
        }
    }

    /// Point-in-time status snapshot of this entry.
    fn status(&self, id: JobId) -> JobStatus {
        JobStatus {
            id,
            state: self.state,
            n_events: self.n_events,
            n_pass: self.n_pass,
            latency: self.latency,
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            baskets_pruned: self.baskets_pruned,
            baskets_scanned: self.baskets_scanned,
            scan_shared: self.scan_shared,
            batch_id: self.batch_id,
            batch_members: self.batch_members,
            retries: self.retries,
            faults_injected: self.faults_injected,
            backoff_us: self.backoff_us,
            cancelled: self.cancelled,
            deadline_exceeded: self.deadline_exceeded,
            error: self.error.clone(),
            files_total: self.files.len() as u64,
            files_done: self.files_done,
            file_errors: self
                .file_errors
                .iter()
                .map(|(i, msg)| format!("{}: {msg}", self.files[*i]))
                .collect(),
            profile: self.profile.clone(),
        }
    }
}

struct SchedInner {
    cfg: ServeConfig,
    cache: Option<Arc<BasketCache>>,
    queue: Mutex<VecDeque<Task>>,
    queue_cv: Condvar,
    jobs: Mutex<HashMap<JobId, JobEntry>>,
    /// Open batching windows (empty forever when
    /// [`ServeConfig::batch_window_ms`] is 0). Lock discipline: never
    /// held together with `queue` or `jobs` — flush paths take the
    /// batch out of `pending` first, then enqueue.
    pending: Mutex<Vec<PendingBatch>>,
    next_id: AtomicU64,
    /// Batch ids start at 1: status surfaces use 0 for "not batched".
    next_batch: AtomicU64,
    stop: AtomicBool,
    /// Admission closed ([`SkimScheduler::drain`]); workers keep
    /// running until the drain completes.
    draining: AtomicBool,
    /// Signalled (with `jobs` held) on every transition into a
    /// terminal state — [`SkimScheduler::wait`] and
    /// [`SkimScheduler::drain`] block on this instead of sleep-polling.
    done_cv: Condvar,
}

/// The bounded-worker-pool job scheduler (see the module docs).
pub struct SkimScheduler {
    inner: Arc<SchedInner>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl SkimScheduler {
    /// Start a scheduler: spawns [`ServeConfig::workers`] worker
    /// threads immediately.
    pub fn new(cfg: ServeConfig) -> Result<Arc<SkimScheduler>> {
        cfg.deployment.validate()?;
        if cfg.batch_window_ms > 0 {
            if let Some(reason) = crate::mqo::deployment_incompatibility(&cfg.deployment) {
                return Err(Error::Config(format!(
                    "batch_window_ms requires a deployment that can host shared scans: {reason}"
                )));
            }
        }
        std::fs::create_dir_all(&cfg.work_dir)?;
        // Crash recovery: a previous process may have died between
        // staging a materialized skim and committing its catalog
        // record; sweep the orphaned temporaries before serving.
        crate::catalog::clean_orphans(&cfg.storage_root);
        let cache = if cfg.cache_bytes > 0 {
            Some(Arc::new(BasketCache::new(cfg.cache_bytes)))
        } else {
            None
        };
        let n_workers = cfg.workers;
        let inner = Arc::new(SchedInner {
            cfg,
            cache,
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            jobs: Mutex::new(HashMap::new()),
            pending: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
            next_batch: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            done_cv: Condvar::new(),
        });
        let sched = Arc::new(SkimScheduler {
            inner: inner.clone(),
            workers: Mutex::new(Vec::new()),
        });
        let mut workers = sched.workers.lock().unwrap();
        for _ in 0..n_workers {
            let inner = inner.clone();
            workers.push(std::thread::spawn(move || worker_loop(&inner)));
        }
        drop(workers);
        Ok(sched)
    }

    /// The service's shared basket cache, if enabled.
    pub fn basket_cache(&self) -> Option<&Arc<BasketCache>> {
        self.inner.cache.as_ref()
    }

    /// False once [`SkimScheduler::drain`] or
    /// [`SkimScheduler::shutdown`] has started: submissions are
    /// rejected with a retriable error (the HTTP layer maps this to
    /// `503` + `Retry-After` rather than the admission-control `429`).
    pub fn is_accepting(&self) -> bool {
        !self.inner.stop.load(Ordering::Relaxed)
            && !self.inner.draining.load(Ordering::Relaxed)
    }

    /// Aggregate shared-cache statistics (zeroed when disabled).
    pub fn cache_stats(&self) -> super::cache::BasketCacheStats {
        self.inner.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Submit a job. The input dataset spec is resolved (and
    /// traversal-validated) against the service's storage root — a
    /// query naming files outside the catalog is rejected here, at
    /// the wire boundary, with a config error. Admission control then
    /// applies per job: an error is returned without enqueuing when
    /// [`ServeConfig::queue_depth`] jobs are already waiting (the
    /// client should back off and resubmit). Dataset jobs decompose
    /// into one queued task per file.
    pub fn submit(&self, query: SkimQuery) -> Result<JobId> {
        self.submit_with_deadline(query, 0)
    }

    /// [`SkimScheduler::submit`] with a virtual-time deadline in
    /// milliseconds (`0` = none): once the job's modeled latency
    /// passes the deadline, it stops at the next basket-group boundary
    /// and reports [`JobState::DeadlineExceeded`]. Every submitted job
    /// also gets a cancel token, so [`SkimScheduler::cancel`] works
    /// whether or not a deadline was set.
    pub fn submit_with_deadline(&self, query: SkimQuery, deadline_ms: u64) -> Result<JobId> {
        if !self.is_accepting() {
            return Err(Error::Config(
                "skim service is draining (not accepting jobs); retry later".into(),
            ));
        }
        let ctl = JobCtl::with_deadline_ms(deadline_ms);
        let files = crate::catalog::resolve(&query.input, &self.inner.cfg.storage_root)?;
        let is_dataset = !query.input.is_single();
        let mut queue = self.inner.queue.lock().unwrap();
        let mut jobs = self.inner.jobs.lock().unwrap();
        let queued = jobs.values().filter(|e| e.state == JobState::Queued).count();
        if queued >= self.inner.cfg.queue_depth {
            return Err(Error::Config(format!(
                "skim service queue full ({} jobs waiting, depth {}); resubmit later",
                queued,
                self.inner.cfg.queue_depth
            )));
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        if is_dataset {
            let n = files.len();
            jobs.insert(id, JobEntry::new(query, files, ctl));
            for index in 0..n {
                queue.push_back(Task::File { job: id, index });
            }
            self.inner.queue_cv.notify_all();
            return Ok(id);
        }
        // Single-file job: with a batching window open, it parks in
        // the window (still Queued, still counted by admission
        // control) instead of enqueuing straight away.
        let batchable = self.inner.cfg.batch_window_ms > 0 && files.len() == 1;
        let key = if batchable { Some(files.into_iter().next().unwrap()) } else { None };
        jobs.insert(id, JobEntry::new(query, Vec::new(), ctl));
        let Some(key) = key else {
            queue.push_back(Task::Whole(id));
            self.inner.queue_cv.notify_one();
            return Ok(id);
        };
        // Lock discipline: drop the queue + jobs locks before touching
        // the pending window.
        drop(jobs);
        drop(queue);
        let full = {
            let mut pending = self.inner.pending.lock().unwrap();
            if let Some(pos) = pending.iter().position(|b| b.key == key) {
                pending[pos].jobs.push(id);
                if pending[pos].jobs.len() >= MAX_BATCH_MEMBERS {
                    Some(pending.remove(pos).jobs)
                } else {
                    None
                }
            } else {
                pending.push(PendingBatch {
                    key,
                    jobs: vec![id],
                    deadline: Instant::now()
                        + Duration::from_millis(self.inner.cfg.batch_window_ms),
                });
                None
            }
        };
        if let Some(batch) = full {
            enqueue_batch(&self.inner, batch);
        }
        Ok(id)
    }

    /// Status of job `id`, or `None` for an unknown (or forgotten) id.
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        let jobs = self.inner.jobs.lock().unwrap();
        jobs.get(&id).map(|e| e.status(id))
    }

    /// Cancel job `id`. A queued job (including one parked in an open
    /// batching window) flips straight to [`JobState::Cancelled`]; a
    /// running job has its token tripped and stops at the next
    /// basket-group boundary; a terminal job is left untouched
    /// (cancellation is idempotent). Returns the post-cancel status.
    /// Errors only for unknown (or forgotten) ids.
    pub fn cancel(&self, id: JobId) -> Result<JobStatus> {
        // Pull the job out of any open batching window first, so a
        // window flushing concurrently does not re-enqueue it. Lock
        // discipline: `pending` is never held together with `jobs`.
        {
            let mut pending = self.inner.pending.lock().unwrap();
            for batch in pending.iter_mut() {
                batch.jobs.retain(|&j| j != id);
            }
            pending.retain(|b| !b.jobs.is_empty());
        }
        let mut jobs = self.inner.jobs.lock().unwrap();
        let entry = jobs
            .get_mut(&id)
            .ok_or_else(|| Error::Config(format!("no such job {id}")))?;
        match entry.state {
            JobState::Queued => {
                // Never ran: terminal immediately. Workers that later
                // pop this job's queued tasks see the terminal state
                // and skip them.
                if let Some(token) = &entry.ctl.cancel {
                    token.cancel();
                }
                entry.state = JobState::Cancelled;
                entry.cancelled = 1;
                entry.error = Some("cancelled before start".into());
                self.inner.done_cv.notify_all();
            }
            JobState::Running => {
                // Cooperative: the engines observe the token at the
                // next basket-group boundary and unwind with
                // `Error::Cancelled`; the worker maps that to the
                // terminal state.
                if let Some(token) = &entry.ctl.cancel {
                    token.cancel();
                }
            }
            // Terminal: idempotent no-op.
            _ => {}
        }
        Ok(entry.status(id))
    }

    /// Filtered-file bytes of a [`JobState::Done`] job. The bytes are
    /// handed out **once** — the table keeps only the job's summary
    /// afterwards, so a long-lived service does not accumulate one
    /// filtered file per job (this is what both wire front-ends call).
    /// Errors for unknown ids, already-delivered results, failed jobs
    /// (with the failure message) and jobs still queued or running.
    pub fn fetch_result(&self, id: JobId) -> Result<Vec<u8>> {
        let mut jobs = self.inner.jobs.lock().unwrap();
        let entry = jobs
            .get_mut(&id)
            .ok_or_else(|| Error::Config(format!("no such job {id}")))?;
        match entry.state {
            JobState::Done => entry
                .output
                .take()
                .ok_or_else(|| Error::Config(format!("job {id} result already delivered"))),
            JobState::Failed | JobState::Cancelled | JobState::DeadlineExceeded => {
                Err(Error::Engine(format!(
                    "job {id} {}: {}",
                    entry.state.name(),
                    entry.error.as_deref().unwrap_or("unknown error")
                )))
            }
            state => Err(Error::Config(format!(
                "job {id} not finished (state: {})",
                state.name()
            ))),
        }
    }

    /// Drop a job's table entry entirely (summary included).
    /// [`SkimScheduler::fetch_result`] already releases the result
    /// bytes; this additionally forgets the job's status.
    pub fn forget(&self, id: JobId) {
        self.inner.jobs.lock().unwrap().remove(&id);
    }

    /// Block until job `id` reaches a terminal state (done, failed,
    /// cancelled or deadline-exceeded). Returns the final status.
    /// Sleeps on the scheduler's completion condvar — woken by the
    /// finishing worker, not by polling (the timeout below only guards
    /// against a lost wakeup).
    pub fn wait(&self, id: JobId) -> Result<JobStatus> {
        let mut jobs = self.inner.jobs.lock().unwrap();
        loop {
            let entry = jobs
                .get(&id)
                .ok_or_else(|| Error::Config(format!("no such job {id}")))?;
            if entry.state.is_terminal() {
                return Ok(entry.status(id));
            }
            let (guard, _timeout) = self
                .inner
                .done_cv
                .wait_timeout(jobs, Duration::from_millis(100))
                .unwrap();
            jobs = guard;
        }
    }

    /// Graceful drain: stop admission (submissions now fail with a
    /// retriable error — the wire layers surface it as `503` +
    /// `Retry-After`), flush every open batching window, then bring
    /// in-flight work to rest by `policy` — [`DrainPolicy::Finish`]
    /// lets queued and running jobs complete, [`DrainPolicy::Cancel`]
    /// cancels everything not yet terminal. Blocks until every job in
    /// the table is terminal, then stops and joins the workers.
    /// (`Finish` with zero workers would wait forever on queued jobs —
    /// drain cancels them instead in that configuration.)
    pub fn drain(&self, policy: DrainPolicy) {
        self.inner.draining.store(true, Ordering::Relaxed);
        // Flush open windows now: parked jobs either run immediately
        // or get cancelled below — nobody waits out a window during
        // drain.
        let windows: Vec<Vec<JobId>> = {
            let mut pending = self.inner.pending.lock().unwrap();
            pending.drain(..).map(|b| b.jobs).collect()
        };
        for jobs in windows {
            enqueue_batch(&self.inner, jobs);
        }
        let cancel_queued =
            policy == DrainPolicy::Cancel || self.inner.cfg.workers == 0;
        if cancel_queued {
            let ids: Vec<JobId> =
                self.inner.jobs.lock().unwrap().keys().copied().collect();
            for id in ids {
                let _ = self.cancel(id);
            }
        }
        let mut jobs = self.inner.jobs.lock().unwrap();
        while jobs.values().any(|e| !e.state.is_terminal()) {
            let (guard, _timeout) = self
                .inner
                .done_cv
                .wait_timeout(jobs, Duration::from_millis(100))
                .unwrap();
            jobs = guard;
        }
        drop(jobs);
        self.shutdown();
    }

    /// Stop the workers and join them. Queued jobs that never ran stay
    /// [`JobState::Queued`] in the table. Idempotent. For an orderly
    /// stop that settles in-flight work first, use
    /// [`SkimScheduler::drain`].
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.queue_cv.notify_all();
        let mut workers = self.workers.lock().unwrap();
        for handle in workers.drain(..) {
            let _ = handle.join();
        }
    }
}

/// What [`SkimScheduler::drain`] does with work that is queued or
/// running when the drain starts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainPolicy {
    /// Let queued and running jobs run to completion.
    Finish,
    /// Cancel everything not yet terminal (queued jobs flip to
    /// [`JobState::Cancelled`] immediately; running jobs stop at the
    /// next basket-group boundary).
    Cancel,
}

impl Drop for SkimScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(inner: &SchedInner) {
    loop {
        // Expired batching windows flush outside the queue lock, at
        // least once per 50 ms wakeup while any worker is idle.
        flush_due_batches(inner);
        let task = {
            let mut queue = inner.queue.lock().unwrap();
            if inner.stop.load(Ordering::Relaxed) {
                return;
            }
            match queue.pop_front() {
                Some(task) => Some(task),
                None => {
                    let (mut q, _timeout) = inner
                        .queue_cv
                        .wait_timeout(queue, Duration::from_millis(50))
                        .unwrap();
                    if inner.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    q.pop_front()
                }
            }
        };
        match task {
            Some(Task::Whole(id)) => run_whole(inner, id),
            Some(Task::File { job, index }) => run_file(inner, job, index),
            Some(Task::Batch(ids)) => run_batch(inner, ids),
            // Timed out empty: loop back to check window deadlines.
            None => {}
        }
    }
}

/// Move every expired batching window to the run queue. Windows flush
/// as one [`Task::Batch`] (or degrade to [`Task::Whole`] when only one
/// job arrived inside the window).
fn flush_due_batches(inner: &SchedInner) {
    let due: Vec<Vec<JobId>> = {
        let mut pending = inner.pending.lock().unwrap();
        let now = Instant::now();
        let mut due = Vec::new();
        pending.retain_mut(|batch| {
            if batch.deadline <= now {
                due.push(std::mem::take(&mut batch.jobs));
                false
            } else {
                true
            }
        });
        due
    };
    for jobs in due {
        enqueue_batch(inner, jobs);
    }
}

/// Enqueue a flushed window; a batch of one degrades to an ordinary
/// solo task.
fn enqueue_batch(inner: &SchedInner, mut jobs: Vec<JobId>) {
    let task = match jobs.len() {
        0 => return,
        1 => Task::Whole(jobs.remove(0)),
        _ => Task::Batch(jobs),
    };
    let mut queue = inner.queue.lock().unwrap();
    queue.push_back(task);
    inner.queue_cv.notify_all();
}

/// Execute one query through the ordinary [`SkimJob`] facade, staging
/// its output under `job_dir` (removed afterwards), panic-isolated: a
/// panicking job must neither kill the worker (shrinking the pool for
/// the service's lifetime) nor strand the entry in `Running` with
/// clients polling forever.
fn execute_query(
    inner: &SchedInner,
    query: SkimQuery,
    job_dir: &std::path::Path,
    ctl: &JobCtl,
) -> Result<(crate::coordinator::JobReport, Vec<u8>)> {
    let mut job = SkimJob::new(query)
        .storage(&inner.cfg.storage_root)
        .client_dir(job_dir)
        .deployment(inner.cfg.deployment.clone())
        .ctl(ctl.clone());
    if let Some(cache) = &inner.cache {
        job = job.basket_cache(cache.clone());
    }
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        job.run().and_then(|report| {
            let bytes = std::fs::read(&report.result.output_path)?;
            Ok((report, bytes))
        })
    }))
    .unwrap_or_else(|panic| Err(Error::Engine(format!("job panicked: {}", panic_msg(&panic)))));
    // The per-task directory only staged the output; the bytes live in
    // the job table now.
    let _ = std::fs::remove_dir_all(job_dir);
    outcome
}

/// Best-effort human-readable payload of a caught panic.
fn panic_msg(panic: &(dyn std::any::Any + Send)) -> String {
    panic
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}

/// Bound retention: abandoned completions (results the client never
/// fetched) must not accumulate forever. Oldest completed entries are
/// dropped first; queued/running jobs are never touched.
fn enforce_retention(jobs: &mut HashMap<JobId, JobEntry>, cap: usize) {
    let cap = cap.max(1);
    let mut completed: Vec<JobId> = jobs
        .iter()
        .filter(|(_, e)| matches!(e.state, JobState::Done | JobState::Failed))
        .map(|(&id, _)| id)
        .collect();
    if completed.len() > cap {
        completed.sort_unstable();
        for victim in &completed[..completed.len() - cap] {
            jobs.remove(victim);
        }
    }
}

/// Record a finished single-piece run (solo or shared-scan member)
/// into its table entry.
fn finish_entry(entry: &mut JobEntry, report: &crate::coordinator::JobReport, bytes: Vec<u8>) {
    entry.state = JobState::Done;
    entry.n_events = report.result.n_events;
    entry.n_pass = report.result.n_pass;
    entry.latency = report.latency;
    entry.cache_hits = report.timeline.counter("basket_cache_hits");
    entry.cache_misses = report.timeline.counter("basket_cache_misses");
    entry.baskets_pruned = report.timeline.counter("baskets_pruned");
    entry.baskets_scanned = report.timeline.counter("baskets_scanned");
    entry.scan_shared = report.timeline.counter("scan_shared");
    entry.retries = report.timeline.counter("retries");
    entry.faults_injected = report.timeline.counter("faults_injected");
    entry.backoff_us = report.timeline.counter("backoff_us");
    if let Some(batch) = report.batch {
        entry.batch_id = batch.id;
        entry.batch_members = u64::from(batch.members);
    }
    entry.merge_profile(&report.timeline.profile());
    entry.output = Some(bytes);
}

/// The terminal [`JobState`] an execution error maps to: cancellation
/// and deadline overruns are first-class outcomes, everything else is
/// an ordinary failure.
fn terminal_state_of(e: &Error) -> JobState {
    match e {
        Error::Cancelled(_) => JobState::Cancelled,
        Error::DeadlineExceeded(_) => JobState::DeadlineExceeded,
        _ => JobState::Failed,
    }
}

/// Record a job-fatal execution error into its table entry, bumping
/// the matching lifecycle counter.
fn fail_entry(entry: &mut JobEntry, e: &Error) {
    entry.state = terminal_state_of(e);
    match entry.state {
        JobState::Cancelled => entry.cancelled = 1,
        JobState::DeadlineExceeded => entry.deadline_exceeded = 1,
        _ => {}
    }
    entry.error = Some(e.to_string());
}

/// Execute one admitted single-file job in one piece.
fn run_whole(inner: &SchedInner, id: JobId) {
    let (query, ctl) = {
        let mut jobs = inner.jobs.lock().unwrap();
        match jobs.get_mut(&id) {
            // Cancelled while queued: the entry is already terminal;
            // the stale task is a no-op.
            Some(entry) if entry.state.is_terminal() => return,
            Some(entry) => {
                entry.state = JobState::Running;
                (entry.query.clone(), entry.ctl.clone())
            }
            // Forgotten while queued: nothing to do.
            None => return,
        }
    };
    let job_dir = inner.cfg.work_dir.join(format!("job{id}"));
    let outcome = execute_query(inner, query, &job_dir, &ctl);
    let mut jobs = inner.jobs.lock().unwrap();
    let Some(entry) = jobs.get_mut(&id) else {
        return; // forgotten mid-run
    };
    match outcome {
        Ok((report, bytes)) => finish_entry(entry, &report, bytes),
        Err(e) => fail_entry(entry, &e),
    }
    inner.done_cv.notify_all();
    enforce_retention(&mut jobs, inner.cfg.retained_jobs);
}

/// Execute a flushed batching window as **one shared scan**
/// ([`Coordinator::run_shared`]): a single phase-1 pass over the union
/// of the members' criteria branches serves every member, with
/// scan costs charged once and amortized across members
/// ([`crate::mqo::amortize`]). Panic-isolated like every task; any
/// shared-scan failure (or panic) falls the members back to
/// independent solo runs — batching must never change outcomes, only
/// cost.
fn run_batch(inner: &SchedInner, ids: Vec<JobId>) {
    // Collect the surviving members (forgotten- or cancelled-while-
    // queued ids drop out) and mark them Running under one lock.
    let members: Vec<(JobId, SkimQuery, JobCtl)> = {
        let mut jobs = inner.jobs.lock().unwrap();
        ids.iter()
            .filter_map(|&id| match jobs.get_mut(&id) {
                Some(entry) if !entry.state.is_terminal() => {
                    entry.state = JobState::Running;
                    Some((id, entry.query.clone(), entry.ctl.clone()))
                }
                _ => None,
            })
            .collect()
    };
    match members.len() {
        0 => return,
        // Attrition below two members: no scan left to share.
        1 => return run_whole(inner, members[0].0),
        _ => {}
    }
    let batch_id = inner.next_batch.fetch_add(1, Ordering::Relaxed);
    let batch_dir = inner.cfg.work_dir.join(format!("batch{batch_id}"));
    let queries: Vec<SkimQuery> = members.iter().map(|(_, q, _)| q.clone()).collect();
    let ctls: Vec<JobCtl> = members.iter().map(|(_, _, c)| c.clone()).collect();
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut coord = Coordinator::new(&inner.cfg.storage_root, &batch_dir, None);
        if let Some(cache) = &inner.cache {
            coord = coord.with_basket_cache(cache.clone());
        }
        // Per-member outcomes: a member cancelled (or past deadline)
        // mid-batch detaches with its own terminal error while the
        // rest of the batch completes normally.
        coord
            .run_shared_ctl(&queries, &inner.cfg.deployment, batch_id, &ctls)
            .map(|results| {
                results
                    .into_iter()
                    .map(|result| {
                        result.and_then(|report| {
                            let bytes = std::fs::read(&report.result.output_path)?;
                            Ok((report, bytes))
                        })
                    })
                    .collect::<Vec<Result<_>>>()
            })
    }))
    .unwrap_or_else(|panic| {
        Err(Error::Engine(format!("shared scan panicked: {}", panic_msg(&panic))))
    });
    // The batch directory only staged member outputs; their bytes are
    // in hand (or the batch failed) either way.
    let _ = std::fs::remove_dir_all(&batch_dir);
    match outcome {
        Ok(results) => {
            let mut jobs = inner.jobs.lock().unwrap();
            for ((id, _, _), result) in members.iter().zip(results) {
                if let Some(entry) = jobs.get_mut(id) {
                    match result {
                        Ok((report, bytes)) => finish_entry(entry, &report, bytes),
                        Err(e) => fail_entry(entry, &e),
                    }
                }
            }
            inner.done_cv.notify_all();
            enforce_retention(&mut jobs, inner.cfg.retained_jobs);
        }
        // Fallback: the batch failed as a unit (one member's bad query
        // can poison the shared plan), so isolate the members again
        // and run each solo — individually panic-guarded, individually
        // reported.
        Err(_) => {
            for (id, _, _) in &members {
                run_whole(inner, *id);
            }
        }
    }
}

/// Execute one file task of a decomposed dataset job; the worker that
/// completes the job's last file runs the deterministic merge.
fn run_file(inner: &SchedInner, id: JobId, index: usize) {
    let (sub, ctl) = {
        let mut jobs = inner.jobs.lock().unwrap();
        match jobs.get_mut(&id) {
            // Cancelled (or expired) while other file tasks ran: the
            // remaining queued tasks are no-ops.
            Some(entry) if entry.state.is_terminal() => return,
            Some(entry) => {
                if entry.state == JobState::Queued {
                    entry.state = JobState::Running;
                }
                let file = entry.files[index].clone();
                // The job's deadline covers the whole dataset: this
                // file's view starts where the accumulated virtual
                // latency of finished files left off.
                let ctl = entry.ctl.at_offset(entry.latency);
                (entry.query.for_file(&file, format!("part{index:05}.troot")), ctl)
            }
            // Forgotten while queued: nothing to do.
            None => return,
        }
    };
    let job_dir = inner.cfg.work_dir.join(format!("job{id}_part{index}"));
    // Stage the part on disk (outside the lock): the table holds only
    // its path until the merge.
    let outcome = execute_query(inner, sub, &job_dir, &ctl).and_then(|(report, bytes)| {
        let part_path = inner.cfg.work_dir.join(format!("job{id}_part{index}.part"));
        std::fs::write(&part_path, &bytes)?;
        Ok((report, part_path))
    });
    let mut jobs = inner.jobs.lock().unwrap();
    let Some(entry) = jobs.get_mut(&id) else {
        return; // forgotten mid-run
    };
    if entry.state.is_terminal() {
        // Another file task already ended the job (cancel / deadline):
        // drop this part's output and leave the terminal state alone.
        if let Ok((_, part_path)) = outcome {
            let _ = std::fs::remove_file(part_path);
        }
        return;
    }
    match outcome {
        Ok((report, part_path)) => {
            entry.parts[index] = Some(part_path);
            entry.files_done += 1;
            entry.n_events += report.result.n_events;
            entry.n_pass += report.result.n_pass;
            entry.latency += report.latency;
            entry.cache_hits += report.timeline.counter("basket_cache_hits");
            entry.cache_misses += report.timeline.counter("basket_cache_misses");
            entry.baskets_pruned += report.timeline.counter("baskets_pruned");
            entry.baskets_scanned += report.timeline.counter("baskets_scanned");
            entry.retries += report.timeline.counter("retries");
            entry.faults_injected += report.timeline.counter("faults_injected");
            entry.backoff_us += report.timeline.counter("backoff_us");
            entry.merge_profile(&report.timeline.profile());
        }
        // Cancellation / deadline overrun is job-fatal, not a
        // fault-isolated per-file failure: flip the job terminal now,
        // drop the staged parts, and let the remaining queued file
        // tasks no-op against the terminal state.
        Err(e) if terminal_state_of(&e) != JobState::Failed => {
            fail_entry(entry, &e);
            for part in entry.parts.iter_mut().filter_map(|p| p.take()) {
                let _ = std::fs::remove_file(part);
            }
            inner.done_cv.notify_all();
            return;
        }
        Err(e) => entry.file_errors.push((index, e.to_string())),
    }
    let completed =
        entry.files_done as usize + entry.file_errors.len() == entry.files.len();
    if !completed || entry.merging {
        return;
    }
    entry.merging = true;
    // Take the part paths out (index order preserved) and merge
    // without holding the table lock; pollers observe `Running`
    // meanwhile.
    let parts: Vec<PathBuf> = entry.parts.iter_mut().filter_map(|p| p.take()).collect();
    let n_files = entry.files.len();
    drop(jobs);
    let merged: Result<Vec<u8>> = if parts.is_empty() {
        Err(Error::Engine(format!("all {n_files} dataset files failed")))
    } else {
        // Panic-isolated like the per-file execution: a merge that
        // panics must mark the job Failed, not kill this worker and
        // strand the entry in `Running`.
        let path = inner.cfg.work_dir.join(format!("job{id}_merged.troot"));
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            crate::troot::merge::concat_files(&parts, &path).and_then(|_| {
                let bytes = std::fs::read(&path)?;
                let _ = std::fs::remove_file(&path);
                Ok(bytes)
            })
        }))
        .unwrap_or_else(|panic| {
            Err(Error::Engine(format!("dataset merge panicked: {}", panic_msg(&panic))))
        });
        // The staged parts only fed the merge; drop them either way.
        for part in &parts {
            let _ = std::fs::remove_file(part);
        }
        outcome
    };
    let mut jobs = inner.jobs.lock().unwrap();
    let Some(entry) = jobs.get_mut(&id) else {
        return; // forgotten mid-merge
    };
    match merged {
        Ok(bytes) => {
            entry.state = JobState::Done;
            entry.output = Some(bytes);
        }
        Err(e) => {
            entry.state = JobState::Failed;
            entry.error = Some(e.to_string());
        }
    }
    inner.done_cv.notify_all();
    enforce_retention(&mut jobs, inner.cfg.retained_jobs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::gen::{self, GenConfig};

    fn dataset(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sched_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 600,
                target_branches: 160,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 31,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        dir
    }

    /// Like [`dataset`], plus 3 small part files under `store/`.
    fn multi_dataset(tag: &str) -> PathBuf {
        let dir = dataset(tag);
        std::fs::create_dir_all(dir.join("store")).unwrap();
        for i in 0..3u64 {
            let path = dir.join(format!("store/f{i}.troot"));
            if !path.exists() {
                let cfg = GenConfig {
                    n_events: 300,
                    target_branches: 160,
                    n_hlt: 40,
                    basket_events: 150,
                    codec: Codec::Lz4,
                    seed: 700 + i,
                };
                gen::generate(&cfg, &path).unwrap();
            }
        }
        dir
    }

    #[test]
    fn submit_run_fetch_roundtrip() {
        let root = dataset("roundtrip");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 2;
        let sched = SkimScheduler::new(cfg).unwrap();
        let id = sched
            .submit(gen::higgs_query("events.troot", "out.troot"))
            .unwrap();
        let status = sched.wait(id).unwrap();
        assert_eq!(status.state, JobState::Done);
        assert!(status.n_pass > 0);
        assert!(status.n_pass < status.n_events);
        assert_eq!(status.files_total, 0, "single-file status shape unchanged");
        let bytes = sched.fetch_result(id).unwrap();
        assert!(bytes.len() > 100);
        sched.forget(id);
        assert!(sched.status(id).is_none());
        assert!(sched.fetch_result(id).is_err());
        sched.shutdown();
    }

    #[test]
    fn status_reports_zone_map_prune_counters() {
        let root = dataset("prune");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        let sched = SkimScheduler::new(cfg).unwrap();
        // `event` is the 1_000_000 + ev counter branch; the cut kills
        // the first two of three 200-event baskets, and gen wrote the
        // `.tridx` sidecar the coordinator picks up automatically.
        let query = SkimQuery::new("events.troot", "pruned.troot")
            .keep(&["MET_pt", "event"])
            .with_cut_str("event >= 1000400")
            .unwrap();
        let id = sched.submit(query).unwrap();
        let status = sched.wait(id).unwrap();
        assert_eq!(status.state, JobState::Done, "{:?}", status.error);
        assert_eq!(status.n_pass, 200);
        assert_eq!(status.baskets_pruned, 2);
        assert_eq!(status.baskets_scanned, 1);
        sched.shutdown();
    }

    #[test]
    fn admission_control_rejects_beyond_queue_depth() {
        let root = dataset("admission");
        let mut cfg = ServeConfig::new(&root);
        // No workers: the queue never drains, so rejection is
        // deterministic.
        cfg.workers = 0;
        cfg.queue_depth = 2;
        let sched = SkimScheduler::new(cfg).unwrap();
        let q = || gen::higgs_query("events.troot", "out.troot");
        sched.submit(q()).unwrap();
        sched.submit(q()).unwrap();
        let err = sched.submit(q()).unwrap_err();
        assert!(format!("{err}").contains("queue full"), "{err}");
        sched.shutdown();
    }

    #[test]
    fn completed_entries_are_bounded() {
        let root = dataset("retention");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        cfg.retained_jobs = 2;
        let sched = SkimScheduler::new(cfg).unwrap();
        let mut ids = Vec::new();
        for i in 0..4 {
            let query = gen::higgs_query("events.troot", &format!("r{i}.troot"));
            let id = sched.submit(query).unwrap();
            sched.wait(id).unwrap();
            ids.push(id);
        }
        // The oldest completions were dropped, the newest two survive.
        assert!(sched.status(ids[0]).is_none());
        assert!(sched.status(ids[1]).is_none());
        assert!(sched.status(ids[2]).is_some());
        assert!(sched.status(ids[3]).is_some());
        sched.shutdown();
    }

    #[test]
    fn failed_job_reports_error() {
        let root = dataset("failure");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        let sched = SkimScheduler::new(cfg).unwrap();
        let id = sched
            .submit(gen::higgs_query("missing.troot", "out.troot"))
            .unwrap();
        let status = sched.wait(id).unwrap();
        assert_eq!(status.state, JobState::Failed);
        assert!(status.error.is_some());
        let err = sched.fetch_result(id).unwrap_err();
        assert!(format!("{err}").contains("failed"));
        sched.shutdown();
    }

    #[test]
    fn successive_jobs_share_the_basket_cache() {
        let root = dataset("sharing");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        let sched = SkimScheduler::new(cfg).unwrap();
        let a = sched
            .submit(gen::higgs_query("events.troot", "a.troot"))
            .unwrap();
        let a = sched.wait(a).unwrap();
        let b = sched
            .submit(gen::higgs_query("events.troot", "b.troot"))
            .unwrap();
        let b = sched.wait(b).unwrap();
        assert_eq!(a.state, JobState::Done);
        assert_eq!(b.state, JobState::Done);
        assert!(a.cache_misses > 0, "first job populates the cache");
        assert!(b.cache_hits > 0, "second job must hit the shared cache");
        assert_eq!(a.n_pass, b.n_pass, "cache must not change the selection");
        let stats = sched.cache_stats();
        assert!(stats.hits >= b.cache_hits);
        sched.shutdown();
    }

    #[test]
    fn dataset_job_decomposes_merges_and_reports_files() {
        let root = multi_dataset("ds");
        let mut cfg = ServeConfig::new(&root);
        // Multiple workers: file tasks complete in nondeterministic
        // order, which must not change the merged bytes.
        cfg.workers = 3;
        let sched = SkimScheduler::new(cfg).unwrap();
        let id = sched
            .submit(gen::higgs_query("store/*.troot", "ds.troot"))
            .unwrap();
        let status = sched.wait(id).unwrap();
        assert_eq!(status.state, JobState::Done);
        assert_eq!(status.files_total, 3);
        assert_eq!(status.files_done, 3);
        assert!(status.file_errors.is_empty());
        assert_eq!(status.n_events, 900);
        let merged = sched.fetch_result(id).unwrap();

        // Reference: skim the files one by one through single-file
        // jobs and merge serially, in resolved (sorted) order.
        let mut parts = Vec::new();
        for i in 0..3 {
            let id = sched
                .submit(gen::higgs_query(
                    &format!("store/f{i}.troot"),
                    &format!("ref{i}.troot"),
                ))
                .unwrap();
            sched.wait(id).unwrap();
            parts.push(sched.fetch_result(id).unwrap());
        }
        let ref_path = std::env::temp_dir()
            .join(format!("sched_ref_{}_merge.troot", std::process::id()));
        crate::troot::merge::concat_buffers(parts, &ref_path).unwrap();
        assert_eq!(merged, std::fs::read(&ref_path).unwrap());
        sched.shutdown();
    }

    #[test]
    fn dataset_job_isolates_file_failures() {
        let root = multi_dataset("dsiso");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 2;
        cfg.deployment.fault.max_retries = 0;
        let sched = SkimScheduler::new(cfg).unwrap();
        let mut q = gen::higgs_query("store/f0.troot", "iso.troot");
        q.input = crate::query::DatasetSpec::Files(vec![
            "store/f0.troot".into(),
            "store/absent.troot".into(),
            "store/f2.troot".into(),
        ]);
        let id = sched.submit(q).unwrap();
        let status = sched.wait(id).unwrap();
        assert_eq!(status.state, JobState::Done, "{:?}", status.error);
        assert_eq!(status.files_total, 3);
        assert_eq!(status.files_done, 2);
        assert_eq!(status.file_errors.len(), 1);
        assert!(status.file_errors[0].starts_with("store/absent.troot:"));
        assert!(sched.fetch_result(id).unwrap().len() > 100);
        sched.shutdown();
    }

    fn cut_job(cut: &str, outname: &str) -> SkimQuery {
        SkimQuery::new("events.troot", outname)
            .keep(&["MET_pt", "event", "nJet", "Jet_pt"])
            .with_cut_str(cut)
            .unwrap()
    }

    #[test]
    fn batched_jobs_share_one_scan_and_stay_byte_identical() {
        let root = dataset("batchid");
        let cuts =
            ["MET_pt > 25", "MET_pt > 60", "MET_pt > 25 && nJet >= 2"];

        // Reference: the same three jobs solo (no batching window).
        let mut solo_cfg = ServeConfig::new(&root);
        solo_cfg.workers = 1;
        let solo = SkimScheduler::new(solo_cfg).unwrap();
        let mut solo_bytes = Vec::new();
        let mut solo_pass = Vec::new();
        for (i, cut) in cuts.iter().enumerate() {
            let id = solo.submit(cut_job(cut, &format!("solo{i}.troot"))).unwrap();
            let status = solo.wait(id).unwrap();
            assert_eq!(status.state, JobState::Done, "{:?}", status.error);
            assert_eq!(status.batch_members, 0, "solo runs are not batched");
            assert_eq!(status.scan_shared, 0);
            solo_pass.push(status.n_pass);
            solo_bytes.push(solo.fetch_result(id).unwrap());
        }
        solo.shutdown();

        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 2;
        cfg.batch_window_ms = 60;
        let sched = SkimScheduler::new(cfg).unwrap();
        let ids: Vec<JobId> = cuts
            .iter()
            .enumerate()
            .map(|(i, cut)| sched.submit(cut_job(cut, &format!("b{i}.troot"))).unwrap())
            .collect();
        let statuses: Vec<JobStatus> =
            ids.iter().map(|&id| sched.wait(id).unwrap()).collect();
        for (i, status) in statuses.iter().enumerate() {
            assert_eq!(status.state, JobState::Done, "{:?}", status.error);
            assert_eq!(status.batch_members, 3, "member {i} must report its batch");
            assert_eq!(status.batch_id, statuses[0].batch_id, "one batch for all");
            assert!(status.batch_id > 0);
            assert!(status.scan_shared > 0, "member {i} saw no shared scan");
            assert_eq!(status.n_pass, solo_pass[i], "member {i} selection changed");
            let bytes = sched.fetch_result(ids[i]).unwrap();
            assert_eq!(bytes, solo_bytes[i], "member {i} output differs from solo");
        }
        // The one scan was charged once and amortized: members' scanned
        // baskets sum to the batch total — at most union branches (2:
        // MET_pt, nJet) × clusters (3 for 600 events at 200/basket) —
        // not the ~12 three independent scans would report.
        let scanned: u64 = statuses.iter().map(|s| s.baskets_scanned).sum();
        assert!(scanned > 0);
        assert!(scanned <= 6, "amortized sum must equal one shared scan, got {scanned}");
        sched.shutdown();
    }

    #[test]
    fn lone_job_in_window_degrades_to_solo() {
        let root = dataset("batchsolo");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        cfg.batch_window_ms = 20;
        let sched = SkimScheduler::new(cfg).unwrap();
        let id = sched.submit(cut_job("MET_pt > 25", "lone.troot")).unwrap();
        let status = sched.wait(id).unwrap();
        assert_eq!(status.state, JobState::Done, "{:?}", status.error);
        assert_eq!(status.batch_members, 0, "a batch of one is just a solo run");
        assert_eq!(status.batch_id, 0);
        assert_eq!(status.scan_shared, 0);
        assert!(sched.fetch_result(id).unwrap().len() > 100);
        sched.shutdown();
    }

    #[test]
    fn different_files_do_not_batch() {
        let root = multi_dataset("batchmix");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 2;
        cfg.batch_window_ms = 60;
        let sched = SkimScheduler::new(cfg).unwrap();
        // Same window in time, different resolved files: each lands in
        // its own window and runs solo.
        let a = sched.submit(cut_job("MET_pt > 25", "mix_a.troot")).unwrap();
        let mut q = cut_job("MET_pt > 25", "mix_b.troot");
        q.input = crate::query::DatasetSpec::File("store/f0.troot".into());
        let b = sched.submit(q).unwrap();
        for id in [a, b] {
            let status = sched.wait(id).unwrap();
            assert_eq!(status.state, JobState::Done, "{:?}", status.error);
            assert_eq!(status.batch_members, 0, "mixed files must not batch");
            assert_eq!(status.scan_shared, 0);
        }
        sched.shutdown();
    }

    #[test]
    fn batch_window_rejects_incompatible_deployment() {
        let root = dataset("batchrej");
        let mut cfg = ServeConfig::new(&root);
        cfg.batch_window_ms = 10;
        cfg.deployment = Deployment::skim_root(LinkModel::wan_1g());
        let err = SkimScheduler::new(cfg).unwrap_err();
        assert!(
            format!("{err}").contains("can host shared scans"),
            "{err}"
        );
    }

    #[test]
    fn cancel_while_queued_is_immediate_and_idempotent() {
        let root = dataset("cancelq");
        let mut cfg = ServeConfig::new(&root);
        // No workers: the job deterministically stays Queued until the
        // cancel lands.
        cfg.workers = 0;
        let sched = SkimScheduler::new(cfg).unwrap();
        let id = sched.submit(gen::higgs_query("events.troot", "out.troot")).unwrap();
        let status = sched.cancel(id).unwrap();
        assert_eq!(status.state, JobState::Cancelled);
        assert_eq!(status.cancelled, 1);
        assert!(status.error.as_deref().unwrap().contains("cancelled"));
        // Terminal: wait returns immediately, the result is an error,
        // and cancelling again changes nothing.
        assert_eq!(sched.wait(id).unwrap().state, JobState::Cancelled);
        assert!(sched.fetch_result(id).is_err());
        let again = sched.cancel(id).unwrap();
        assert_eq!(again.state, JobState::Cancelled);
        assert_eq!(again.cancelled, 1);
        assert!(sched.cancel(9999).is_err(), "unknown ids still error");
        sched.shutdown();
    }

    #[test]
    fn cancelled_window_member_is_dropped_from_its_batch() {
        let root = dataset("cancelwin");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 2;
        // Window far longer than the test: flushes only via
        // MAX_BATCH_MEMBERS, so the sequencing is deterministic.
        cfg.batch_window_ms = 60_000;
        let sched = SkimScheduler::new(cfg).unwrap();
        let victim = sched.submit(cut_job("MET_pt > 25", "v.troot")).unwrap();
        let status = sched.cancel(victim).unwrap();
        assert_eq!(status.state, JobState::Cancelled);
        // Fill a fresh window to the brim; it flushes immediately as
        // one batch that must not contain the cancelled member.
        let ids: Vec<JobId> = (0..MAX_BATCH_MEMBERS)
            .map(|i| sched.submit(cut_job("MET_pt > 25", &format!("w{i}.troot"))).unwrap())
            .collect();
        for &id in &ids {
            let status = sched.wait(id).unwrap();
            assert_eq!(status.state, JobState::Done, "{:?}", status.error);
            assert_eq!(status.batch_members, MAX_BATCH_MEMBERS as u64);
        }
        let victim = sched.status(victim).unwrap();
        assert_eq!(victim.state, JobState::Cancelled);
        assert_eq!(victim.batch_id, 0, "cancelled member must not join the batch");
        sched.shutdown();
    }

    #[test]
    fn deadline_exceeded_releases_the_worker_slot() {
        let root = dataset("deadline");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        // Every read stalls 60 virtual seconds; a 1-second deadline
        // trips at the first basket-group boundary. The stall is
        // virtual time, so the no-deadline job still finishes fast in
        // real time — proving the one worker slot was released.
        cfg.deployment.fault.kind = crate::coordinator::FaultKind::StallRead;
        cfg.deployment.fault.fail_prob = 1.0;
        cfg.deployment.fault.stall_s = 60.0;
        cfg.deployment.fault.seed = 7;
        let sched = SkimScheduler::new(cfg).unwrap();
        let doomed = sched
            .submit_with_deadline(gen::higgs_query("events.troot", "doomed.troot"), 1_000)
            .unwrap();
        let status = sched.wait(doomed).unwrap();
        assert_eq!(status.state, JobState::DeadlineExceeded, "{:?}", status.error);
        assert_eq!(status.deadline_exceeded, 1);
        assert!(status.error.as_deref().unwrap().contains("deadline"), "{:?}", status.error);
        assert!(sched.fetch_result(doomed).is_err());
        let free = sched
            .submit(gen::higgs_query("events.troot", "free.troot"))
            .unwrap();
        let status = sched.wait(free).unwrap();
        assert_eq!(status.state, JobState::Done, "{:?}", status.error);
        assert!(status.faults_injected > 0, "stalls were injected");
        sched.shutdown();
    }

    #[test]
    fn drain_finish_completes_queued_work_then_rejects_submissions() {
        let root = dataset("drainfin");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        let sched = SkimScheduler::new(cfg).unwrap();
        let ids: Vec<JobId> = (0..3)
            .map(|i| sched.submit(gen::higgs_query("events.troot", &format!("d{i}.troot"))).unwrap())
            .collect();
        sched.drain(DrainPolicy::Finish);
        for id in ids {
            assert_eq!(sched.status(id).unwrap().state, JobState::Done);
        }
        let err = sched.submit(gen::higgs_query("events.troot", "late.troot")).unwrap_err();
        assert!(format!("{err}").contains("retry later"), "{err}");
        assert!(!sched.is_accepting());
    }

    #[test]
    fn drain_cancel_terminates_queued_work_without_workers() {
        let root = dataset("draincan");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 0;
        let sched = SkimScheduler::new(cfg).unwrap();
        let a = sched.submit(gen::higgs_query("events.troot", "a.troot")).unwrap();
        let b = sched.submit(gen::higgs_query("events.troot", "b.troot")).unwrap();
        sched.drain(DrainPolicy::Cancel);
        assert_eq!(sched.status(a).unwrap().state, JobState::Cancelled);
        assert_eq!(sched.status(b).unwrap().state, JobState::Cancelled);
        assert!(sched.submit(gen::higgs_query("events.troot", "c.troot")).is_err());
    }

    #[test]
    fn traversal_rejected_at_submission() {
        let root = dataset("trav");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 0;
        let sched = SkimScheduler::new(cfg).unwrap();
        for input in ["../../secret", "/etc/passwd"] {
            let err = sched
                .submit(gen::higgs_query(input, "out.troot"))
                .unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{input}: {err}");
            assert!(format!("{err}").contains("escapes the storage root"), "{err}");
        }
        sched.shutdown();
    }
}
