//! The multi-tenant job scheduler: a bounded worker pool over
//! [`SkimJob`]s with admission control and per-job status / result
//! retrieval.
//!
//! Lifecycle of one job (see `ARCHITECTURE.md` § "Serving layer"):
//!
//! 1. **submit** — [`SkimScheduler::submit`] parses nothing (it takes a
//!    validated [`SkimQuery`]) and applies *admission control*: if the
//!    number of queued-but-not-yet-running jobs has reached the
//!    configured [`ServeConfig::queue_depth`], the submission is
//!    rejected immediately (WLCG-style back-pressure: resubmission is
//!    the client's job, not a hidden unbounded queue's).
//! 2. **admit / schedule** — accepted jobs enter a FIFO queue drained
//!    by [`ServeConfig::workers`] worker threads. Each worker drives
//!    the ordinary [`SkimJob`] facade under the service's
//!    [`Deployment`] template, so a scheduled job is indistinguishable
//!    from a one-shot CLI run — including custom pipeline stages and
//!    WLCG retry semantics.
//! 3. **shared-cache scan** — every job runs with the service's shared
//!    [`BasketCache`] installed, so concurrent (and successive) jobs
//!    over the same dataset decompress each basket once.
//! 4. **stream result** — the filtered file's bytes are held in the
//!    job table until fetched ([`SkimScheduler::fetch_result`]) or
//!    dropped ([`SkimScheduler::forget`]).

use super::cache::BasketCache;
use crate::coordinator::Deployment;
use crate::job::SkimJob;
use crate::net::LinkModel;
use crate::query::SkimQuery;
use crate::{Error, Result};
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Job identifier handed out by [`SkimScheduler::submit`].
pub type JobId = u64;

/// Default worker-pool size for a skim service.
pub const DEFAULT_WORKERS: usize = 4;
/// Default admission-control queue depth.
pub const DEFAULT_QUEUE_DEPTH: usize = 32;
/// Default shared basket-cache capacity (decompressed bytes).
pub const DEFAULT_CACHE_BYTES: u64 = 256 * 1000 * 1000;
/// Default cap on completed job entries retained for status/result
/// pickup (abandoned results must not leak forever).
pub const DEFAULT_RETAINED_JOBS: usize = 256;

/// Configuration of one multi-tenant skim service.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory the service's file catalog exports (job inputs are
    /// catalog-relative, exactly as for one-shot jobs).
    pub storage_root: PathBuf,
    /// Scratch directory for per-job outputs (one subdirectory per
    /// job, removed once the result bytes are captured). Defaults to a
    /// unique directory under the system temp dir — deliberately
    /// **outside** the exported catalog, so staged tenant outputs are
    /// never readable through the service's file-serving frames.
    pub work_dir: PathBuf,
    /// Worker threads draining the queue. `0` accepts submissions but
    /// never runs them — useful for tests of admission control.
    pub workers: usize,
    /// Admission control: submissions beyond this many *queued* jobs
    /// are rejected (running jobs do not count).
    pub queue_depth: usize,
    /// Topology template every job runs under (placement, links,
    /// disk, retries). The default is server-side filtering over a
    /// free local link — the real TCP/HTTP response is the output
    /// transfer, so no virtual transfer time should be charged.
    pub deployment: Deployment,
    /// Shared decompressed-basket cache capacity; `0` disables the
    /// cache (every job re-reads and re-decompresses, as before).
    pub cache_bytes: u64,
    /// Cap on *completed* (done/failed) job entries kept in the table
    /// for status/result pickup; beyond it the oldest completed
    /// entries — result bytes included — are dropped, so clients that
    /// abandon jobs cannot leak memory forever.
    pub retained_jobs: usize,
}

impl ServeConfig {
    /// Defaults for serving `storage_root`: [`DEFAULT_WORKERS`]
    /// workers, [`DEFAULT_QUEUE_DEPTH`] queue slots, a
    /// [`DEFAULT_CACHE_BYTES`] shared cache,
    /// [`DEFAULT_RETAINED_JOBS`] retained completions, and server-side
    /// placement over a local link.
    pub fn new(storage_root: impl Into<PathBuf>) -> Self {
        // Per-service-instance scratch, outside the exported catalog.
        static INSTANCE: AtomicU64 = AtomicU64::new(0);
        let instance = INSTANCE.fetch_add(1, Ordering::Relaxed);
        let work_dir = std::env::temp_dir()
            .join(format!("skimroot_serve_{}_{instance}", std::process::id()));
        ServeConfig {
            storage_root: storage_root.into(),
            work_dir,
            workers: DEFAULT_WORKERS,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            deployment: Deployment::server_side(LinkModel::local()),
            cache_bytes: DEFAULT_CACHE_BYTES,
            retained_jobs: DEFAULT_RETAINED_JOBS,
        }
    }
}

/// Coarse job state, as reported over the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for a worker.
    Queued,
    /// A worker is executing the skim.
    Running,
    /// Finished; the filtered bytes await [`SkimScheduler::fetch_result`].
    Done,
    /// The job errored (status carries the message).
    Failed,
}

impl JobState {
    /// Stable wire code (used by the protocol's `JobState` frame).
    pub fn code(self) -> u8 {
        match self {
            JobState::Queued => 0,
            JobState::Running => 1,
            JobState::Done => 2,
            JobState::Failed => 3,
        }
    }

    /// Inverse of [`JobState::code`].
    pub fn from_code(code: u8) -> Result<JobState> {
        Ok(match code {
            0 => JobState::Queued,
            1 => JobState::Running,
            2 => JobState::Done,
            3 => JobState::Failed,
            other => return Err(Error::protocol(format!("bad job state code {other}"))),
        })
    }

    /// Human-readable name.
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }
}

/// Point-in-time view of one job.
#[derive(Debug, Clone)]
pub struct JobStatus {
    /// The id [`SkimScheduler::submit`] returned.
    pub id: JobId,
    /// Current coarse state.
    pub state: JobState,
    /// Events covered (0 until the job finishes).
    pub n_events: u64,
    /// Events passing the selection (0 until the job finishes).
    pub n_pass: u64,
    /// Modeled end-to-end latency in seconds (0 until finished).
    pub latency: f64,
    /// Shared-basket-cache hits this job scored.
    pub cache_hits: u64,
    /// Shared-basket-cache misses this job paid for.
    pub cache_misses: u64,
    /// Failure message when `state` is [`JobState::Failed`].
    pub error: Option<String>,
}

struct JobEntry {
    query: SkimQuery,
    state: JobState,
    output: Option<Vec<u8>>,
    n_events: u64,
    n_pass: u64,
    latency: f64,
    cache_hits: u64,
    cache_misses: u64,
    error: Option<String>,
}

struct SchedInner {
    cfg: ServeConfig,
    cache: Option<Arc<BasketCache>>,
    queue: Mutex<VecDeque<JobId>>,
    queue_cv: Condvar,
    jobs: Mutex<HashMap<JobId, JobEntry>>,
    next_id: AtomicU64,
    stop: AtomicBool,
}

/// The bounded-worker-pool job scheduler (see the module docs).
pub struct SkimScheduler {
    inner: Arc<SchedInner>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl SkimScheduler {
    /// Start a scheduler: spawns [`ServeConfig::workers`] worker
    /// threads immediately.
    pub fn new(cfg: ServeConfig) -> Result<Arc<SkimScheduler>> {
        cfg.deployment.validate()?;
        std::fs::create_dir_all(&cfg.work_dir)?;
        let cache = if cfg.cache_bytes > 0 {
            Some(Arc::new(BasketCache::new(cfg.cache_bytes)))
        } else {
            None
        };
        let n_workers = cfg.workers;
        let inner = Arc::new(SchedInner {
            cfg,
            cache,
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            jobs: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
        });
        let sched = Arc::new(SkimScheduler {
            inner: inner.clone(),
            workers: Mutex::new(Vec::new()),
        });
        let mut workers = sched.workers.lock().unwrap();
        for _ in 0..n_workers {
            let inner = inner.clone();
            workers.push(std::thread::spawn(move || worker_loop(&inner)));
        }
        drop(workers);
        Ok(sched)
    }

    /// The service's shared basket cache, if enabled.
    pub fn basket_cache(&self) -> Option<&Arc<BasketCache>> {
        self.inner.cache.as_ref()
    }

    /// False once [`SkimScheduler::shutdown`] has started: submissions
    /// are rejected and clients should stop retrying (the HTTP layer
    /// maps this to `503` rather than the admission-control `429`).
    pub fn is_accepting(&self) -> bool {
        !self.inner.stop.load(Ordering::Relaxed)
    }

    /// Aggregate shared-cache statistics (zeroed when disabled).
    pub fn cache_stats(&self) -> super::cache::BasketCacheStats {
        self.inner.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Submit a job. Applies admission control: returns an error
    /// without enqueuing when [`ServeConfig::queue_depth`] jobs are
    /// already waiting (the client should back off and resubmit).
    pub fn submit(&self, query: SkimQuery) -> Result<JobId> {
        if self.inner.stop.load(Ordering::Relaxed) {
            return Err(Error::Config("skim service is shutting down".into()));
        }
        let mut queue = self.inner.queue.lock().unwrap();
        if queue.len() >= self.inner.cfg.queue_depth {
            return Err(Error::Config(format!(
                "skim service queue full ({} jobs waiting, depth {}); resubmit later",
                queue.len(),
                self.inner.cfg.queue_depth
            )));
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.inner.jobs.lock().unwrap().insert(
            id,
            JobEntry {
                query,
                state: JobState::Queued,
                output: None,
                n_events: 0,
                n_pass: 0,
                latency: 0.0,
                cache_hits: 0,
                cache_misses: 0,
                error: None,
            },
        );
        queue.push_back(id);
        self.inner.queue_cv.notify_one();
        Ok(id)
    }

    /// Status of job `id`, or `None` for an unknown (or forgotten) id.
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        let jobs = self.inner.jobs.lock().unwrap();
        jobs.get(&id).map(|e| JobStatus {
            id,
            state: e.state,
            n_events: e.n_events,
            n_pass: e.n_pass,
            latency: e.latency,
            cache_hits: e.cache_hits,
            cache_misses: e.cache_misses,
            error: e.error.clone(),
        })
    }

    /// Filtered-file bytes of a [`JobState::Done`] job. The bytes are
    /// handed out **once** — the table keeps only the job's summary
    /// afterwards, so a long-lived service does not accumulate one
    /// filtered file per job (this is what both wire front-ends call).
    /// Errors for unknown ids, already-delivered results, failed jobs
    /// (with the failure message) and jobs still queued or running.
    pub fn fetch_result(&self, id: JobId) -> Result<Vec<u8>> {
        let mut jobs = self.inner.jobs.lock().unwrap();
        let entry = jobs
            .get_mut(&id)
            .ok_or_else(|| Error::Config(format!("no such job {id}")))?;
        match entry.state {
            JobState::Done => entry
                .output
                .take()
                .ok_or_else(|| Error::Config(format!("job {id} result already delivered"))),
            JobState::Failed => Err(Error::Engine(format!(
                "job {id} failed: {}",
                entry.error.as_deref().unwrap_or("unknown error")
            ))),
            state => Err(Error::Config(format!(
                "job {id} not finished (state: {})",
                state.name()
            ))),
        }
    }

    /// Drop a job's table entry entirely (summary included).
    /// [`SkimScheduler::fetch_result`] already releases the result
    /// bytes; this additionally forgets the job's status.
    pub fn forget(&self, id: JobId) {
        self.inner.jobs.lock().unwrap().remove(&id);
    }

    /// Block until job `id` leaves the queue/running states, polling at
    /// millisecond granularity. Returns the final status.
    pub fn wait(&self, id: JobId) -> Result<JobStatus> {
        loop {
            let status = self
                .status(id)
                .ok_or_else(|| Error::Config(format!("no such job {id}")))?;
            match status.state {
                JobState::Done | JobState::Failed => return Ok(status),
                _ => std::thread::sleep(std::time::Duration::from_millis(1)),
            }
        }
    }

    /// Stop the workers and join them. Queued jobs that never ran stay
    /// [`JobState::Queued`] in the table. Idempotent.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.queue_cv.notify_all();
        let mut workers = self.workers.lock().unwrap();
        for handle in workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for SkimScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(inner: &SchedInner) {
    loop {
        let id = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if inner.stop.load(Ordering::Relaxed) {
                    return;
                }
                if let Some(id) = queue.pop_front() {
                    break id;
                }
                let (q, _timeout) = inner
                    .queue_cv
                    .wait_timeout(queue, std::time::Duration::from_millis(50))
                    .unwrap();
                queue = q;
            }
        };
        run_one(inner, id);
    }
}

/// Execute one admitted job through the ordinary [`SkimJob`] facade.
fn run_one(inner: &SchedInner, id: JobId) {
    let query = {
        let mut jobs = inner.jobs.lock().unwrap();
        match jobs.get_mut(&id) {
            Some(entry) => {
                entry.state = JobState::Running;
                entry.query.clone()
            }
            // Forgotten while queued: nothing to do.
            None => return,
        }
    };
    let job_dir = inner.cfg.work_dir.join(format!("job{id}"));
    let mut job = SkimJob::new(query)
        .storage(&inner.cfg.storage_root)
        .client_dir(&job_dir)
        .deployment(inner.cfg.deployment.clone());
    if let Some(cache) = &inner.cache {
        job = job.basket_cache(cache.clone());
    }
    // Panic isolation: a panicking job must neither kill this worker
    // (shrinking the pool for the service's lifetime) nor strand the
    // job in `Running` with clients polling forever.
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        job.run().and_then(|report| {
            let bytes = std::fs::read(&report.result.output_path)?;
            Ok((report, bytes))
        })
    }))
    .unwrap_or_else(|panic| {
        let msg = panic
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string panic>".into());
        Err(Error::Engine(format!("job panicked: {msg}")))
    });
    // The per-job directory only staged the output; the bytes live in
    // the job table now.
    let _ = std::fs::remove_dir_all(&job_dir);
    let mut jobs = inner.jobs.lock().unwrap();
    let Some(entry) = jobs.get_mut(&id) else {
        return; // forgotten mid-run
    };
    match outcome {
        Ok((report, bytes)) => {
            entry.state = JobState::Done;
            entry.n_events = report.result.n_events;
            entry.n_pass = report.result.n_pass;
            entry.latency = report.latency;
            entry.cache_hits = report.timeline.counter("basket_cache_hits");
            entry.cache_misses = report.timeline.counter("basket_cache_misses");
            entry.output = Some(bytes);
        }
        Err(e) => {
            entry.state = JobState::Failed;
            entry.error = Some(e.to_string());
        }
    }
    // Bound retention: abandoned completions (results the client never
    // fetched) must not accumulate forever. Oldest completed entries
    // are dropped first; queued/running jobs are never touched.
    let cap = inner.cfg.retained_jobs.max(1);
    let mut completed: Vec<JobId> = jobs
        .iter()
        .filter(|(_, e)| matches!(e.state, JobState::Done | JobState::Failed))
        .map(|(&id, _)| id)
        .collect();
    if completed.len() > cap {
        completed.sort_unstable();
        for victim in &completed[..completed.len() - cap] {
            jobs.remove(victim);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::gen::{self, GenConfig};

    fn dataset(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sched_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 600,
                target_branches: 160,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 31,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        dir
    }

    #[test]
    fn submit_run_fetch_roundtrip() {
        let root = dataset("roundtrip");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 2;
        let sched = SkimScheduler::new(cfg).unwrap();
        let id = sched
            .submit(gen::higgs_query("events.troot", "out.troot"))
            .unwrap();
        let status = sched.wait(id).unwrap();
        assert_eq!(status.state, JobState::Done);
        assert!(status.n_pass > 0);
        assert!(status.n_pass < status.n_events);
        let bytes = sched.fetch_result(id).unwrap();
        assert!(bytes.len() > 100);
        sched.forget(id);
        assert!(sched.status(id).is_none());
        assert!(sched.fetch_result(id).is_err());
        sched.shutdown();
    }

    #[test]
    fn admission_control_rejects_beyond_queue_depth() {
        let root = dataset("admission");
        let mut cfg = ServeConfig::new(&root);
        // No workers: the queue never drains, so rejection is
        // deterministic.
        cfg.workers = 0;
        cfg.queue_depth = 2;
        let sched = SkimScheduler::new(cfg).unwrap();
        let q = || gen::higgs_query("events.troot", "out.troot");
        sched.submit(q()).unwrap();
        sched.submit(q()).unwrap();
        let err = sched.submit(q()).unwrap_err();
        assert!(format!("{err}").contains("queue full"), "{err}");
        sched.shutdown();
    }

    #[test]
    fn completed_entries_are_bounded() {
        let root = dataset("retention");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        cfg.retained_jobs = 2;
        let sched = SkimScheduler::new(cfg).unwrap();
        let mut ids = Vec::new();
        for i in 0..4 {
            let query = gen::higgs_query("events.troot", &format!("r{i}.troot"));
            let id = sched.submit(query).unwrap();
            sched.wait(id).unwrap();
            ids.push(id);
        }
        // The oldest completions were dropped, the newest two survive.
        assert!(sched.status(ids[0]).is_none());
        assert!(sched.status(ids[1]).is_none());
        assert!(sched.status(ids[2]).is_some());
        assert!(sched.status(ids[3]).is_some());
        sched.shutdown();
    }

    #[test]
    fn failed_job_reports_error() {
        let root = dataset("failure");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        let sched = SkimScheduler::new(cfg).unwrap();
        let id = sched
            .submit(gen::higgs_query("missing.troot", "out.troot"))
            .unwrap();
        let status = sched.wait(id).unwrap();
        assert_eq!(status.state, JobState::Failed);
        assert!(status.error.is_some());
        let err = sched.fetch_result(id).unwrap_err();
        assert!(format!("{err}").contains("failed"));
        sched.shutdown();
    }

    #[test]
    fn successive_jobs_share_the_basket_cache() {
        let root = dataset("sharing");
        let mut cfg = ServeConfig::new(&root);
        cfg.workers = 1;
        let sched = SkimScheduler::new(cfg).unwrap();
        let a = sched
            .submit(gen::higgs_query("events.troot", "a.troot"))
            .unwrap();
        let a = sched.wait(a).unwrap();
        let b = sched
            .submit(gen::higgs_query("events.troot", "b.troot"))
            .unwrap();
        let b = sched.wait(b).unwrap();
        assert_eq!(a.state, JobState::Done);
        assert_eq!(b.state, JobState::Done);
        assert!(a.cache_misses > 0, "first job populates the cache");
        assert!(b.cache_hits > 0, "second job must hit the shared cache");
        assert_eq!(a.n_pass, b.n_pass, "cache must not change the selection");
        let stats = sched.cache_stats();
        assert!(stats.hits >= b.cache_hits);
        sched.shutdown();
    }
}
