//! The multi-tenant skim service: a long-lived server answering many
//! concurrent queries over one storage catalog, sharing scans through
//! a server-side decompressed-basket cache.
//!
//! The one-shot paths (CLI `skim`, `POST /skim`) tear everything down
//! after each job; at "millions of users" scale the serving layer must
//! instead keep the hot state alive and multiplex. This module adds:
//!
//! * [`cache`] — the shared [`BasketCache`]: LRU by decompressed
//!   bytes, keyed `(file, branch, basket)`, single-flight so N
//!   concurrent jobs hitting one cold basket trigger one
//!   read + decompress;
//! * [`sched`] — the [`SkimScheduler`]: a bounded worker pool over
//!   [`crate::SkimJob`]s with admission control (configurable queue
//!   depth) and per-job status / result retrieval;
//! * [`SkimService`] — the wire front-end: the XRootD-like protocol
//!   ([`crate::xrootd::proto`]) grows `SubmitQuery` / `JobStatus` /
//!   `FetchResult` frames, and the service answers those *plus* the
//!   plain file-access frames (a skim server is still a storage
//!   server), in-process or over real TCP;
//! * [`SkimServiceClient`] — the client half over any
//!   [`Wire`](crate::xrootd::client::Wire) (TCP for real deployments,
//!   loopback for virtual-time benches).
//!
//! The DPU HTTP endpoint gains the same capability as `POST /jobs` +
//! `GET /jobs/<id>[/result]` routes — see [`crate::dpu::http`]. The
//! CLI front-end is `skimroot serve`.

pub mod cache;
pub mod sched;

pub use cache::{BasketCache, BasketCacheStats, BasketKey};
pub use sched::{DrainPolicy, JobId, JobState, JobStatus, ServeConfig, SkimScheduler};

use crate::net::DiskModel;
use crate::query::SkimQuery;
use crate::xrootd::client::Wire;
use crate::xrootd::proto::{Request, Response};
use crate::xrootd::server::{serve_requests_tcp, XrdServer};
use crate::{Error, Result};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

/// The multi-tenant skim service: job frames handled by a
/// [`SkimScheduler`], file frames by an embedded [`XrdServer`] over
/// the same catalog (a skim server is still a storage server).
#[derive(Clone)]
pub struct SkimService {
    files: XrdServer,
    sched: Arc<SkimScheduler>,
}

impl SkimService {
    /// Start a service for `cfg`: spawns the scheduler's worker pool;
    /// the embedded file server exports [`ServeConfig::storage_root`]
    /// with the deployment's disk model.
    pub fn new(cfg: ServeConfig) -> Result<SkimService> {
        let files = XrdServer::new(&cfg.storage_root, cfg.deployment.disk);
        let sched = SkimScheduler::new(cfg)?;
        Ok(SkimService { files, sched })
    }

    /// The underlying scheduler (in-process submissions, cache stats).
    pub fn scheduler(&self) -> &Arc<SkimScheduler> {
        &self.sched
    }

    /// The embedded file server (raw byte reads over the catalog).
    pub fn file_server(&self) -> &XrdServer {
        &self.files
    }

    /// Handle one protocol request: job frames go to the scheduler,
    /// everything else to the embedded file server.
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::SubmitQuery { query_json, deadline_ms } => {
                let query = match SkimQuery::from_json_text(&query_json) {
                    Ok(q) => q,
                    Err(e) => return Response::Error { msg: e.to_string() },
                };
                match self.sched.submit_with_deadline(query, deadline_ms) {
                    Ok(job) => Response::JobAccepted { job },
                    Err(e) => Response::Error { msg: e.to_string() },
                }
            }
            Request::JobStatus { job } => match self.sched.status(job) {
                Some(status) => status_frame(&status),
                None => Response::Error { msg: format!("no such job {job}") },
            },
            Request::CancelJob { job } => match self.sched.cancel(job) {
                Ok(status) => status_frame(&status),
                Err(e) => Response::Error { msg: e.to_string() },
            },
            Request::FetchResult { job } => match self.sched.fetch_result(job) {
                Ok(bytes) => Response::Data { data: bytes },
                Err(e) => Response::Error { msg: e.to_string() },
            },
            other => self.files.handle(other),
        }
    }

    /// Serve TCP connections until `stop` goes true (same framing and
    /// shutdown behavior as [`XrdServer::serve_tcp`]).
    pub fn serve_tcp(
        &self,
        listener: std::net::TcpListener,
        stop: Arc<AtomicBool>,
    ) -> std::thread::JoinHandle<()> {
        let service = self.clone();
        serve_requests_tcp(listener, stop, move |req| service.handle(req))
    }

    /// Graceful drain ([`SkimScheduler::drain`]): stop admission —
    /// further submissions get a retriable error — then settle
    /// in-flight work by `policy` and stop the workers. The TCP loop
    /// keeps answering status/fetch frames until its `stop` flag goes
    /// true, so clients can still collect results after the drain.
    pub fn drain(&self, policy: DrainPolicy) {
        self.sched.drain(policy);
    }

    /// Stop the scheduler's worker pool (the TCP loop is stopped via
    /// its `stop` flag).
    pub fn shutdown(&self) {
        self.sched.shutdown();
    }
}

/// Render a [`JobStatus`] as its wire frame (shared by the status and
/// cancel handlers — both answer with the job's current state).
fn status_frame(status: &JobStatus) -> Response {
    Response::JobState {
        state: status.state.code(),
        n_events: status.n_events,
        n_pass: status.n_pass,
        latency_us: (status.latency * 1e6) as u64,
        cache_hits: status.cache_hits,
        cache_misses: status.cache_misses,
        baskets_pruned: status.baskets_pruned,
        baskets_scanned: status.baskets_scanned,
        scan_shared: status.scan_shared,
        batch_id: status.batch_id,
        batch_members: status.batch_members,
        files_done: status.files_done,
        files_total: status.files_total,
        retries: status.retries,
        faults_injected: status.faults_injected,
        backoff_us: status.backoff_us,
        cancelled: status.cancelled,
        deadline_exceeded: status.deadline_exceeded,
        msg: status.error.clone().unwrap_or_default(),
        file_errors: status.file_errors.clone(),
        profile: status
            .profile
            .iter()
            .map(|p| (p.key.clone(), p.stage, p.visited, p.passed, p.cost_us))
            .collect(),
    }
}

/// Convenience: a service over `storage_root` with all-default
/// configuration and an ideal (uncharged) file-server disk.
pub fn service_over(storage_root: impl Into<std::path::PathBuf>) -> Result<SkimService> {
    let mut cfg = ServeConfig::new(storage_root);
    cfg.deployment.disk = DiskModel::ideal();
    SkimService::new(cfg)
}

/// Client half of the job frames, over any [`Wire`] (TCP for real
/// deployments, [`crate::xrootd::LoopbackWire`] for virtual-time
/// benches).
pub struct SkimServiceClient {
    wire: Arc<dyn Wire>,
}

impl SkimServiceClient {
    /// A client speaking over `wire`.
    pub fn new(wire: Arc<dyn Wire>) -> Self {
        SkimServiceClient { wire }
    }

    /// Connect a TCP client to a `skimroot serve` address.
    pub fn connect(addr: &str) -> Result<Self> {
        Ok(SkimServiceClient { wire: Arc::new(crate::xrootd::TcpWire::connect(addr)?) })
    }

    /// Submit a query; returns the service-assigned job id.
    pub fn submit(&self, query: &SkimQuery) -> Result<JobId> {
        self.submit_with_deadline(query, 0)
    }

    /// [`SkimServiceClient::submit`] with a virtual-time deadline in
    /// milliseconds (`0` = none): the service ends the job
    /// [`JobState::DeadlineExceeded`] once its modeled latency passes
    /// the deadline.
    pub fn submit_with_deadline(&self, query: &SkimQuery, deadline_ms: u64) -> Result<JobId> {
        let query_json = query.to_json().to_string();
        match self.wire.call(Request::SubmitQuery { query_json, deadline_ms })? {
            Response::JobAccepted { job } => Ok(job),
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// Cancel `job` on the service
    /// ([`SkimScheduler::cancel`] semantics; idempotent). Returns the
    /// post-cancel status.
    pub fn cancel(&self, job: JobId) -> Result<JobStatus> {
        match self.wire.call(Request::CancelJob { job })? {
            resp @ Response::JobState { .. } => parse_status(job, resp),
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// Fetch the current status of `job`.
    pub fn status(&self, job: JobId) -> Result<JobStatus> {
        match self.wire.call(Request::JobStatus { job })? {
            resp @ Response::JobState { .. } => parse_status(job, resp),
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// List the files a dataset spec resolves to on the service's
    /// catalog (the `ListCatalog` frame) — preview a glob or
    /// `catalog:NAME` before submitting a query over it.
    pub fn list_dataset(&self, spec: &str) -> Result<Vec<String>> {
        match self.wire.call(Request::ListCatalog { spec: spec.into() })? {
            Response::Listing { files } => Ok(files),
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// Fetch the filtered-file bytes of a finished job.
    pub fn fetch_result(&self, job: JobId) -> Result<Vec<u8>> {
        match self.wire.call(Request::FetchResult { job })? {
            Response::Data { data } => Ok(data),
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// Poll until `job` reaches a terminal state, then return
    /// `(status, result bytes)`. Errors if the job failed, was
    /// cancelled or exceeded its deadline (carrying the service's
    /// message and, for the lifecycle outcomes, the state name).
    pub fn wait_result(&self, job: JobId) -> Result<(JobStatus, Vec<u8>)> {
        loop {
            let status = self.status(job)?;
            match status.state {
                JobState::Done => {
                    let bytes = self.fetch_result(job)?;
                    return Ok((status, bytes));
                }
                JobState::Failed | JobState::Cancelled | JobState::DeadlineExceeded => {
                    return Err(Error::Engine(format!(
                        "job {job} {}: {}",
                        status.state.name(),
                        status.error.as_deref().unwrap_or("unknown error")
                    )))
                }
                _ => std::thread::sleep(std::time::Duration::from_millis(2)),
            }
        }
    }
}

/// Decode a [`Response::JobState`] frame into a [`JobStatus`].
fn parse_status(job: JobId, resp: Response) -> Result<JobStatus> {
    let Response::JobState {
        state,
        n_events,
        n_pass,
        latency_us,
        cache_hits,
        cache_misses,
        baskets_pruned,
        baskets_scanned,
        scan_shared,
        batch_id,
        batch_members,
        files_done,
        files_total,
        retries,
        faults_injected,
        backoff_us,
        cancelled,
        deadline_exceeded,
        msg,
        file_errors,
        profile,
    } = resp
    else {
        return Err(Error::protocol("not a JobState frame"));
    };
    Ok(JobStatus {
        id: job,
        state: JobState::from_code(state)?,
        n_events,
        n_pass,
        latency: latency_us as f64 / 1e6,
        cache_hits,
        cache_misses,
        baskets_pruned,
        baskets_scanned,
        scan_shared,
        batch_id,
        batch_members,
        retries,
        faults_injected,
        backoff_us,
        cancelled,
        deadline_exceeded,
        error: if msg.is_empty() { None } else { Some(msg) },
        files_total,
        files_done,
        file_errors,
        profile: profile
            .into_iter()
            .map(|(key, stage, visited, passed, cost_us)| crate::metrics::ConjunctProfile {
                key,
                stage,
                visited,
                passed,
                cost_us,
            })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::gen::{self, GenConfig};

    fn dataset(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("serve_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 600,
                target_branches: 160,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 47,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        dir
    }

    #[test]
    fn tcp_submit_status_fetch_roundtrip() {
        let root = dataset("tcp");
        let service = service_over(&root).unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = service.serve_tcp(listener, stop.clone());

        let client = SkimServiceClient::connect(&addr).unwrap();
        let query = gen::higgs_query("events.troot", "tcp_out.troot");
        let job = client.submit(&query).unwrap();
        let (status, bytes) = client.wait_result(job).unwrap();
        assert_eq!(status.state, JobState::Done);
        assert!(status.n_pass > 0);
        assert!(bytes.len() > 100);

        // The service still answers plain file frames on the same
        // socket protocol.
        let xrd = crate::xrootd::XrdClient::new(client.wire.clone());
        let file = xrd.open("events.troot").unwrap();
        assert!(crate::troot::ReadAt::size(&file).unwrap() > 0);

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
        service.shutdown();
    }

    #[test]
    fn pruned_tcp_job_reports_counters_and_bytes_match_direct_run() {
        let root = dataset("tcpprune");
        let service = service_over(&root).unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = service.serve_tcp(listener, stop.clone());

        // `event` is the counter branch: the cut provably kills the
        // first two of three 200-event baskets, and the `.tridx`
        // sidecar gen wrote is picked up server-side.
        let client = SkimServiceClient::connect(&addr).unwrap();
        let query = SkimQuery::new("events.troot", "pruned_tcp.troot")
            .keep(&["MET_pt", "event"])
            .with_cut_str("event >= 1000400")
            .unwrap();
        let job = client.submit(&query).unwrap();
        let (status, bytes) = client.wait_result(job).unwrap();
        assert_eq!(status.state, JobState::Done, "{:?}", status.error);
        assert_eq!(status.n_pass, 200);
        assert_eq!(status.baskets_pruned, 2, "prune counters must cross the wire");
        assert_eq!(status.baskets_scanned, 1);

        // The same query through the one-shot SkimJob facade must
        // produce byte-identical output.
        let work = std::env::temp_dir()
            .join(format!("serve_pruneclient_{}", std::process::id()));
        std::fs::create_dir_all(&work).unwrap();
        let report = crate::job::SkimJob::new(query)
            .storage(&root)
            .client_dir(&work)
            .run()
            .unwrap();
        assert_eq!(report.timeline.counter("baskets_pruned"), 2);
        assert_eq!(bytes, std::fs::read(&report.result.output_path).unwrap());

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
        service.shutdown();
    }

    #[test]
    fn batched_tcp_jobs_report_batch_info_and_bytes_match_solo() {
        let root = dataset("tcpbatch");
        let mut cfg = ServeConfig::new(&root);
        cfg.deployment.disk = DiskModel::ideal();
        // Generous window: both submissions must land inside it even
        // on a slow CI box.
        cfg.batch_window_ms = 150;
        let service = SkimService::new(cfg).unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = service.serve_tcp(listener, stop.clone());

        let client = SkimServiceClient::connect(&addr).unwrap();
        let mk = |cut: &str, out: &str| {
            SkimQuery::new("events.troot", out)
                .keep(&["MET_pt", "nJet", "Jet_pt"])
                .with_cut_str(cut)
                .unwrap()
        };
        let cuts = ["MET_pt > 25", "MET_pt > 25 && nJet >= 2"];
        let jobs: Vec<JobId> = cuts
            .iter()
            .enumerate()
            .map(|(i, cut)| client.submit(&mk(cut, &format!("wb{i}.troot"))).unwrap())
            .collect();
        for (i, &job) in jobs.iter().enumerate() {
            let (status, bytes) = client.wait_result(job).unwrap();
            assert_eq!(status.state, JobState::Done, "{:?}", status.error);
            assert_eq!(status.batch_members, 2, "batch info must cross the wire");
            assert!(status.batch_id > 0);
            assert!(status.scan_shared > 0, "member {i} saw no shared scan");

            // The same query through the one-shot SkimJob facade must
            // produce byte-identical output.
            let work = std::env::temp_dir()
                .join(format!("serve_batchclient_{}_{i}", std::process::id()));
            std::fs::create_dir_all(&work).unwrap();
            let report = crate::job::SkimJob::new(mk(cuts[i], &format!("ref{i}.troot")))
                .storage(&root)
                .client_dir(&work)
                .run()
                .unwrap();
            assert_eq!(
                bytes,
                std::fs::read(&report.result.output_path).unwrap(),
                "member {i} batched bytes differ from solo"
            );
        }

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
        service.shutdown();
    }

    #[test]
    fn malformed_query_rejected_over_wire() {
        let root = dataset("badquery");
        let service = service_over(&root).unwrap();
        let resp = service.handle(Request::SubmitQuery { query_json: "{not json".into() });
        assert!(matches!(resp, Response::Error { .. }));
        let resp = service.handle(Request::JobStatus { job: 999 });
        assert!(matches!(resp, Response::Error { .. }));
        let resp = service.handle(Request::FetchResult { job: 999 });
        assert!(matches!(resp, Response::Error { .. }));
        service.shutdown();
    }

    #[test]
    fn traversal_queries_rejected_over_wire() {
        // The path-traversal gate at the wire boundary: a remotely
        // submitted query whose input (or dataset entries) escapes
        // the storage root must be rejected as a config error, before
        // any job is enqueued.
        let root = dataset("wiretrav");
        let service = service_over(&root).unwrap();
        for payload in [
            r#"{"input": "../../secret", "output": "o.troot"}"#,
            r#"{"input": "/etc/passwd", "output": "o.troot"}"#,
            r#"{"input": ["events.troot", "../leak"], "output": "o.troot"}"#,
            r#"{"input": "catalog:../escape", "output": "o.troot"}"#,
        ] {
            match service.handle(Request::SubmitQuery { query_json: payload.into() }) {
                Response::Error { msg } => {
                    assert!(msg.contains("escapes the storage root"), "{payload}: {msg}")
                }
                other => panic!("{payload}: expected error, got {other:?}"),
            }
        }
        // Listing requests are gated identically.
        match service.file_server().handle(Request::ListCatalog { spec: "../*".into() }) {
            Response::Error { msg } => {
                assert!(msg.contains("escapes the storage root"), "{msg}")
            }
            other => panic!("expected error, got {other:?}"),
        }
        service.shutdown();
    }

    #[test]
    fn cancel_and_deadline_cross_the_tcp_wire() {
        let root = dataset("tcplifecycle");
        let mut cfg = ServeConfig::new(&root);
        cfg.deployment.disk = DiskModel::ideal();
        // One worker + virtual-time stalls: a deadlined job expires
        // deterministically, then the freed worker runs a clean job.
        cfg.workers = 1;
        cfg.deployment.fault.kind = crate::coordinator::FaultKind::StallRead;
        cfg.deployment.fault.fail_prob = 1.0;
        cfg.deployment.fault.stall_s = 60.0;
        cfg.deployment.fault.seed = 11;
        let service = SkimService::new(cfg).unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = service.serve_tcp(listener, stop.clone());
        let client = SkimServiceClient::connect(&addr).unwrap();

        let doomed = client
            .submit_with_deadline(&gen::higgs_query("events.troot", "doom.troot"), 1_000)
            .unwrap();
        let err = client.wait_result(doomed).unwrap_err();
        assert!(format!("{err}").contains("deadline-exceeded"), "{err}");
        let status = client.status(doomed).unwrap();
        assert_eq!(status.state, JobState::DeadlineExceeded);
        assert_eq!(status.deadline_exceeded, 1, "counter must cross the wire");
        assert!(status.faults_injected > 0, "stall faults must cross the wire");

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
        service.shutdown();

        // Cancellation over the wire, deterministically: a zero-worker
        // service never picks jobs up, so the victim is still Queued
        // when the CancelJob frame lands; a second cancel is an
        // idempotent no-op.
        let mut cfg = ServeConfig::new(&root);
        cfg.deployment.disk = DiskModel::ideal();
        cfg.workers = 0;
        let service = SkimService::new(cfg).unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = service.serve_tcp(listener, stop.clone());
        let client = SkimServiceClient::connect(&addr).unwrap();

        let victim = client.submit(&gen::higgs_query("events.troot", "v.troot")).unwrap();
        let status = client.cancel(victim).unwrap();
        assert_eq!(status.state, JobState::Cancelled);
        assert_eq!(status.cancelled, 1, "counter must cross the wire");
        let again = client.cancel(victim).unwrap();
        assert_eq!(again.state, JobState::Cancelled, "cancel must be idempotent");
        let err = client.wait_result(victim).unwrap_err();
        assert!(format!("{err}").contains("cancelled"), "{err}");
        assert!(client.cancel(99_999).is_err(), "unknown job ids error");

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
        service.shutdown();
    }

    #[test]
    fn dataset_job_over_tcp_with_listing() {
        let root = dataset("tcpds");
        // Two more files so a glob resolves to a 3-file dataset.
        for i in 0..2u64 {
            let path = root.join(format!("extra{i}.troot"));
            if !path.exists() {
                let cfg = GenConfig {
                    n_events: 200,
                    target_branches: 160,
                    n_hlt: 40,
                    basket_events: 100,
                    codec: Codec::Lz4,
                    seed: 90 + i,
                };
                gen::generate(&cfg, &path).unwrap();
            }
        }
        let service = service_over(&root).unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = service.serve_tcp(listener, stop.clone());

        let client = SkimServiceClient::connect(&addr).unwrap();
        // Preview the dataset by spec, then submit a query over it.
        let files = client.list_dataset("*.troot").unwrap();
        assert_eq!(files.len(), 3, "{files:?}");
        let query = gen::higgs_query("*.troot", "ds_tcp.troot");
        let job = client.submit(&query).unwrap();
        let (status, bytes) = client.wait_result(job).unwrap();
        assert_eq!(status.state, JobState::Done);
        assert_eq!(status.files_total, 3);
        assert_eq!(status.files_done, 3);
        assert!(status.file_errors.is_empty());
        assert!(bytes.len() > 100);

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
        service.shutdown();
    }
}
