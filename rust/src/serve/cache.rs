//! The shared server-side **decompressed-basket cache**.
//!
//! A long-lived skim service answers many queries over the same hot
//! datasets. Without sharing, every job re-reads and re-decompresses
//! every criteria basket it touches — the redundancy the CMS
//! Spark-based reduction stack and the real-time HEP query-service
//! vision both exist to eliminate. [`BasketCache`] removes it at the
//! natural unit of work: one *decompressed* basket, keyed by
//! `(file, branch, basket index)`.
//!
//! Properties:
//!
//! * **LRU by bytes** — entries are evicted least-recently-used-first
//!   once the decompressed working set exceeds the configured
//!   capacity. An entry is never evicted by its own insertion (its
//!   single-flight waiters must observe it first), so a basket larger
//!   than the whole capacity is served normally and becomes the LRU
//!   victim of the next insertion.
//! * **Single-flight** — when N concurrent jobs touch the same cold
//!   basket, exactly one performs the fetch + decompress; the other
//!   N−1 block on the in-flight entry and then score *hits*. A failed
//!   load wakes the waiters, and the next caller retries the load.
//! * **First-toucher accounting** — the job that performs the load
//!   charges its own [`crate::metrics::Timeline`] for the transport
//!   and decompression; jobs that hit charge nothing. See
//!   `ARCHITECTURE.md` § "Serving layer" for how this composes with
//!   virtual-time latencies.
//!
//! The engine consults the cache in its `fetch` stage (and in the
//! phase-2 selective fetch) when [`crate::engine::EngineOpts`] carries
//! one — see `engine/pipeline.rs`. The multi-tenant scheduler
//! ([`crate::serve::sched::SkimScheduler`]) installs a single cache
//! into every job it runs.

use crate::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Condvar, Mutex};

/// Cache key: one basket of one branch of one catalog file.
///
/// The components are `Arc<str>` so per-job key construction is two
/// refcount bumps, not two string clones (jobs intern their file and
/// phase-1 branch names once at start).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BasketKey {
    /// Catalog-relative path of the input file.
    pub file: Arc<str>,
    /// Branch name.
    pub branch: Arc<str>,
    /// Basket index within the branch.
    pub basket: u32,
}

/// Effectiveness counters for one [`BasketCache`] (lifetime totals).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct BasketCacheStats {
    /// Lookups served from memory (including single-flight waiters).
    pub hits: u64,
    /// Lookups that had to fetch + decompress.
    pub misses: u64,
    /// Entries evicted to respect the byte capacity.
    pub evictions: u64,
    /// Decompressed bytes inserted over the cache's lifetime.
    pub inserted_bytes: u64,
    /// Decompressed bytes served from memory (re-reads avoided).
    pub hit_bytes: u64,
    /// Decompressed bytes currently resident.
    pub resident_bytes: u64,
    /// Entries currently resident.
    pub entries: u64,
}

impl BasketCacheStats {
    /// Hits as a fraction of all lookups (0 when the cache is unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

enum Slot {
    /// Decompressed bytes plus the recency sequence the entry is filed
    /// under in the LRU index.
    Ready { data: Arc<Vec<u8>>, seq: u64 },
    /// A load is in flight; waiters block on the condvar.
    Pending,
}

#[derive(Default)]
struct CacheState {
    map: HashMap<BasketKey, Slot>,
    /// Recency sequence → key; the smallest sequence is the LRU victim.
    recency: BTreeMap<u64, BasketKey>,
    next_seq: u64,
    resident_bytes: u64,
    stats: BasketCacheStats,
}

/// Shared decompressed-basket cache (see the module docs).
///
/// `Clone`-free by design: share it as `Arc<BasketCache>` (that is
/// what [`crate::engine::EngineOpts::basket_cache`] and the scheduler
/// take).
///
/// ```
/// use skimroot::serve::{BasketCache, BasketKey};
/// use std::sync::Arc;
///
/// let cache = BasketCache::new(1 << 20);
/// let key = BasketKey { file: Arc::from("f.troot"), branch: Arc::from("Jet_pt"), basket: 0 };
/// let (bytes, hit) = cache.get_or_load(key.clone(), || Ok(vec![1, 2, 3])).unwrap();
/// assert!(!hit);
/// let (again, hit) = cache.get_or_load(key, || unreachable!("cached")).unwrap();
/// assert!(hit);
/// assert_eq!(again, bytes);
/// ```
pub struct BasketCache {
    capacity: u64,
    state: Mutex<CacheState>,
    cv: Condvar,
}

impl std::fmt::Debug for BasketCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let stats = self.stats();
        f.debug_struct("BasketCache")
            .field("capacity", &self.capacity)
            .field("stats", &stats)
            .finish()
    }
}

impl BasketCache {
    /// A cache holding at most `capacity` decompressed bytes.
    pub fn new(capacity: u64) -> Self {
        BasketCache {
            capacity: capacity.max(1),
            state: Mutex::new(CacheState::default()),
            cv: Condvar::new(),
        }
    }

    /// Configured capacity in decompressed bytes.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Snapshot of the lifetime counters plus current residency.
    pub fn stats(&self) -> BasketCacheStats {
        let st = self.state.lock().unwrap();
        let mut stats = st.stats;
        stats.resident_bytes = st.resident_bytes;
        stats.entries = st.recency.len() as u64;
        stats
    }

    /// Look up `key`, or run `load` (fetch + decompress) to fill it.
    ///
    /// Returns the decompressed bytes and whether the lookup was a hit.
    /// Single-flight: concurrent callers of a cold key block until the
    /// one in-flight `load` completes, then score hits on its result.
    /// If the load fails the error propagates to the loading caller and
    /// one blocked waiter retries the load.
    pub fn get_or_load<F>(&self, key: BasketKey, load: F) -> Result<(Arc<Vec<u8>>, bool)>
    where
        F: FnOnce() -> Result<Vec<u8>>,
    {
        enum Action {
            Hit(Arc<Vec<u8>>, u64),
            Wait,
            Load,
        }
        let mut st = self.state.lock().unwrap();
        loop {
            let action = match st.map.get(&key) {
                Some(Slot::Ready { data, seq }) => Action::Hit(data.clone(), *seq),
                Some(Slot::Pending) => Action::Wait,
                None => Action::Load,
            };
            match action {
                Action::Hit(data, old_seq) => {
                    let new_seq = st.next_seq;
                    st.next_seq += 1;
                    st.recency.remove(&old_seq);
                    st.recency.insert(new_seq, key.clone());
                    if let Some(Slot::Ready { seq, .. }) = st.map.get_mut(&key) {
                        *seq = new_seq;
                    }
                    st.stats.hits += 1;
                    st.stats.hit_bytes += data.len() as u64;
                    return Ok((data, true));
                }
                Action::Wait => {
                    st = self.cv.wait(st).unwrap();
                }
                Action::Load => break,
            }
        }
        st.map.insert(key.clone(), Slot::Pending);
        st.stats.misses += 1;
        drop(st);

        // Unwind guard: jobs are panic-isolated by the scheduler, so a
        // panic inside `load` must not strand the Pending marker (every
        // future toucher of this key would block forever). The guard
        // removes the marker and wakes waiters unless defused by a
        // normal return.
        struct PendingGuard<'a> {
            cache: &'a BasketCache,
            key: Option<BasketKey>,
        }
        impl Drop for PendingGuard<'_> {
            fn drop(&mut self) {
                if let Some(key) = self.key.take() {
                    let mut st = self.cache.state.lock().unwrap();
                    st.map.remove(&key);
                    self.cache.cv.notify_all();
                }
            }
        }
        let mut guard = PendingGuard { cache: self, key: Some(key.clone()) };
        let result = load();
        guard.key = None; // load returned without unwinding
        drop(guard);
        let mut st = self.state.lock().unwrap();
        match result {
            Ok(bytes) => {
                let data = Arc::new(bytes);
                let seq = st.next_seq;
                st.next_seq += 1;
                st.resident_bytes += data.len() as u64;
                st.stats.inserted_bytes += data.len() as u64;
                st.map.insert(key.clone(), Slot::Ready { data: data.clone(), seq });
                st.recency.insert(seq, key);
                while st.resident_bytes > self.capacity {
                    let victim_seq = match st.recency.keys().next() {
                        Some(&s) => s,
                        None => break,
                    };
                    // Never evict the entry inserted by *this* call:
                    // its single-flight waiters have not observed it
                    // yet (evicting here would serialize them into N
                    // sequential reloads). An over-capacity entry is
                    // the LRU victim of the next insertion instead.
                    if victim_seq == seq {
                        break;
                    }
                    let victim = st.recency.remove(&victim_seq).expect("victim present");
                    if let Some(Slot::Ready { data, .. }) = st.map.remove(&victim) {
                        st.resident_bytes -= data.len() as u64;
                    }
                    st.stats.evictions += 1;
                }
                self.cv.notify_all();
                Ok((data, false))
            }
            Err(e) => {
                // Remove the pending marker so a waiter can retry.
                st.map.remove(&key);
                self.cv.notify_all();
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn key(branch: &str, basket: u32) -> BasketKey {
        BasketKey { file: Arc::from("f.troot"), branch: Arc::from(branch), basket }
    }

    #[test]
    fn hit_after_miss_and_stats() {
        let cache = BasketCache::new(1 << 20);
        let (a, hit) = cache.get_or_load(key("b", 0), || Ok(vec![1u8; 100])).unwrap();
        assert!(!hit);
        assert_eq!(a.len(), 100);
        let (b, hit) = cache.get_or_load(key("b", 0), || panic!("must not load")).unwrap();
        assert!(hit);
        assert!(Arc::ptr_eq(&a, &b));
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses, stats.evictions), (1, 1, 0));
        assert_eq!(stats.resident_bytes, 100);
        assert_eq!(stats.hit_bytes, 100);
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_least_recent_by_bytes() {
        let cache = BasketCache::new(250);
        cache.get_or_load(key("a", 0), || Ok(vec![0u8; 100])).unwrap();
        cache.get_or_load(key("b", 0), || Ok(vec![0u8; 100])).unwrap();
        // Touch "a" so "b" becomes the LRU victim.
        cache.get_or_load(key("a", 0), || panic!("hit expected")).unwrap();
        cache.get_or_load(key("c", 0), || Ok(vec![0u8; 100])).unwrap();
        let stats = cache.stats();
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.resident_bytes, 200);
        // "a" survived, "b" was evicted.
        cache.get_or_load(key("a", 0), || panic!("a must still be cached")).unwrap();
        let loaded = std::cell::Cell::new(false);
        cache
            .get_or_load(key("b", 0), || {
                loaded.set(true);
                Ok(vec![0u8; 10])
            })
            .unwrap();
        assert!(loaded.get(), "b should have been evicted");
    }

    #[test]
    fn oversized_entry_stays_until_next_insertion() {
        let cache = BasketCache::new(10);
        let (data, hit) = cache.get_or_load(key("big", 0), || Ok(vec![0u8; 100])).unwrap();
        assert!(!hit);
        assert_eq!(data.len(), 100);
        // Not evicted within its own insertion: single-flight waiters
        // must still be able to observe the entry.
        assert_eq!(cache.stats().resident_bytes, 100);
        assert_eq!(cache.stats().evictions, 0);
        cache.get_or_load(key("big", 0), || panic!("still resident")).unwrap();
        // The next insertion evicts it as the LRU victim.
        cache.get_or_load(key("small", 0), || Ok(vec![0u8; 4])).unwrap();
        assert_eq!(cache.stats().resident_bytes, 4);
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn failed_load_propagates_and_unblocks() {
        let cache = BasketCache::new(1 << 20);
        let err = cache
            .get_or_load(key("x", 0), || Err(crate::Error::format("boom")))
            .unwrap_err();
        assert!(format!("{err}").contains("boom"));
        // The key is loadable again after the failure.
        let (data, hit) = cache.get_or_load(key("x", 0), || Ok(vec![7u8; 3])).unwrap();
        assert!(!hit);
        assert_eq!(&*data, &vec![7u8; 3]);
    }

    #[test]
    fn panicking_load_does_not_wedge_the_key() {
        let cache = BasketCache::new(1 << 20);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = cache.get_or_load(key("p", 0), || panic!("load blew up"));
        }));
        assert!(r.is_err());
        // No stranded Pending marker: the key is loadable again.
        let (data, hit) = cache.get_or_load(key("p", 0), || Ok(vec![9])).unwrap();
        assert!(!hit);
        assert_eq!(&*data, &vec![9]);
    }

    #[test]
    fn single_flight_loads_once_across_threads() {
        let cache = Arc::new(BasketCache::new(1 << 20));
        let loads = Arc::new(AtomicU64::new(0));
        let n = 8;
        std::thread::scope(|scope| {
            for _ in 0..n {
                let cache = cache.clone();
                let loads = loads.clone();
                scope.spawn(move || {
                    let (data, _) = cache
                        .get_or_load(key("hot", 0), || {
                            loads.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(std::time::Duration::from_millis(30));
                            Ok(vec![42u8; 64])
                        })
                        .unwrap();
                    assert_eq!(data.len(), 64);
                });
            }
        });
        assert_eq!(loads.load(Ordering::Relaxed), 1, "exactly one load");
        let stats = cache.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, n - 1);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cache = BasketCache::new(1 << 20);
        cache.get_or_load(key("b", 0), || Ok(vec![1])).unwrap();
        let (d, hit) = cache.get_or_load(key("b", 1), || Ok(vec![2])).unwrap();
        assert!(!hit);
        assert_eq!(&*d, &vec![2]);
        let other_file =
            BasketKey { file: Arc::from("g.troot"), branch: Arc::from("b"), basket: 0 };
        let (d, hit) = cache.get_or_load(other_file, || Ok(vec![3])).unwrap();
        assert!(!hit);
        assert_eq!(&*d, &vec![3]);
    }
}
