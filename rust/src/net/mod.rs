//! Network & storage-device models (the Wondershaper / testbed
//! substitute, §4 setup).
//!
//! * [`LinkModel`] — a bandwidth + RTT model of one network hop. The
//!   paper evaluates 1 Gbps (remote WAN), 10 Gbps (shared Tier-2
//!   storage), 100 Gbps (dedicated Tier-1) client↔server links, and the
//!   DPU's 128 Gb/s PCIe attachment to the storage host.
//! * [`DiskModel`] — seek + sequential-bandwidth model of the storage
//!   backend, with range coalescing for vector reads (this is why
//!   XRootD's readv beats per-basket random reads in Figure 5a).
//! * [`ThrottledStream`] — a token-bucket pacer over a real
//!   `TcpStream`, used by the `remote_tcp` integration example to show
//!   the same protocol code over genuine sockets.
//!
//! Link/disk models *charge virtual time* to a [`Timeline`]
//! (`metrics`); they never sleep, so WAN-scale experiments run fast and
//! deterministically.

use crate::metrics::{Stage, Timeline};
use std::io::{Read, Write};
use std::time::{Duration, Instant};

/// One directional network hop: `time(bytes) = rtt + bytes / bandwidth`
/// (+ a fixed per-message software overhead).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Name for reports ("1 Gbps WAN").
    pub label: &'static str,
    /// Link bandwidth in bytes per second.
    pub bandwidth_bps: f64,
    /// Round-trip time charged once per request/response exchange.
    pub rtt_s: f64,
    /// Fixed protocol/software overhead per message exchange.
    pub per_msg_s: f64,
}

impl LinkModel {
    /// 1 Gbps dedicated research WAN, ~30 ms RTT — the paper's primary
    /// (most realistic) remote-access case.
    pub fn wan_1g() -> Self {
        LinkModel { label: "1 Gbps WAN", bandwidth_bps: 1e9 / 8.0, rtt_s: 0.030, per_msg_s: 50e-6 }
    }

    /// 10 Gbps shared Tier-2 storage access, metro RTT.
    pub fn shared_10g() -> Self {
        LinkModel {
            label: "10 Gbps shared",
            bandwidth_bps: 10e9 / 8.0,
            rtt_s: 0.002,
            per_msg_s: 50e-6,
        }
    }

    /// 100 Gbps dedicated Tier-1 storage access, LAN RTT.
    pub fn dedicated_100g() -> Self {
        LinkModel {
            label: "100 Gbps dedicated",
            bandwidth_bps: 100e9 / 8.0,
            rtt_s: 0.0002,
            per_msg_s: 20e-6,
        }
    }

    /// DPU ↔ host over PCIe (paper testbed: Gen3 x16 ≈ 128 Gb/s,
    /// sub-microsecond latency).
    pub fn pcie_128g() -> Self {
        LinkModel {
            label: "128 Gb/s PCIe",
            bandwidth_bps: 128e9 / 8.0,
            rtt_s: 2e-6,
            per_msg_s: 2e-6,
        }
    }

    /// In-process / same-host path (server-side filtering reads locally;
    /// only the disk model applies).
    pub fn local() -> Self {
        LinkModel { label: "local", bandwidth_bps: f64::INFINITY, rtt_s: 0.0, per_msg_s: 0.0 }
    }

    /// Seconds to move `bytes` in one request/response exchange.
    pub fn exchange_time(&self, bytes: u64) -> f64 {
        let bw = if self.bandwidth_bps.is_finite() && self.bandwidth_bps > 0.0 {
            bytes as f64 / self.bandwidth_bps
        } else {
            0.0
        };
        self.rtt_s + self.per_msg_s + bw
    }

    /// A copy of this link with bandwidth scaled by `factor` (< 1 slows
    /// it down). Used by the eval harness to shrink the testbed's
    /// bandwidths by the dataset-size ratio so byte-time proportions
    /// match the paper's 5 GB file (latencies are left physical).
    pub fn scaled(mut self, factor: f64) -> Self {
        if self.bandwidth_bps.is_finite() {
            self.bandwidth_bps *= factor;
        }
        self
    }

    /// Charge one exchange of `bytes` to `stage` on `timeline`.
    pub fn charge(&self, timeline: &Timeline, stage: Stage, bytes: u64) {
        timeline.charge(stage, self.exchange_time(bytes));
        timeline.add_bytes(stage, bytes);
        timeline.count("link_round_trips", 1);
    }
}

/// Seek + bandwidth model of the storage backend (HDD-pool-like, as in
/// a WLCG disk pool).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiskModel {
    /// Name for reports ("disk pool", "nvme").
    pub label: &'static str,
    /// Cost of one random positioning (seek + rotational + request).
    pub seek_s: f64,
    /// Sequential read bandwidth.
    pub read_bw_bps: f64,
    /// Ranges closer than this are treated as one sequential run for
    /// *individual* positioned reads (OS readahead window).
    pub coalesce_gap: u64,
    /// Coalescing window for *vector* reads: the server sorts a readv,
    /// merges nearby ranges and streams with deep readahead, so much
    /// larger gaps still behave sequentially (cf. server-side per-basket
    /// reads, which do not get this and pay seeks — the Fig. 5a gap).
    pub readv_gap: u64,
}

impl DiskModel {
    /// Disk-pool default: a DTN-class RAID/disk-pool backend — 5 ms
    /// random positioning, ~1 GB/s aggregate sequential bandwidth.
    pub fn disk_pool() -> Self {
        DiskModel {
            label: "disk pool",
            seek_s: 0.005,
            read_bw_bps: 1e9,
            coalesce_gap: 256 * 1024,
            readv_gap: 4 * 1024 * 1024,
        }
    }

    /// NVMe-backed storage (fast seeks — used in ablations).
    pub fn nvme() -> Self {
        DiskModel { label: "nvme", seek_s: 60e-6, read_bw_bps: 3e9, coalesce_gap: 256 * 1024, readv_gap: 4 * 1024 * 1024 }
    }

    /// Free storage (isolate network effects in ablations).
    pub fn ideal() -> Self {
        DiskModel { label: "ideal", seek_s: 0.0, read_bw_bps: f64::INFINITY, coalesce_gap: 0, readv_gap: 0 }
    }

    /// A copy with sequential bandwidth scaled by `factor` (seeks are
    /// latencies and stay physical). See [`LinkModel::scaled`].
    pub fn scaled(mut self, factor: f64) -> Self {
        if self.read_bw_bps.is_finite() {
            self.read_bw_bps *= factor;
        }
        self
    }

    /// Seconds to serve a single contiguous read.
    pub fn read_time(&self, len: u64) -> f64 {
        let bw = if self.read_bw_bps.is_finite() && self.read_bw_bps > 0.0 {
            len as f64 / self.read_bw_bps
        } else {
            0.0
        };
        self.seek_s + bw
    }

    /// Seconds to serve a vector read: ranges are sorted and coalesced
    /// (as an XRootD server does), paying one seek per resulting run.
    pub fn readv_time(&self, ranges: &[(u64, usize)]) -> f64 {
        if ranges.is_empty() {
            return 0.0;
        }
        let mut sorted: Vec<(u64, u64)> =
            ranges.iter().map(|&(o, l)| (o, l as u64)).collect();
        sorted.sort_unstable();
        let mut runs = 1u64;
        let mut total_bytes = sorted[0].1;
        let mut end = sorted[0].0 + sorted[0].1;
        for &(o, l) in &sorted[1..] {
            if o > end + self.readv_gap {
                runs += 1;
            }
            total_bytes += l;
            end = end.max(o + l);
        }
        let bw = if self.read_bw_bps.is_finite() && self.read_bw_bps > 0.0 {
            total_bytes as f64 / self.read_bw_bps
        } else {
            0.0
        };
        runs as f64 * self.seek_s + bw
    }
}

/// A [`ReadAt`](crate::troot::ReadAt) wrapper that charges a
/// [`DiskModel`] for every access — the *local* storage path of
/// server-side filtering, where no XRootD server (and therefore no
/// readv coalescing and no TTreeCache) sits in front of the disk.
pub struct ModeledStore<R> {
    inner: R,
    disk: DiskModel,
    timeline: Timeline,
    stage: Stage,
    /// End offset of the previous read: sequential (or near-sequential,
    /// within `coalesce_gap`) follow-ups ride OS readahead / the page
    /// cache and skip the seek charge.
    last_end: std::sync::atomic::AtomicU64,
}

impl<R> ModeledStore<R> {
    /// Wrap `inner`, charging `disk` time to `timeline` per access.
    pub fn new(inner: R, disk: DiskModel, timeline: Timeline) -> Self {
        ModeledStore {
            inner,
            disk,
            timeline,
            stage: Stage::BasketFetch,
            last_end: std::sync::atomic::AtomicU64::new(u64::MAX),
        }
    }

    fn charge_read(&self, offset: u64, len: u64) {
        use std::sync::atomic::Ordering;
        let prev = self.last_end.swap(offset + len, Ordering::Relaxed);
        let sequential = prev != u64::MAX
            && offset >= prev.saturating_sub(self.disk.coalesce_gap)
            && offset <= prev + self.disk.coalesce_gap;
        let bw = if self.disk.read_bw_bps.is_finite() && self.disk.read_bw_bps > 0.0 {
            len as f64 / self.disk.read_bw_bps
        } else {
            0.0
        };
        let t = if sequential { bw } else { self.disk.seek_s + bw };
        self.timeline.charge(self.stage, t);
        self.timeline.add_bytes(self.stage, len);
        self.timeline.count("disk_ops", 1);
    }
}

impl<R: crate::troot::ReadAt> crate::troot::ReadAt for ModeledStore<R> {
    fn read_at(&self, offset: u64, len: usize) -> crate::Result<Vec<u8>> {
        self.charge_read(offset, len as u64);
        self.inner.read_at(offset, len)
    }

    fn read_vec(&self, ranges: &[(u64, usize)]) -> crate::Result<Vec<Vec<u8>>> {
        self.timeline.charge(self.stage, self.disk.readv_time(ranges));
        let total: u64 = ranges.iter().map(|&(_, l)| l as u64).sum();
        if let Some(&(o, l)) = ranges.last() {
            self.last_end
                .store(o + l as u64, std::sync::atomic::Ordering::Relaxed);
        }
        self.timeline.add_bytes(self.stage, total);
        self.timeline.count("disk_ops", 1);
        self.inner.read_vec(ranges)
    }

    fn size(&self) -> crate::Result<u64> {
        self.inner.size()
    }
}

/// Token-bucket pacer wrapping a real byte stream — the Wondershaper
/// analogue for the real-TCP integration path. Sleeps to enforce the
/// configured bandwidth (real time, not virtual).
pub struct ThrottledStream<S> {
    inner: S,
    bytes_per_sec: f64,
    /// Available tokens (bytes) and the last refill instant.
    tokens: f64,
    last: Instant,
    burst: f64,
}

impl<S> ThrottledStream<S> {
    /// Pace `inner` at `bytes_per_sec` (infinite = no pacing).
    pub fn new(inner: S, bytes_per_sec: f64) -> Self {
        let burst = (bytes_per_sec / 20.0).max(16.0 * 1024.0);
        ThrottledStream { inner, bytes_per_sec, tokens: burst, last: Instant::now(), burst }
    }

    /// The wrapped stream.
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    fn acquire(&mut self, n: usize) {
        if !self.bytes_per_sec.is_finite() {
            return;
        }
        let now = Instant::now();
        self.tokens = (self.tokens + now.duration_since(self.last).as_secs_f64() * self.bytes_per_sec)
            .min(self.burst);
        self.last = now;
        if self.tokens < n as f64 {
            let deficit = n as f64 - self.tokens;
            let wait = deficit / self.bytes_per_sec;
            std::thread::sleep(Duration::from_secs_f64(wait));
            self.last = Instant::now();
            self.tokens = 0.0;
        } else {
            self.tokens -= n as f64;
        }
    }
}

impl<S: Read> Read for ThrottledStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.acquire(n);
        Ok(n)
    }
}

impl<S: Write> Write for ThrottledStream<S> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        // Pace in chunks so large writes spread over time.
        let chunk = buf.len().min(64 * 1024);
        let n = self.inner.write(&buf[..chunk])?;
        self.acquire(n);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_exchange_time_scales_with_bytes() {
        let l = LinkModel::wan_1g();
        let t1 = l.exchange_time(125_000_000); // 1 s of payload at 1 Gbps
        assert!((t1 - 1.030_05).abs() < 1e-3, "t1={t1}");
        let t0 = l.exchange_time(0);
        assert!((t0 - 0.030_05).abs() < 1e-6);
        // 100 Gbps moves the same bytes ~100x faster (modulo rtt).
        let fast = LinkModel::dedicated_100g().exchange_time(125_000_000);
        assert!(fast < t1 / 50.0, "fast={fast}");
    }

    #[test]
    fn local_link_is_free() {
        let l = LinkModel::local();
        assert_eq!(l.exchange_time(1 << 30), 0.0);
    }

    #[test]
    fn link_charges_timeline() {
        let tl = Timeline::new();
        LinkModel::wan_1g().charge(&tl, Stage::BasketFetch, 1_000_000);
        assert!(tl.stage_total(Stage::BasketFetch) > 0.03);
        assert_eq!(tl.bytes(Stage::BasketFetch), 1_000_000);
        assert_eq!(tl.counter("link_round_trips"), 1);
    }

    #[test]
    fn disk_readv_coalesces_adjacent_ranges() {
        let d = DiskModel::disk_pool();
        // 10 adjacent 64 KiB ranges: one seek.
        let adjacent: Vec<(u64, usize)> =
            (0..10).map(|i| (i * 65_536, 65_536usize)).collect();
        let t_adj = d.readv_time(&adjacent);
        // 10 ranges spread 100 MB apart: ten seeks.
        let spread: Vec<(u64, usize)> =
            (0..10).map(|i| (i * 100_000_000, 65_536usize)).collect();
        let t_spread = d.readv_time(&spread);
        assert!(t_spread > t_adj + 8.0 * d.seek_s, "adj={t_adj} spread={t_spread}");
    }

    #[test]
    fn disk_readv_unsorted_input_ok() {
        let d = DiskModel::disk_pool();
        let a = d.readv_time(&[(0, 100), (1000, 100), (2000, 100)]);
        let b = d.readv_time(&[(2000, 100), (0, 100), (1000, 100)]);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn readv_beats_individual_reads() {
        // The Figure-5a effect: batched vector reads amortize seeks.
        let d = DiskModel::disk_pool();
        let ranges: Vec<(u64, usize)> = (0..50).map(|i| (i * 200_000, 50_000usize)).collect();
        let individual: f64 = ranges.iter().map(|&(_, l)| d.read_time(l as u64)).sum();
        let batched = d.readv_time(&ranges);
        assert!(batched < individual / 2.0, "batched={batched} individual={individual}");
    }

    #[test]
    fn throttle_enforces_bandwidth() {
        // 1 MiB through a 10 MiB/s pipe should take >= ~80 ms.
        let data = vec![0u8; 1 << 20];
        let mut sink = ThrottledStream::new(std::io::sink(), 10.0 * 1024.0 * 1024.0);
        let t0 = Instant::now();
        sink.write_all(&data).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.05, "dt={dt}");
    }

    #[test]
    fn empty_readv_is_free() {
        assert_eq!(DiskModel::disk_pool().readv_time(&[]), 0.0);
    }
}
