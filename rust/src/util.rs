//! Small shared utilities: a deterministic PRNG (PCG32), a seeded
//! property-testing helper (offline stand-in for `proptest`), and
//! human-readable formatting.

/// PCG32 (XSH-RR 64/32) — deterministic, fast, good-enough statistical
/// quality for synthetic data generation and property tests.
///
/// `rand` is not available offline; this is the crate-wide PRNG.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seeded generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seeded generator on a specific stream (independent sequences
    /// from one seed — the per-branch generation trick).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next uniform 32-bit draw.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform 64-bit draw (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift).
    pub fn below(&mut self, bound: u32) -> u32 {
        if bound == 0 {
            return 0;
        }
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with mean `mean` (for pt-like falling spectra).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson via inversion (small means only; fine for nJet ~ O(10)).
    pub fn poisson(&mut self, mean: f64) -> u32 {
        let l = (-mean).exp();
        let mut k = 0u32;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l || k > 10_000 {
                return k;
            }
            k += 1;
        }
    }

    /// Fill `buf` with random bytes.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(4) {
            let v = self.next_u32().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// Random byte string whose compressibility is controlled by
    /// `redundancy` in [0,1]: 0 = incompressible, 1 = highly repetitive.
    pub fn compressible_bytes(&mut self, len: usize, redundancy: f64) -> Vec<u8> {
        let mut out = Vec::with_capacity(len);
        while out.len() < len {
            if !out.is_empty() && self.chance(redundancy) {
                // Copy a back-reference: emulate structured data.
                let max_dist = out.len().min(4096);
                let dist = 1 + self.below(max_dist as u32) as usize;
                let n = (4 + self.below(60)) as usize;
                let n = n.min(len - out.len());
                let start = out.len() - dist;
                for i in 0..n {
                    let b = out[start + (i % dist)];
                    out.push(b);
                }
            } else {
                // Low-entropy literal run (values clustered).
                let base = self.below(64) as u8;
                let n = (1 + self.below(8)) as usize;
                let n = n.min(len - out.len());
                for _ in 0..n {
                    out.push(base.wrapping_add(self.below(16) as u8));
                }
            }
        }
        out
    }
}

/// Seeded randomized property tests — the offline stand-in for proptest.
///
/// Runs `f` over `cases` deterministic seeds; on failure, panics with the
/// failing seed so the case can be replayed exactly.
pub fn prop_check<F: Fn(&mut Pcg32)>(name: &str, cases: u32, f: F) {
    for case in 0..cases {
        let seed = 0x5eed_0000u64 + case as u64;
        let mut rng = Pcg32::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at seed {seed:#x} (case {case}/{cases}): {msg}");
        }
    }
}

/// Format a byte count with binary units.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds the way the paper's tables do.
pub fn human_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

/// Read a little-endian u32 from a byte slice at `off`.
pub fn read_u32(buf: &[u8], off: usize) -> Option<u32> {
    buf.get(off..off + 4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
}

/// Read a little-endian u64 from a byte slice at `off`.
pub fn read_u64(buf: &[u8], off: usize) -> Option<u64> {
    buf.get(off..off + 8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg32_is_deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg32_streams_differ() {
        let mut a = Pcg32::with_stream(42, 1);
        let mut b = Pcg32::with_stream(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Pcg32::new(7);
        for _ in 0..10_000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(9);
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn exp_mean_roughly_correct() {
        let mut rng = Pcg32::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.exp(25.0)).sum::<f64>() / n as f64;
        assert!((mean - 25.0).abs() < 1.0, "mean={mean}");
    }

    #[test]
    fn poisson_mean_roughly_correct() {
        let mut rng = Pcg32::new(13);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.poisson(6.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 6.0).abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn compressible_bytes_len_exact() {
        let mut rng = Pcg32::new(17);
        for len in [0usize, 1, 7, 1024, 65_537] {
            assert_eq!(rng.compressible_bytes(len, 0.7).len(), len);
        }
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn prop_check_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            prop_check("always-fails", 1, |_| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("seed"), "{msg}");
    }
}
