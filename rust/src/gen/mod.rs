//! Synthetic NanoAOD dataset generator (the CMS-data substitute, §4
//! setup).
//!
//! Real NanoAOD is unavailable offline, but skimming cost depends on
//! the file's *structure*, not on real physics values. The generator
//! reproduces the paper's census:
//!
//! * ~**1749 branches** by default: seven jagged particle collections
//!   (Electron, Muon, Jet, Tau, Photon, FatJet, SubJet) with per-object
//!   kinematics/ID variables (plus enough per-collection "user"
//!   variables to hit the target), `n<Collection>` count branches,
//!   event-level scalars (MET, PV, run/event numbers), and
//! * **677 `HLT_*` trigger flags** (the ">650" of §3.1), sparse 0/1
//!   bytes; curated triggers fire at a few percent, the long tail at
//!   per-mille rates;
//! * physics-shaped distributions: falling exponential pT spectra,
//!   Gaussian η, uniform φ, Poisson multiplicities — quantized to a
//!   1/64 grid so baskets compress at realistic ratios;
//! * per-branch deterministic RNG streams: any branch can be
//!   regenerated independently of generation order.
//!
//! The companion [`higgs_query`] builds the paper's evaluation
//! workload: a UCSD-Higgs-style selection with **27 filtering-criteria
//! branches and 89 output branches**.

use crate::compress::Codec;
use crate::query::SkimQuery;
use crate::troot::{BranchDesc, BranchKind, ColumnData, ColumnValues, DType, TRootWriter};
use crate::util::Pcg32;
use crate::Result;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Events to generate.
    pub n_events: u64,
    /// Total branch target (paper: 1749). The schema builder pads
    /// per-collection user variables to reach it exactly.
    pub target_branches: usize,
    /// Number of HLT_* flags (paper: >650).
    pub n_hlt: usize,
    /// Events per basket (ROOT default cluster ~1000 events).
    pub basket_events: u32,
    /// Basket compression codec.
    pub codec: Codec,
    /// Master seed (per-branch streams derive from it).
    pub seed: u64,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            n_events: 100_000,
            target_branches: 1749,
            n_hlt: 677,
            basket_events: 1000,
            codec: Codec::Lz4,
            seed: 0x5eed_cafe,
        }
    }
}

impl GenConfig {
    /// A small configuration for tests (full schema shape, few events).
    pub fn tiny(n_events: u64) -> Self {
        GenConfig { n_events, target_branches: 160, n_hlt: 40, basket_events: 200, ..Default::default() }
    }
}

/// One jagged particle collection: mean multiplicity + variable names.
struct Collection {
    name: &'static str,
    mean_mult: f64,
    /// Core physics variables every collection gets.
    core_vars: &'static [&'static str],
}

const COLLECTIONS: [Collection; 7] = [
    Collection { name: "Electron", mean_mult: 0.4, core_vars: &["pt", "eta", "phi", "mass", "dxy", "dz", "sip3d", "pfRelIso03_all", "cutBased", "charge"] },
    Collection { name: "Muon", mean_mult: 0.5, core_vars: &["pt", "eta", "phi", "mass", "dxy", "dz", "pfRelIso04_all", "tightId", "charge", "nTrackerLayers"] },
    Collection { name: "Jet", mean_mult: 5.5, core_vars: &["pt", "eta", "phi", "mass", "btagDeepFlavB", "jetId", "area", "nConstituents", "chHEF", "neHEF"] },
    Collection { name: "Tau", mean_mult: 0.3, core_vars: &["pt", "eta", "phi", "mass", "dxy", "dz", "idDeepTau", "charge"] },
    Collection { name: "Photon", mean_mult: 0.6, core_vars: &["pt", "eta", "phi", "mass", "pfRelIso03_all", "mvaID", "r9", "sieie"] },
    Collection { name: "FatJet", mean_mult: 0.8, core_vars: &["pt", "eta", "phi", "mass", "msoftdrop", "tau1", "tau2", "tau3", "particleNet_mass", "deepTagMD"] },
    Collection { name: "SubJet", mean_mult: 1.4, core_vars: &["pt", "eta", "phi", "mass", "btagDeepB", "rawFactor"] },
];

const EVENT_SCALARS: [(&str, DType); 12] = [
    ("run", DType::I64),
    ("luminosityBlock", DType::I64),
    ("event", DType::I64),
    ("MET_pt", DType::F32),
    ("MET_phi", DType::F32),
    ("MET_sumEt", DType::F32),
    ("PV_npvs", DType::I32),
    ("PV_z", DType::F32),
    ("fixedGridRhoFastjetAll", DType::F32),
    ("Pileup_nTrueInt", DType::F32),
    ("genWeight", DType::F32),
    ("L1PreFiringWeight_Nom", DType::F32),
];

/// A branch in the generated schema, with its value model.
#[derive(Debug, Clone)]
pub struct GenBranch {
    /// The branch's schema entry.
    pub desc: BranchDesc,
    model: ValueModel,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum ValueModel {
    /// Falling exponential with the given mean (pt, mass, iso...).
    Exp(f64),
    /// Gaussian with sigma (eta, dxy...).
    Normal(f64),
    /// Uniform in [-pi, pi] (phi).
    Phi,
    /// Small non-negative integer-ish (ids, counts, charges).
    SmallInt(u32),
    /// 0/1 flag firing with probability p (triggers, bools).
    Flag(f64),
    /// Monotone counter (run/event numbers).
    Counter,
    /// Multiplicity of the collection at `COLLECTIONS[idx]`.
    CountOf(usize),
}

fn var_model(var: &str) -> ValueModel {
    match var {
        "pt" => ValueModel::Exp(35.0),
        "mass" | "msoftdrop" | "particleNet_mass" => ValueModel::Exp(12.0),
        "eta" => ValueModel::Normal(1.6),
        "phi" => ValueModel::Phi,
        "dxy" | "dz" | "PV_z" => ValueModel::Normal(0.04),
        "sip3d" => ValueModel::Exp(1.5),
        "charge" => ValueModel::SmallInt(2),
        "cutBased" | "jetId" | "idDeepTau" | "nTrackerLayers" | "nConstituents" => {
            ValueModel::SmallInt(15)
        }
        "tightId" => ValueModel::Flag(0.7),
        v if v.contains("Iso") || v.contains("tag") || v.contains("mva")
            || v.contains("tau") || v.contains("EF") || v.contains("r9")
            || v.contains("sieie") =>
        {
            ValueModel::Exp(0.2)
        }
        _ => ValueModel::Exp(10.0),
    }
}

/// Build the full schema for a config: returns branches in ROOT-like
/// order (counts + collections interleaved, then scalars, then HLT).
pub fn schema(cfg: &GenConfig) -> Vec<GenBranch> {
    let mut out = Vec::new();

    // Count how many branches the fixed parts contribute.
    let fixed: usize = COLLECTIONS.iter().map(|c| 1 + c.core_vars.len()).sum::<usize>()
        + EVENT_SCALARS.len()
        + cfg.n_hlt;
    // Distribute extra user variables round-robin over collections.
    let extra_total = cfg.target_branches.saturating_sub(fixed);

    let mut extra_per: Vec<usize> = vec![extra_total / COLLECTIONS.len(); COLLECTIONS.len()];
    for i in 0..extra_total % COLLECTIONS.len() {
        extra_per[i] += 1;
    }

    for (ci, coll) in COLLECTIONS.iter().enumerate() {
        out.push(GenBranch {
            desc: BranchDesc::scalar(format!("n{}", coll.name), DType::I32),
            model: ValueModel::CountOf(ci),
        });
        for var in coll.core_vars {
            out.push(GenBranch {
                desc: BranchDesc::jagged(
                    format!("{}_{var}", coll.name),
                    DType::F32,
                    coll.name,
                ),
                model: var_model(var),
            });
        }
        for x in 0..extra_per[ci] {
            out.push(GenBranch {
                desc: BranchDesc::jagged(
                    format!("{}_userVar{x:03}", coll.name),
                    DType::F32,
                    coll.name,
                ),
                model: ValueModel::Exp(5.0),
            });
        }
    }

    for (name, dtype) in EVENT_SCALARS {
        let model = match name {
            "run" | "luminosityBlock" | "event" => ValueModel::Counter,
            "PV_npvs" => ValueModel::SmallInt(60),
            "MET_pt" | "MET_sumEt" => ValueModel::Exp(40.0),
            "MET_phi" => ValueModel::Phi,
            _ => ValueModel::Exp(1.0),
        };
        out.push(GenBranch { desc: BranchDesc::scalar(name, dtype), model });
    }

    // HLT flags: curated names first (so queries can reference them),
    // then a long tail of versioned paths.
    let curated = crate::query::wildcard::CURATED_TRIGGERS;
    for (i, name) in curated.iter().take(cfg.n_hlt).enumerate() {
        let p = 0.02 + 0.06 * ((i % 5) as f64 / 5.0);
        out.push(GenBranch {
            desc: BranchDesc::scalar(*name, DType::U8),
            model: ValueModel::Flag(p),
        });
    }
    for i in curated.len()..cfg.n_hlt {
        out.push(GenBranch {
            desc: BranchDesc::scalar(format!("HLT_Path{i:03}_v{}", 1 + i % 9), DType::U8),
            model: ValueModel::Flag(0.002),
        });
    }

    out
}

/// Quantize to a 1/64 grid: keeps distribution shape while giving the
/// codecs realistic redundancy to find (real detector data has limited
/// significant digits too).
#[inline]
fn q(v: f64) -> f32 {
    ((v * 64.0).round() / 64.0) as f32
}

fn gen_value(model: ValueModel, rng: &mut Pcg32, ev: u64) -> f64 {
    match model {
        ValueModel::Exp(mean) => rng.exp(mean),
        ValueModel::Normal(sigma) => rng.normal() * sigma,
        ValueModel::Phi => (rng.f64() * 2.0 - 1.0) * std::f64::consts::PI,
        ValueModel::SmallInt(hi) => rng.below(hi + 1) as f64,
        ValueModel::Flag(p) => rng.chance(p) as u8 as f64,
        ValueModel::Counter => 1_000_000.0 + ev as f64,
        ValueModel::CountOf(_) => unreachable!("counts handled separately"),
    }
}

/// Generate the per-collection multiplicities (shared by all of a
/// collection's jagged branches *and* its `n<Coll>` count branch).
fn multiplicities(cfg: &GenConfig, ci: usize) -> Vec<u32> {
    let mut rng = Pcg32::with_stream(cfg.seed, 0x1000 + ci as u64);
    (0..cfg.n_events)
        .map(|_| rng.poisson(COLLECTIONS[ci].mean_mult).min(24))
        .collect()
}

/// Generate one branch's full column, deterministic per branch.
fn gen_column(cfg: &GenConfig, branch_idx: usize, branch: &GenBranch, mults: &[Vec<u32>]) -> ColumnData {
    let mut rng = Pcg32::with_stream(cfg.seed, 0x2000 + branch_idx as u64);
    match branch.desc.kind {
        BranchKind::Scalar => {
            if let ValueModel::CountOf(ci) = branch.model {
                return ColumnData::Scalar(ColumnValues::I32(
                    mults[ci].iter().map(|&m| m as i32).collect(),
                ));
            }
            match branch.desc.dtype {
                DType::F32 => ColumnData::Scalar(ColumnValues::F32(
                    (0..cfg.n_events).map(|ev| q(gen_value(branch.model, &mut rng, ev))).collect(),
                )),
                DType::I32 => ColumnData::Scalar(ColumnValues::I32(
                    (0..cfg.n_events)
                        .map(|ev| gen_value(branch.model, &mut rng, ev) as i32)
                        .collect(),
                )),
                DType::I64 => ColumnData::Scalar(ColumnValues::I64(
                    (0..cfg.n_events)
                        .map(|ev| gen_value(branch.model, &mut rng, ev) as i64)
                        .collect(),
                )),
                DType::U8 => ColumnData::Scalar(ColumnValues::U8(
                    (0..cfg.n_events)
                        .map(|ev| gen_value(branch.model, &mut rng, ev) as u8)
                        .collect(),
                )),
                DType::F64 => ColumnData::Scalar(ColumnValues::F64(
                    (0..cfg.n_events).map(|ev| gen_value(branch.model, &mut rng, ev)).collect(),
                )),
            }
        }
        BranchKind::Jagged => {
            let ci = COLLECTIONS
                .iter()
                .position(|c| c.name == branch.desc.group)
                .expect("known collection");
            let m = &mults[ci];
            let total: usize = m.iter().map(|&x| x as usize).sum();
            let mut offsets = Vec::with_capacity(m.len() + 1);
            let mut values = Vec::with_capacity(total);
            offsets.push(0u32);
            for (ev, &n) in m.iter().enumerate() {
                for _ in 0..n {
                    values.push(q(gen_value(branch.model, &mut rng, ev as u64)));
                }
                offsets.push(values.len() as u32);
            }
            ColumnData::Jagged { offsets, values: ColumnValues::F32(values) }
        }
    }
}

/// Generate a full dataset at `path`, plus its `.tridx` zone-map
/// sidecar (derived for free at write time — selective skims over
/// generated data prune dead baskets out of the box). Returns the
/// write summary.
pub fn generate(cfg: &GenConfig, path: impl AsRef<std::path::Path>) -> Result<crate::troot::writer::WriteSummary> {
    let branches = schema(cfg);
    let mults: Vec<Vec<u32>> = (0..COLLECTIONS.len()).map(|ci| multiplicities(cfg, ci)).collect();
    let mut writer = TRootWriter::new(path.as_ref(), cfg.codec, cfg.basket_events);
    for (i, b) in branches.iter().enumerate() {
        let col = gen_column(cfg, i, b, &mults);
        writer.add_branch(b.desc.clone(), col)?;
    }
    let summary = writer.finalize()?;
    summary.index.save(crate::index::sidecar_path(path.as_ref()))?;
    Ok(summary)
}

/// Generate a multi-file dataset under `dir`: `n_files` files named
/// `partNNN.troot` (each with the full schema shape and a distinct
/// per-file seed stream, each with its `.tridx` zone-map sidecar)
/// plus a `<catalog_name>.catalog` listing them in order — ready for
/// glob (`dir/part*.troot`) or `catalog:<catalog_name>` dataset
/// queries. Returns the per-file write summaries in file order.
pub fn generate_dataset(
    cfg: &GenConfig,
    dir: impl AsRef<std::path::Path>,
    n_files: usize,
    catalog_name: &str,
) -> Result<Vec<crate::troot::writer::WriteSummary>> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut summaries = Vec::with_capacity(n_files);
    let mut listing = String::new();
    for i in 0..n_files {
        let name = format!("part{i:03}.troot");
        let file_cfg = GenConfig {
            seed: cfg
                .seed
                .wrapping_add(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(i as u64 + 1)),
            ..cfg.clone()
        };
        summaries.push(generate(&file_cfg, dir.join(&name))?);
        listing.push_str(&name);
        listing.push('\n');
    }
    std::fs::write(dir.join(format!("{catalog_name}.catalog")), listing)?;
    Ok(summaries)
}

/// The paper's evaluation workload: a UCSD-Higgs-style skim with
/// **27 criteria branches** (1 + 11 jagged + 15 scalar) and **89 output
/// branches**, matching §4's "27 branches are used for filtering and 89
/// are required in the final output".
pub fn higgs_query(input: &str, output: &str) -> SkimQuery {
    let text = format!(
        r#"{{
        "input": "{input}",
        "output": "{output}",
        "branches": [
            "Electron_pt", "Electron_eta", "Electron_phi", "Electron_mass",
            "Electron_dxy", "Electron_dz", "Electron_sip3d",
            "Electron_pfRelIso03_all", "Electron_cutBased", "Electron_charge",
            "Muon_pt", "Muon_eta", "Muon_phi", "Muon_mass",
            "Muon_dxy", "Muon_dz", "Muon_pfRelIso04_all", "Muon_tightId",
            "Muon_charge", "Muon_nTrackerLayers",
            "Jet_pt", "Jet_eta", "Jet_phi", "Jet_mass", "Jet_btagDeepFlavB",
            "Jet_jetId", "Jet_area", "Jet_nConstituents", "Jet_chHEF", "Jet_neHEF",
            "Tau_pt", "Tau_eta", "Tau_phi", "Tau_mass",
            "Photon_pt", "Photon_eta", "Photon_phi", "Photon_mass",
            "FatJet_pt", "FatJet_eta", "FatJet_phi", "FatJet_mass",
            "FatJet_msoftdrop", "FatJet_tau1", "FatJet_tau2",
            "SubJet_pt", "SubJet_eta", "SubJet_phi", "SubJet_mass",
            "nElectron", "nMuon", "nJet", "nTau", "nPhoton", "nFatJet", "nSubJet",
            "MET_pt", "MET_phi",
            "PV_npvs", "PV_z", "fixedGridRhoFastjetAll",
            "Pileup_nTrueInt", "genWeight",
            "run", "luminosityBlock", "event",
            "HLT_*"
        ],
        "force_all": false,
        "selection": {{
            "preselection": [
                {{"branch": "nElectron", "op": ">=", "value": 1}},
                {{"branch": "nJet", "op": ">=", "value": 2}},
                {{"branch": "MET_pt", "op": ">", "value": 20.0}}
            ],
            "objects": [
                {{"collection": "Electron", "min_count": 1, "cuts": [
                    {{"var": "Electron_pt", "op": ">", "value": 25.0}},
                    {{"var": "Electron_eta", "op": "|<|", "value": 2.4}},
                    {{"var": "Electron_dxy", "op": "|<|", "value": 0.05}},
                    {{"var": "Electron_dz", "op": "|<|", "value": 0.1}},
                    {{"var": "Electron_sip3d", "op": "<", "value": 4.0}},
                    {{"var": "Electron_pfRelIso03_all", "op": "<", "value": 0.35}},
                    {{"var": "Electron_cutBased", "op": ">=", "value": 3}}
                ]}},
                {{"collection": "Muon", "min_count": 0, "cuts": [
                    {{"var": "Muon_pt", "op": ">", "value": 20.0}},
                    {{"var": "Muon_eta", "op": "|<|", "value": 2.4}},
                    {{"var": "Muon_pfRelIso04_all", "op": "<", "value": 0.25}},
                    {{"var": "Muon_tightId", "op": "==", "value": 1}}
                ]}}
            ],
            "event": {{
                "ht": {{"jet_pt": "Jet_pt", "object_pt_min": 30.0, "min": 60.0}},
                "triggers_any": [
                    "HLT_IsoMu24", "HLT_IsoMu27", "HLT_Mu50",
                    "HLT_Ele27_WPTight", "HLT_Ele32_WPTight", "HLT_Ele35_WPTight",
                    "HLT_Photon200", "HLT_PFMET120_PFMHT120", "HLT_PFHT1050",
                    "HLT_PFJet500", "HLT_MET105_IsoTrk50", "HLT_TkMu100"
                ]
            }}
        }}
    }}"#
    );
    SkimQuery::from_json_text(&text).expect("higgs query is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::SkimPlan;
    use crate::troot::{LocalFile, TRootReader};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("gen_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn schema_hits_branch_target() {
        let cfg = GenConfig { n_events: 10, ..Default::default() };
        let branches = schema(&cfg);
        assert_eq!(branches.len(), 1749);
        let hlt = branches.iter().filter(|b| b.desc.name.starts_with("HLT_")).count();
        assert_eq!(hlt, 677);
        // No duplicate names.
        let mut names: Vec<&str> = branches.iter().map(|b| b.desc.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 1749);
    }

    #[test]
    fn tiny_file_roundtrips_with_consistent_jaggedness() {
        let cfg = GenConfig::tiny(500);
        let path = tmp("tiny.troot");
        let summary = generate(&cfg, &path).unwrap();
        assert_eq!(summary.n_events, 500);
        assert_eq!(summary.n_branches, 160);
        assert!(summary.compression_ratio() > 1.2, "ratio {}", summary.compression_ratio());

        let r = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
        // nElectron must equal Electron_pt's multiplicities.
        let counts = match r.read_branch_all("nElectron").unwrap() {
            ColumnData::Scalar(ColumnValues::I32(v)) => v,
            other => panic!("{other:?}"),
        };
        let pts = r.read_branch_all("Electron_pt").unwrap();
        let offsets = match &pts {
            ColumnData::Jagged { offsets, .. } => offsets.clone(),
            other => panic!("{other:?}"),
        };
        for (ev, &n) in counts.iter().enumerate() {
            assert_eq!(offsets[ev + 1] - offsets[ev], n as u32, "event {ev}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = GenConfig::tiny(100);
        let p1 = tmp("det1.troot");
        let p2 = tmp("det2.troot");
        generate(&cfg, &p1).unwrap();
        generate(&cfg, &p2).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    }

    #[test]
    fn seeds_change_content() {
        let mut cfg = GenConfig::tiny(100);
        let p1 = tmp("seed1.troot");
        cfg.seed = 1;
        generate(&cfg, &p1).unwrap();
        let p2 = tmp("seed2.troot");
        cfg.seed = 2;
        generate(&cfg, &p2).unwrap();
        assert_ne!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    }

    #[test]
    fn higgs_query_matches_paper_census() {
        // Generate a full-schema (1749-branch) metadata-only check.
        let cfg = GenConfig { n_events: 50, basket_events: 25, ..Default::default() };
        let path = tmp("census.troot");
        generate(&cfg, &path).unwrap();
        let r = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
        assert_eq!(r.meta().branches.len(), 1749);

        let q = higgs_query("census.troot", "out.troot");
        let plan = SkimPlan::build(&q, r.meta()).unwrap();
        assert_eq!(
            plan.criteria_branches.len(),
            27,
            "criteria: {:?}",
            plan.criteria_branches
        );
        assert_eq!(
            plan.output_branches.len(),
            89,
            "outputs ({}): {:?}",
            plan.output_branches.len(),
            plan.output_branches
        );
        assert!(plan.program.fits_kernel());
        // Curated mapping trimmed HLT_* from 677 to the curated set.
        assert!(plan.warnings.iter().any(|w| w.contains("curated")));
    }

    #[test]
    fn generated_files_carry_loadable_sidecars() {
        let cfg = GenConfig::tiny(300);
        let path = tmp("sidecar.troot");
        let summary = generate(&cfg, &path).unwrap();
        let loaded = crate::index::load_sidecar(&path).unwrap().expect("sidecar written");
        assert_eq!(loaded, summary.index);
        // The sidecar is current: its digest matches the data file.
        let r = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
        assert_eq!(loaded.digest, crate::index::meta_digest(r.meta()));
    }

    #[test]
    fn generate_dataset_writes_parts_and_catalog() {
        let dir = tmp("multi_ds");
        let cfg = GenConfig::tiny(120);
        let summaries = generate_dataset(&cfg, &dir, 3, "all").unwrap();
        assert_eq!(summaries.len(), 3);
        for i in 0..3 {
            assert!(dir.join(format!("part{i:03}.troot.tridx")).is_file());
        }
        let listing = std::fs::read_to_string(dir.join("all.catalog")).unwrap();
        assert_eq!(listing, "part000.troot\npart001.troot\npart002.troot\n");
        // Distinct seed streams: the parts differ, but every part
        // carries the same schema.
        let a = std::fs::read(dir.join("part000.troot")).unwrap();
        let b = std::fs::read(dir.join("part001.troot")).unwrap();
        assert_ne!(a, b);
        let r0 = TRootReader::open(LocalFile::open(dir.join("part000.troot")).unwrap()).unwrap();
        let r1 = TRootReader::open(LocalFile::open(dir.join("part001.troot")).unwrap()).unwrap();
        assert_eq!(r0.meta().branches.len(), r1.meta().branches.len());
        assert_eq!(r0.n_events(), 120);
    }

    #[test]
    fn trigger_rates_are_sparse() {
        let cfg = GenConfig::tiny(2000);
        let path = tmp("rates.troot");
        generate(&cfg, &path).unwrap();
        let r = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
        let flags = match r.read_branch_all("HLT_IsoMu24").unwrap() {
            ColumnData::Scalar(ColumnValues::U8(v)) => v,
            other => panic!("{other:?}"),
        };
        let rate = flags.iter().filter(|&&x| x == 1).count() as f64 / flags.len() as f64;
        assert!(rate > 0.001 && rate < 0.2, "rate {rate}");
    }
}
