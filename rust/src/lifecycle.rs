//! Job lifecycle primitives: cooperative cancellation, virtual-time
//! deadlines, the fault taxonomy, and the retry/backoff policy.
//!
//! The WLCG setting the paper reproduces is defined by operational
//! failure ("jobs frequently fail and require resubmission", §1). This
//! module is the substrate the serving stack hardens itself with:
//!
//! * [`CancelToken`] / [`JobCtl`] — a cooperative cancel flag plus an
//!   optional **virtual-time deadline**, threaded through
//!   [`crate::engine::EngineOpts`] and checked at basket-group
//!   boundaries. Deadlines are measured on the job's
//!   [`crate::metrics::Timeline`] (`elapsed()` = real compute +
//!   modeled transport), so a stalled-read fault deterministically
//!   trips a deadline regardless of wall-clock speed.
//! * [`FaultKind`] / [`FaultPlan`] — the fault taxonomy, generalizing
//!   the old read-error-only `FaultConfig`: injected read errors,
//!   corrupt basket frames (bad magic), payload corruption (CRC
//!   mismatch in the decompressor), virtual-time read stalls, and
//!   deterministic fail-at-read-N. All faults derive from the plan's
//!   seeded stream, so every run is reproducible.
//! * [`backoff_delay`] — exponential backoff with deterministic
//!   jitter, charged as *virtual* time on the job timeline (replacing
//!   the old fixed 1 s resubmission constant), so retries both model
//!   WLCG scheduling delay and count toward the job's deadline.
//!
//! Terminal outcomes surface as the dedicated error variants
//! [`crate::Error::Cancelled`] and [`crate::Error::DeadlineExceeded`];
//! retry loops treat both as non-retriable.

use crate::metrics::Timeline;
use crate::util::Pcg32;
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A cooperative cancellation flag, cheaply cloneable and shared
/// between the submitting surface (scheduler, wire, HTTP) and the
/// engine, which polls it at basket-group boundaries.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, uncancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation. Idempotent; the engine observes it at the
    /// next group boundary.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    /// Has cancellation been requested?
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// Per-job lifecycle controls: an optional [`CancelToken`] and an
/// optional virtual-time deadline in seconds. The default (`none`) is
/// a job that can neither be cancelled nor time out — the legacy
/// contract, unchanged.
#[derive(Debug, Clone, Default)]
pub struct JobCtl {
    /// Cooperative cancel flag (`None` = not cancellable).
    pub cancel: Option<CancelToken>,
    /// Deadline in **virtual seconds** on the job timeline (`None` =
    /// no deadline). Compared against `Timeline::elapsed()`, which
    /// sums real compute and modeled transport — including injected
    /// stalls and backoff charges.
    pub deadline_s: Option<f64>,
}

impl JobCtl {
    /// No cancellation, no deadline (the legacy contract).
    pub fn none() -> Self {
        Self::default()
    }

    /// A control block with a fresh token and an optional deadline in
    /// milliseconds (`0` = none, matching the wire encoding).
    pub fn with_deadline_ms(deadline_ms: u64) -> Self {
        JobCtl {
            cancel: Some(CancelToken::new()),
            deadline_s: (deadline_ms > 0).then(|| deadline_ms as f64 / 1000.0),
        }
    }

    /// A view of this control block for a sub-timeline that starts
    /// `consumed` virtual seconds into the job: the cancel token is
    /// shared, the deadline shrinks by what the job has already spent
    /// (may go negative — the next check trips immediately). Used by
    /// the dataset path, where each file runs on a private timeline.
    pub fn at_offset(&self, consumed: f64) -> JobCtl {
        JobCtl {
            cancel: self.cancel.clone(),
            deadline_s: self.deadline_s.map(|d| d - consumed),
        }
    }

    /// Is any control active (worth checking at group boundaries)?
    pub fn is_active(&self) -> bool {
        self.cancel.is_some() || self.deadline_s.is_some()
    }

    /// The cooperative checkpoint: returns [`Error::Cancelled`] when
    /// the token is set, [`Error::DeadlineExceeded`] when the
    /// timeline's virtual clock has passed the deadline, `Ok` else.
    pub fn check(&self, timeline: &Timeline) -> Result<()> {
        if let Some(token) = &self.cancel {
            if token.is_cancelled() {
                return Err(Error::Cancelled("job cancelled".into()));
            }
        }
        if let Some(deadline) = self.deadline_s {
            let elapsed = timeline.elapsed();
            if elapsed > deadline {
                return Err(Error::DeadlineExceeded(format!(
                    "deadline {deadline:.3}s exceeded at {elapsed:.3}s virtual time"
                )));
            }
        }
        Ok(())
    }
}

/// Is this error a terminal lifecycle outcome (never retried)?
pub fn is_terminal(err: &Error) -> bool {
    matches!(err, Error::Cancelled(_) | Error::DeadlineExceeded(_))
}

/// The fault taxonomy: what a [`FaultPlan`] injects into storage reads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The read itself fails with an I/O error (the legacy
    /// `FaultConfig` behavior).
    ReadError,
    /// The read succeeds but the leading bytes are flipped — a basket
    /// frame loses its magic, surfacing as a format/compression error
    /// in the decoder.
    CorruptFrame,
    /// The read succeeds but the trailing payload bytes are flipped —
    /// the decompressor's CRC check fails ("crc mismatch").
    DecompressCorrupt,
    /// The read succeeds after charging a **virtual-time stall** to
    /// the job timeline: data is clean, but the stall counts toward
    /// the job's deadline (a hung storage server, not a corrupt one).
    StallRead,
    /// Deterministically fail the Nth read of the attempt
    /// ([`FaultPlan::fail_at_read`], 1-based) with an I/O error.
    FailAtRead,
}

impl FaultKind {
    /// Every kind, in taxonomy order (the chaos matrix iterates this).
    pub const ALL: [FaultKind; 5] = [
        FaultKind::ReadError,
        FaultKind::CorruptFrame,
        FaultKind::DecompressCorrupt,
        FaultKind::StallRead,
        FaultKind::FailAtRead,
    ];

    /// Canonical CLI name.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::ReadError => "read-error",
            FaultKind::CorruptFrame => "corrupt-frame",
            FaultKind::DecompressCorrupt => "decompress-corrupt",
            FaultKind::StallRead => "stall-read",
            FaultKind::FailAtRead => "fail-at-read",
        }
    }

    /// Parse a CLI name; unknown names list every valid spelling.
    pub fn parse(s: &str) -> Result<FaultKind> {
        FaultKind::ALL
            .into_iter()
            .find(|k| k.name() == s)
            .ok_or_else(|| {
                let valid: Vec<&str> = FaultKind::ALL.iter().map(|k| k.name()).collect();
                Error::Config(format!(
                    "unknown fault kind '{s}'; valid kinds: {}",
                    valid.join(", ")
                ))
            })
    }
}

/// WLCG-style failure injection + retry policy, generalizing the old
/// read-error-only `FaultConfig` into a deterministic fault taxonomy.
///
/// Selection: for probabilistic kinds each read is selected with
/// `fail_prob` from a stream seeded by `(seed, attempt, read index)`;
/// [`FaultKind::FailAtRead`] selects exactly read `fail_at_read`.
/// When `fail_attempts > 0`, injection stops after that many attempts
/// — a guaranteed-recovery fault for byte-identity testing.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    /// What gets injected.
    pub kind: FaultKind,
    /// Probability that any one storage read is selected for injection
    /// (ignored by [`FaultKind::FailAtRead`]).
    pub fail_prob: f64,
    /// For [`FaultKind::FailAtRead`]: the 1-based read index that
    /// fails (`0` disables the kind).
    pub fail_at_read: u64,
    /// Inject only on the first N attempts (`0` = every attempt).
    /// `fail_attempts: 1` makes the first attempt fail and every
    /// resubmission run clean — deterministic retry-success.
    pub fail_attempts: u32,
    /// Virtual seconds charged per stalled read
    /// ([`FaultKind::StallRead`]).
    pub stall_s: f64,
    /// Resubmissions before the job (or dataset file) is abandoned.
    pub max_retries: u32,
    /// Circuit breaker: consecutive failures before retrying stops
    /// early and the failure is surfaced as the degraded per-file
    /// result (`0` = disabled, burn all retries).
    pub breaker_after: u32,
    /// Fault-stream seed (each attempt derives a distinct stream).
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            kind: FaultKind::ReadError,
            fail_prob: 0.0,
            fail_at_read: 0,
            fail_attempts: 0,
            stall_s: 0.0,
            max_retries: 3,
            breaker_after: 0,
            seed: 0,
        }
    }
}

impl FaultPlan {
    /// The legacy constructor shape: seeded read errors with
    /// probability `fail_prob`, `max_retries` resubmissions.
    pub fn read_errors(fail_prob: f64, max_retries: u32, seed: u64) -> Self {
        FaultPlan { fail_prob, max_retries, seed, ..Default::default() }
    }

    /// Does this plan inject anything at all? (Shared-scan batches and
    /// the fault-wrapping fast path key off this.)
    pub fn active(&self) -> bool {
        self.fail_prob > 0.0 || self.fail_at_read > 0
    }

    /// Does this plan inject on the given 1-based attempt?
    pub fn active_on_attempt(&self, attempt: u32) -> bool {
        self.active() && (self.fail_attempts == 0 || attempt <= self.fail_attempts)
    }

    /// Retry-cap check shared by the job and per-file retry loops.
    pub fn retries_exhausted(&self, attempts: u32) -> bool {
        attempts > self.max_retries
    }

    /// Circuit-breaker check: `true` once `consecutive` failures have
    /// hit the configured trip point.
    pub fn breaker_tripped(&self, consecutive: u32) -> bool {
        self.breaker_after > 0 && consecutive >= self.breaker_after
    }
}

/// Exponential backoff with deterministic jitter for resubmission
/// `attempt` (1-based: the delay charged *after* that attempt fails).
///
/// `0.25 s · 2^(attempt-1)`, capped at 8 s, scaled by a jitter factor
/// in `[0.5, 1.5)` drawn from a stream seeded by `(seed, attempt)` —
/// fully deterministic per plan seed, strictly positive, and charged
/// as virtual time so it counts toward deadlines.
pub fn backoff_delay(attempt: u32, seed: u64) -> f64 {
    const BASE_S: f64 = 0.25;
    const CAP_S: f64 = 8.0;
    let exp = attempt.saturating_sub(1).min(10);
    let raw = (BASE_S * (1u64 << exp) as f64).min(CAP_S);
    let mut rng = Pcg32::new(
        seed.wrapping_add(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(attempt as u64)),
    );
    raw * (0.5 + rng.f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_is_sticky_and_shared() {
        let t = CancelToken::new();
        let clone = t.clone();
        assert!(!t.is_cancelled());
        clone.cancel();
        assert!(t.is_cancelled());
        t.cancel(); // idempotent
        assert!(clone.is_cancelled());
    }

    #[test]
    fn ctl_check_reports_cancel_then_deadline() {
        let tl = Timeline::new();
        let ctl = JobCtl::with_deadline_ms(1_000);
        assert!(ctl.is_active());
        assert!(ctl.check(&tl).is_ok());
        tl.charge(crate::metrics::Stage::Other, 2.0);
        assert!(matches!(ctl.check(&tl), Err(Error::DeadlineExceeded(_))));
        // Cancellation takes precedence over the deadline.
        ctl.cancel.as_ref().unwrap().cancel();
        assert!(matches!(ctl.check(&tl), Err(Error::Cancelled(_))));
        assert!(JobCtl::none().check(&tl).is_ok());
    }

    #[test]
    fn fault_kind_parse_roundtrips_and_lists_valid_names() {
        for kind in FaultKind::ALL {
            assert_eq!(FaultKind::parse(kind.name()).unwrap(), kind);
        }
        let err = FaultKind::parse("bit-rot").unwrap_err();
        let msg = format!("{err}");
        for kind in FaultKind::ALL {
            assert!(msg.contains(kind.name()), "missing {} in: {msg}", kind.name());
        }
    }

    #[test]
    fn fault_plan_attempt_gating() {
        let plan = FaultPlan { fail_prob: 1.0, fail_attempts: 2, ..Default::default() };
        assert!(plan.active());
        assert!(plan.active_on_attempt(1));
        assert!(plan.active_on_attempt(2));
        assert!(!plan.active_on_attempt(3));
        let always = FaultPlan { fail_prob: 1.0, ..Default::default() };
        assert!(always.active_on_attempt(999));
        assert!(!FaultPlan::default().active());
        assert!(FaultPlan { fail_at_read: 3, ..Default::default() }.active());
    }

    #[test]
    fn breaker_and_retry_caps() {
        let plan = FaultPlan { max_retries: 2, breaker_after: 3, ..Default::default() };
        assert!(!plan.retries_exhausted(2));
        assert!(plan.retries_exhausted(3));
        assert!(!plan.breaker_tripped(2));
        assert!(plan.breaker_tripped(3));
        assert!(!FaultPlan::default().breaker_tripped(u32::MAX));
    }

    #[test]
    fn backoff_grows_exponentially_with_bounded_jitter() {
        for seed in [0u64, 7, 0xdead_beef] {
            let mut prev_cap = 0.0f64;
            for attempt in 1..=6 {
                let d = backoff_delay(attempt, seed);
                let raw = (0.25 * (1u64 << (attempt - 1)) as f64).min(8.0);
                assert!(d >= raw * 0.5 && d < raw * 1.5, "attempt {attempt}: {d}");
                assert!(d > prev_cap * 0.49, "not growing: {d} after {prev_cap}");
                prev_cap = raw;
            }
            // Deterministic per (seed, attempt).
            assert_eq!(backoff_delay(3, seed), backoff_delay(3, seed));
        }
        // Capped.
        assert!(backoff_delay(40, 1) < 8.0 * 1.5);
    }
}
