//! The job coordinator: deploys a skim across the testbed topology and
//! produces the paper's comparison rows.
//!
//! A [`Deployment`] is an **open** description of one topology: where
//! filtering runs ([`Placement`]), over which links and storage
//! backend, with which execution policy (two-phase, vectorized eval,
//! cache) and — for DPU placements — how many DPU shards
//! (`fan_out`). Build one with [`Deployment::builder`], or use the
//! four paper methods, which are thin presets over the same builder:
//!
//! | preset | data path | filter on | decompress | TTreeCache |
//! |---|---|---|---|---|
//! | [`Deployment::client_legacy`] | storage → client over WAN | client (per-event, single-phase) | client CPU | yes |
//! | [`Deployment::client_opt`] | storage → client over WAN | client (two-phase, vectorized) | client CPU | yes |
//! | [`Deployment::server_side`] | local disk | server (two-phase, vectorized) | server CPU | **no** (local access) |
//! | [`Deployment::skim_root`] | storage → DPU over PCIe | DPU ARM cores | **hw engine** | yes |
//!
//! [`Mode`] survives as the preset catalog (CLI names, figure rows);
//! the execution path itself dispatches on [`Placement`] only, so new
//! topologies (e.g. multi-DPU fan-out, NVMe server-side) need no new
//! enum variant — just a builder call.
//!
//! All deployments ship the filtered file to the client at the end (a
//! no-op for client placements, where the output is already there).
//!
//! The coordinator also models WLCG's operational reality (§1: "jobs
//! frequently fail and require resubmission"): a [`FaultPlan`]
//! injects storage faults from a seeded taxonomy — read errors,
//! corrupt frames, CRC-breaking payload corruption, virtual-time read
//! stalls, deterministic fail-at-read-N ([`crate::lifecycle`]). Failed
//! attempts burn their time on the job timeline and the job is
//! resubmitted after exponential backoff with deterministic jitter
//! (charged as virtual time, so retries count toward deadlines), up
//! to [`FaultPlan::max_retries`] — or fewer when the per-file circuit
//! breaker ([`FaultPlan::breaker_after`]) trips first. Jobs carry a
//! [`crate::lifecycle::JobCtl`]: cooperative cancellation and
//! virtual-time deadlines are terminal (never retried).

pub mod eval;

use crate::dpu::{DpuCluster, DpuConfig, DpuNode};
use crate::engine::{DecompMode, EngineOpts, SkimEngine, SkimResult, StageReg};
use crate::lifecycle::{self, JobCtl};
pub use crate::lifecycle::{FaultKind, FaultPlan};
use crate::metrics::{Node, Stage, Timeline};
use crate::net::{DiskModel, LinkModel};
use crate::query::SkimQuery;
use crate::runtime::SkimRuntime;
use crate::troot::{LocalFile, ReadAt};
use crate::util::Pcg32;
use crate::xrootd::{LoopbackWire, XrdClient, XrdServer};
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Where the filtering engine runs.
#[derive(Debug, Clone)]
pub enum Placement {
    /// On the requesting client: data crosses the client↔storage link.
    Client,
    /// On the storage server itself: local reads (no XRootD in the
    /// path, no TTreeCache — §4), output shipped to the client.
    Server,
    /// Near-storage, on DPU(s) attached to the storage host over PCIe.
    Dpu(DpuConfig),
}

/// The paper's four methods, kept as named presets over the
/// [`Deployment`] builder (CLI `--mode` names, figure row labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Unoptimized client-side filtering: single-phase, per-event
    /// interpreter (the hand-written-macro baseline).
    ClientLegacy,
    /// Client-side with SkimROOT's two-phase model + vectorized eval
    /// ("Client Opt" in Figure 4).
    ClientOpt,
    /// Filtering on the storage server itself (local reads, no cache).
    ServerSide,
    /// Near-storage filtering on the DPU.
    SkimRoot,
}

impl Mode {
    /// Every preset, in figure-row order.
    pub const ALL: [Mode; 4] =
        [Mode::ClientLegacy, Mode::ClientOpt, Mode::ServerSide, Mode::SkimRoot];

    /// The preset's canonical CLI / figure-row name.
    pub fn name(self) -> &'static str {
        match self {
            Mode::ClientLegacy => "client-legacy",
            Mode::ClientOpt => "client-opt",
            Mode::ServerSide => "server-side",
            Mode::SkimRoot => "skimroot",
        }
    }

    /// Accepted aliases for each preset (CLI convenience).
    pub fn aliases(self) -> &'static [&'static str] {
        match self {
            Mode::ClientLegacy => &["client", "legacy"],
            Mode::ClientOpt => &["opt"],
            Mode::ServerSide => &["server"],
            Mode::SkimRoot => &["dpu"],
        }
    }

    /// Parse a preset name or alias. Unknown names produce a
    /// [`Error::Config`] listing every valid spelling, derived from
    /// [`Mode::ALL`] so new presets are picked up automatically.
    pub fn parse(s: &str) -> Result<Mode> {
        for mode in Mode::ALL {
            if s == mode.name() || mode.aliases().contains(&s) {
                return Ok(mode);
            }
        }
        let valid: Vec<String> = Mode::ALL
            .iter()
            .map(|m| {
                if m.aliases().is_empty() {
                    m.name().to_string()
                } else {
                    format!("{} (aliases: {})", m.name(), m.aliases().join(", "))
                }
            })
            .collect();
        Err(Error::Config(format!(
            "unknown mode '{s}'; valid modes: {}",
            valid.join("; ")
        )))
    }

    /// The preset deployment for this mode over `link`.
    pub fn deployment(self, link: LinkModel) -> Deployment {
        let b = Deployment::builder().name(self.name()).link(link);
        match self {
            Mode::ClientLegacy => b
                .placement(Placement::Client)
                .two_phase(false)
                .use_pjrt(false)
                .build(),
            Mode::ClientOpt => b.placement(Placement::Client).build(),
            Mode::ServerSide => b.placement(Placement::Server).build(),
            Mode::SkimRoot => b.placement(Placement::Dpu(DpuConfig::default())).build(),
        }
        .expect("presets are valid")
    }
}

/// Full testbed description for one job. Open: build any topology with
/// [`Deployment::builder`]; the paper's four methods are presets.
#[derive(Debug, Clone)]
pub struct Deployment {
    /// Row label for reports (`client-legacy`, `skimroot`, or any
    /// custom name).
    pub name: String,
    /// Where the filtering engine runs.
    pub placement: Placement,
    /// Client ↔ storage-site link (the 1/10/100 Gbps axis of Fig. 4a).
    pub client_link: LinkModel,
    /// Storage backend behind the XRootD server.
    pub disk: DiskModel,
    /// WLCG-style failure injection + retry policy (the fault
    /// taxonomy: [`crate::lifecycle::FaultPlan`]).
    pub fault: FaultPlan,
    /// TTreeCache capacity for remote clients (`None` disables).
    /// Server placement never uses a cache (§4: "TTreeCache does not
    /// function for local ROOT file access"); DPU placements use the
    /// capacity in their [`DpuConfig`].
    pub cache_bytes: Option<usize>,
    /// Two-phase execution (§3.2) vs legacy fetch-everything
    /// (client/server placements; DPU nodes are always two-phase).
    pub two_phase: bool,
    /// Vectorized PJRT kernel vs per-event interpreter (client/server
    /// placements; DPU nodes always prefer the kernel).
    pub use_pjrt: bool,
    /// Number of DPU shards for [`Placement::Dpu`]: `1` is the paper's
    /// single-DPU testbed, `> 1` fans the job out across N DPU nodes
    /// sharing one storage server, split by event range.
    pub fan_out: usize,
    /// Selectivity-adaptive interpreter execution (off by default;
    /// client/server placements only — DPU nodes prefer the kernel).
    pub adaptive: crate::engine::AdaptiveOpts,
    /// Profile-guided fused cut kernels ([`crate::engine::EngineOpts::fuse`];
    /// off by default, interpreter placements only — same scope as
    /// `adaptive`, with which it composes).
    pub fuse: bool,
}

impl Deployment {
    /// Start building a custom topology.
    pub fn builder() -> DeploymentBuilder {
        DeploymentBuilder::default()
    }

    /// Preset-by-enum (back-compat constructor used by the eval
    /// harness and tests): `Deployment::new(Mode::SkimRoot, link)`.
    pub fn new(mode: Mode, client_link: LinkModel) -> Self {
        mode.deployment(client_link)
    }

    /// The unoptimized client-side baseline (paper "Client").
    pub fn client_legacy(link: LinkModel) -> Self {
        Mode::ClientLegacy.deployment(link)
    }

    /// Client-side with two-phase + vectorized eval ("Client Opt").
    pub fn client_opt(link: LinkModel) -> Self {
        Mode::ClientOpt.deployment(link)
    }

    /// Filtering on the storage server (local reads, no cache).
    pub fn server_side(link: LinkModel) -> Self {
        Mode::ServerSide.deployment(link)
    }

    /// Near-storage filtering on the DPU (the SkimROOT method).
    pub fn skim_root(link: LinkModel) -> Self {
        Mode::SkimRoot.deployment(link)
    }

    /// The DPU configuration, if this is a DPU placement.
    pub fn dpu_config_mut(&mut self) -> Option<&mut DpuConfig> {
        match &mut self.placement {
            Placement::Dpu(cfg) => Some(cfg),
            _ => None,
        }
    }

    /// Check the deployment's invariants. Called by the builder and
    /// again by the coordinator at job start — the fields are public,
    /// so a deployment mutated after `build()` (e.g. the CLI setting
    /// `fan_out`) is still validated before it runs.
    pub fn validate(&self) -> Result<()> {
        if self.fan_out == 0 {
            return Err(Error::Config("fan_out must be at least 1".into()));
        }
        if self.fan_out > 1 && !matches!(self.placement, Placement::Dpu(_)) {
            return Err(Error::Config(
                "fan_out > 1 requires Placement::Dpu (only DPU jobs shard)".into(),
            ));
        }
        Ok(())
    }
}

/// Builder for [`Deployment`] — the open topology API.
///
/// ```ignore
/// let dep = Deployment::builder()
///     .name("skimroot-x4")
///     .placement(Placement::Dpu(DpuConfig::default()))
///     .store(DiskModel::nvme())
///     .link(LinkModel::wan_1g())
///     .fan_out(4)
///     .build()?;
/// ```
pub struct DeploymentBuilder {
    name: Option<String>,
    placement: Placement,
    link: LinkModel,
    disk: DiskModel,
    fault: FaultPlan,
    cache_bytes: Option<usize>,
    two_phase: bool,
    use_pjrt: bool,
    fan_out: usize,
    adaptive: crate::engine::AdaptiveOpts,
    fuse: bool,
}

impl Default for DeploymentBuilder {
    fn default() -> Self {
        DeploymentBuilder {
            name: None,
            placement: Placement::Client,
            link: LinkModel::wan_1g(),
            disk: DiskModel::disk_pool(),
            fault: FaultPlan::default(),
            cache_bytes: Some(crate::xrootd::DEFAULT_CACHE_BYTES),
            two_phase: true,
            use_pjrt: true,
            fan_out: 1,
            adaptive: crate::engine::AdaptiveOpts::default(),
            fuse: false,
        }
    }
}

impl DeploymentBuilder {
    /// Report label; defaults to the placement's kind name.
    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }

    /// Where the filtering engine runs.
    pub fn placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }

    /// Storage backend behind the XRootD server.
    pub fn store(mut self, disk: DiskModel) -> Self {
        self.disk = disk;
        self
    }

    /// Client ↔ storage-site link.
    pub fn link(mut self, link: LinkModel) -> Self {
        self.link = link;
        self
    }

    /// Failure injection + retry policy.
    pub fn fault(mut self, fault: FaultPlan) -> Self {
        self.fault = fault;
        self
    }

    /// TTreeCache capacity for remote clients (`None` disables).
    pub fn cache_bytes(mut self, cache_bytes: Option<usize>) -> Self {
        self.cache_bytes = cache_bytes;
        self
    }

    /// Two-phase execution (§3.2) vs legacy fetch-everything.
    pub fn two_phase(mut self, two_phase: bool) -> Self {
        self.two_phase = two_phase;
        self
    }

    /// Vectorized PJRT kernel vs per-event interpreter.
    pub fn use_pjrt(mut self, use_pjrt: bool) -> Self {
        self.use_pjrt = use_pjrt;
        self
    }

    /// Number of DPU shards (DPU placements only).
    pub fn fan_out(mut self, fan_out: usize) -> Self {
        self.fan_out = fan_out;
        self
    }

    /// Selectivity-adaptive interpreter execution.
    pub fn adaptive(mut self, adaptive: crate::engine::AdaptiveOpts) -> Self {
        self.adaptive = adaptive;
        self
    }

    /// Profile-guided fused cut kernels (interpreter placements only).
    pub fn fuse(mut self, fuse: bool) -> Self {
        self.fuse = fuse;
        self
    }

    /// Assemble and validate the deployment.
    pub fn build(self) -> Result<Deployment> {
        let name = self.name.unwrap_or_else(|| {
            match &self.placement {
                Placement::Client => "client",
                Placement::Server => "server",
                Placement::Dpu(_) => "dpu",
            }
            .to_string()
        });
        let deployment = Deployment {
            name,
            placement: self.placement,
            client_link: self.link,
            disk: self.disk,
            fault: self.fault,
            cache_bytes: self.cache_bytes,
            two_phase: self.two_phase,
            use_pjrt: self.use_pjrt,
            fan_out: self.fan_out,
            adaptive: self.adaptive,
            fuse: self.fuse,
        };
        deployment.validate()?;
        Ok(deployment)
    }
}

/// Result of a coordinated job: engine outcome + per-node accounting.
pub struct JobReport {
    /// The deployment's report label.
    pub name: String,
    /// The engine outcome (selection counts, funnel, output). For a
    /// dataset job this is the aggregate over its files, and the
    /// output is the deterministic merge of the per-file skims.
    pub result: SkimResult,
    /// Full per-stage/per-node accounting for the job.
    pub timeline: Timeline,
    /// End-to-end latency (request submission → filtered file at the
    /// client), seconds.
    pub latency: f64,
    /// Attempts including WLCG-style resubmissions (1 = first try;
    /// for dataset jobs, summed over files).
    pub attempts: u32,
    /// CPU utilization per node (busy / end-to-end).
    pub utilization: Vec<(Node, f64)>,
    /// Per-file outcomes for dataset jobs, in resolved dataset order.
    /// Empty for single-file jobs, whose report shape is unchanged.
    pub files: Vec<FileReport>,
    /// When this job ran as a member of a shared-scan batch
    /// ([`Coordinator::run_shared`]): the batch identity. `None` for
    /// solo runs.
    pub batch: Option<crate::mqo::BatchInfo>,
}

impl JobReport {
    /// Per-stage breakdown rows (the Fig. 4b / 5a decomposition).
    pub fn breakdown(&self) -> Vec<(Stage, f64)> {
        Stage::ALL
            .iter()
            .map(|&s| (s, self.timeline.stage_total(s)))
            .filter(|&(_, t)| t > 0.0)
            .collect()
    }

    /// Files in the job's dataset (0 for single-file jobs).
    pub fn files_total(&self) -> usize {
        self.files.len()
    }

    /// Dataset files that skimmed successfully.
    pub fn files_done(&self) -> usize {
        self.files.iter().filter(|f| f.error.is_none()).count()
    }

    /// Dataset files that failed after exhausting their retries
    /// (fault-isolated: the rest of the job still completed).
    pub fn files_failed(&self) -> usize {
        self.files.len() - self.files_done()
    }
}

/// Outcome of one file of a dataset job (per-file timeline summary +
/// failure detail; see [`JobReport::files`]).
#[derive(Debug, Clone)]
pub struct FileReport {
    /// Catalog-relative path of the file.
    pub path: String,
    /// Events the file's skim covered (0 if it failed).
    pub n_events: u64,
    /// Events passing the selection (0 if it failed).
    pub n_pass: u64,
    /// Attempts including per-file WLCG-style resubmissions.
    pub attempts: u32,
    /// Modeled elapsed seconds on the file's private timeline.
    pub elapsed: f64,
    /// Failure message when the file failed after all retries; `None`
    /// for a successful file.
    pub error: Option<String>,
}

/// A `ReadAt` wrapper that injects deterministic faults from a
/// [`FaultPlan`]'s seeded stream — one decision per read, keyed by
/// `(attempt seed, read index)`, so a given attempt always injects the
/// same faults at the same reads regardless of thread interleaving.
struct FaultStore<R> {
    inner: R,
    plan: FaultPlan,
    /// Attempt-derived stream seed (distinct per resubmission).
    seed: u64,
    /// 1-based read index counter for this attempt.
    reads: AtomicU64,
    /// Charged with stalls and `faults_injected` counts.
    timeline: Timeline,
}

impl<R> FaultStore<R> {
    fn new(inner: R, plan: FaultPlan, seed: u64, timeline: Timeline) -> Self {
        FaultStore { inner, plan, seed, reads: AtomicU64::new(0), timeline }
    }

    /// Decide whether this read is selected for injection; counts the
    /// injection when it is.
    fn inject(&self) -> Option<FaultKind> {
        let idx = self.reads.fetch_add(1, Ordering::Relaxed) + 1;
        let hit = match self.plan.kind {
            FaultKind::FailAtRead => {
                self.plan.fail_at_read > 0 && idx == self.plan.fail_at_read
            }
            _ => {
                self.plan.fail_prob > 0.0 && {
                    let mut rng = Pcg32::new(
                        self.seed
                            .wrapping_add(idx.wrapping_mul(0x2545_f491_4f6c_dd1d)),
                    );
                    rng.chance(self.plan.fail_prob)
                }
            }
        };
        if hit {
            self.timeline.count("faults_injected", 1);
            Some(self.plan.kind)
        } else {
            None
        }
    }

    /// Apply one injected fault to a successful read's buffers.
    /// Returns an error for the failing kinds, corrupted/stalled data
    /// for the rest.
    fn apply(&self, kind: FaultKind, bufs: &mut [Vec<u8>]) -> Result<()> {
        match kind {
            FaultKind::ReadError | FaultKind::FailAtRead => {
                Err(Error::Io(std::io::Error::other("injected storage fault")))
            }
            FaultKind::CorruptFrame => {
                // Flip the leading bytes: a basket frame loses its
                // magic; metadata reads surface as format errors.
                if let Some(buf) = bufs.iter_mut().find(|b| !b.is_empty()) {
                    for b in buf.iter_mut().take(4) {
                        *b ^= 0x5a;
                    }
                }
                Ok(())
            }
            FaultKind::DecompressCorrupt => {
                // Flip the trailing payload bytes: the frame header
                // stays intact and the decompressor's CRC trips.
                if let Some(buf) = bufs.iter_mut().find(|b| !b.is_empty()) {
                    let n = buf.len();
                    for b in buf[n.saturating_sub(4)..].iter_mut() {
                        *b ^= 0x5a;
                    }
                }
                Ok(())
            }
            FaultKind::StallRead => {
                // A hung storage server: clean data after a
                // virtual-time stall that counts toward deadlines.
                self.timeline
                    .charge(Stage::BasketFetch, self.plan.stall_s.max(0.0));
                Ok(())
            }
        }
    }
}

impl<R: ReadAt> ReadAt for FaultStore<R> {
    fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        match self.inject() {
            None => self.inner.read_at(offset, len),
            Some(kind) => {
                let mut buf = [self.inner.read_at(offset, len)?];
                self.apply(kind, &mut buf)?;
                let [data] = buf;
                Ok(data)
            }
        }
    }

    fn read_vec(&self, ranges: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        match self.inject() {
            None => self.inner.read_vec(ranges),
            Some(kind) => {
                let mut bufs = self.inner.read_vec(ranges)?;
                self.apply(kind, &mut bufs)?;
                Ok(bufs)
            }
        }
    }

    fn size(&self) -> Result<u64> {
        self.inner.size()
    }
}

/// The coordinator: owns the storage root and runtime handle, runs
/// jobs under any deployment.
pub struct Coordinator<'rt> {
    storage_root: std::path::PathBuf,
    runtime: Option<&'rt SkimRuntime>,
    /// Where client-side outputs / shipped outputs land.
    client_dir: std::path::PathBuf,
    /// Shared decompressed-basket cache installed into every engine
    /// (the multi-tenant serving layer sets this; one-shot jobs don't).
    basket_cache: Option<Arc<crate::serve::BasketCache>>,
    /// Lifecycle controls threaded into every engine this coordinator
    /// runs: cooperative cancellation + virtual-time deadline.
    ctl: JobCtl,
}

impl<'rt> Coordinator<'rt> {
    /// A coordinator reading inputs under `storage_root` and landing
    /// filtered outputs under `client_dir`, evaluating with `runtime`
    /// (`None` = the scalar interpreter).
    pub fn new(
        storage_root: impl Into<std::path::PathBuf>,
        client_dir: impl Into<std::path::PathBuf>,
        runtime: Option<&'rt SkimRuntime>,
    ) -> Self {
        Coordinator {
            storage_root: storage_root.into(),
            runtime,
            client_dir: client_dir.into(),
            basket_cache: None,
            ctl: JobCtl::none(),
        }
    }

    /// Install job lifecycle controls ([`JobCtl`]): the cancel token
    /// and virtual-time deadline are checked at every basket-group
    /// boundary of every engine this coordinator spins up, and between
    /// retry attempts. Cancellation and expired deadlines are
    /// terminal — never resubmitted.
    pub fn with_ctl(mut self, ctl: JobCtl) -> Self {
        self.ctl = ctl;
        self
    }

    /// Install a shared [`crate::serve::BasketCache`] into every
    /// engine this coordinator spins up (all placements, all fan-out
    /// shards). See [`crate::engine::EngineOpts::basket_cache`].
    pub fn with_basket_cache(mut self, cache: Arc<crate::serve::BasketCache>) -> Self {
        self.basket_cache = Some(cache);
        self
    }

    /// Run one skim job under `deployment`, with WLCG-style retries.
    pub fn run_job(&self, query: &SkimQuery, deployment: &Deployment) -> Result<JobReport> {
        self.run_job_with(query, deployment, &[])
    }

    /// [`Coordinator::run_job`] with custom pipeline stages registered
    /// into every engine the deployment spins up (each shard of a
    /// fan-out deployment, and each file of a dataset, gets the same
    /// stages).
    ///
    /// The query's input is a [`crate::query::DatasetSpec`]; it is
    /// resolved (and traversal-validated) against the storage root
    /// here. Single-file specs keep the exact legacy job contract:
    /// whole-job retries, one engine run, unchanged report shape.
    /// Multi-file specs go through the dataset path: per-file
    /// execution with per-file retries and fault isolation,
    /// file-granular striping across DPU fan-out lanes, and a
    /// deterministic merge (see `ARCHITECTURE.md` § "Dataset layer").
    ///
    /// The stage `Arc`s are shared across retry attempts and shards:
    /// a *stateful* stage (e.g. a byte-audit accumulator) observes all
    /// work actually performed — including attempts that later failed
    /// and were resubmitted. Reset or snapshot your stage's state per
    /// job if you need successful-attempt-only numbers.
    pub fn run_job_with(
        &self,
        query: &SkimQuery,
        deployment: &Deployment,
        stages: &[StageReg],
    ) -> Result<JobReport> {
        deployment.validate()?;
        // Resolve the dataset up front. This is also the
        // path-traversal gate: entries that could escape the storage
        // root are rejected with a config error before any I/O.
        let files = crate::catalog::resolve(&query.input, &self.storage_root)?;
        if query.input.is_single() {
            return self.run_single_file(query, deployment, stages);
        }
        self.run_dataset(query, &files, deployment, stages)
    }

    /// Run a batch of compatible queries as **one shared scan**: a
    /// single phase-1 fetch → decompress → deserialize pass over the
    /// union of the members' criteria branches serves every member
    /// (see [`crate::mqo`] for the planner and
    /// [`crate::engine::run_shared`] for the executor). Per-member
    /// masks, funnels and output files are byte-identical to solo
    /// [`Coordinator::run_job`] runs.
    ///
    /// Requirements: every query targets the **same resolved single
    /// file**, and the deployment passes
    /// [`crate::mqo::deployment_incompatibility`] (client or server
    /// placement, two-phase, `fan_out` 1, no fault injection) — the
    /// scheduler checks the same predicate before forming batches and
    /// falls back to solo runs otherwise. The shared pass always
    /// evaluates members on the scalar interpreter (kernel batch
    /// shapes are per-member), which is bit-identical to the kernel.
    ///
    /// Attribution: the shared pass charges the batch once, then
    /// amortizes across members as exact integer counter shares and
    /// `1/N` virtual-time slices; each member's phase-2 and output
    /// work stays on its own timeline. Member outputs land under
    /// collision-free `b<batch>_m<i>_` names in the client dir, and
    /// every report carries [`JobReport::batch`] identity.
    pub fn run_shared(
        &self,
        queries: &[SkimQuery],
        deployment: &Deployment,
        batch_id: u64,
    ) -> Result<Vec<JobReport>> {
        self.run_shared_ctl(queries, deployment, batch_id, &[])?
            .into_iter()
            .collect()
    }

    /// [`Coordinator::run_shared`] with per-member lifecycle controls.
    ///
    /// `ctls` carries one [`JobCtl`] per member (or is empty: no
    /// controls). A member whose token is cancelled — or whose
    /// virtual-time deadline expires — **detaches** from the batch at
    /// the next group boundary: it stops receiving decoded baskets,
    /// writes no output, and its slot in the returned vector carries
    /// the terminal error, while the remaining members complete
    /// normally. Batch-level failures (divergence, store errors in the
    /// shared pass) still fail the whole call.
    pub fn run_shared_ctl(
        &self,
        queries: &[SkimQuery],
        deployment: &Deployment,
        batch_id: u64,
        ctls: &[JobCtl],
    ) -> Result<Vec<Result<JobReport>>> {
        deployment.validate()?;
        if queries.is_empty() {
            return Err(Error::Config("shared-scan batch has no members".into()));
        }
        if let Some(reason) = crate::mqo::deployment_incompatibility(deployment) {
            return Err(Error::Config(format!(
                "deployment cannot host shared scans: {reason}"
            )));
        }
        // Every member must resolve to the same single file — the
        // batching window keys on exactly this.
        let files = crate::catalog::resolve(&queries[0].input, &self.storage_root)?;
        if !queries[0].input.is_single() || files.len() != 1 {
            return Err(Error::Config("shared scans require single-file members".into()));
        }
        for q in &queries[1..] {
            if !q.input.is_single()
                || crate::catalog::resolve(&q.input, &self.storage_root)? != files
            {
                return Err(Error::Config(
                    "shared-scan members must target the same resolved dataset".into(),
                ));
            }
        }
        let input_path = files[0].as_str();
        std::fs::create_dir_all(&self.client_dir)?;

        let n = queries.len();
        let batch_timeline = Timeline::new();
        let member_timelines: Vec<Timeline> = (0..n).map(|_| Timeline::new()).collect();

        // Zone-map sidecar: loaded once, validated per member context
        // (a corrupt sidecar degrades every member to a full scan with
        // a warning, exactly like solo runs).
        let (zone_map, zone_warning) =
            match crate::index::load_sidecar(&self.storage_root.join(input_path)) {
                Ok(Some(idx)) => (Some(Arc::new(idx)), None),
                Ok(None) => (None, None),
                Err(e) => (
                    None,
                    Some(format!(
                        "corrupt zone-map sidecar for {input_path} ignored ({e}); running a full scan"
                    )),
                ),
            };

        // One store per member (phase-2 selective fetches charge the
        // member's timeline) plus one for the shared scan (charges the
        // batch timeline) — mirroring the solo placement arms.
        let mk_store = |tl: &Timeline| -> Result<(Arc<dyn ReadAt>, Option<XrdServer>)> {
            match &deployment.placement {
                Placement::Client => {
                    let server = XrdServer::new(&self.storage_root, deployment.disk);
                    server.set_timeline(Some(tl.clone()));
                    let stats = server.clone();
                    let wire = Arc::new(LoopbackWire::new(
                        server,
                        deployment.client_link,
                        tl.clone(),
                    ));
                    let store: Arc<dyn ReadAt> =
                        Arc::new(XrdClient::new(wire).open(input_path)?);
                    Ok((store, Some(stats)))
                }
                Placement::Server => {
                    let local = LocalFile::open(self.storage_root.join(input_path))?;
                    let store: Arc<dyn ReadAt> = Arc::new(crate::net::ModeledStore::new(
                        local,
                        deployment.disk,
                        tl.clone(),
                    ));
                    Ok((store, None))
                }
                Placement::Dpu(_) => Err(Error::Config(
                    "shared scans cannot run on DPU placements".into(),
                )),
            }
        };
        let (scan_store, scan_server) = mk_store(&batch_timeline)?;
        let mut member_stores: Vec<Arc<dyn ReadAt>> = Vec::with_capacity(n);
        let mut member_servers: Vec<Option<XrdServer>> = Vec::with_capacity(n);
        for tl in &member_timelines {
            let (store, server) = mk_store(tl)?;
            member_stores.push(store);
            member_servers.push(server);
        }

        let opts = EngineOpts {
            two_phase: true,
            use_pjrt: false,
            compute_node: match &deployment.placement {
                Placement::Server => Node::Server,
                _ => Node::Client,
            },
            decomp: DecompMode::Software,
            cache_bytes: match &deployment.placement {
                Placement::Client => deployment.cache_bytes,
                _ => None,
            },
            basket_cache: self.basket_cache.clone(),
            zone_map: zone_map.clone(),
            adaptive: deployment.adaptive.clone(),
            fuse: deployment.fuse,
            ..Default::default()
        };
        // Collision-free member output names: two members may request
        // the same output file name.
        let out_paths: Vec<std::path::PathBuf> = queries
            .iter()
            .enumerate()
            .map(|(i, q)| {
                self.client_dir
                    .join(format!("b{batch_id}_m{i}_{}", sanitize(&q.output)))
            })
            .collect();

        let mut results = crate::engine::run_shared(
            scan_store,
            &member_stores,
            queries,
            &member_timelines,
            &batch_timeline,
            &opts,
            &out_paths,
            ctls,
        )?;

        // Ship each member's output to the client (a no-op for client
        // placements, where the output is already local; detached
        // members produced no output to ship).
        if !matches!(deployment.placement, Placement::Client) {
            for (result, tl) in results.iter().zip(&member_timelines) {
                if let Ok(result) = result {
                    deployment
                        .client_link
                        .charge(tl, Stage::OutputTransfer, result.output_bytes);
                }
            }
        }
        // Served-byte accounting, solo-parity: each member's own
        // (phase-2) server total lands on its timeline; the scan
        // server's total is charged to the batch and amortized in
        // exact integer shares.
        if let Some(stats) = scan_server {
            let served = stats.bytes_served();
            if served > 0 {
                batch_timeline.count("xrd_bytes_served", served);
                for (i, tl) in member_timelines.iter().enumerate() {
                    let share = crate::mqo::amortized_share(served, n, i);
                    if share > 0 {
                        tl.count("xrd_bytes_served", share);
                    }
                }
            }
        }
        for (server, tl) in member_servers.iter().zip(&member_timelines) {
            if let Some(stats) = server {
                let served = stats.bytes_served();
                if served > 0 {
                    tl.count("xrd_bytes_served", served);
                }
            }
        }
        if let Some(w) = zone_warning {
            for r in results.iter_mut().flatten() {
                r.warnings.push(w.clone());
            }
        }

        let info = crate::mqo::BatchInfo { id: batch_id, members: n as u32 };
        Ok(results
            .into_iter()
            .zip(member_timelines)
            .map(|(result, timeline)| {
                timeline.count("attempts", 1);
                let result = match result {
                    Ok(result) => result,
                    Err(e) => {
                        note_terminal(&timeline, &e);
                        return Err(e);
                    }
                };
                let latency = timeline.elapsed();
                let utilization = node_utilization(&timeline);
                Ok(JobReport {
                    name: deployment.name.clone(),
                    result,
                    timeline,
                    latency,
                    attempts: 1,
                    utilization,
                    files: Vec::new(),
                    batch: Some(info),
                })
            })
            .collect())
    }

    /// The legacy single-file job: whole-job WLCG-style retries with
    /// exponential backoff, a circuit breaker, and terminal
    /// cancel/deadline outcomes.
    fn run_single_file(
        &self,
        query: &SkimQuery,
        deployment: &Deployment,
        stages: &[StageReg],
    ) -> Result<JobReport> {
        let timeline = Timeline::new();
        let plan = deployment.fault;
        let mut attempts = 0;
        loop {
            attempts += 1;
            // A cancel raised between attempts — or a deadline burned
            // through by backoff charges — terminates before the next
            // attempt spends anything.
            if let Err(e) = self.ctl.check(&timeline) {
                note_terminal(&timeline, &e);
                return Err(e);
            }
            // Each attempt gets a distinct fault stream: a resubmitted
            // job does not hit the identical failure.
            let attempt_seed = plan
                .seed
                .wrapping_add(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(attempts as u64));
            match self.run_attempt(query, deployment, &timeline, attempt_seed, attempts, stages)
            {
                Ok(result) => {
                    timeline.count("attempts", 1);
                    let latency = timeline.elapsed();
                    let utilization = node_utilization(&timeline);
                    return Ok(JobReport {
                        name: deployment.name.clone(),
                        result,
                        timeline,
                        latency,
                        attempts,
                        utilization,
                        files: Vec::new(),
                        batch: None,
                    });
                }
                Err(e) => {
                    timeline.count("attempts", 1);
                    if lifecycle::is_terminal(&e) {
                        note_terminal(&timeline, &e);
                        return Err(e);
                    }
                    timeline.count("failures", 1);
                    // Single-file jobs fail as a whole, so every
                    // failure here is consecutive: the breaker caps
                    // the retry budget early for hopeless inputs.
                    if plan.breaker_tripped(attempts) {
                        return Err(Error::Engine(format!(
                            "job failed after {attempts} attempts (circuit breaker open): {e}"
                        )));
                    }
                    if plan.retries_exhausted(attempts) {
                        return Err(Error::Engine(format!(
                            "job failed after {attempts} attempts: {e}"
                        )));
                    }
                    charge_backoff(&timeline, attempts, plan.seed);
                }
            }
        }
    }

    /// The dataset path: execute each resolved file as its own
    /// fault-isolated sub-job, then merge deterministically.
    ///
    /// * **Striping** — for DPU placements the file list is striped
    ///   round-robin across the `fan_out` lanes
    ///   ([`crate::catalog::lane_of`]); whole files are the placement
    ///   unit (locality: one file's baskets stay on one node's
    ///   wire/cache), replacing the single-file cluster-range split as
    ///   the only fan-out axis. Client/server placements run the files
    ///   sequentially on one lane.
    /// * **Fault isolation** — each file gets its own retry loop
    ///   ([`FaultPlan::max_retries`]); a file that exhausts its
    ///   retries (e.g. one corrupt input) fails *that file*, recorded
    ///   in [`JobReport::files`] and the result warnings, while the
    ///   rest of the dataset completes. The job errors only when
    ///   every file failed.
    /// * **Virtual-time accounting** — every file runs on a private
    ///   timeline; lanes model parallel hardware, so only the critical
    ///   (slowest) lane's accounting folds into the job timeline, and
    ///   the merge + output transfer land on top.
    /// * **Determinism** — per-file outputs are merged in resolved
    ///   dataset order through [`crate::troot::merge`], so the merged
    ///   bytes are independent of fan-out and completion order (the
    ///   dataset tests cross-check against a serial single-file loop).
    fn run_dataset(
        &self,
        query: &SkimQuery,
        files: &[String],
        deployment: &Deployment,
        stages: &[StageReg],
    ) -> Result<JobReport> {
        let timeline = Timeline::new();
        std::fs::create_dir_all(&self.client_dir)?;
        // Keyed by output name so concurrent dataset jobs with
        // distinct outputs never share a staging directory (same-output
        // concurrency already races on the final file, as it always
        // has for single-file jobs). Removed after the merge.
        let parts_dir = self
            .client_dir
            .join(format!("dataset_parts_{}", sanitize(&query.output)));
        std::fs::create_dir_all(&parts_dir)?;
        let lanes = match &deployment.placement {
            Placement::Dpu(_) => deployment.fan_out.max(1),
            _ => 1,
        };

        let plan = deployment.fault;
        let mut lane_timelines: Vec<Vec<Timeline>> = vec![Vec::new(); lanes];
        // Virtual time already consumed per lane: job-level deadlines
        // are measured on the critical-path model, so each file checks
        // against the deadline minus what its lane has already spent.
        let mut lane_consumed: Vec<f64> = vec![0.0; lanes];
        let mut file_reports: Vec<FileReport> = Vec::with_capacity(files.len());
        let mut part_paths: Vec<std::path::PathBuf> = Vec::new();
        let mut part_results: Vec<SkimResult> = Vec::new();
        let mut total_attempts: u32 = 0;
        for (idx, file) in files.iter().enumerate() {
            // The output name flows into DPU scratch staging too, so
            // it carries the job's output to stay collision-free
            // across concurrent dataset jobs.
            let part_name = format!("part{idx:05}_{}", sanitize(&query.output));
            let sub = query.for_file(file, part_name.clone());
            let part_path = parts_dir.join(&part_name);
            let file_tl = Timeline::new();
            let lane = crate::catalog::lane_of(idx, lanes);
            let file_ctl = self.ctl.at_offset(lane_consumed[lane]);
            let mut attempts = 0u32;
            let mut consecutive = 0u32;
            let outcome = loop {
                attempts += 1;
                if let Err(e) = file_ctl.check(&file_tl) {
                    break Err(e);
                }
                // Distinct fault stream per (file, attempt).
                let attempt_seed = plan
                    .seed
                    .wrapping_add((idx as u64 + 1).wrapping_mul(0xa076_1d64_78bd_642f))
                    .wrapping_add(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(attempts as u64));
                match self.execute_placement(
                    &sub, deployment, &file_tl, &file_ctl, attempt_seed, attempts, stages,
                    &part_path, 1, false,
                ) {
                    Ok(result) => break Ok(result),
                    Err(e) if lifecycle::is_terminal(&e) => break Err(e),
                    Err(e) => {
                        file_tl.count("failures", 1);
                        consecutive += 1;
                        // The circuit breaker converts a persistently
                        // failing file into the degraded per-file
                        // result without burning the full retry
                        // budget.
                        if plan.breaker_tripped(consecutive) {
                            break Err(Error::Engine(format!(
                                "circuit breaker open after {consecutive} consecutive failures: {e}"
                            )));
                        }
                        if plan.retries_exhausted(attempts) {
                            break Err(e);
                        }
                        charge_backoff(&file_tl, attempts, plan.seed);
                    }
                }
            };
            // Cancellation and expired deadlines are job-terminal, not
            // per-file degradation: stop the dataset, clean the parts.
            if let Err(e) = &outcome {
                if lifecycle::is_terminal(e) {
                    note_terminal(&timeline, e);
                    let _ = std::fs::remove_dir_all(&parts_dir);
                    return Err(outcome.unwrap_err());
                }
            }
            file_tl.count("attempts", attempts as u64);
            total_attempts = total_attempts.saturating_add(attempts);
            let report = match outcome {
                Ok(result) => {
                    let fr = FileReport {
                        path: file.clone(),
                        n_events: result.n_events,
                        n_pass: result.n_pass,
                        attempts,
                        elapsed: file_tl.elapsed(),
                        error: None,
                    };
                    part_paths.push(part_path);
                    part_results.push(result);
                    fr
                }
                Err(e) => FileReport {
                    path: file.clone(),
                    n_events: 0,
                    n_pass: 0,
                    attempts,
                    elapsed: file_tl.elapsed(),
                    error: Some(e.to_string()),
                },
            };
            file_reports.push(report);
            lane_consumed[lane] += file_tl.elapsed();
            lane_timelines[lane].push(file_tl);
        }

        // Lanes model parallel hardware: only the critical (slowest)
        // lane's modeled time folds into the job timeline, exactly
        // like DPU shards — but counters are *real work totals*
        // (attempts, failures, cache hits, served bytes), so every
        // lane contributes those.
        let lane_elapsed =
            |lane: usize| lane_timelines[lane].iter().map(|t| t.elapsed()).sum::<f64>();
        let critical = (0..lanes)
            .max_by(|&a, &b| {
                lane_elapsed(a).partial_cmp(&lane_elapsed(b)).expect("finite")
            })
            .expect("at least one lane");
        for (lane, tls) in lane_timelines.iter().enumerate() {
            for tl in tls {
                if lane == critical {
                    timeline.merge_from(tl);
                } else {
                    timeline.merge_counters_from(tl);
                }
            }
        }

        let done = file_reports.iter().filter(|f| f.error.is_none()).count();
        timeline.count("files_total", files.len() as u64);
        timeline.count("files_done", done as u64);
        timeline.count("files_failed", (files.len() - done) as u64);
        if done == 0 {
            let first = file_reports
                .iter()
                .find_map(|f| f.error.clone())
                .unwrap_or_default();
            let _ = std::fs::remove_dir_all(&parts_dir);
            return Err(Error::Engine(format!(
                "dataset job failed: all {} files failed; first error: {first}",
                files.len()
            )));
        }

        // Deterministic merge in resolved dataset order; attributed to
        // the node that holds the parts.
        let out_path = self.client_dir.join(sanitize(&query.output));
        let merge_node = match &deployment.placement {
            Placement::Client => Node::Client,
            Placement::Server => Node::Server,
            Placement::Dpu(_) => Node::Dpu,
        };
        let t0 = std::time::Instant::now();
        let merge_outcome = crate::troot::merge::concat_files(&part_paths, &out_path);
        timeline.add_real(Stage::OutputWrite, merge_node, t0.elapsed().as_secs_f64());
        // The parts only staged the merge inputs; drop them either way.
        let _ = std::fs::remove_dir_all(&parts_dir);
        let summary = merge_outcome?;
        // Only the merged file crosses the client link (parts live
        // where they were produced; client placements already hold
        // them locally).
        if !matches!(deployment.placement, Placement::Client) {
            deployment
                .client_link
                .charge(&timeline, Stage::OutputTransfer, summary.file_bytes);
        }

        let mut result = SkimResult::merge_parts(part_results.iter());
        result.output_path = out_path;
        result.output_bytes = summary.file_bytes;
        for f in file_reports.iter().filter(|f| f.error.is_some()) {
            result.warnings.push(format!(
                "dataset file '{}' failed after {} attempts: {}",
                f.path,
                f.attempts,
                f.error.as_deref().unwrap_or("unknown error")
            ));
        }

        let latency = timeline.elapsed();
        let utilization = node_utilization(&timeline);
        Ok(JobReport {
            name: deployment.name.clone(),
            result,
            timeline,
            latency,
            attempts: total_attempts,
            utilization,
            files: file_reports,
            batch: None,
        })
    }

    fn run_attempt(
        &self,
        query: &SkimQuery,
        deployment: &Deployment,
        timeline: &Timeline,
        fault_seed: u64,
        attempt: u32,
        stages: &[StageReg],
    ) -> Result<SkimResult> {
        std::fs::create_dir_all(&self.client_dir)?;
        let out_path = self.client_dir.join(sanitize(&query.output));
        self.execute_placement(
            query,
            deployment,
            timeline,
            &self.ctl,
            fault_seed,
            attempt,
            stages,
            &out_path,
            deployment.fan_out,
            true,
        )
    }

    /// Run one single-file engine pass under the deployment's
    /// placement, writing the filtered file to `out_path`.
    ///
    /// `dpu_fan_out` controls intra-file cluster-range sharding on DPU
    /// placements (single-file jobs pass the deployment's `fan_out`;
    /// the dataset path passes 1 — whole files are its placement
    /// unit, which keeps per-file outputs identical to single-file
    /// runs). `ship_output` charges the final client-link hop (the
    /// dataset path ships only the merged file, once).
    #[allow(clippy::too_many_arguments)]
    fn execute_placement(
        &self,
        query: &SkimQuery,
        deployment: &Deployment,
        timeline: &Timeline,
        ctl: &JobCtl,
        fault_seed: u64,
        attempt: u32,
        stages: &[StageReg],
        out_path: &std::path::Path,
        dpu_fan_out: usize,
        ship_output: bool,
    ) -> Result<SkimResult> {
        let input_path = query.input.single_path()?;
        let server = XrdServer::new(&self.storage_root, deployment.disk);
        server.set_timeline(Some(timeline.clone()));
        // Keep a stat handle: the DPU arm moves `server` into the node.
        let server_stats = server.clone();

        // Load the input's `.tridx` zone-map sidecar, if one sits next
        // to the data file. An unreadable/corrupt sidecar degrades to a
        // full scan with a warning — it must never fail the job; the
        // engine digest-validates a loaded one the same way.
        let (zone_map, zone_warning) =
            match crate::index::load_sidecar(&self.storage_root.join(input_path)) {
                Ok(Some(idx)) => (Some(Arc::new(idx)), None),
                Ok(None) => (None, None),
                Err(e) => (
                    None,
                    Some(format!(
                        "corrupt zone-map sidecar for {input_path} ignored ({e}); running a full scan"
                    )),
                ),
            };

        let fault = deployment.fault;
        let wrap_faults = |store: Arc<dyn ReadAt>| -> Arc<dyn ReadAt> {
            // `fail_attempts` gating lives here: once the plan stops
            // injecting for this attempt, the store isn't wrapped at
            // all, so recovered attempts run the exact clean path.
            if fault.active_on_attempt(attempt) {
                Arc::new(FaultStore::new(store, fault, fault_seed, timeline.clone()))
            } else {
                store
            }
        };

        let result = match &deployment.placement {
            Placement::Client => {
                let wire = Arc::new(LoopbackWire::new(
                    server,
                    deployment.client_link,
                    timeline.clone(),
                ));
                let client = XrdClient::new(wire);
                let remote: Arc<dyn ReadAt> = Arc::new(client.open(input_path)?);
                let store = wrap_faults(remote);
                let opts = EngineOpts {
                    two_phase: deployment.two_phase,
                    use_pjrt: deployment.use_pjrt,
                    compute_node: Node::Client,
                    decomp: DecompMode::Software,
                    cache_bytes: deployment.cache_bytes,
                    basket_cache: self.basket_cache.clone(),
                    zone_map: zone_map.clone(),
                    ctl: ctl.clone(),
                    adaptive: deployment.adaptive.clone(),
                    fuse: deployment.fuse,
                    ..Default::default()
                };
                let engine = SkimEngine::with_stages(self.runtime, stages)?;
                // Output is produced directly on the client: no final
                // transfer hop.
                engine.run(store, query, timeline, &opts, out_path)
            }
            Placement::Server => {
                // Local reads: no XRootD in the path, no TTreeCache
                // (§4: "TTreeCache does not function for local ROOT
                // file access"), per-basket disk seeks.
                let local = LocalFile::open(self.storage_root.join(input_path))?;
                let modeled: Arc<dyn ReadAt> = Arc::new(crate::net::ModeledStore::new(
                    local,
                    deployment.disk,
                    timeline.clone(),
                ));
                let store = wrap_faults(modeled);
                let opts = EngineOpts {
                    two_phase: deployment.two_phase,
                    use_pjrt: deployment.use_pjrt,
                    compute_node: Node::Server,
                    decomp: DecompMode::Software,
                    cache_bytes: None,
                    basket_cache: self.basket_cache.clone(),
                    zone_map: zone_map.clone(),
                    ctl: ctl.clone(),
                    adaptive: deployment.adaptive.clone(),
                    fuse: deployment.fuse,
                    ..Default::default()
                };
                let engine = SkimEngine::with_stages(self.runtime, stages)?;
                let result = engine.run(store, query, timeline, &opts, out_path)?;
                if ship_output {
                    // Ship the filtered file to the client.
                    deployment.client_link.charge(
                        timeline,
                        Stage::OutputTransfer,
                        result.output_bytes,
                    );
                }
                Ok(result)
            }
            Placement::Dpu(config) => {
                // The DPU path: PCIe-attached near-storage filtering.
                // (Fault injection is modeled at the job level — the
                // DPU retries whole jobs like any WLCG worker. Failing
                // kinds abort the attempt; a stall charges its virtual
                // time and proceeds with clean data.)
                if fault.active_on_attempt(attempt) {
                    let mut rng = Pcg32::new(fault_seed);
                    let hit = match fault.kind {
                        FaultKind::FailAtRead => true,
                        _ => rng.chance(fault.fail_prob),
                    };
                    if hit {
                        timeline.count("faults_injected", 1);
                        match fault.kind {
                            FaultKind::StallRead => timeline
                                .charge(Stage::BasketFetch, fault.stall_s.max(0.0)),
                            _ => {
                                return Err(Error::Io(std::io::Error::other(
                                    "injected DPU job fault",
                                )))
                            }
                        }
                    }
                }
                let scratch = self.client_dir.join("dpu_scratch");
                let out = if dpu_fan_out <= 1 {
                    let mut dpu = DpuNode::new(config.clone(), server, self.runtime, &scratch)
                        .with_ctl(ctl.clone());
                    if let Some(cache) = &self.basket_cache {
                        dpu = dpu.with_basket_cache(cache.clone());
                    }
                    if let Some(zm) = &zone_map {
                        dpu = dpu.with_zone_map(zm.clone());
                    }
                    dpu.run_query_with(query, timeline, None, stages)?
                } else {
                    let mut cluster = DpuCluster::new(
                        dpu_fan_out,
                        config.clone(),
                        server,
                        self.runtime,
                        &scratch,
                    )
                    .with_ctl(ctl.clone());
                    if let Some(cache) = &self.basket_cache {
                        cluster = cluster.with_basket_cache(cache.clone());
                    }
                    if let Some(zm) = &zone_map {
                        cluster = cluster.with_zone_map(zm.clone());
                    }
                    cluster.run_query_with(query, timeline, stages)?
                };
                if ship_output {
                    deployment.client_link.charge(
                        timeline,
                        Stage::OutputTransfer,
                        out.output.len() as u64,
                    );
                }
                std::fs::write(out_path, &out.output)?;
                let mut result = out.result;
                result.output_path = out_path.to_path_buf();
                Ok(result)
            }
        };
        // Surface the storage server's served-byte count in the
        // end-of-job metrics report (`pub_served` was write-only
        // before): zero for placements that bypass the XRootD server
        // (server-side local reads), so only nonzero totals are kept.
        let served = server_stats.bytes_served();
        if served > 0 {
            timeline.count("xrd_bytes_served", served);
        }
        match result {
            Ok(mut r) => {
                if let Some(w) = zone_warning {
                    r.warnings.push(w);
                }
                Ok(r)
            }
            err => err,
        }
    }
}

/// Charge one resubmission's exponential backoff (with deterministic
/// jitter) as virtual time, and record the `retries` / `backoff_us`
/// counters that flow through to job status surfaces.
fn charge_backoff(timeline: &Timeline, attempt: u32, seed: u64) {
    let delay = lifecycle::backoff_delay(attempt, seed);
    timeline.charge(Stage::Other, delay);
    timeline.count("retries", 1);
    timeline.count("backoff_us", (delay * 1e6) as u64);
}

/// Record a terminal lifecycle outcome on the timeline counters.
fn note_terminal(timeline: &Timeline, e: &Error) {
    match e {
        Error::Cancelled(_) => timeline.count("cancelled", 1),
        Error::DeadlineExceeded(_) => timeline.count("deadline_exceeded", 1),
        _ => {}
    }
}

/// Per-node CPU utilization rows for a finished job timeline.
fn node_utilization(timeline: &Timeline) -> Vec<(Node, f64)> {
    [Node::Client, Node::Server, Node::Dpu, Node::DpuEngine]
        .iter()
        .map(|&n| (n, timeline.utilization(n)))
        .collect()
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::gen::{self, GenConfig};

    fn setup(codec: Codec) -> (std::path::PathBuf, std::path::PathBuf) {
        setup_named(codec, "shared")
    }

    /// Per-test dirs: parallel tests must not race on dataset creation.
    fn setup_named(codec: Codec, tag: &str) -> (std::path::PathBuf, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("coord_{}_{codec}_{tag}", std::process::id()));
        let storage = dir.join("storage");
        let client = dir.join("client");
        std::fs::create_dir_all(&storage).unwrap();
        let path = storage.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 800,
                target_branches: 180,
                n_hlt: 40,
                basket_events: 200,
                codec,
                seed: 11,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        (storage, client)
    }

    fn query() -> SkimQuery {
        gen::higgs_query("events.troot", "skim.troot")
    }

    #[test]
    fn all_modes_agree_on_selection() {
        let (storage, client) = setup_named(Codec::Lz4, "all_modes");
        let coord = Coordinator::new(&storage, &client, None);
        let mut n_pass = Vec::new();
        for mode in Mode::ALL {
            let dep = Deployment::new(mode, LinkModel::wan_1g());
            let report = coord.run_job(&query(), &dep).unwrap();
            assert!(report.latency > 0.0);
            assert_eq!(report.name, mode.name());
            n_pass.push(report.result.n_pass);
        }
        assert!(n_pass.iter().all(|&n| n == n_pass[0]), "{n_pass:?}");
        assert!(n_pass[0] > 0);
    }

    #[test]
    fn skimroot_beats_client_side_at_1gbps() {
        let (storage, client) = setup_named(Codec::Lz4, "beats");
        let coord = Coordinator::new(&storage, &client, None);
        let legacy = coord
            .run_job(&query(), &Deployment::client_legacy(LinkModel::wan_1g()))
            .unwrap();
        let dpu = coord
            .run_job(&query(), &Deployment::skim_root(LinkModel::wan_1g()))
            .unwrap();
        // Small test file: fixed costs damp the ratio (the fig4a bench
        // shows the full-gap numbers at scale).
        assert!(
            dpu.latency < legacy.latency / 1.5,
            "dpu {} vs legacy {}",
            dpu.latency,
            legacy.latency
        );
    }

    #[test]
    fn server_side_pays_seeks_skimroot_does_not() {
        let (storage, client) = setup_named(Codec::Lz4, "seeks");
        let coord = Coordinator::new(&storage, &client, None);
        let srv = coord
            .run_job(&query(), &Deployment::server_side(LinkModel::wan_1g()))
            .unwrap();
        let dpu = coord
            .run_job(&query(), &Deployment::skim_root(LinkModel::wan_1g()))
            .unwrap();
        // (The fetch-time gap itself is scale-dependent — at this tiny
        // dataset sequential local reads are nearly free; the fig5a
        // bench asserts the paper-scale gap. Here we check placement.)
        let srv_fetch = srv.timeline.stage_total(Stage::BasketFetch);
        let dpu_fetch = dpu.timeline.stage_total(Stage::BasketFetch);
        assert!(srv_fetch > 0.0 && dpu_fetch > 0.0);
        // Server-side runs without a TTreeCache; SkimROOT with one.
        assert!(srv.result.cache.is_none());
        assert!(dpu.result.cache.is_some());
        // Server-side client CPU is idle; server does the work.
        assert_eq!(srv.timeline.node_busy(Node::Client), 0.0);
        assert!(srv.timeline.node_busy(Node::Server) > 0.0);
        // DPU mode: client and server CPUs mostly idle, DPU busy.
        assert!(dpu.timeline.node_busy(Node::Dpu) > 0.0);
        assert_eq!(dpu.timeline.node_busy(Node::Client), 0.0);
    }

    #[test]
    fn faults_trigger_resubmission_and_eventually_succeed() {
        let (storage, client) = setup_named(Codec::Lz4, "faults");
        let coord = Coordinator::new(&storage, &client, None);
        let mut dep = Deployment::client_opt(LinkModel::dedicated_100g());
        dep.fault = FaultPlan::read_errors(0.3, 50, 3);
        let report = coord.run_job(&query(), &dep).unwrap();
        assert!(report.attempts > 1, "expected at least one resubmission");
        assert!(report.result.n_pass > 0);
        assert!(report.timeline.counter("failures") > 0);
        // Each resubmission charged backoff virtual time + counters.
        let retries = report.timeline.counter("retries");
        assert_eq!(retries, report.attempts as u64 - 1);
        assert!(report.timeline.counter("backoff_us") > 0);
        assert!(report.timeline.counter("faults_injected") > 0);
        assert!(report.timeline.stage_total(Stage::Other) > 0.0);
    }

    #[test]
    fn hopeless_faults_exhaust_retries() {
        let (storage, client) = setup_named(Codec::Lz4, "hopeless");
        let coord = Coordinator::new(&storage, &client, None);
        let mut dep = Deployment::client_opt(LinkModel::dedicated_100g());
        dep.fault = FaultPlan::read_errors(1.0, 2, 3);
        assert!(coord.run_job(&query(), &dep).is_err());
    }

    #[test]
    fn fault_taxonomy_recovers_byte_identical_after_deterministic_retry() {
        // Every corruption-flavored fault kind with `fail_attempts: 1`
        // fails the first attempt and recovers clean on resubmission —
        // the recovered output must be byte-identical to a fault-free
        // run.
        let (storage, client) = setup_named(Codec::Lz4, "taxonomy");
        let coord = Coordinator::new(&storage, &client, None);
        let clean = coord
            .run_job(&query(), &Deployment::client_opt(LinkModel::dedicated_100g()))
            .unwrap();
        let clean_bytes = std::fs::read(&clean.result.output_path).unwrap();
        for kind in [
            FaultKind::ReadError,
            FaultKind::CorruptFrame,
            FaultKind::DecompressCorrupt,
            FaultKind::FailAtRead,
        ] {
            let mut dep = Deployment::client_opt(LinkModel::dedicated_100g());
            dep.fault = FaultPlan {
                kind,
                fail_prob: 1.0,
                fail_at_read: 3,
                fail_attempts: 1,
                max_retries: 3,
                seed: 9,
                ..Default::default()
            };
            let report = coord.run_job(&query(), &dep).unwrap();
            assert_eq!(report.attempts, 2, "{kind:?} should fail exactly once");
            assert!(report.timeline.counter("faults_injected") > 0, "{kind:?}");
            assert_eq!(report.timeline.counter("retries"), 1, "{kind:?}");
            assert_eq!(
                std::fs::read(&report.result.output_path).unwrap(),
                clean_bytes,
                "{kind:?} recovered output diverged from the clean run"
            );
        }
    }

    #[test]
    fn stalled_reads_trip_virtual_time_deadlines() {
        let (storage, client) = setup_named(Codec::Lz4, "stall");
        // Stalls alone: job succeeds, just slower in virtual time.
        let mut dep = Deployment::client_opt(LinkModel::dedicated_100g());
        dep.fault = FaultPlan {
            kind: FaultKind::StallRead,
            fail_prob: 1.0,
            stall_s: 30.0,
            seed: 5,
            ..Default::default()
        };
        let coord = Coordinator::new(&storage, &client, None);
        let slow = coord.run_job(&query(), &dep).unwrap();
        assert!(slow.latency > 30.0, "stalls must charge virtual time");
        // Same plan under a deadline: deterministic DeadlineExceeded
        // (virtual time, so wall-clock speed is irrelevant).
        let coord = Coordinator::new(&storage, &client, None)
            .with_ctl(JobCtl::with_deadline_ms(5_000));
        match coord.run_job(&query(), &dep) {
            Err(Error::DeadlineExceeded(_)) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn pre_cancelled_job_is_terminal_without_attempts() {
        let (storage, client) = setup_named(Codec::Lz4, "precancel");
        let token = crate::lifecycle::CancelToken::new();
        token.cancel();
        let coord = Coordinator::new(&storage, &client, None)
            .with_ctl(JobCtl { cancel: Some(token), deadline_s: None });
        let dep = Deployment::client_opt(LinkModel::dedicated_100g());
        match coord.run_job(&query(), &dep) {
            Err(Error::Cancelled(_)) => {}
            other => panic!("expected Cancelled, got {other:?}"),
        }
    }

    #[test]
    fn circuit_breaker_stops_retrying_before_budget_exhausts() {
        let (storage, client) = setup_named(Codec::Lz4, "breaker");
        let coord = Coordinator::new(&storage, &client, None);
        let mut dep = Deployment::client_opt(LinkModel::dedicated_100g());
        dep.fault = FaultPlan {
            fail_prob: 1.0,
            max_retries: 50,
            breaker_after: 2,
            seed: 3,
            ..Default::default()
        };
        let err = coord.run_job(&query(), &dep).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("circuit breaker open"), "{msg}");
        assert!(msg.contains("after 2 attempts"), "{msg}");
    }

    #[test]
    fn bandwidth_sweep_shrinks_client_side_gap() {
        let (storage, client) = setup_named(Codec::Lz4, "sweep");
        let coord = Coordinator::new(&storage, &client, None);
        let q = query();
        let lat = |link: LinkModel| {
            coord
                .run_job(&q, &Deployment::client_opt(link))
                .unwrap()
                .latency
        };
        let l1 = lat(LinkModel::wan_1g());
        let l10 = lat(LinkModel::shared_10g());
        let l100 = lat(LinkModel::dedicated_100g());
        assert!(l1 > l10 && l10 > l100, "{l1} {l10} {l100}");
    }

    #[test]
    fn output_lands_at_client_in_all_modes() {
        let (storage, client) = setup(Codec::Zlib);
        let coord = Coordinator::new(&storage, &client, None);
        for mode in Mode::ALL {
            let dep = Deployment::new(mode, LinkModel::shared_10g());
            coord.run_job(&query(), &dep).unwrap();
            let out = client.join("skim.troot");
            assert!(out.exists(), "mode {mode:?}");
            let r = crate::troot::TRootReader::open(LocalFile::open(&out).unwrap()).unwrap();
            assert_eq!(r.meta().branches.len(), 89);
            std::fs::remove_file(&out).unwrap();
        }
    }

    // ---------------- redesigned-API coverage -------------------------

    #[test]
    fn presets_are_expressible_via_builder() {
        // Each paper preset is a plain builder configuration — assert
        // the load-bearing knobs, not private wiring.
        let legacy = Deployment::client_legacy(LinkModel::wan_1g());
        assert!(matches!(legacy.placement, Placement::Client));
        assert!(!legacy.two_phase && !legacy.use_pjrt);

        let opt = Deployment::client_opt(LinkModel::wan_1g());
        assert!(matches!(opt.placement, Placement::Client));
        assert!(opt.two_phase && opt.use_pjrt);

        let server = Deployment::server_side(LinkModel::wan_1g());
        assert!(matches!(server.placement, Placement::Server));

        let dpu = Deployment::skim_root(LinkModel::wan_1g());
        assert!(matches!(dpu.placement, Placement::Dpu(_)));
        assert_eq!(dpu.fan_out, 1);
        assert_eq!(dpu.name, "skimroot");
    }

    #[test]
    fn custom_deployment_via_builder_runs() {
        let (storage, client) = setup_named(Codec::Lz4, "builder");
        let coord = Coordinator::new(&storage, &client, None);
        let dep = Deployment::builder()
            .name("nvme-server")
            .placement(Placement::Server)
            .store(crate::net::DiskModel::nvme())
            .link(LinkModel::shared_10g())
            .use_pjrt(false)
            .build()
            .unwrap();
        let report = coord.run_job(&query(), &dep).unwrap();
        assert_eq!(report.name, "nvme-server");
        assert!(report.result.n_pass > 0);
    }

    #[test]
    fn builder_rejects_bad_fan_out() {
        assert!(Deployment::builder().fan_out(0).build().is_err());
        assert!(Deployment::builder()
            .placement(Placement::Client)
            .fan_out(2)
            .build()
            .is_err());
        assert!(Deployment::builder()
            .placement(Placement::Dpu(DpuConfig::default()))
            .fan_out(2)
            .build()
            .is_ok());
    }

    #[test]
    fn multi_dpu_fan_out_matches_single_dpu() {
        let (storage, client) = setup_named(Codec::Lz4, "fanout");
        let coord = Coordinator::new(&storage, &client, None);
        let single = coord
            .run_job(&query(), &Deployment::skim_root(LinkModel::wan_1g()))
            .unwrap();
        let dep = Deployment::builder()
            .name("skimroot-x3")
            .placement(Placement::Dpu(DpuConfig::default()))
            .link(LinkModel::wan_1g())
            .fan_out(3)
            .build()
            .unwrap();
        let fanned = coord.run_job(&query(), &dep).unwrap();
        assert_eq!(fanned.result.n_pass, single.result.n_pass);
        assert_eq!(fanned.result.n_events, single.result.n_events);
        assert_eq!(fanned.result.stage_funnel, single.result.stage_funnel);
        assert_eq!(fanned.timeline.counter("dpu_shards"), 3);
        // The merged output is a valid troot file with the full schema.
        let out = client.join("skim.troot");
        let r = crate::troot::TRootReader::open(LocalFile::open(&out).unwrap()).unwrap();
        assert_eq!(r.meta().branches.len(), 89);
        assert_eq!(r.n_events(), fanned.result.n_pass);
    }

    // ---------------- dataset-layer coverage --------------------------

    /// A 3-file dataset under its own storage root, plus a catalog.
    fn setup_dataset(tag: &str) -> (std::path::PathBuf, std::path::PathBuf) {
        let dir =
            std::env::temp_dir().join(format!("coord_ds_{}_{tag}", std::process::id()));
        let storage = dir.join("storage");
        let client = dir.join("client");
        std::fs::create_dir_all(storage.join("store")).unwrap();
        for i in 0..3u64 {
            let path = storage.join(format!("store/part{i}.troot"));
            if !path.exists() {
                let cfg = GenConfig {
                    n_events: 400,
                    target_branches: 160,
                    n_hlt: 40,
                    basket_events: 200,
                    codec: Codec::Lz4,
                    seed: 100 + i,
                };
                gen::generate(&cfg, &path).unwrap();
            }
        }
        std::fs::write(
            storage.join("all.catalog"),
            "store/part0.troot\nstore/part1.troot\nstore/part2.troot\n",
        )
        .unwrap();
        (storage, client)
    }

    #[test]
    fn dataset_glob_aggregates_files_and_merges() {
        let (storage, client) = setup_dataset("glob");
        let coord = Coordinator::new(&storage, &client, None);
        let q = gen::higgs_query("store/*.troot", "ds.troot");
        let report = coord
            .run_job(&q, &Deployment::client_opt(LinkModel::dedicated_100g()))
            .unwrap();
        assert_eq!(report.files_total(), 3);
        assert_eq!(report.files_done(), 3);
        assert_eq!(report.files_failed(), 0);
        assert_eq!(
            report.result.n_events,
            report.files.iter().map(|f| f.n_events).sum::<u64>()
        );
        assert_eq!(report.result.n_events, 1200);
        assert!(report.result.n_pass > 0);
        assert_eq!(report.timeline.counter("files_total"), 3);
        assert_eq!(report.timeline.counter("files_done"), 3);
        // The merged output holds exactly the passing events.
        let r = crate::troot::TRootReader::open(
            LocalFile::open(client.join("ds.troot")).unwrap(),
        )
        .unwrap();
        assert_eq!(r.n_events(), report.result.n_pass);
    }

    #[test]
    fn dataset_named_catalog_matches_glob_byte_for_byte() {
        let (storage, client) = setup_dataset("catalog");
        let coord = Coordinator::new(&storage, &client, None);
        let dep = Deployment::client_opt(LinkModel::dedicated_100g());
        let a = coord
            .run_job(&gen::higgs_query("store/*.troot", "a.troot"), &dep)
            .unwrap();
        let b = coord
            .run_job(&gen::higgs_query("catalog:all", "b.troot"), &dep)
            .unwrap();
        assert_eq!(a.result.n_pass, b.result.n_pass);
        assert_eq!(
            std::fs::read(client.join("a.troot")).unwrap(),
            std::fs::read(client.join("b.troot")).unwrap()
        );
    }

    #[test]
    fn dataset_rejects_path_traversal_with_config_error() {
        let (storage, client) = setup_dataset("traversal");
        let coord = Coordinator::new(&storage, &client, None);
        let dep = Deployment::client_opt(LinkModel::dedicated_100g());
        for input in ["../../secret.troot", "/etc/passwd"] {
            let q = SkimQuery::new(input, "out.troot");
            let err = coord.run_job(&q, &dep).err().expect("traversal must be rejected");
            match err {
                Error::Config(msg) => {
                    assert!(msg.contains("escapes the storage root"), "{msg}")
                }
                other => panic!("expected config error for {input}, got {other}"),
            }
        }
        // Explicit lists are validated entry-by-entry too.
        let q = SkimQuery::new(
            vec!["store/part0.troot".to_string(), "../leak.troot".to_string()],
            "out.troot",
        );
        assert!(matches!(coord.run_job(&q, &dep), Err(Error::Config(_))));
    }

    #[test]
    fn dataset_isolates_per_file_failures() {
        let (storage, client) = setup_dataset("faulty");
        // A dataset where one entry does not exist: that file fails,
        // the others complete, and the job still succeeds.
        let mut q = gen::higgs_query("store/part0.troot", "iso.troot");
        q.input = crate::query::DatasetSpec::Files(vec![
            "store/part0.troot".into(),
            "store/missing.troot".into(),
            "store/part2.troot".into(),
        ]);
        let coord = Coordinator::new(&storage, &client, None);
        let mut dep = Deployment::client_opt(LinkModel::dedicated_100g());
        dep.fault.max_retries = 1;
        let report = coord.run_job(&q, &dep).unwrap();
        assert_eq!(report.files_total(), 3);
        assert_eq!(report.files_done(), 2);
        assert_eq!(report.files_failed(), 1);
        assert!(report.files[1].error.is_some());
        assert!(report.files[1].attempts >= 2, "failed file retried");
        assert!(report
            .result
            .warnings
            .iter()
            .any(|w| w.contains("store/missing.troot")));
        assert_eq!(report.result.n_events, 800);
        // All files failing fails the job.
        q.input = crate::query::DatasetSpec::Files(vec![
            "store/gone1.troot".into(),
            "store/gone2.troot".into(),
        ]);
        let err = coord.run_job(&q, &dep).unwrap_err();
        assert!(format!("{err}").contains("all 2 files failed"), "{err}");
    }

    #[test]
    fn dataset_stripes_files_across_dpu_lanes() {
        let (storage, client) = setup_dataset("stripe");
        let coord = Coordinator::new(&storage, &client, None);
        let q = gen::higgs_query("store/*.troot", "striped.troot");
        let single = coord
            .run_job(&q, &Deployment::skim_root(LinkModel::wan_1g()))
            .unwrap();
        let single_bytes = std::fs::read(client.join("striped.troot")).unwrap();
        let dep = Deployment::builder()
            .name("skimroot-x3")
            .placement(Placement::Dpu(DpuConfig::default()))
            .link(LinkModel::wan_1g())
            .fan_out(3)
            .build()
            .unwrap();
        let fanned = coord.run_job(&q, &dep).unwrap();
        // Same selection, byte-identical merged output regardless of
        // fan-out, and the fanned run's critical lane carries ~1 of
        // the 3 files, so it finishes faster.
        assert_eq!(fanned.result.n_pass, single.result.n_pass);
        assert!(fanned.latency < single.latency, "{} vs {}", fanned.latency, single.latency);
        assert_eq!(single_bytes, std::fs::read(client.join("striped.troot")).unwrap());
    }

    // ---------------- shared-scan batches -----------------------------

    fn cut_query(cut: &str, outname: &str) -> SkimQuery {
        SkimQuery::new("events.troot", outname)
            .keep(&["MET_pt", "event", "nJet", "Jet_pt", "nMuon", "Muon_pt"])
            .with_cut_str(cut)
            .unwrap()
    }

    #[test]
    fn shared_batch_is_byte_identical_to_solo_runs_and_dpu_fanout() {
        let (storage, client) = setup_named(Codec::Lz4, "mqo_id");
        let coord = Coordinator::new(&storage, &client, None);
        let cuts = [
            "MET_pt > 25 || max(Jet_pt) > 60",
            "nMuon >= 1 && max(Muon_pt) > 30",
            "MET_pt > 60",
        ];
        let queries: Vec<SkimQuery> = cuts
            .iter()
            .enumerate()
            .map(|(i, c)| cut_query(c, &format!("mqo{i}.troot")))
            .collect();

        let mut dep = Deployment::server_side(LinkModel::local());
        dep.use_pjrt = false;
        let reports = coord.run_shared(&queries, &dep, 7).unwrap();
        assert_eq!(reports.len(), 3);

        let mut client_dep = Deployment::client_opt(LinkModel::wan_1g());
        client_dep.use_pjrt = false;
        let mut dpu_dep = Deployment::skim_root(LinkModel::wan_1g());
        dpu_dep.fan_out = 4;

        for (i, q) in queries.iter().enumerate() {
            let r = &reports[i];
            assert_eq!(r.batch, Some(crate::mqo::BatchInfo { id: 7, members: 3 }));
            assert_eq!(r.attempts, 1);
            assert!(r.timeline.counter("scan_shared") > 0, "member {i} saw no shared scan");
            let shared_bytes = std::fs::read(&r.result.output_path).unwrap();

            // Solo on the same deployment: byte-identical output,
            // identical mask and funnel.
            let solo = coord.run_job(q, &dep).unwrap();
            assert_eq!(r.result.n_pass, solo.result.n_pass, "member {i}");
            assert_eq!(r.result.stage_funnel, solo.result.stage_funnel, "member {i}");
            let solo_bytes = std::fs::read(&solo.result.output_path).unwrap();
            assert_eq!(shared_bytes, solo_bytes, "member {i} vs server solo");

            // And across placements: client solo and DPU fan_out-4
            // solo produce the same bytes too (solo outputs are
            // placement- and fan-out-invariant).
            let csolo = coord.run_job(q, &client_dep).unwrap();
            assert_eq!(
                shared_bytes,
                std::fs::read(&csolo.result.output_path).unwrap(),
                "member {i} vs client solo"
            );
            let dsolo = coord.run_job(q, &dpu_dep).unwrap();
            assert_eq!(
                shared_bytes,
                std::fs::read(&dsolo.result.output_path).unwrap(),
                "member {i} vs dpu fan-out 4 solo"
            );
        }

        // Amortized scan shares sum to a consistent whole: every
        // member carries a nonzero slice of the one scan.
        let scanned: u64 =
            reports.iter().map(|r| r.timeline.counter("baskets_scanned")).sum();
        assert!(scanned > 0);
    }

    #[test]
    fn shared_batch_rejects_mixed_datasets_and_unsupported_deployments() {
        let (storage, client) = setup_named(Codec::Lz4, "mqo_rej");
        // A second, different file in the same storage root.
        let other = storage.join("other.troot");
        if !other.exists() {
            let cfg = GenConfig {
                n_events: 400,
                target_branches: 180,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 12,
            };
            gen::generate(&cfg, &other).unwrap();
        }
        let coord = Coordinator::new(&storage, &client, None);
        let mut dep = Deployment::server_side(LinkModel::local());
        dep.use_pjrt = false;

        // Mixed resolved datasets must not batch.
        let mixed = [
            cut_query("MET_pt > 25", "mix0.troot"),
            SkimQuery::new("other.troot", "mix1.troot")
                .keep(&["MET_pt"])
                .with_cut_str("MET_pt > 25")
                .unwrap(),
        ];
        let err = coord.run_shared(&mixed, &dep, 1).unwrap_err();
        assert!(format!("{err}").contains("same resolved dataset"), "{err}");

        // Unsupported deployments are refused with the predicate's
        // reason.
        let same = [cut_query("MET_pt > 25", "a.troot"), cut_query("MET_pt > 60", "b.troot")];
        let mut faulty = Deployment::server_side(LinkModel::local());
        faulty.fault.fail_prob = 0.5;
        for bad in [
            Deployment::skim_root(LinkModel::wan_1g()),
            Deployment::client_legacy(LinkModel::wan_1g()),
            faulty,
        ] {
            let err = coord.run_shared(&same, &bad, 2).unwrap_err();
            assert!(
                format!("{err}").contains("cannot host shared scans"),
                "{bad:?} → {err}"
            );
        }
        // Empty batches are refused.
        assert!(coord.run_shared(&[], &dep, 3).is_err());
    }

    #[test]
    fn mode_parse_lists_valid_names_on_error() {
        let err = Mode::parse("warp-drive").unwrap_err();
        let msg = format!("{err}");
        for mode in Mode::ALL {
            assert!(msg.contains(mode.name()), "missing {} in: {msg}", mode.name());
        }
        // Aliases still accepted.
        assert_eq!(Mode::parse("dpu").unwrap(), Mode::SkimRoot);
        assert_eq!(Mode::parse("legacy").unwrap(), Mode::ClientLegacy);
        assert_eq!(Mode::parse("client-opt").unwrap(), Mode::ClientOpt);
    }
}
