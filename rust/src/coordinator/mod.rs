//! The job coordinator: deploys a skim across the testbed topology and
//! produces the paper's comparison rows.
//!
//! A [`Deployment`] fixes *where* filtering runs and over *which*
//! links, reproducing §4's four methods:
//!
//! | mode | data path | filter on | decompress | TTreeCache |
//! |---|---|---|---|---|
//! | `ClientLegacy` | storage → client over WAN | client (per-event, single-phase) | client CPU | yes |
//! | `ClientOpt` | storage → client over WAN | client (two-phase, vectorized) | client CPU | yes |
//! | `ServerSide` | local disk | server (two-phase, vectorized) | server CPU | **no** (local access) |
//! | `SkimRoot` | storage → DPU over PCIe | DPU ARM cores | **hw engine** | yes |
//!
//! All modes ship the filtered file to the client at the end (a no-op
//! for the client-side modes, where the output is already there).
//!
//! The coordinator also models WLCG's operational reality (§1: "jobs
//! frequently fail and require resubmission"): a [`FaultConfig`]
//! injects storage-read failures; failed attempts burn their time on
//! the job timeline and the job is retried, exactly like a WLCG
//! resubmission.

pub mod eval;

use crate::dpu::{DpuConfig, DpuNode};
use crate::engine::{DecompMode, EngineOpts, SkimEngine, SkimResult};
use crate::metrics::{Node, Stage, Timeline};
use crate::net::{DiskModel, LinkModel, ModeledStore};
use crate::query::SkimQuery;
use crate::runtime::SkimRuntime;
use crate::troot::{LocalFile, ReadAt};
use crate::util::Pcg32;
use crate::xrootd::{LoopbackWire, XrdClient, XrdServer};
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Which of the paper's four methods to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Unoptimized client-side filtering: single-phase, per-event
    /// interpreter (the hand-written-macro baseline).
    ClientLegacy,
    /// Client-side with SkimROOT's two-phase model + vectorized eval
    /// ("Client Opt" in Figure 4).
    ClientOpt,
    /// Filtering on the storage server itself (local reads, no cache).
    ServerSide,
    /// Near-storage filtering on the DPU.
    SkimRoot,
}

impl Mode {
    pub const ALL: [Mode; 4] = [Mode::ClientLegacy, Mode::ClientOpt, Mode::ServerSide, Mode::SkimRoot];

    pub fn name(self) -> &'static str {
        match self {
            Mode::ClientLegacy => "client-legacy",
            Mode::ClientOpt => "client-opt",
            Mode::ServerSide => "server-side",
            Mode::SkimRoot => "skimroot",
        }
    }

    pub fn parse(s: &str) -> Result<Mode> {
        Ok(match s {
            "client" | "client-legacy" | "legacy" => Mode::ClientLegacy,
            "client-opt" | "opt" => Mode::ClientOpt,
            "server" | "server-side" => Mode::ServerSide,
            "skimroot" | "dpu" => Mode::SkimRoot,
            other => return Err(Error::Config(format!("unknown mode '{other}'"))),
        })
    }
}

/// WLCG-style failure injection: each storage read fails with
/// `read_fail_prob`; the coordinator resubmits up to `max_retries`.
#[derive(Debug, Clone, Copy)]
pub struct FaultConfig {
    pub read_fail_prob: f64,
    pub max_retries: u32,
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig { read_fail_prob: 0.0, max_retries: 3, seed: 0 }
    }
}

/// Full testbed description for one job.
#[derive(Clone)]
pub struct Deployment {
    pub mode: Mode,
    /// Client ↔ storage-site link (the 1/10/100 Gbps axis of Fig. 4a).
    pub client_link: LinkModel,
    /// Storage backend behind the XRootD server.
    pub disk: DiskModel,
    pub dpu: DpuConfig,
    pub fault: FaultConfig,
    /// TTreeCache capacity for remote clients.
    pub cache_bytes: usize,
}

impl Deployment {
    pub fn new(mode: Mode, client_link: LinkModel) -> Self {
        Deployment {
            mode,
            client_link,
            disk: DiskModel::disk_pool(),
            dpu: DpuConfig::default(),
            fault: FaultConfig::default(),
            cache_bytes: crate::xrootd::DEFAULT_CACHE_BYTES,
        }
    }
}

/// Result of a coordinated job: engine outcome + per-node accounting.
pub struct JobReport {
    pub mode: Mode,
    pub result: SkimResult,
    pub timeline: Timeline,
    /// End-to-end latency (request submission → filtered file at the
    /// client), seconds.
    pub latency: f64,
    pub attempts: u32,
    pub utilization: Vec<(Node, f64)>,
}

impl JobReport {
    /// Per-stage breakdown rows (the Fig. 4b / 5a decomposition).
    pub fn breakdown(&self) -> Vec<(Stage, f64)> {
        Stage::ALL
            .iter()
            .map(|&s| (s, self.timeline.stage_total(s)))
            .filter(|&(_, t)| t > 0.0)
            .collect()
    }
}

/// A `ReadAt` wrapper that injects deterministic read failures.
struct FlakyStore<R> {
    inner: R,
    fail_prob: f64,
    rng_state: AtomicU64,
}

impl<R> FlakyStore<R> {
    fn new(inner: R, fail_prob: f64, seed: u64) -> Self {
        FlakyStore { inner, fail_prob, rng_state: AtomicU64::new(seed) }
    }

    fn should_fail(&self) -> bool {
        if self.fail_prob <= 0.0 {
            return false;
        }
        let s = self.rng_state.fetch_add(1, Ordering::Relaxed);
        let mut rng = Pcg32::new(s);
        rng.chance(self.fail_prob)
    }
}

impl<R: ReadAt> ReadAt for FlakyStore<R> {
    fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        if self.should_fail() {
            return Err(Error::Io(std::io::Error::other("injected storage fault")));
        }
        self.inner.read_at(offset, len)
    }

    fn read_vec(&self, ranges: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        if self.should_fail() {
            return Err(Error::Io(std::io::Error::other("injected storage fault")));
        }
        self.inner.read_vec(ranges)
    }

    fn size(&self) -> Result<u64> {
        self.inner.size()
    }
}

/// The coordinator: owns the storage root and runtime handle, runs
/// jobs under any deployment.
pub struct Coordinator<'rt> {
    storage_root: std::path::PathBuf,
    runtime: Option<&'rt SkimRuntime>,
    /// Where client-side outputs / shipped outputs land.
    client_dir: std::path::PathBuf,
}

impl<'rt> Coordinator<'rt> {
    pub fn new(
        storage_root: impl Into<std::path::PathBuf>,
        client_dir: impl Into<std::path::PathBuf>,
        runtime: Option<&'rt SkimRuntime>,
    ) -> Self {
        Coordinator {
            storage_root: storage_root.into(),
            runtime,
            client_dir: client_dir.into(),
        }
    }

    /// Run one skim job under `deployment`, with WLCG-style retries.
    pub fn run_job(&self, query: &SkimQuery, deployment: &Deployment) -> Result<JobReport> {
        let timeline = Timeline::new();
        let mut attempts = 0;
        loop {
            attempts += 1;
            // Each attempt gets a distinct fault stream: a resubmitted
            // job does not hit the identical failure.
            let attempt_seed = deployment
                .fault
                .seed
                .wrapping_add(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(attempts as u64));
            match self.run_attempt(query, deployment, &timeline, attempt_seed) {
                Ok(result) => {
                    timeline.count("attempts", 1);
                    let latency = timeline.elapsed();
                    let utilization = [Node::Client, Node::Server, Node::Dpu, Node::DpuEngine]
                        .iter()
                        .map(|&n| (n, timeline.utilization(n)))
                        .collect();
                    return Ok(JobReport {
                        mode: deployment.mode,
                        result,
                        timeline,
                        latency,
                        attempts,
                    utilization,
                    });
                }
                Err(e) => {
                    timeline.count("attempts", 1);
                    timeline.count("failures", 1);
                    if attempts > deployment.fault.max_retries {
                        return Err(Error::Engine(format!(
                            "job failed after {attempts} attempts: {e}"
                        )));
                    }
                    // Resubmission overhead (scheduling delay in WLCG).
                    timeline.charge(Stage::Other, 1.0);
                }
            }
        }
    }

    fn run_attempt(
        &self,
        query: &SkimQuery,
        deployment: &Deployment,
        timeline: &Timeline,
        fault_seed: u64,
    ) -> Result<SkimResult> {
        std::fs::create_dir_all(&self.client_dir)?;
        let out_path = self.client_dir.join(sanitize(&query.output));
        let server = XrdServer::new(&self.storage_root, deployment.disk);
        server.set_timeline(Some(timeline.clone()));

        let wrap_faults = |store: Arc<dyn ReadAt>| -> Arc<dyn ReadAt> {
            if deployment.fault.read_fail_prob > 0.0 {
                Arc::new(FlakyStore::new(
                    store,
                    deployment.fault.read_fail_prob,
                    fault_seed,
                ))
            } else {
                store
            }
        };

        match deployment.mode {
            Mode::ClientLegacy | Mode::ClientOpt => {
                let optimized = deployment.mode == Mode::ClientOpt;
                let wire = Arc::new(LoopbackWire::new(
                    server,
                    deployment.client_link,
                    timeline.clone(),
                ));
                let client = XrdClient::new(wire);
                let remote: Arc<dyn ReadAt> = Arc::new(client.open(&query.input)?);
                let store = wrap_faults(remote);
                let opts = EngineOpts {
                    two_phase: optimized,
                    use_pjrt: optimized,
                    compute_node: Node::Client,
                    decomp: DecompMode::Software,
                    cache_bytes: Some(deployment.cache_bytes),
                    output_codec: None,
                    max_objects: 16,
                    ..Default::default()
                };
                let engine = SkimEngine::new(self.runtime);
                // Output is produced directly on the client: no final
                // transfer hop.
                engine.run(store, query, timeline, &opts, &out_path)
            }
            Mode::ServerSide => {
                // Local reads: no XRootD in the path, no TTreeCache
                // (§4: "TTreeCache does not function for local ROOT
                // file access"), per-basket disk seeks.
                let local = LocalFile::open(self.storage_root.join(&query.input))?;
                let modeled: Arc<dyn ReadAt> =
                    Arc::new(ModeledStore::new(local, deployment.disk, timeline.clone()));
                let store = wrap_faults(modeled);
                let opts = EngineOpts {
                    two_phase: true,
                    use_pjrt: true,
                    compute_node: Node::Server,
                    decomp: DecompMode::Software,
                    cache_bytes: None,
                    output_codec: None,
                    max_objects: 16,
                    ..Default::default()
                };
                let engine = SkimEngine::new(self.runtime);
                let result = engine.run(store, query, timeline, &opts, &out_path)?;
                // Ship the filtered file to the client.
                deployment.client_link.charge(
                    timeline,
                    Stage::OutputTransfer,
                    result.output_bytes,
                );
                Ok(result)
            }
            Mode::SkimRoot => {
                // The DPU path: PCIe-attached near-storage filtering.
                // (Fault injection applies inside the DPU's fetch path
                // through the storage server; model faults at the job
                // level by wrapping the DPU scratch read — the DPU
                // retries whole jobs like any WLCG worker.)
                if deployment.fault.read_fail_prob > 0.0 {
                    let mut rng = Pcg32::new(fault_seed);
                    if rng.chance(deployment.fault.read_fail_prob) {
                        return Err(Error::Io(std::io::Error::other(
                            "injected DPU job fault",
                        )));
                    }
                }
                let scratch = self.client_dir.join("dpu_scratch");
                let dpu = DpuNode::new(deployment.dpu.clone(), server, self.runtime, &scratch);
                let out = dpu.run_query(query, timeline)?;
                dpu.ship_output(out.output.len(), &deployment.client_link, timeline);
                std::fs::write(&out_path, &out.output)?;
                Ok(out.result)
            }
        }
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::gen::{self, GenConfig};

    fn setup(codec: Codec) -> (std::path::PathBuf, std::path::PathBuf) {
        setup_named(codec, "shared")
    }

    /// Per-test dirs: parallel tests must not race on dataset creation.
    fn setup_named(codec: Codec, tag: &str) -> (std::path::PathBuf, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("coord_{}_{codec}_{tag}", std::process::id()));
        let storage = dir.join("storage");
        let client = dir.join("client");
        std::fs::create_dir_all(&storage).unwrap();
        let path = storage.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 800,
                target_branches: 180,
                n_hlt: 40,
                basket_events: 200,
                codec,
                seed: 11,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        (storage, client)
    }

    fn query() -> SkimQuery {
        gen::higgs_query("events.troot", "skim.troot")
    }

    #[test]
    fn all_modes_agree_on_selection() {
        let (storage, client) = setup_named(Codec::Lz4, "all_modes");
        let coord = Coordinator::new(&storage, &client, None);
        let mut n_pass = Vec::new();
        for mode in Mode::ALL {
            let dep = Deployment::new(mode, LinkModel::wan_1g());
            let report = coord.run_job(&query(), &dep).unwrap();
            assert!(report.latency > 0.0);
            n_pass.push(report.result.n_pass);
        }
        assert!(n_pass.iter().all(|&n| n == n_pass[0]), "{n_pass:?}");
        assert!(n_pass[0] > 0);
    }

    #[test]
    fn skimroot_beats_client_side_at_1gbps() {
        let (storage, client) = setup_named(Codec::Lz4, "beats");
        let coord = Coordinator::new(&storage, &client, None);
        let legacy = coord
            .run_job(&query(), &Deployment::new(Mode::ClientLegacy, LinkModel::wan_1g()))
            .unwrap();
        let dpu = coord
            .run_job(&query(), &Deployment::new(Mode::SkimRoot, LinkModel::wan_1g()))
            .unwrap();
        // Small test file: fixed costs damp the ratio (the fig4a bench
        // shows the full-gap numbers at scale).
        assert!(
            dpu.latency < legacy.latency / 1.5,
            "dpu {} vs legacy {}",
            dpu.latency,
            legacy.latency
        );
    }

    #[test]
    fn server_side_pays_seeks_skimroot_does_not() {
        let (storage, client) = setup_named(Codec::Lz4, "seeks");
        let coord = Coordinator::new(&storage, &client, None);
        let srv = coord
            .run_job(&query(), &Deployment::new(Mode::ServerSide, LinkModel::wan_1g()))
            .unwrap();
        let dpu = coord
            .run_job(&query(), &Deployment::new(Mode::SkimRoot, LinkModel::wan_1g()))
            .unwrap();
        // (The fetch-time gap itself is scale-dependent — at this tiny
        // dataset sequential local reads are nearly free; the fig5a
        // bench asserts the paper-scale gap. Here we check placement.)
        let srv_fetch = srv.timeline.stage_total(Stage::BasketFetch);
        let dpu_fetch = dpu.timeline.stage_total(Stage::BasketFetch);
        assert!(srv_fetch > 0.0 && dpu_fetch > 0.0);
        // Server-side runs without a TTreeCache; SkimROOT with one.
        assert!(srv.result.cache.is_none());
        assert!(dpu.result.cache.is_some());
        // Server-side client CPU is idle; server does the work.
        assert_eq!(srv.timeline.node_busy(Node::Client), 0.0);
        assert!(srv.timeline.node_busy(Node::Server) > 0.0);
        // DPU mode: client and server CPUs mostly idle, DPU busy.
        assert!(dpu.timeline.node_busy(Node::Dpu) > 0.0);
        assert_eq!(dpu.timeline.node_busy(Node::Client), 0.0);
    }

    #[test]
    fn faults_trigger_resubmission_and_eventually_succeed() {
        let (storage, client) = setup_named(Codec::Lz4, "faults");
        let coord = Coordinator::new(&storage, &client, None);
        let mut dep = Deployment::new(Mode::ClientOpt, LinkModel::dedicated_100g());
        dep.fault = FaultConfig { read_fail_prob: 0.3, max_retries: 50, seed: 3 };
        let report = coord.run_job(&query(), &dep).unwrap();
        assert!(report.attempts > 1, "expected at least one resubmission");
        assert!(report.result.n_pass > 0);
        assert!(report.timeline.counter("failures") > 0);
    }

    #[test]
    fn hopeless_faults_exhaust_retries() {
        let (storage, client) = setup_named(Codec::Lz4, "hopeless");
        let coord = Coordinator::new(&storage, &client, None);
        let mut dep = Deployment::new(Mode::ClientOpt, LinkModel::dedicated_100g());
        dep.fault = FaultConfig { read_fail_prob: 1.0, max_retries: 2, seed: 3 };
        assert!(coord.run_job(&query(), &dep).is_err());
    }

    #[test]
    fn bandwidth_sweep_shrinks_client_side_gap() {
        let (storage, client) = setup_named(Codec::Lz4, "sweep");
        let coord = Coordinator::new(&storage, &client, None);
        let q = query();
        let lat = |link: LinkModel| {
            coord
                .run_job(&q, &Deployment::new(Mode::ClientOpt, link))
                .unwrap()
                .latency
        };
        let l1 = lat(LinkModel::wan_1g());
        let l10 = lat(LinkModel::shared_10g());
        let l100 = lat(LinkModel::dedicated_100g());
        assert!(l1 > l10 && l10 > l100, "{l1} {l10} {l100}");
    }

    #[test]
    fn output_lands_at_client_in_all_modes() {
        let (storage, client) = setup(Codec::Zlib);
        let coord = Coordinator::new(&storage, &client, None);
        for mode in Mode::ALL {
            let dep = Deployment::new(mode, LinkModel::shared_10g());
            coord.run_job(&query(), &dep).unwrap();
            let out = client.join("skim.troot");
            assert!(out.exists(), "mode {mode:?}");
            let r = crate::troot::TRootReader::open(LocalFile::open(&out).unwrap()).unwrap();
            assert_eq!(r.meta().branches.len(), 89);
            std::fs::remove_file(&out).unwrap();
        }
    }
}
