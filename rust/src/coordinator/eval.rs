//! The paper-evaluation harness: one function per figure in §4,
//! shared by `cargo bench` targets, the `skimroot eval` subcommand and
//! the `higgs_skim` example.
//!
//! Each function runs the real pipeline (generation → deployment →
//! skim) at a configurable scale and renders the same rows the paper
//! reports, with the paper's testbed numbers printed alongside for
//! shape comparison. Absolute values differ (software substrate,
//! scaled dataset); the comparisons that must hold are: who wins, by
//! roughly what factor, and where the crossovers fall.

use super::{Deployment, JobReport, Mode, Placement};
use crate::compress::Codec;
use crate::gen::{self, GenConfig};
use crate::job::SkimJob;
use crate::metrics::{Node, Stage};
use crate::net::LinkModel;
use crate::query::SkimQuery;
use crate::runtime::SkimRuntime;
use crate::util::human_secs;
use crate::Result;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Dataset scale for an evaluation run.
#[derive(Debug, Clone, Copy)]
pub struct EvalScale {
    /// Events per generated dataset.
    pub n_events: u64,
    /// Total branch count (paper: 1749).
    pub target_branches: usize,
    /// Number of `HLT_*` flags (paper: 677).
    pub n_hlt: usize,
    /// Events per basket.
    pub basket_events: u32,
}

impl EvalScale {
    /// Fast scale for `cargo bench` smoke runs (~seconds).
    pub fn small() -> Self {
        EvalScale { n_events: 6_000, target_branches: 240, n_hlt: 60, basket_events: 500 }
    }

    /// Default evaluation scale: the paper's full branch census
    /// (1749 branches, 677 HLT flags) at a laptop-friendly event count.
    pub fn standard() -> Self {
        EvalScale { n_events: 30_000, target_branches: 1749, n_hlt: 677, basket_events: 1000 }
    }
}

/// Prepared on-disk evaluation environment.
pub struct EvalEnv {
    /// Storage directory the datasets live in.
    pub storage: PathBuf,
    /// Client directory outputs land in.
    pub client: PathBuf,
    /// Catalog name of the LZ4-compressed dataset.
    pub lz4: String,
    /// Catalog name of the LZMA-class (xz-like) dataset.
    pub xz: String,
    /// The scale the datasets were generated at.
    pub scale: EvalScale,
    /// Bandwidth scale factor: our LZ4 file size / the paper's 5 GB.
    /// Link and disk *bandwidths* are multiplied by this so the
    /// dataset:bandwidth proportions match the paper's testbed (paying
    /// 5 GB of real transfers per bench run is not viable); latencies
    /// (RTT, seek) stay physical. See DESIGN.md §Execution-time model.
    pub bw_scale: f64,
}

/// The paper's LZ4 dataset size that bandwidths are normalized to.
pub const PAPER_LZ4_BYTES: f64 = 5.0e9;

/// Generate (once) the LZ4 and xz-like variants of the evaluation
/// dataset under `dir/storage`, mirroring the paper's "compressed to
/// 3 GB with LZMA and 5 GB with LZ4" file pair.
pub fn prepare(dir: impl AsRef<Path>, scale: EvalScale) -> Result<EvalEnv> {
    let dir = dir.as_ref();
    let storage = dir.join("storage");
    let client = dir.join("client");
    std::fs::create_dir_all(&storage)?;
    std::fs::create_dir_all(&client)?;
    let lz4 = format!("events_{}k_lz4.troot", scale.n_events / 1000);
    let xz = format!("events_{}k_xz.troot", scale.n_events / 1000);
    for (name, codec) in [(&lz4, Codec::Lz4), (&xz, Codec::XzLike)] {
        let path = storage.join(name);
        if !path.exists() {
            let cfg = GenConfig {
                n_events: scale.n_events,
                target_branches: scale.target_branches,
                n_hlt: scale.n_hlt,
                basket_events: scale.basket_events,
                codec,
                seed: 0x4a55,
            };
            eprintln!("[eval] generating {name} ({} events)...", scale.n_events);
            let summary = gen::generate(&cfg, &path)?;
            eprintln!(
                "[eval]   {} branches, {} → {} (ratio {:.2})",
                summary.n_branches,
                crate::util::human_bytes(summary.raw_bytes),
                crate::util::human_bytes(summary.file_bytes),
                summary.compression_ratio()
            );
        }
    }
    let lz4_bytes = std::fs::metadata(storage.join(&lz4))?.len() as f64;
    let bw_scale = (lz4_bytes / PAPER_LZ4_BYTES).min(1.0);
    Ok(EvalEnv { storage, client, lz4, xz, scale, bw_scale })
}

/// Deployment with testbed bandwidths scaled to the dataset.
fn deployment(env: &EvalEnv, mode: Mode, link: LinkModel) -> Deployment {
    let mut dep = Deployment::new(mode, link.scaled(env.bw_scale));
    dep.disk = dep.disk.scaled(env.bw_scale);
    if let Placement::Dpu(cfg) = &mut dep.placement {
        cfg.pcie = cfg.pcie.scaled(env.bw_scale);
    }
    dep
}

/// Run one figure row through the [`SkimJob`] facade.
fn run_row(
    env: &EvalEnv,
    runtime: Option<&SkimRuntime>,
    query: &SkimQuery,
    dep: Deployment,
) -> Result<JobReport> {
    SkimJob::new(query.clone())
        .storage(&env.storage)
        .client_dir(&env.client)
        .runtime(runtime)
        .deployment(dep)
        .run()
}

/// The four §4 methods with their dataset variant.
fn methods(env: &EvalEnv) -> [(&'static str, Mode, String, Option<f64>); 4] {
    [
        // (label, mode, input file, paper latency @1 Gbps)
        ("Client LZMA", Mode::ClientLegacy, env.xz.clone(), Some(430.0)),
        ("Client LZ4", Mode::ClientLegacy, env.lz4.clone(), Some(382.1)),
        ("Client Opt LZ4", Mode::ClientOpt, env.lz4.clone(), Some(155.9)),
        ("SkimROOT", Mode::SkimRoot, env.lz4.clone(), Some(8.62)),
    ]
}

const LINKS: [(&str, fn() -> LinkModel, bool); 3] = [
    ("1 Gbps", LinkModel::wan_1g, true),
    ("10 Gbps", LinkModel::shared_10g, false),
    ("100 Gbps", LinkModel::dedicated_100g, false),
];

/// Figure 4a: end-to-end latency, methods × network speeds.
pub fn fig4a(env: &EvalEnv, runtime: Option<&SkimRuntime>) -> Result<String> {
    let mut out = String::new();
    writeln!(out, "== Figure 4a: filtering latency across network speeds ==").unwrap();
    writeln!(
        out,
        "{:<16} {:>12} {:>12} {:>12}   {:>14}",
        "method", "1 Gbps", "10 Gbps", "100 Gbps", "paper @1Gbps"
    )
    .unwrap();
    let mut lat_1g = Vec::new();
    for (label, mode, input, paper) in methods(env) {
        let query = gen::higgs_query(&input, &format!("skim_{}.troot", mode.name()));
        let mut cells = Vec::new();
        for (_, link, _) in LINKS {
            let report = run_row(env, runtime, &query, deployment(env, mode, link()))?;
            cells.push(report.latency);
        }
        lat_1g.push((label, cells[0]));
        writeln!(
            out,
            "{:<16} {:>12} {:>12} {:>12}   {:>14}",
            label,
            human_secs(cells[0]),
            human_secs(cells[1]),
            human_secs(cells[2]),
            paper.map(|p| format!("{p} s")).unwrap_or_default()
        )
        .unwrap();
    }
    let legacy = lat_1g.iter().find(|(l, _)| *l == "Client LZ4").unwrap().1;
    let skim = lat_1g.iter().find(|(l, _)| *l == "SkimROOT").unwrap().1;
    writeln!(
        out,
        "\nSkimROOT speedup over Client LZ4 @1 Gbps: {:.1}x (paper: 44.3x)",
        legacy / skim
    )
    .unwrap();
    Ok(out)
}

const BREAKDOWN_STAGES: [Stage; 5] = [
    Stage::BasketFetch,
    Stage::Decompress,
    Stage::Deserialize,
    Stage::OutputWrite,
    Stage::OutputTransfer,
];

fn breakdown_row(label: &str, report: &super::JobReport) -> String {
    let mut s = format!("{label:<16}");
    for stage in BREAKDOWN_STAGES {
        let mut t = report.timeline.stage_total(stage);
        // Fold filter eval into "deserialize" the way the paper's
        // breakdown folds processing into its deserialization bar.
        if stage == Stage::Deserialize {
            t += report.timeline.stage_total(Stage::Filter);
        }
        s.push_str(&format!(" {:>12}", human_secs(t)));
    }
    s.push_str(&format!(" {:>12}", human_secs(report.latency)));
    s
}

fn breakdown_header() -> String {
    let mut s = format!("{:<16}", "method");
    for stage in BREAKDOWN_STAGES {
        s.push_str(&format!(" {:>12}", stage.name()));
    }
    s.push_str(&format!(" {:>12}", "TOTAL"));
    s
}

/// Figure 4b: per-operation breakdown over the 1 Gbps link.
pub fn fig4b(env: &EvalEnv, runtime: Option<&SkimRuntime>) -> Result<String> {
    let mut out = String::new();
    writeln!(out, "== Figure 4b: operation breakdown @ 1 Gbps ==").unwrap();
    writeln!(out, "{}", breakdown_header()).unwrap();
    for (label, mode, input, _) in methods(env) {
        let query = gen::higgs_query(&input, &format!("skim_{}.troot", mode.name()));
        let report = run_row(env, runtime, &query, deployment(env, mode, LinkModel::wan_1g()))?;
        writeln!(out, "{}", breakdown_row(label, &report)).unwrap();
    }
    writeln!(
        out,
        "\npaper @1 Gbps: LZMA decompress 130.4 s | LZ4 decompress 3.2 s, deserialize 240.4 s |"
    )
    .unwrap();
    writeln!(
        out,
        "               ClientOpt deserialize 16.8 s, fetch 135.9 s | SkimROOT total 8.62 s"
    )
    .unwrap();
    Ok(out)
}

/// Figure 5a: near-storage (server-side) vs SkimROOT breakdown, LZ4.
pub fn fig5a(env: &EvalEnv, runtime: Option<&SkimRuntime>) -> Result<String> {
    let mut out = String::new();
    writeln!(out, "== Figure 5a: server-side vs SkimROOT (LZ4) ==").unwrap();
    writeln!(out, "{}", breakdown_header()).unwrap();
    let mut totals = Vec::new();
    for (label, mode) in [("Server-side", Mode::ServerSide), ("SkimROOT", Mode::SkimRoot)] {
        let query = gen::higgs_query(&env.lz4, &format!("skim5a_{}.troot", mode.name()));
        let report = run_row(env, runtime, &query, deployment(env, mode, LinkModel::wan_1g()))?;
        writeln!(out, "{}", breakdown_row(label, &report)).unwrap();
        totals.push(report.latency);
    }
    writeln!(
        out,
        "\nserver-side / SkimROOT latency: {:.2}x (paper: 3.18x; fetch 18 s vs 2.3 s,\n\
         decompress 3.1 s vs 2.2 s, deserialize 6.3 s vs 4.1 s, output fetch 0.02 s)",
        totals[0] / totals[1]
    )
    .unwrap();
    Ok(out)
}

/// Figure 5b: CPU utilization per node (LZ4 @ 1 Gbps).
pub fn fig5b(env: &EvalEnv, runtime: Option<&SkimRuntime>) -> Result<String> {
    let mut out = String::new();
    writeln!(out, "== Figure 5b: CPU utilization (LZ4 @ 1 Gbps) ==").unwrap();
    writeln!(
        out,
        "{:<16} {:>9} {:>9} {:>9} {:>11}   paper",
        "method", "client", "server", "dpu", "dpu-engine"
    )
    .unwrap();
    let rows: [(&str, Mode, &str); 4] = [
        ("Client LZ4", Mode::ClientLegacy, "client 99%"),
        ("Client Opt LZ4", Mode::ClientOpt, "client 17%"),
        ("Server-side", Mode::ServerSide, "client 0.1%, server 41%"),
        ("SkimROOT", Mode::SkimRoot, "dpu 87%, server 21%"),
    ];
    for (label, mode, paper) in rows {
        let query = gen::higgs_query(&env.lz4, &format!("skim5b_{}.troot", mode.name()));
        let report = run_row(env, runtime, &query, deployment(env, mode, LinkModel::wan_1g()))?;
        let pct = |n: Node| format!("{:.1}%", (100.0 * report.timeline.utilization(n)).max(0.0));
        writeln!(
            out,
            "{:<16} {:>9} {:>9} {:>9} {:>11}   {paper}",
            label,
            pct(Node::Client),
            pct(Node::Server),
            pct(Node::Dpu),
            pct(Node::DpuEngine),
        )
        .unwrap();
    }
    Ok(out)
}

/// Run every figure (the `skimroot eval --fig all` path).
pub fn all_figures(env: &EvalEnv, runtime: Option<&SkimRuntime>) -> Result<String> {
    let mut out = String::new();
    for f in [fig4a, fig4b, fig5a, fig5b] {
        out.push_str(&f(env, runtime)?);
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> EvalEnv {
        let dir = std::env::temp_dir().join(format!("evalsuite_{}", std::process::id()));
        let scale = EvalScale {
            n_events: 1_000,
            target_branches: 150,
            n_hlt: 40,
            basket_events: 250,
        };
        prepare(dir, scale).unwrap()
    }

    #[test]
    fn fig4a_shape_holds_at_tiny_scale() {
        let e = env();
        let table = fig4a(&e, None).unwrap();
        assert!(table.contains("SkimROOT speedup"));
        // SkimROOT's 1 Gbps cell must be the smallest in its column —
        // parse the speedup line.
        let speedup: f64 = table
            .lines()
            .find(|l| l.contains("speedup"))
            .and_then(|l| l.split_whitespace().nth(7))
            .and_then(|s| s.trim_end_matches('x').parse().ok())
            .unwrap();
        assert!(speedup > 1.0, "speedup {speedup}\n{table}");
    }

    #[test]
    fn fig5b_utilization_shape() {
        let e = env();
        let table = fig5b(&e, None).unwrap();
        assert!(table.contains("Client LZ4"));
        assert!(table.contains("SkimROOT"));
    }
}
