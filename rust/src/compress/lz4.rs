//! From-scratch LZ4 *block* codec.
//!
//! Implements the standard LZ4 block wire format (token byte with
//! literal/match length nibbles, 255-continuation length extension,
//! little-endian 2-byte match offsets, minimum match length 4) with a
//! single-pass greedy compressor using a 4-byte hash table — the same
//! design point as the reference `LZ4_compress_default`.
//!
//! End-of-block rules followed by the compressor (and assumed by the
//! decompressor, as in the spec):
//! * the last sequence is literals-only;
//! * the last 5 bytes are always literals;
//! * no match starts within the last 12 bytes.
//!
//! This is the "fast decode, moderate ratio" codec of the paper's
//! evaluation; decode is a tight copy loop with no entropy coding.

use crate::{Error, Result};

const MIN_MATCH: usize = 4;
/// Matches may not start within the final 12 bytes of input.
const MFLIMIT: usize = 12;
/// The final 5 bytes must be encoded as literals.
const LAST_LITERALS: usize = 5;
const HASH_LOG: usize = 16;
const HASH_SIZE: usize = 1 << HASH_LOG;
const MAX_OFFSET: usize = 65_535;

#[inline]
fn hash4(v: u32) -> usize {
    // Fibonacci hashing of a 4-byte little-endian window.
    ((v.wrapping_mul(2_654_435_761)) >> (32 - HASH_LOG as u32)) as usize
}

#[inline]
fn read_u32_le(data: &[u8], pos: usize) -> u32 {
    u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap())
}

/// Append an LZ4-style extended length (base-nibble overflow) to `out`.
#[inline]
fn write_ext_length(out: &mut Vec<u8>, mut len: usize) {
    while len >= 255 {
        out.push(255);
        len -= 255;
    }
    out.push(len as u8);
}

/// Compress `data` into an LZ4 block. Empty input yields an empty block.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let n = data.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    if n == 0 {
        return out;
    }
    // Inputs too small to contain a legal match: emit one literal run.
    if n < MFLIMIT + 1 {
        emit_last_literals(&mut out, data);
        return out;
    }

    let mut table = vec![0u32; HASH_SIZE]; // position + 1 (0 = empty)
    let match_limit = n - MFLIMIT; // last legal match start (exclusive)
    let mut anchor = 0usize; // start of pending literals
    let mut pos = 0usize;

    while pos < match_limit {
        let h = hash4(read_u32_le(data, pos));
        let cand = table[h] as usize;
        table[h] = (pos + 1) as u32;
        let found = cand != 0 && {
            let cand = cand - 1;
            pos - cand <= MAX_OFFSET && read_u32_le(data, cand) == read_u32_le(data, pos)
        };
        if !found {
            pos += 1;
            continue;
        }
        let cand = cand - 1;

        // Extend the match forward, but stop so the last 5 bytes stay
        // literal (match may run into the MFLIMIT zone, just not to EOF).
        let max_len = n - LAST_LITERALS - pos;
        let mut mlen = MIN_MATCH;
        debug_assert!(max_len >= MIN_MATCH);
        while mlen < max_len && data[cand + mlen] == data[pos + mlen] {
            mlen += 1;
        }

        // Emit sequence: token, literals, offset, extended match length.
        let lit_len = pos - anchor;
        let token_lit = lit_len.min(15) as u8;
        let token_match = (mlen - MIN_MATCH).min(15) as u8;
        out.push((token_lit << 4) | token_match);
        if lit_len >= 15 {
            write_ext_length(&mut out, lit_len - 15);
        }
        out.extend_from_slice(&data[anchor..pos]);
        let offset = (pos - cand) as u16;
        out.extend_from_slice(&offset.to_le_bytes());
        if mlen - MIN_MATCH >= 15 {
            write_ext_length(&mut out, mlen - MIN_MATCH - 15);
        }

        // Index a couple of positions inside the match to help the next
        // search (cheap ratio win, mirrors the reference's step insert).
        if pos + 2 < match_limit {
            let mid = pos + mlen / 2;
            if mid < match_limit {
                table[hash4(read_u32_le(data, mid))] = (mid + 1) as u32;
            }
        }

        pos += mlen;
        anchor = pos;
    }

    emit_last_literals(&mut out, &data[anchor..]);
    out
}

fn emit_last_literals(out: &mut Vec<u8>, lits: &[u8]) {
    let lit_len = lits.len();
    let token_lit = lit_len.min(15) as u8;
    out.push(token_lit << 4);
    if lit_len >= 15 {
        write_ext_length(out, lit_len - 15);
    }
    out.extend_from_slice(lits);
}

/// Decompress an LZ4 block into exactly `raw_len` bytes.
pub fn decompress(block: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    decompress_into(block, raw_len, &mut out)?;
    Ok(out)
}

/// [`decompress`] into a reusable buffer (cleared first, capacity
/// retained across calls).
pub fn decompress_into(block: &[u8], raw_len: usize, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    out.reserve(raw_len);
    if raw_len == 0 {
        if block.is_empty() {
            return Ok(());
        }
        return Err(Error::Compress("lz4: nonempty block for empty output".into()));
    }
    let mut pos = 0usize;
    let err = |msg: &str| Error::Compress(format!("lz4: {msg}"));

    loop {
        let token = *block.get(pos).ok_or_else(|| err("truncated token"))?;
        pos += 1;

        // Literal run.
        let mut lit_len = (token >> 4) as usize;
        if lit_len == 15 {
            loop {
                let b = *block.get(pos).ok_or_else(|| err("truncated literal length"))?;
                pos += 1;
                lit_len += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        let lit_end = pos.checked_add(lit_len).ok_or_else(|| err("literal overflow"))?;
        if lit_end > block.len() {
            return Err(err("literal run past end of block"));
        }
        out.extend_from_slice(&block[pos..lit_end]);
        pos = lit_end;
        if out.len() > raw_len {
            return Err(err("output longer than declared raw length"));
        }

        // Block may legally end after a literals-only sequence.
        if pos == block.len() {
            break;
        }

        // Match.
        if pos + 2 > block.len() {
            return Err(err("truncated match offset"));
        }
        let offset = u16::from_le_bytes([block[pos], block[pos + 1]]) as usize;
        pos += 2;
        if offset == 0 || offset > out.len() {
            return Err(err("match offset out of range"));
        }
        let mut mlen = (token & 0x0f) as usize + MIN_MATCH;
        if mlen == 15 + MIN_MATCH {
            loop {
                let b = *block.get(pos).ok_or_else(|| err("truncated match length"))?;
                pos += 1;
                mlen += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        if out.len() + mlen > raw_len {
            return Err(err("match overruns declared raw length"));
        }
        // Overlapping copy must proceed byte-wise (offset < mlen is the
        // RLE-like case the format exploits).
        let start = out.len() - offset;
        if offset >= mlen {
            out.extend_from_within(start..start + mlen);
        } else {
            for i in 0..mlen {
                let b = out[start + i];
                out.push(b);
            }
        }
    }

    if out.len() != raw_len {
        return Err(err(&format!(
            "raw length mismatch: got {} expected {raw_len}",
            out.len()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop_check, Pcg32};

    fn roundtrip(data: &[u8]) {
        let block = compress(data);
        let back = decompress(&block, data.len()).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn empty_and_small() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"hello world");
        roundtrip(&[0u8; 13]);
    }

    #[test]
    fn highly_repetitive_compresses_hard() {
        let data = vec![42u8; 100_000];
        let block = compress(&data);
        assert!(block.len() < 500, "got {}", block.len());
        assert_eq!(decompress(&block, data.len()).unwrap(), data);
    }

    #[test]
    fn incompressible_random_roundtrips() {
        let mut rng = Pcg32::new(3);
        let mut data = vec![0u8; 50_000];
        rng.fill_bytes(&mut data);
        let block = compress(&data);
        // Random data expands slightly (literal-run framing), never a lot.
        assert!(block.len() < data.len() + data.len() / 100 + 64);
        assert_eq!(decompress(&block, data.len()).unwrap(), data);
    }

    #[test]
    fn overlapping_match_rle_case() {
        // "abcabcabc..." forces offset (3) < match length.
        let data: Vec<u8> = b"abc".iter().copied().cycle().take(10_000).collect();
        let block = compress(&data);
        assert!(block.len() < 200);
        assert_eq!(decompress(&block, data.len()).unwrap(), data);
    }

    #[test]
    fn long_literal_runs_use_extension_bytes() {
        // Incompressible prefix > 15 bytes exercises extended literal length.
        let mut rng = Pcg32::new(4);
        let mut data = vec![0u8; 1000];
        rng.fill_bytes(&mut data);
        data.extend_from_slice(&[7u8; 2000]); // then a long match region
        roundtrip(&data);
    }

    #[test]
    fn far_matches_within_window() {
        // Repeat a block at distance close to (but below) 64 KiB.
        let mut rng = Pcg32::new(5);
        let mut unit = vec![0u8; 300];
        rng.fill_bytes(&mut unit);
        let mut data = unit.clone();
        data.resize(60_000, 0x11);
        data.extend_from_slice(&unit);
        roundtrip(&data);
    }

    #[test]
    fn matches_beyond_window_fall_back_to_literals() {
        // Same 300-byte unit repeated at distance > 64 KiB: must still
        // round-trip (compressor just can't reference that far back).
        let mut rng = Pcg32::new(6);
        let mut unit = vec![0u8; 300];
        rng.fill_bytes(&mut unit);
        let mut data = unit.clone();
        let mut filler = vec![0u8; 70_000];
        rng.fill_bytes(&mut filler);
        data.extend_from_slice(&filler);
        data.extend_from_slice(&unit);
        roundtrip(&data);
    }

    #[test]
    fn prop_roundtrip() {
        prop_check("lz4-roundtrip", 60, |rng| {
            let len = rng.below(80_000) as usize;
            let r = rng.f64();
            let data = rng.compressible_bytes(len, r);
            roundtrip(&data);
        });
    }

    #[test]
    fn prop_decoder_rejects_mutations_or_roundtrips() {
        // Fuzz the decoder: a mutated block must either error out or
        // produce *some* output without panicking / OOM — never UB.
        prop_check("lz4-decoder-robust", 60, |rng| {
            let data = rng.compressible_bytes(2_000, 0.6);
            let mut block = compress(&data);
            if block.is_empty() {
                return;
            }
            let idx = rng.below(block.len() as u32) as usize;
            block[idx] ^= 1 << rng.below(8);
            let _ = decompress(&block, data.len()); // must not panic
        });
    }

    #[test]
    fn decoder_rejects_truncated_blocks() {
        let data = vec![9u8; 4000];
        let block = compress(&data);
        for cut in [0, 1, block.len() / 2, block.len() - 1] {
            assert!(decompress(&block[..cut], data.len()).is_err());
        }
    }

    #[test]
    fn decoder_rejects_wrong_raw_len() {
        let data = vec![9u8; 4000];
        let block = compress(&data);
        assert!(decompress(&block, 3999).is_err());
        assert!(decompress(&block, 4001).is_err());
    }

    #[test]
    fn decode_known_handcrafted_block() {
        // 5 literals "hello" then end: token 0x50.
        let block = [0x50, b'h', b'e', b'l', b'l', b'o'];
        assert_eq!(decompress(&block, 5).unwrap(), b"hello");
        // "abcd" + match(offset 4, len 4) + 5 final literals "abcd!":
        // token1 = lit 4, match 4-4=0 → 0x40; offset 0x0004;
        // token2 = lit 5 → 0x50.
        let block = [
            0x40, b'a', b'b', b'c', b'd', 0x04, 0x00, 0x50, b'a', b'b', b'c', b'd', b'!',
        ];
        assert_eq!(decompress(&block, 13).unwrap(), b"abcdabcdabcd!");
    }
}
