//! Compression codecs for basket payloads.
//!
//! ROOT compresses each basket independently with a per-file algorithm
//! (zlib, LZ4 or LZMA).  The paper's evaluation contrasts **LZMA**
//! (small files, slow decode) with **LZ4** (larger files, fast decode);
//! we reproduce that trade-off with:
//!
//! * [`lz4`] — a from-scratch LZ4 *block* codec (greedy hash-table
//!   matcher, standard token/offset wire format);
//! * [`xz_like`] — a from-scratch LZMA-class codec: LZ77 with hash-chain
//!   match finding entropy-coded by an adaptive binary **range coder**.
//!   Like real LZMA it trades decode speed for ratio (every bit goes
//!   through the range decoder);
//! * `Zlib` — DEFLATE via the vendored `flate2` (ROOT's historical
//!   default), kept as a mid-point and for cross-checking.
//!
//! Every compressed buffer is wrapped in a small frame
//! (`magic, codec id, raw length, payload length, crc32`) so baskets are
//! self-describing and corruption is detected at decode time — mirroring
//! ROOT's 9-byte basket compression header + checksums.

pub mod lz4;
pub mod xz_like;

use crate::{Error, Result};
use std::io::{Read, Write};

/// Frame header: magic(2) codec(1) raw_len(4) payload_len(4) crc32(4).
pub const FRAME_HEADER_LEN: usize = 15;
const FRAME_MAGIC: [u8; 2] = [0x53, 0x4b]; // "SK"

/// Which codec a basket (or file) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Codec {
    /// No compression (stored).
    None,
    /// From-scratch LZ4 block codec: fast decode, moderate ratio.
    Lz4,
    /// DEFLATE via flate2: ROOT's historical default.
    Zlib,
    /// From-scratch LZMA-class range-coded LZ77: slow decode, best ratio.
    XzLike,
}

impl Codec {
    /// Stable frame-header id.
    pub fn id(self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::Lz4 => 1,
            Codec::Zlib => 2,
            Codec::XzLike => 3,
        }
    }

    /// Inverse of [`Codec::id`].
    pub fn from_id(id: u8) -> Result<Codec> {
        Ok(match id {
            0 => Codec::None,
            1 => Codec::Lz4,
            2 => Codec::Zlib,
            3 => Codec::XzLike,
            _ => return Err(Error::Compress(format!("unknown codec id {id}"))),
        })
    }

    /// Canonical name (CLI spelling, Display).
    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Lz4 => "lz4",
            Codec::Zlib => "zlib",
            Codec::XzLike => "xz-like",
        }
    }

    /// Parse a codec name (as used by the CLI and JSON queries).
    pub fn parse(s: &str) -> Result<Codec> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "stored" => Codec::None,
            "lz4" => Codec::Lz4,
            "zlib" | "deflate" | "gzip" => Codec::Zlib,
            "xz" | "xz-like" | "xzlike" | "lzma" => Codec::XzLike,
            other => return Err(Error::Compress(format!("unknown codec '{other}'"))),
        })
    }
}

impl std::fmt::Display for Codec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Compress `data` into a self-describing frame.
pub fn compress(codec: Codec, data: &[u8]) -> Vec<u8> {
    let payload = match codec {
        Codec::None => data.to_vec(),
        Codec::Lz4 => lz4::compress(data),
        Codec::Zlib => zlib_compress(data),
        Codec::XzLike => xz_like::compress(data),
    };
    let crc = crc32fast::hash(&payload);
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    out.extend_from_slice(&FRAME_MAGIC);
    out.push(codec.id());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Inspect a frame without decoding: returns `(codec, raw_len, payload_len)`.
pub fn frame_info(frame: &[u8]) -> Result<(Codec, usize, usize)> {
    if frame.len() < FRAME_HEADER_LEN {
        return Err(Error::Compress("frame too short".into()));
    }
    if frame[..2] != FRAME_MAGIC {
        return Err(Error::Compress("bad frame magic".into()));
    }
    let codec = Codec::from_id(frame[2])?;
    let raw_len = u32::from_le_bytes(frame[3..7].try_into().unwrap()) as usize;
    let payload_len = u32::from_le_bytes(frame[7..11].try_into().unwrap()) as usize;
    if frame.len() < FRAME_HEADER_LEN + payload_len {
        return Err(Error::Compress(format!(
            "truncated frame: have {} need {}",
            frame.len(),
            FRAME_HEADER_LEN + payload_len
        )));
    }
    Ok((codec, raw_len, payload_len))
}

/// Decompress a frame produced by [`compress`].
pub fn decompress(frame: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    decompress_into(frame, &mut out)?;
    Ok(out)
}

/// Decompress a frame into a caller-provided buffer (cleared first,
/// capacity retained). The engine's selective phase-2 path reuses one
/// scratch allocation across baskets instead of allocating per frame.
pub fn decompress_into(frame: &[u8], out: &mut Vec<u8>) -> Result<()> {
    let (codec, raw_len, payload_len) = frame_info(frame)?;
    let crc_stored = u32::from_le_bytes(frame[11..15].try_into().unwrap());
    let payload = &frame[FRAME_HEADER_LEN..FRAME_HEADER_LEN + payload_len];
    if crc32fast::hash(payload) != crc_stored {
        return Err(Error::Compress("crc mismatch (corrupt basket)".into()));
    }
    out.clear();
    out.reserve(raw_len);
    match codec {
        Codec::None => out.extend_from_slice(payload),
        Codec::Lz4 => lz4::decompress_into(payload, raw_len, out)?,
        Codec::Zlib => zlib_decompress_into(payload, raw_len, out)?,
        Codec::XzLike => xz_like::decompress_into(payload, raw_len, out)?,
    }
    if out.len() != raw_len {
        return Err(Error::Compress(format!(
            "raw length mismatch: got {} expected {raw_len}",
            out.len()
        )));
    }
    Ok(())
}

fn zlib_compress(data: &[u8]) -> Vec<u8> {
    let mut enc =
        flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::new(6));
    enc.write_all(data).expect("in-memory zlib write cannot fail");
    enc.finish().expect("in-memory zlib finish cannot fail")
}

fn zlib_decompress_into(payload: &[u8], raw_len: usize, out: &mut Vec<u8>) -> Result<()> {
    // `read_to_end` probes for EOF by reading into *spare* capacity:
    // with an exactly-sized buffer the probe finds none and triggers a
    // geometric doubling realloc right at the end of every basket.
    // Reserving a small slack beyond the frame header's raw_len keeps
    // the whole decode within the original allocation.
    out.reserve(raw_len.saturating_add(64));
    let mut dec = flate2::read::ZlibDecoder::new(payload);
    dec.read_to_end(out)
        .map_err(|e| Error::Compress(format!("zlib: {e}")))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop_check, Pcg32};

    const ALL: [Codec; 4] = [Codec::None, Codec::Lz4, Codec::Zlib, Codec::XzLike];

    #[test]
    fn roundtrip_empty_and_tiny() {
        for codec in ALL {
            for data in [&b""[..], b"a", b"ab", b"abc", b"aaaa", b"abcabcabcabc"] {
                let frame = compress(codec, data);
                assert_eq!(decompress(&frame).unwrap(), data, "codec={codec}");
            }
        }
    }

    #[test]
    fn decompress_into_reuses_scratch_across_frames() {
        // One scratch buffer drained across frames of varying sizes and
        // codecs: every decode must match the one-shot path, and stale
        // bytes from a previous (larger) frame must never leak.
        let mut rng = Pcg32::new(7);
        let mut scratch = Vec::new();
        for codec in ALL {
            for len in [10_000usize, 100, 0, 5_000] {
                let data = rng.compressible_bytes(len, 0.5);
                let frame = compress(codec, &data);
                decompress_into(&frame, &mut scratch).unwrap();
                assert_eq!(scratch, data, "codec={codec} len={len}");
                assert_eq!(decompress(&frame).unwrap(), data);
            }
        }
    }

    #[test]
    fn roundtrip_structured_payloads() {
        let mut rng = Pcg32::new(1);
        for codec in ALL {
            for redundancy in [0.0, 0.3, 0.7, 0.95] {
                let data = rng.compressible_bytes(100_000, redundancy);
                let frame = compress(codec, &data);
                assert_eq!(decompress(&frame).unwrap(), data, "codec={codec} r={redundancy}");
            }
        }
    }

    #[test]
    fn prop_roundtrip_all_codecs() {
        prop_check("compress-roundtrip", 40, |rng| {
            let len = rng.below(50_000) as usize;
            let redundancy = rng.f64();
            let data = rng.compressible_bytes(len, redundancy);
            for codec in ALL {
                let frame = compress(codec, &data);
                assert_eq!(decompress(&frame).unwrap(), data, "codec={codec}");
            }
        });
    }

    #[test]
    fn ratio_ordering_matches_paper() {
        // Paper: LZMA file (3 GB) smaller than LZ4 file (5 GB) for the
        // same data. Our xz-like codec must beat lz4's ratio on
        // structured payloads.
        let mut rng = Pcg32::new(2);
        let data = rng.compressible_bytes(400_000, 0.7);
        let lz4_len = compress(Codec::Lz4, &data).len();
        let xz_len = compress(Codec::XzLike, &data).len();
        assert!(
            xz_len < lz4_len,
            "xz-like ({xz_len}) should compress better than lz4 ({lz4_len})"
        );
        assert!(lz4_len < data.len(), "lz4 should compress structured data");
    }

    #[test]
    fn crc_detects_corruption() {
        let data = b"some basket payload that is long enough to compress";
        for codec in ALL {
            let mut frame = compress(codec, data);
            let n = frame.len();
            frame[n - 1] ^= 0xff;
            assert!(decompress(&frame).is_err(), "codec={codec}");
        }
    }

    #[test]
    fn frame_info_reports_sizes() {
        let data = vec![7u8; 1000];
        let frame = compress(Codec::Lz4, &data);
        let (codec, raw, payload) = frame_info(&frame).unwrap();
        assert_eq!(codec, Codec::Lz4);
        assert_eq!(raw, 1000);
        assert_eq!(payload, frame.len() - FRAME_HEADER_LEN);
        assert!(payload < 100, "1000 identical bytes must compress well");
    }

    #[test]
    fn rejects_bad_magic_and_short_frames() {
        assert!(decompress(&[]).is_err());
        assert!(decompress(&[0u8; 10]).is_err());
        let mut frame = compress(Codec::None, b"hello");
        frame[0] = 0;
        assert!(decompress(&frame).is_err());
    }

    #[test]
    fn codec_parse_and_display() {
        assert_eq!(Codec::parse("LZMA").unwrap(), Codec::XzLike);
        assert_eq!(Codec::parse("lz4").unwrap(), Codec::Lz4);
        assert_eq!(Codec::parse("deflate").unwrap(), Codec::Zlib);
        assert!(Codec::parse("snappy").is_err());
        for c in ALL {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
            assert_eq!(Codec::from_id(c.id()).unwrap(), c);
        }
    }
}
