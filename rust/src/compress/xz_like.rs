//! From-scratch LZMA-class codec ("xz-like"): LZ77 with hash-chain match
//! finding, entropy-coded by an adaptive binary **range coder**.
//!
//! This is the "LZMA" of the paper's evaluation: markedly better ratio
//! than LZ4 on structured basket payloads, but every bit of output flows
//! through the range decoder, so decompression is 1–2 orders of
//! magnitude slower — exactly the trade-off Figure 4b measures
//! (LZMA decompress 130.4 s vs LZ4 3.2 s).
//!
//! Wire model (decoder needs `raw_len` out-of-band, which the frame
//! header in [`super`] provides):
//!
//! ```text
//! stream  := symbol* ; decode until raw_len bytes are produced
//! symbol  := is_match(bit, adaptive)
//!            0 → literal: 8-bit bit-tree, context = prev_byte >> 5
//!            1 → match:   len-3 as 8-bit bit-tree (len ∈ [3, 258]),
//!                         distance as 5-bit nb-slot tree + (nb-1)
//!                         direct bits
//! ```
//!
//! The range coder is the canonical LZMA construction: 32-bit range,
//! carry-propagating 64-bit low with cache byte on the encode side;
//! 11-bit probabilities with shift-5 adaptation.

use crate::{Error, Result};

const PROB_BITS: u32 = 11;
const PROB_INIT: u16 = (1 << PROB_BITS) / 2; // 1024
const MOVE_BITS: u32 = 5;
const TOP: u32 = 1 << 24;

const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = MIN_MATCH + 255; // 258
const WINDOW: usize = 1 << 20; // 1 MiB dictionary
const HASH_LOG: usize = 15;
const HASH_SIZE: usize = 1 << HASH_LOG;
const MAX_CHAIN: usize = 48; // match-finder search depth
const LIT_CTX: usize = 8; // literal context = prev_byte >> 5

// ---------------------------------------------------------------------
// Range encoder / decoder
// ---------------------------------------------------------------------

struct RangeEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl RangeEncoder {
    fn new() -> Self {
        RangeEncoder { low: 0, range: u32::MAX, cache: 0, cache_size: 1, out: Vec::new() }
    }

    #[inline]
    fn shift_low(&mut self) {
        if (self.low as u32) < 0xFF00_0000 || (self.low >> 32) != 0 {
            let carry = (self.low >> 32) as u8;
            let mut temp = self.cache;
            loop {
                self.out.push(temp.wrapping_add(carry));
                temp = 0xFF;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        // Canonical: low = (UInt32)low << 8 — computed in 32-bit so the
        // byte that just went into `cache` (bits 24..32) is dropped.
        self.low = ((self.low as u32) << 8) as u64;
    }

    #[inline]
    fn encode_bit(&mut self, prob: &mut u16, bit: u32) {
        let bound = (self.range >> PROB_BITS) * (*prob as u32);
        if bit == 0 {
            self.range = bound;
            *prob += ((1 << PROB_BITS) - *prob) >> MOVE_BITS;
        } else {
            self.low += bound as u64;
            self.range -= bound;
            *prob -= *prob >> MOVE_BITS;
        }
        if self.range < TOP {
            self.range <<= 8;
            self.shift_low();
        }
    }

    /// Encode `nbits` of `v` (MSB first) without probability modelling.
    #[inline]
    fn encode_direct(&mut self, v: u32, nbits: u32) {
        for i in (0..nbits).rev() {
            self.range >>= 1;
            if (v >> i) & 1 != 0 {
                self.low += self.range as u64;
            }
            if self.range < TOP {
                self.range <<= 8;
                self.shift_low();
            }
        }
    }

    fn encode_tree(&mut self, probs: &mut [u16], nbits: u32, sym: u32) {
        let mut ctx = 1usize;
        for i in (0..nbits).rev() {
            let bit = (sym >> i) & 1;
            self.encode_bit(&mut probs[ctx], bit);
            ctx = (ctx << 1) | bit as usize;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

struct RangeDecoder<'a> {
    code: u32,
    range: u32,
    input: &'a [u8],
    pos: usize,
}

impl<'a> RangeDecoder<'a> {
    fn new(input: &'a [u8]) -> Result<Self> {
        if input.len() < 5 {
            return Err(Error::Compress("xz-like: stream too short".into()));
        }
        // First encoder byte is always 0 (cache flush), skip it.
        let mut code = 0u32;
        for i in 1..5 {
            code = (code << 8) | input[i] as u32;
        }
        Ok(RangeDecoder { code, range: u32::MAX, input, pos: 5 })
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        // Reading past the end yields zeros: the encoder's flush pads the
        // tail, and raw_len terminates decoding, so this is safe.
        let b = self.input.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }

    #[inline]
    fn normalize(&mut self) {
        if self.range < TOP {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte() as u32;
        }
    }

    #[inline]
    fn decode_bit(&mut self, prob: &mut u16) -> u32 {
        let bound = (self.range >> PROB_BITS) * (*prob as u32);
        let bit;
        if self.code < bound {
            self.range = bound;
            *prob += ((1 << PROB_BITS) - *prob) >> MOVE_BITS;
            bit = 0;
        } else {
            self.code -= bound;
            self.range -= bound;
            *prob -= *prob >> MOVE_BITS;
            bit = 1;
        }
        self.normalize();
        bit
    }

    #[inline]
    fn decode_direct(&mut self, nbits: u32) -> u32 {
        let mut v = 0u32;
        for _ in 0..nbits {
            self.range >>= 1;
            let bit = if self.code >= self.range {
                self.code -= self.range;
                1
            } else {
                0
            };
            v = (v << 1) | bit;
            self.normalize();
        }
        v
    }

    fn decode_tree(&mut self, probs: &mut [u16], nbits: u32) -> u32 {
        let mut ctx = 1usize;
        for _ in 0..nbits {
            let bit = self.decode_bit(&mut probs[ctx]);
            ctx = (ctx << 1) | bit as usize;
        }
        ctx as u32 - (1 << nbits)
    }
}

// ---------------------------------------------------------------------
// Probability model
// ---------------------------------------------------------------------

struct Model {
    is_match: u16,
    literal: Vec<[u16; 256]>, // LIT_CTX bit-trees of 8 bits
    len: [u16; 256],          // 8-bit bit-tree over len - MIN_MATCH
    dist_slot: [u16; 32],     // 5-bit bit-tree over nb(dist-1)
}

impl Model {
    fn new() -> Self {
        Model {
            is_match: PROB_INIT,
            literal: vec![[PROB_INIT; 256]; LIT_CTX],
            len: [PROB_INIT; 256],
            dist_slot: [PROB_INIT; 32],
        }
    }

    #[inline]
    fn lit_ctx(prev: u8) -> usize {
        (prev >> 5) as usize
    }
}

/// Number of significant bits of `v` (0 for v == 0).
#[inline]
fn nbits(v: u32) -> u32 {
    32 - v.leading_zeros()
}

// ---------------------------------------------------------------------
// Match finder: hash chains over 3-byte heads with one-step lazy match.
// ---------------------------------------------------------------------

#[inline]
fn hash3(data: &[u8], pos: usize) -> usize {
    let v = (data[pos] as u32) | ((data[pos + 1] as u32) << 8) | ((data[pos + 2] as u32) << 16);
    ((v.wrapping_mul(2_654_435_761)) >> (32 - HASH_LOG as u32)) as usize
}

struct MatchFinder {
    head: Vec<u32>, // pos + 1, 0 = empty
    prev: Vec<u32>,
}

impl MatchFinder {
    fn new(len: usize) -> Self {
        MatchFinder { head: vec![0; HASH_SIZE], prev: vec![0; len] }
    }

    #[inline]
    fn insert(&mut self, data: &[u8], pos: usize) {
        if pos + MIN_MATCH <= data.len() {
            let h = hash3(data, pos);
            self.prev[pos] = self.head[h];
            self.head[h] = (pos + 1) as u32;
        }
    }

    /// Best `(length, distance)` match at `pos`, or None.
    fn find(&self, data: &[u8], pos: usize) -> Option<(usize, usize)> {
        if pos + MIN_MATCH > data.len() {
            return None;
        }
        let max_len = (data.len() - pos).min(MAX_MATCH);
        let mut best_len = MIN_MATCH - 1;
        let mut best_dist = 0usize;
        let mut cand = self.head[hash3(data, pos)] as usize;
        let mut depth = 0;
        while cand != 0 && depth < MAX_CHAIN {
            let cpos = cand - 1;
            let dist = pos - cpos;
            if dist > WINDOW {
                break;
            }
            // Quick reject: check the byte after the current best.
            if best_len < max_len && data[cpos + best_len] == data[pos + best_len] {
                let mut l = 0;
                while l < max_len && data[cpos + l] == data[pos + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = dist;
                    if l == max_len {
                        break;
                    }
                }
            }
            cand = self.prev[cpos] as usize;
            depth += 1;
        }
        if best_len >= MIN_MATCH {
            Some((best_len, best_dist))
        } else {
            None
        }
    }
}

// ---------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------

/// Compress `data` with the xz-like codec.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut enc = RangeEncoder::new();
    let mut model = Model::new();
    let mut mf = MatchFinder::new(data.len());
    let mut pos = 0usize;
    let mut prev_byte = 0u8;

    while pos < data.len() {
        let m = mf.find(data, pos);
        // One-step lazy matching: prefer a strictly longer match at pos+1.
        let take = match m {
            Some((len, dist)) => {
                let lazy_better = if len < 64 && pos + 1 < data.len() {
                    // Peek without inserting (insert happens below).
                    mf.find(data, pos + 1).map(|(l2, _)| l2 > len).unwrap_or(false)
                } else {
                    false
                };
                if lazy_better {
                    None
                } else {
                    Some((len, dist))
                }
            }
            None => None,
        };

        match take {
            None => {
                let b = data[pos];
                enc.encode_bit(&mut model.is_match, 0);
                enc.encode_tree(&mut model.literal[Model::lit_ctx(prev_byte)], 8, b as u32);
                mf.insert(data, pos);
                prev_byte = b;
                pos += 1;
            }
            Some((len, dist)) => {
                enc.encode_bit(&mut model.is_match, 1);
                enc.encode_tree(&mut model.len, 8, (len - MIN_MATCH) as u32);
                let v = (dist - 1) as u32;
                let nb = nbits(v);
                enc.encode_tree(&mut model.dist_slot, 5, nb);
                if nb >= 2 {
                    // Top bit of v is implied by nb; send the rest raw.
                    enc.encode_direct(v & ((1 << (nb - 1)) - 1), nb - 1);
                }
                for i in 0..len {
                    mf.insert(data, pos + i);
                }
                pos += len;
                prev_byte = data[pos - 1];
            }
        }
    }
    enc.finish()
}

/// Decompress an xz-like stream into exactly `raw_len` bytes.
pub fn decompress(stream: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    decompress_into(stream, raw_len, &mut out)?;
    Ok(out)
}

/// [`decompress`] into a reusable buffer (cleared first, capacity
/// retained across calls).
pub fn decompress_into(stream: &[u8], raw_len: usize, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    out.reserve(raw_len);
    if raw_len == 0 {
        return Ok(());
    }
    let mut dec = RangeDecoder::new(stream)?;
    let mut model = Model::new();
    let mut prev_byte = 0u8;

    while out.len() < raw_len {
        if dec.decode_bit(&mut model.is_match) == 0 {
            let b = dec.decode_tree(&mut model.literal[Model::lit_ctx(prev_byte)], 8) as u8;
            out.push(b);
            prev_byte = b;
        } else {
            let len = dec.decode_tree(&mut model.len, 8) as usize + MIN_MATCH;
            let nb = dec.decode_tree(&mut model.dist_slot, 5);
            let v = match nb {
                0 => 0u32,
                1 => 1u32,
                _ => (1 << (nb - 1)) | dec.decode_direct(nb - 1),
            };
            let dist = v as usize + 1;
            if dist > out.len() {
                return Err(Error::Compress(format!(
                    "xz-like: match distance {dist} exceeds produced {} bytes",
                    out.len()
                )));
            }
            if out.len() + len > raw_len {
                return Err(Error::Compress("xz-like: match overruns raw length".into()));
            }
            let start = out.len() - dist;
            if dist >= len {
                out.extend_from_within(start..start + len);
            } else {
                for i in 0..len {
                    let b = out[start + i];
                    out.push(b);
                }
            }
            prev_byte = *out.last().expect("match produced bytes");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop_check, Pcg32};

    fn roundtrip(data: &[u8]) {
        let stream = compress(data);
        let back = decompress(&stream, data.len()).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn empty_and_small() {
        roundtrip(b"");
        roundtrip(b"x");
        roundtrip(b"ab");
        roundtrip(b"hello, range coder");
    }

    #[test]
    fn repetitive_data_compresses_very_well() {
        let data: Vec<u8> = b"Electron_pt ".iter().copied().cycle().take(50_000).collect();
        let stream = compress(&data);
        assert!(stream.len() < 600, "got {}", stream.len());
        assert_eq!(decompress(&stream, data.len()).unwrap(), data);
    }

    #[test]
    fn random_data_roundtrips() {
        let mut rng = Pcg32::new(21);
        let mut data = vec![0u8; 30_000];
        rng.fill_bytes(&mut data);
        roundtrip(&data);
    }

    #[test]
    fn overlapping_rle_match() {
        let data = vec![0xAB; 10_000];
        let stream = compress(&data);
        assert!(stream.len() < 200);
        assert_eq!(decompress(&stream, data.len()).unwrap(), data);
    }

    #[test]
    fn beats_lz4_on_structured_data() {
        let mut rng = Pcg32::new(22);
        let data = rng.compressible_bytes(200_000, 0.65);
        let xz = compress(&data);
        let lz4 = super::super::lz4::compress(&data);
        assert!(
            xz.len() < lz4.len(),
            "xz-like {} should beat lz4 {}",
            xz.len(),
            lz4.len()
        );
    }

    #[test]
    fn long_matches_split_across_max_match() {
        // A run much longer than MAX_MATCH forces chained matches.
        let mut data = b"prefix-".to_vec();
        data.extend(std::iter::repeat(7u8).take(5 * MAX_MATCH + 13));
        data.extend_from_slice(b"-suffix");
        roundtrip(&data);
    }

    #[test]
    fn far_matches_use_direct_bits() {
        // Distance needing many direct bits (several hundred KiB).
        let mut rng = Pcg32::new(23);
        let mut unit = vec![0u8; 500];
        rng.fill_bytes(&mut unit);
        let mut data = unit.clone();
        data.resize(700_000, 0x5c);
        data.extend_from_slice(&unit);
        roundtrip(&data);
    }

    #[test]
    fn prop_roundtrip() {
        prop_check("xz-roundtrip", 30, |rng| {
            let len = rng.below(40_000) as usize;
            let r = rng.f64();
            let data = rng.compressible_bytes(len, r);
            roundtrip(&data);
        });
    }

    #[test]
    fn prop_decoder_never_panics_on_mutation() {
        prop_check("xz-decoder-robust", 40, |rng| {
            let data = rng.compressible_bytes(2_000, 0.6);
            let mut stream = compress(&data);
            if stream.is_empty() {
                return;
            }
            let idx = rng.below(stream.len() as u32) as usize;
            stream[idx] ^= 1 << rng.below(8);
            let _ = decompress(&stream, data.len()); // must not panic
        });
    }

    #[test]
    fn truncated_stream_errors_or_terminates() {
        let data = vec![3u8; 10_000];
        let stream = compress(&data);
        // Hard truncation: the decoder either errors or, because the
        // tail pads with zeros, produces *something* of raw_len — but it
        // must never panic. For a 4-byte stub it must error.
        assert!(decompress(&stream[..4.min(stream.len())], data.len()).is_err());
    }

    #[test]
    fn nbits_helper() {
        assert_eq!(nbits(0), 0);
        assert_eq!(nbits(1), 1);
        assert_eq!(nbits(2), 2);
        assert_eq!(nbits(3), 2);
        assert_eq!(nbits(4), 3);
        assert_eq!(nbits(u32::MAX), 32);
    }
}
