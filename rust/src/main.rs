//! `skimroot` — the SkimROOT launcher.
//!
//! Subcommands:
//!
//! * `gen`   — generate a synthetic NanoAOD-like dataset.
//! * `skim`  — run one skim job under any deployment mode (simulated
//!   testbed: virtual links + real compute).
//! * `index` — build `.tridx` zone-map sidecars for existing troot
//!   files (gen writes them automatically; this is the
//!   after-the-fact path for legacy files).
//! * `serve` — run the **multi-tenant skim service** over TCP: a
//!   bounded worker pool with admission control and a shared
//!   decompressed-basket cache, answering `SubmitQuery` / `JobStatus`
//!   / `FetchResult` frames *and* plain XRootD-like file access.
//! * `dpu`   — run the DPU HTTP service (separated-host mode) backed
//!   by a storage directory; includes the async `/jobs` API.
//! * `post`  — submit a JSON query to a running DPU over HTTP and save
//!   the filtered file (what the paper does with `curl`).
//! * `eval`  — reproduce the paper's figures (4a, 4b, 5a, 5b).
//!
//! Run `skimroot <cmd> --help` for flags.

use skimroot::cli::Args;
use skimroot::compress::Codec;
use skimroot::coordinator::{eval, Deployment, FaultKind, FaultPlan, Mode, Placement};
use skimroot::dpu::http::{self, post_skim, DpuHttpServer};
use skimroot::dpu::DpuConfig;
use skimroot::gen::{self, GenConfig};
use skimroot::metrics::Node;
use skimroot::net::LinkModel;
use skimroot::query::SkimQuery;
use skimroot::runtime::SkimRuntime;
use skimroot::serve::{ServeConfig, SkimScheduler, SkimService};
use skimroot::{Error, Result, SkimJob};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn main() {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        print_help();
        return;
    }
    let cmd = raw.remove(0);
    let result = match cmd.as_str() {
        "gen" => cmd_gen(raw),
        "skim" => cmd_skim(raw),
        "index" => cmd_index(raw),
        "serve" => cmd_serve(raw),
        "dpu" => cmd_dpu(raw),
        "post" => cmd_post(raw),
        "eval" => cmd_eval(raw),
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "skimroot — near-storage LHC data filtering (SkimROOT reproduction)

USAGE: skimroot <command> [flags]

COMMANDS:
  gen    --out FILE --events N [--branches 1749] [--hlt 677]
         [--basket 1000] [--codec lz4|zlib|xz|none] [--seed N]
         [--files N [--catalog NAME]]
         (--files N treats --out as a directory and writes an N-file
          dataset partNNN.troot plus a NAME.catalog listing)
  skim   --storage DIR (--query FILE | --higgs --input SPEC |
         --input SPEC [--branches A,B,*]) [--cut 'EXPR'] [--explain]
         [--stats] [--adaptive [--warmup-groups N] [--replan-every N]]
         [--fuse]
         [--mode client-legacy|client-opt|server-side|skimroot]
         [--link 1g|10g|100g] [--fan-out N] [--artifacts DIR]
         [--client-dir DIR] [--deadline-ms N] [--materialize NAME]
         [--fault-kind read-error|corrupt-frame|decompress-corrupt|
          stall-read|fail-at-read] [--fail-prob P] [--fault-at N]
         [--fail-attempts N] [--stall-s S] [--retries N]
         [--breaker-after N] [--fault-seed N]
         (SPEC is a dataset spec: one file, a glob like
          'store/*.troot', or catalog:NAME — multi-file datasets run
          per file with fault isolation and merge deterministically;
          --cut takes a TCut-style string, e.g.
          'nMuon >= 2 && (HLT_Mu50 || max(Muon_pt) > 100)';
          --explain prints the compiled plan without running;
          --explain --stats also prints the conjunct inventory with
          persisted selectivity tallies; --adaptive reorders the cut
          funnel by measured selectivity after a warm-up window — the
          run report then includes the per-conjunct profile;
          --fuse evaluates matching conjuncts through fused cut
          kernels (interpreter path only, composes with --adaptive;
          masks and outputs are bit-identical either way) —
          --explain --fuse prints the fusion plan with per-conjunct
          reasons without running;
          --materialize registers the output in the storage catalog
          as catalog:NAME with lineage, re-skimmable by name)
  index  [--force] FILE...
         (build .tridx zone-map sidecars next to existing troot files;
          fresh sidecars are skipped unless --force)
  serve  --root DIR --listen ADDR [--workers N] [--queue-depth N]
         [--cache-mb N] [--batch-window-ms N] [--mode client-legacy|
         client-opt|server-side|skimroot] [--fan-out N] [--work-dir DIR]
         (multi-tenant skim service: SubmitQuery/JobStatus/FetchResult
          frames + plain file access; --cache-mb 0 disables the shared
          basket cache; --batch-window-ms N merges same-file jobs
          arriving within N ms into one shared scan, 0 disables)
  dpu    --root DIR --listen ADDR [--artifacts DIR] [--scratch DIR]
         [--fan-out N] [--workers N] [--queue-depth N] [--cache-mb N]
         (POST /skim runs synchronously; POST /jobs + GET /jobs/<id>
          [/result] is the async multi-tenant API)
  post   --dpu ADDR --query FILE --out FILE
  eval   --dir DIR [--fig 4a|4b|5a|5b|all] [--scale small|standard]
         [--artifacts DIR]"
    );
}

fn parse_link(s: &str) -> Result<LinkModel> {
    Ok(match s {
        "1g" | "1" => LinkModel::wan_1g(),
        "10g" | "10" => LinkModel::shared_10g(),
        "100g" | "100" => LinkModel::dedicated_100g(),
        "local" => LinkModel::local(),
        other => return Err(Error::Config(format!("unknown link '{other}'"))),
    })
}

fn load_runtime(args: &Args) -> Option<SkimRuntime> {
    if args.switch("no-runtime") {
        return None;
    }
    let dir = args.get_or("artifacts", "artifacts");
    match SkimRuntime::load(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("[warn] PJRT runtime unavailable ({e}); using interpreter");
            None
        }
    }
}

fn cmd_gen(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let cfg = GenConfig {
        n_events: args.parse_num("events", 100_000u64)?,
        target_branches: args.parse_num("branches", 1749usize)?,
        n_hlt: args.parse_num("hlt", 677usize)?,
        basket_events: args.parse_num("basket", 1000u32)?,
        codec: Codec::parse(args.get_or("codec", "lz4"))?,
        seed: args.parse_num("seed", 0x5eed_cafeu64)?,
    };
    let out = args.require("out")?;
    let n_files: usize = args.parse_num("files", 1usize)?;
    if args.get("files").is_some() {
        // --files given (any N ≥ 1): dataset mode, --out is a
        // directory — a 1-file dataset still gets its catalog.
        if n_files == 0 {
            return Err(Error::Config("--files must be at least 1".into()));
        }
        let catalog = args.get_or("catalog", "dataset");
        let summaries = gen::generate_dataset(&cfg, out, n_files, catalog)?;
        let events: u64 = summaries.iter().map(|s| s.n_events).sum();
        let bytes: u64 = summaries.iter().map(|s| s.file_bytes).sum();
        // The hint treats the generated directory itself as the
        // storage root — always valid; prefix the inputs yourself when
        // exporting a parent directory instead.
        println!(
            "wrote {n_files}-file dataset under {out}: {} events total, {} on disk; \
             catalog {catalog}.catalog (skim it with --storage {out} \
             --input 'part*.troot' or --input catalog:{catalog})",
            events,
            skimroot::util::human_bytes(bytes),
        );
        return Ok(());
    }
    let summary = gen::generate(&cfg, out)?;
    println!(
        "wrote {out}: {} events, {} branches, {} baskets, {} raw → {} ({}x)",
        summary.n_events,
        summary.n_branches,
        summary.n_baskets,
        skimroot::util::human_bytes(summary.raw_bytes),
        skimroot::util::human_bytes(summary.file_bytes),
        format!("{:.2}", summary.compression_ratio()),
    );
    Ok(())
}

fn cmd_index(raw: Vec<String>) -> Result<()> {
    use skimroot::troot::{LocalFile, TRootReader};
    let args = Args::parse(raw, &["force"])?;
    if args.positional.is_empty() {
        return Err(Error::Config(
            "usage: skimroot index [--force] FILE... (writes FILE.tridx next to each file)"
                .into(),
        ));
    }
    for path in &args.positional {
        let path = std::path::Path::new(path);
        if !args.switch("force") {
            // Freshness check needs only the metadata, not a scan.
            let reader = TRootReader::open(LocalFile::open(path)?)?;
            let digest = skimroot::index::meta_digest(reader.meta());
            if let Ok(Some(existing)) = skimroot::index::load_sidecar(path) {
                if existing.digest == digest {
                    println!("{}: sidecar up to date", path.display());
                    continue;
                }
            }
        }
        let idx = skimroot::index::FileIndex::build_from_file(path)?;
        let sidecar = skimroot::index::sidecar_path(path);
        idx.save(&sidecar)?;
        println!(
            "{}: wrote {} ({} branches x {} baskets)",
            path.display(),
            sidecar.display(),
            idx.branches.len(),
            idx.branches.first().map(|b| b.baskets.len()).unwrap_or(0),
        );
    }
    Ok(())
}

fn cmd_skim(raw: Vec<String>) -> Result<()> {
    let args =
        Args::parse(raw, &["higgs", "no-runtime", "explain", "adaptive", "stats", "fuse"])?;
    let storage = args.require("storage")?;
    let mut query = if args.switch("higgs") {
        let input = args.require("input")?;
        gen::higgs_query(input, args.get_or("output", "skim_out.troot"))
    } else if let Some(path) = args.get("query") {
        let text = std::fs::read_to_string(path)?;
        SkimQuery::from_json_text(&text)?
    } else if let Some(input) = args.get("input") {
        // Ad-hoc query built from flags (pair with --cut for the full
        // selection surface without writing a JSON file).
        let patterns: Vec<String> = args
            .get("branches")
            .map(|s| s.split(',').map(|p| p.trim().to_string()).collect())
            .unwrap_or_else(|| vec!["*".to_string()]);
        let pattern_refs: Vec<&str> = patterns.iter().map(|s| s.as_str()).collect();
        SkimQuery::new(input, args.get_or("output", "skim_out.troot")).keep(&pattern_refs)
    } else {
        return Err(Error::Config(
            "provide --query FILE, --higgs --input NAME, or --input NAME [--cut EXPR]".into(),
        ));
    };
    if let Some(cut) = args.get("cut") {
        query = query.with_cut_str(cut)?;
    }

    if args.switch("explain") {
        // Compile and print the plan (expression tree, phase-1/2 fetch
        // sets, kernel-fit decision) without executing the job. With
        // --stats, also print the adaptive conjunct inventory — and,
        // for a catalog:NAME input with a persisted selectivity
        // sidecar, the measured pass rates a warm start would use.
        let job = SkimJob::new(query).storage(storage);
        println!("{}", job.explain()?);
        if args.switch("stats") {
            println!("{}", job.explain_stats()?);
        }
        if args.switch("fuse") {
            println!("{}", job.explain_fuse()?);
        }
        return Ok(());
    }

    let mode = Mode::parse(args.get_or("mode", "skimroot"))?;
    let link = parse_link(args.get_or("link", "1g"))?;
    let runtime = load_runtime(&args);
    let client_dir = args.get_or("client-dir", "skim_client");

    let mut deployment = Deployment::new(mode, link);
    deployment.fault = FaultPlan {
        kind: FaultKind::parse(args.get_or("fault-kind", "read-error"))?,
        fail_prob: args.parse_num("fail-prob", 0.0f64)?,
        fail_at_read: args.parse_num("fault-at", 0u64)?,
        fail_attempts: args.parse_num("fail-attempts", 0u32)?,
        stall_s: args.parse_num("stall-s", 0.0f64)?,
        max_retries: args.parse_num("retries", 3u32)?,
        breaker_after: args.parse_num("breaker-after", 0u32)?,
        seed: args.parse_num("fault-seed", 0u64)?,
    };
    deployment.fan_out = args.parse_num("fan-out", 1usize)?;
    // Selectivity-adaptive funnel ordering (interpreter path only;
    // strictly opt-in — the fixed stage order stays the default).
    deployment.adaptive.enabled = args.switch("adaptive");
    deployment.adaptive.warmup_groups = args.parse_num("warmup-groups", 4u64)?;
    deployment.adaptive.replan_every = args.parse_num("replan-every", 8u64)?;
    // Profile-guided fused cut kernels (interpreter path only; opt-in
    // exactly like --adaptive, with which it composes).
    deployment.fuse = args.switch("fuse");

    let mut job = SkimJob::new(query)
        .storage(storage)
        .client_dir(client_dir)
        .runtime(runtime.as_ref())
        .deployment(deployment)
        .deadline_ms(args.parse_num("deadline-ms", 0u64)?);
    if let Some(name) = args.get("materialize") {
        job = job.materialize(name);
    }
    let report = job.run()?;
    println!(
        "mode={} events={} pass={} ({:.3}%) attempts={} output={}",
        report.name,
        report.result.n_events,
        report.result.n_pass,
        100.0 * report.result.n_pass as f64 / report.result.n_events.max(1) as f64,
        report.attempts,
        skimroot::util::human_bytes(report.result.output_bytes),
    );
    if !report.files.is_empty() {
        println!("files: {}/{} ok", report.files_done(), report.files_total());
        for f in &report.files {
            match &f.error {
                Some(e) => println!(
                    "  FAIL {} (attempts {}): {e}",
                    f.path, f.attempts
                ),
                None => println!(
                    "  ok   {} events={} pass={} ({})",
                    f.path,
                    f.n_events,
                    f.n_pass,
                    skimroot::util::human_secs(f.elapsed)
                ),
            }
        }
    }
    println!("\n{}", report.timeline.report());
    println!("\nutilization:");
    for (node, u) in &report.utilization {
        if *u > 0.0 {
            println!("  {:<12} {:.1}%", node.name(), u * 100.0);
        }
    }
    for w in &report.result.warnings {
        println!("[warn] {w}");
    }
    if let Some(name) = args.get("materialize") {
        println!(
            "materialized as catalog:{name} under {storage} \
             (re-skim with --input catalog:{name})"
        );
    }
    Ok(())
}

/// Build a [`ServeConfig`] from the shared `serve`/`dpu` flags.
fn serve_config(args: &Args, root: &str, default_mode: &str) -> Result<ServeConfig> {
    let mut cfg = ServeConfig::new(root);
    cfg.workers = args.parse_num("workers", cfg.workers)?;
    cfg.queue_depth = args.parse_num("queue-depth", cfg.queue_depth)?;
    cfg.cache_bytes = args.parse_num("cache-mb", cfg.cache_bytes / 1_000_000)? * 1_000_000;
    cfg.batch_window_ms = args.parse_num("batch-window-ms", cfg.batch_window_ms)?;
    if let Some(dir) = args.get("work-dir") {
        cfg.work_dir = dir.into();
    }
    // The real TCP/HTTP transfer is the output hop: keep the link
    // local so no virtual output-transfer time is charged.
    let mode = Mode::parse(args.get_or("mode", default_mode))?;
    cfg.deployment = Deployment::new(mode, LinkModel::local());
    cfg.deployment.fan_out = args.parse_num("fan-out", 1usize)?;
    Ok(cfg)
}

fn cmd_serve(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let root = args.require("root")?;
    let listen = args.require("listen")?;
    let cfg = serve_config(&args, root, "server-side")?;
    let (workers, depth, cache) = (cfg.workers, cfg.queue_depth, cfg.cache_bytes);
    let service = SkimService::new(cfg)?;
    let listener = std::net::TcpListener::bind(listen)
        .map_err(|e| Error::Config(format!("bind {listen}: {e}")))?;
    println!(
        "multi-tenant skim service on {listen}, root={root} \
         ({workers} workers, queue depth {depth}, {} basket cache; ctrl-c to stop)",
        skimroot::util::human_bytes(cache),
    );
    let stop = Arc::new(AtomicBool::new(false));
    service.serve_tcp(listener, stop).join().ok();
    service.shutdown();
    Ok(())
}

fn cmd_dpu(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, &["no-runtime"])?;
    let root = args.require("root")?.to_string();
    let listen = args.require("listen")?;
    let scratch = args.get_or("scratch", "dpu_scratch").to_string();
    let fan_out = args.parse_num("fan-out", 1usize)?;
    let runtime = load_runtime(&args);
    // Leak the runtime: the service runs for the process lifetime and
    // handler threads need a 'static borrow.
    let runtime: Option<&'static SkimRuntime> = runtime.map(|rt| &*Box::leak(Box::new(rt)));

    let listener = std::net::TcpListener::bind(listen)
        .map_err(|e| Error::Config(format!("bind {listen}: {e}")))?;
    println!(
        "DPU service on {listen} (separated-host mode, fan-out {fan_out}), storage root={root}"
    );

    // Each POST /skim runs a SkimJob with DPU placement over `root`;
    // the local link leaves the (real) HTTP transfer uncharged.
    let deployment = Deployment::builder()
        .name("dpu-http")
        .placement(Placement::Dpu(DpuConfig::default()))
        .link(LinkModel::local())
        .fan_out(fan_out)
        .build()?;
    // The async `/jobs` API runs through the multi-tenant scheduler
    // (shared basket cache, admission control); the interpreter
    // evaluates those jobs — bit-identical to the kernel path.
    let sched = SkimScheduler::new(serve_config(&args, &root, "skimroot")?)?;
    let server = DpuHttpServer::new(http::storage_handler(root, scratch, runtime, deployment))
        .with_scheduler(sched.clone());
    let stop = Arc::new(AtomicBool::new(false));
    server.serve(listener, stop).join().ok();
    sched.shutdown();
    Ok(())
}

fn cmd_post(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let dpu = args.require("dpu")?;
    let query = std::fs::read_to_string(args.require("query")?)?;
    let out = args.require("out")?;
    let (status, headers, body) = post_skim(dpu, &query)?;
    if status != 200 {
        return Err(Error::protocol(format!(
            "DPU returned {status}: {}",
            String::from_utf8_lossy(&body)
        )));
    }
    std::fs::write(out, &body)?;
    println!(
        "saved {out} ({}); events={} pass={} dpu-elapsed={}s",
        skimroot::util::human_bytes(body.len() as u64),
        headers.get("x-skim-events").map(|s| s.as_str()).unwrap_or("?"),
        headers.get("x-skim-pass").map(|s| s.as_str()).unwrap_or("?"),
        headers.get("x-skim-elapsed-secs").map(|s| s.as_str()).unwrap_or("?"),
    );
    Ok(())
}

fn cmd_eval(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, &["no-runtime"])?;
    let dir = args.get_or("dir", "eval_data");
    let scale = match args.get_or("scale", "standard") {
        "small" => eval::EvalScale::small(),
        "standard" => eval::EvalScale::standard(),
        other => return Err(Error::Config(format!("unknown scale '{other}'"))),
    };
    let runtime = load_runtime(&args);
    let env = eval::prepare(dir, scale)?;
    let table = match args.get_or("fig", "all") {
        "4a" => eval::fig4a(&env, runtime.as_ref())?,
        "4b" => eval::fig4b(&env, runtime.as_ref())?,
        "5a" => eval::fig5a(&env, runtime.as_ref())?,
        "5b" => eval::fig5b(&env, runtime.as_ref())?,
        "all" => eval::all_figures(&env, runtime.as_ref())?,
        other => return Err(Error::Config(format!("unknown figure '{other}'"))),
    };
    println!("{table}");
    let _ = Node::Client; // keep import used in all cfgs
    Ok(())
}
