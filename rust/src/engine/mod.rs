//! The two-phase, multi-stage filtering engine (§3.2) — the code that
//! actually performs a skim, wherever it is deployed (client, server,
//! or DPU; the [`crate::coordinator`] decides where and over which
//! transport).
//!
//! Execution model:
//!
//! * **Phase 1** streams event clusters, fetching only the *criteria*
//!   branches, decompressing (software or DPU hardware engine),
//!   deserializing into padded batches, and evaluating the cut program
//!   — vectorized through the AOT PJRT kernel ([`crate::runtime`]) or
//!   with the batch-vectorized columnar [`interp`]reter (the per-event
//!   scalar evaluator is retained as its property-tested oracle).
//!   Decompress/deserialize/batch-append fan out across
//!   [`EngineOpts::workers`] real threads (branch names are interned
//!   to dense ids at plan time, so the hot path is all `Vec`
//!   indexing). Consecutive clusters are packed into one batch so a
//!   single kernel invocation evaluates many clusters (PJRT call
//!   overhead is amortized). Values of criteria branches that are also
//!   output branches are gathered for passing events immediately (they
//!   are already in memory).
//! * **Phase 2** fetches *output-only* branches — only for clusters
//!   containing passing events — and **selectively deserializes just
//!   the passing events** (the per-event `GetEntry` path). This is the
//!   paper's big deserialization win: 240.4 s → 16.8 s in Figure 4b.
//! * **Legacy mode** (`two_phase = false`) reproduces the baseline:
//!   every selected branch is fetched and *fully* deserialized for
//!   every cluster before evaluation.
//!
//! Since the API redesign these phases are **pluggable stages** of a
//! [`pipeline::Pipeline`] (`fetch → decompress → deserialize → eval`
//! per cluster group; `phase2 → output` per job): register custom
//! [`pipeline::FilterStage`]s around the built-ins to extend the
//! engine without forking it. See the [`pipeline`] module docs and
//! `ARCHITECTURE.md`.
//!
//! Every stage is attributed to the job [`Timeline`] (fetch via the
//! transport's virtual charges; decompress / deserialize / filter /
//! output as measured compute on the configured [`Node`]).

pub mod batch;
pub mod fused;
pub mod interp;
pub mod pipeline;
mod shared;

pub use pipeline::{FilterStage, GroupState, Hook, Pipeline, StageCtx, StageReg, Verdict};
pub use shared::run_shared;

use crate::compress::Codec;
use crate::metrics::{Node, Timeline};
use crate::query::SkimQuery;
use crate::runtime::SkimRuntime;
use crate::troot::ReadAt;
use crate::xrootd::cache::CacheStats;
use crate::Result;
use std::sync::Arc;

/// Where decompression runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DecompMode {
    /// On the compute node's CPU (cost attributed there).
    Software,
    /// On the DPU's hardware engine: wall time divided by `speedup`,
    /// attributed to [`Node::DpuEngine`] (not ARM-core CPU). Paper:
    /// 3.1 s software → 2.2 s engine ⇒ calibrated speedup ≈ 1.4.
    HwEngine {
        /// Calibrated engine speedup over one-core software decode.
        speedup: f64,
    },
}

/// Engine configuration for one run.
#[derive(Debug, Clone)]
pub struct EngineOpts {
    /// Two-phase execution (§3.2) vs legacy fetch-everything.
    pub two_phase: bool,
    /// Vectorized PJRT kernel vs per-event interpreter.
    pub use_pjrt: bool,
    /// Node whose CPU the compute stages burn.
    pub compute_node: Node,
    /// Where decompression runs (software CPU vs DPU engine).
    pub decomp: DecompMode,
    /// TTreeCache capacity; `None` disables the cache (local access).
    pub cache_bytes: Option<usize>,
    /// Output file codec (default: same as input).
    pub output_codec: Option<Codec>,
    /// Object-slot truncation for the interpreter path (must equal the
    /// kernel variant's M when comparing modes). Default 16.
    pub max_objects: usize,
    /// ROOT deserialization cost model (see [`DeserModel`]): our
    /// substrate decodes flat arrays at memcpy speed, but the system
    /// being reproduced pays ROOT's per-entry `GetEntry` dispatch plus
    /// per-byte streaming — the paper's dominant 240.4 s
    /// "deserialization" bar. Charged as modeled busy time on the
    /// compute node; `None` disables (pure-substrate timings). See
    /// DESIGN.md §Execution-time model.
    pub deser_model: Option<DeserModel>,
    /// Effective compute parallelism of the filtering pipeline: WLCG
    /// client/server jobs are single-threaded (1.0); the DPU filters
    /// across its 16 ARM cores (paper Fig. 5a: ClientOpt deserialize
    /// 16.8 s vs DPU 4.1 s on identical output ⇒ effective ≈ 4× after
    /// Amdahl losses). Since the threaded-engine refactor this is no
    /// longer only a cost-model divisor: the engine spawns
    /// [`EngineOpts::workers`] real worker threads for per-group
    /// decompress / deserialize / batch-append, and the modeled
    /// [`DeserModel`] cost is charged per worker and folded
    /// max-over-workers (see `engine/pipeline.rs`). `parallelism = 1`
    /// reproduces the legacy single-threaded timelines exactly.
    pub parallelism: f64,
    /// Restrict the skim to events in `[start, end)` — the sharding
    /// hook used by multi-DPU fan-out deployments
    /// ([`crate::dpu::DpuCluster`]). `None` covers the whole file.
    /// Shard boundaries are honored exactly; fetches stay
    /// basket-granular at the edges.
    pub event_range: Option<(u64, u64)>,
    /// Shared server-side decompressed-basket cache
    /// ([`crate::serve::BasketCache`]). When set, the `fetch` stage
    /// (and the phase-2 selective fetch) consults it before touching
    /// the store: hits skip both the read *and* the decompression,
    /// misses load through it single-flight so concurrent jobs pay
    /// for each cold basket once. `None` (the default, and every
    /// one-shot job) preserves the uncached behavior exactly. See
    /// `engine/pipeline.rs` and ARCHITECTURE.md § "Serving layer".
    pub basket_cache: Option<std::sync::Arc<crate::serve::BasketCache>>,
    /// Zone-map index of the input file (from a `.tridx` sidecar).
    /// When set and the plan compiled [`crate::query::ZonePredicate`]s,
    /// the fetch stage skips clusters the index proves dead — before
    /// any read, decompression or deserialization. The index digest is
    /// verified against the file's metadata first; a mismatch (stale
    /// sidecar) is ignored with a warning and the run degrades to a
    /// full scan. Output bytes, `n_pass` and `n_events` are identical
    /// with or without a zone map; only `stage_funnel` tallies differ
    /// (pruned events never enter the funnel). `None` disables pruning.
    pub zone_map: Option<std::sync::Arc<crate::index::FileIndex>>,
    /// Job lifecycle controls ([`crate::lifecycle::JobCtl`]): an
    /// optional cooperative [`crate::lifecycle::CancelToken`] and an
    /// optional virtual-time deadline. The engine checks them at every
    /// basket-group boundary and before phase 2, so a cancel or an
    /// expired deadline surfaces within one group of work. The default
    /// (inactive) adds no checks and preserves the legacy contract.
    pub ctl: crate::lifecycle::JobCtl,
    /// Selectivity-adaptive execution ([`AdaptiveOpts`]). Off by
    /// default: the interpreter evaluates conjuncts in fixed stage
    /// order and per-stage funnels are reproducible across
    /// configurations. When enabled (interpreter path only — the AOT
    /// kernel's stage order is fixed in silicon), the engine measures
    /// per-conjunct selectivity during a warm-up window, then reorders
    /// the funnel cheapest-most-selective-first and re-plans
    /// periodically. Final masks and output bytes are bit-identical
    /// either way; only per-stage funnel tallies may differ.
    pub adaptive: AdaptiveOpts,
    /// Profile-guided fused cut kernels ([`crate::query::fuse`] plans,
    /// [`fused`] executes). Off by default: the interpreter sweeps one
    /// conjunct at a time. When enabled (interpreter path only, like
    /// `adaptive`), conjuncts whose shape matches a fused kernel —
    /// scalar compares, ranges, 2–3-cut AND-chains, single-cut object
    /// counts, the HT sum — evaluate in word-packed fused sweeps;
    /// everything else falls back to the per-conjunct interpreter
    /// sweep untouched. Masks, funnels and output bytes are
    /// bit-identical with or without fusion; composes with `adaptive`
    /// (the plan is rebuilt at every replan checkpoint) and works
    /// standalone in fixed conjunct order.
    pub fuse: bool,
}

/// Configuration of selectivity-adaptive execution (see
/// [`crate::query::stats`] and `engine/interp.rs`'s `eval_adaptive`).
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveOpts {
    /// Master switch; `false` (default) keeps the fixed-order
    /// evaluators and collects no per-conjunct statistics.
    pub enabled: bool,
    /// Basket groups evaluated in fixed order (while measuring) before
    /// the first reorder.
    pub warmup_groups: u64,
    /// Re-rank cadence after warm-up: every N groups the accumulated
    /// statistics are re-ranked (N ≥ 1).
    pub replan_every: u64,
    /// Warm-start profile (e.g. loaded from a materialized skim's
    /// `.prof` sidecar): conjuncts found in it by canonical key start
    /// with measured tallies, so the first reorder happens at group 0
    /// instead of after warm-up.
    pub seed: Option<crate::query::SelectivityProfile>,
}

impl Default for AdaptiveOpts {
    fn default() -> Self {
        AdaptiveOpts { enabled: false, warmup_groups: 4, replan_every: 8, seed: None }
    }
}

impl EngineOpts {
    /// Real worker threads the engine fans a group's (cluster × branch)
    /// basket work across: the modeled `parallelism`, materialized
    /// (rounded, at least one; capped at 64 so a miscalibrated model
    /// can't fork-bomb the host).
    pub fn workers(&self) -> usize {
        let w = self.parallelism.round();
        if w.is_finite() && w > 1.0 {
            (w as usize).min(64)
        } else {
            1
        }
    }
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            two_phase: true,
            use_pjrt: true,
            compute_node: Node::Client,
            decomp: DecompMode::Software,
            cache_bytes: Some(crate::xrootd::DEFAULT_CACHE_BYTES),
            output_codec: None,
            max_objects: 16,
            deser_model: Some(DeserModel::root_like()),
            parallelism: 1.0,
            event_range: None,
            basket_cache: None,
            zone_map: None,
            ctl: crate::lifecycle::JobCtl::none(),
            adaptive: AdaptiveOpts::default(),
            fuse: false,
        }
    }
}

/// Modeled cost of ROOT deserialization: `entries × per_entry +
/// bytes / bytes_per_sec`, where an *entry* is one (branch, event)
/// `GetEntry` materialization.
///
/// Calibration: Figure 4b's 240.4 s to materialize ~116 branches ×
/// 1.8 M events from the 5 GB LZ4 file ⇒ ≈ 1.1 µs per entry, with a
/// ~60 MB/s streaming term for the value payloads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeserModel {
    /// Seconds per (branch, event) materialized.
    pub per_entry: f64,
    /// Payload streaming rate (bytes/s).
    pub bytes_per_sec: f64,
}

impl DeserModel {
    /// The Figure-4b calibration (≈1.1 µs/entry, ~60 MB/s streaming).
    pub fn root_like() -> Self {
        DeserModel { per_entry: 1.1e-6, bytes_per_sec: 60e6 }
    }

    /// Modeled seconds for `entries` entries covering `bytes` of raw
    /// payload at the given parallelism.
    pub fn cost(&self, entries: u64, bytes: u64, parallelism: f64) -> f64 {
        (entries as f64 * self.per_entry + bytes as f64 / self.bytes_per_sec)
            / parallelism.max(1.0)
    }
}

/// Outcome of one skim run (timings live on the caller's [`Timeline`]).
#[derive(Debug, Clone)]
pub struct SkimResult {
    /// Events this job covered (whole file, or its `event_range`).
    pub n_events: u64,
    /// Events passing the full selection.
    pub n_pass: u64,
    /// Cumulative survivors after (preselection, +object, +event,
    /// +trigger) — the §3.2 funnel. The event stage covers the HT unit
    /// plus any residual IR expressions of the open query frontend.
    pub stage_funnel: [u64; 4],
    /// Where the filtered file was written.
    pub output_path: std::path::PathBuf,
    /// Size of the filtered file.
    pub output_bytes: u64,
    /// Compressed baskets fetched from the store (shared-basket-cache
    /// hits fetch nothing and are not counted).
    pub baskets_fetched: u64,
    /// Compressed bytes fetched from the store.
    pub fetched_bytes: u64,
    /// TTreeCache effectiveness if a cache was used.
    pub cache: Option<CacheStats>,
    /// True if the vectorized PJRT path evaluated the cuts.
    pub vectorized: bool,
    /// Engine warnings (planner fallbacks, interpreter use).
    pub warnings: Vec<String>,
}

impl SkimResult {
    /// Fold per-part results — event-range shards of a DPU fan-out or
    /// per-file results of a dataset job — into one aggregate: counts
    /// and funnels add, cache stats merge, `vectorized` is the AND
    /// over parts, warnings are deduplicated in first-seen order. The
    /// caller sets `output_path` / `output_bytes` after writing the
    /// merged file (they start empty / zero here).
    pub fn merge_parts<'a>(parts: impl IntoIterator<Item = &'a SkimResult>) -> SkimResult {
        let mut acc = SkimResult {
            n_events: 0,
            n_pass: 0,
            stage_funnel: [0; 4],
            output_path: std::path::PathBuf::new(),
            output_bytes: 0,
            baskets_fetched: 0,
            fetched_bytes: 0,
            cache: None,
            vectorized: true,
            warnings: Vec::new(),
        };
        for s in parts {
            acc.n_events += s.n_events;
            acc.n_pass += s.n_pass;
            for (a, x) in acc.stage_funnel.iter_mut().zip(s.stage_funnel) {
                *a += x;
            }
            acc.baskets_fetched += s.baskets_fetched;
            acc.fetched_bytes += s.fetched_bytes;
            acc.cache = merge_cache_stats(acc.cache, s.cache);
            acc.vectorized &= s.vectorized;
            for w in &s.warnings {
                if !acc.warnings.contains(w) {
                    acc.warnings.push(w.clone());
                }
            }
        }
        acc
    }
}

fn merge_cache_stats(a: Option<CacheStats>, b: Option<CacheStats>) -> Option<CacheStats> {
    match (a, b) {
        (Some(x), Some(y)) => Some(CacheStats {
            hits: x.hits + y.hits,
            misses: x.misses + y.misses,
            passthrough: x.passthrough + y.passthrough,
            prefetch_batches: x.prefetch_batches + y.prefetch_batches,
            prefetched_bytes: x.prefetched_bytes + y.prefetched_bytes,
        }),
        (x, None) => x,
        (None, y) => y,
    }
}

/// The filtering engine: an optional PJRT runtime handle plus the
/// stage [`Pipeline`]. Without a runtime only the interpreter path is
/// available; with the default pipeline it reproduces the paper's
/// engine exactly.
pub struct SkimEngine<'rt> {
    runtime: Option<&'rt SkimRuntime>,
    pipeline: Pipeline,
}

impl<'rt> SkimEngine<'rt> {
    /// An engine with the built-in stage pipeline.
    pub fn new(runtime: Option<&'rt SkimRuntime>) -> Self {
        SkimEngine { runtime, pipeline: Pipeline::builtin() }
    }

    /// An engine with a caller-assembled pipeline (advanced; most
    /// callers want [`SkimEngine::new`] + [`SkimEngine::pipeline_mut`]).
    pub fn with_pipeline(runtime: Option<&'rt SkimRuntime>, pipeline: Pipeline) -> Self {
        SkimEngine { runtime, pipeline }
    }

    /// The built-in pipeline extended with portable registrations
    /// (how [`crate::coordinator::Coordinator`] threads custom stages
    /// into every engine a deployment spins up).
    pub fn with_stages(
        runtime: Option<&'rt SkimRuntime>,
        stages: &[StageReg],
    ) -> Result<SkimEngine<'rt>> {
        let mut engine = SkimEngine::new(runtime);
        for reg in stages {
            let after: Vec<&str> = reg.after.iter().map(|s| s.as_str()).collect();
            engine.pipeline.register(reg.hook, &after, reg.stage.clone())?;
        }
        Ok(engine)
    }

    /// The engine's stage registry.
    pub fn pipeline(&self) -> &Pipeline {
        &self.pipeline
    }

    /// Mutable access for registering custom stages.
    pub fn pipeline_mut(&mut self) -> &mut Pipeline {
        &mut self.pipeline
    }

    /// Run a skim: read from `store`, write the filtered file to
    /// `output_path` (local), account all stages on `timeline`.
    ///
    /// Drives the stage pipeline: per cluster group the Group-hook
    /// stages run in DAG order (a [`Verdict::Drop`] vetoes the group),
    /// surviving passes are committed, then the Job-hook stages run
    /// once (a `Drop` skips the rest — aborting the job if `output`
    /// never runs).
    pub fn run(
        &self,
        store: Arc<dyn ReadAt>,
        query: &SkimQuery,
        timeline: &Timeline,
        opts: &EngineOpts,
        output_path: impl Into<std::path::PathBuf>,
    ) -> Result<SkimResult> {
        let group_order = self.pipeline.ordered(Hook::Group)?;
        let job_order = self.pipeline.ordered(Hook::Job)?;
        let mut ctx =
            StageCtx::new(self.runtime, store, query, timeline, opts, output_path.into())?;

        while ctx.begin_group() {
            // Cooperative lifecycle checkpoint: a cancel or an expired
            // virtual-time deadline surfaces at the group boundary,
            // before any more fetch/decompress work is spent.
            opts.ctl.check(timeline)?;
            let mut vetoed = false;
            for reg in &group_order {
                match reg.stage.run(&mut ctx)? {
                    Verdict::Continue => {}
                    Verdict::Drop => {
                        vetoed = true;
                        break;
                    }
                }
            }
            if vetoed {
                ctx.abort_group();
            } else {
                ctx.commit_group()?;
            }
        }

        opts.ctl.check(timeline)?;
        for reg in &job_order {
            if let Verdict::Drop = reg.stage.run(&mut ctx)? {
                break;
            }
        }
        ctx.finish()
    }
}
