//! The two-phase, multi-stage filtering engine (§3.2) — the code that
//! actually performs a skim, wherever it is deployed (client, server,
//! or DPU; the [`crate::coordinator`] decides where and over which
//! transport).
//!
//! Execution model:
//!
//! * **Phase 1** streams event clusters, fetching only the *criteria*
//!   branches, decompressing (software or DPU hardware engine),
//!   deserializing into padded batches, and evaluating the cut program
//!   — vectorized through the AOT PJRT kernel ([`crate::runtime`]) or
//!   with the per-event [`interp`]reter. Consecutive clusters are
//!   packed into one batch so a single kernel invocation evaluates
//!   many clusters (PJRT call overhead is amortized). Values of
//!   criteria branches that are also output branches are gathered for
//!   passing events immediately (they are already in memory).
//! * **Phase 2** fetches *output-only* branches — only for clusters
//!   containing passing events — and **selectively deserializes just
//!   the passing events** (the per-event `GetEntry` path). This is the
//!   paper's big deserialization win: 240.4 s → 16.8 s in Figure 4b.
//! * **Legacy mode** (`two_phase = false`) reproduces the baseline:
//!   every selected branch is fetched and *fully* deserialized for
//!   every cluster before evaluation.
//!
//! Every stage is attributed to the job [`Timeline`] (fetch via the
//! transport's virtual charges; decompress / deserialize / filter /
//! output as measured compute on the configured [`Node`]).

pub mod batch;
pub mod interp;

use crate::compress::Codec;
use crate::metrics::{Node, Stage, Timeline};
use crate::query::plan::SkimPlan;
use crate::query::SkimQuery;
use crate::runtime::{Batch, Capacities, CutParams, MaskResult, SkimRuntime};
use crate::troot::{
    basket as basket_codec, BasketInfo, BranchKind, BranchMeta, ColumnData, ColumnValues,
    DecodedBasket, ReadAt, TRootReader, TRootWriter,
};
use crate::xrootd::cache::CacheStats;
use crate::xrootd::TTreeCache;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Where decompression runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DecompMode {
    /// On the compute node's CPU (cost attributed there).
    Software,
    /// On the DPU's hardware engine: wall time divided by `speedup`,
    /// attributed to [`Node::DpuEngine`] (not ARM-core CPU). Paper:
    /// 3.1 s software → 2.2 s engine ⇒ calibrated speedup ≈ 1.4.
    HwEngine { speedup: f64 },
}

/// Engine configuration for one run.
#[derive(Debug, Clone)]
pub struct EngineOpts {
    /// Two-phase execution (§3.2) vs legacy fetch-everything.
    pub two_phase: bool,
    /// Vectorized PJRT kernel vs per-event interpreter.
    pub use_pjrt: bool,
    /// Node whose CPU the compute stages burn.
    pub compute_node: Node,
    pub decomp: DecompMode,
    /// TTreeCache capacity; `None` disables the cache (local access).
    pub cache_bytes: Option<usize>,
    /// Output file codec (default: same as input).
    pub output_codec: Option<Codec>,
    /// Object-slot truncation for the interpreter path (must equal the
    /// kernel variant's M when comparing modes). Default 16.
    pub max_objects: usize,
    /// ROOT deserialization cost model (see [`DeserModel`]): our
    /// substrate decodes flat arrays at memcpy speed, but the system
    /// being reproduced pays ROOT's per-entry `GetEntry` dispatch plus
    /// per-byte streaming — the paper's dominant 240.4 s
    /// "deserialization" bar. Charged as modeled busy time on the
    /// compute node; `None` disables (pure-substrate timings). See
    /// DESIGN.md §Execution-time model.
    pub deser_model: Option<DeserModel>,
    /// Effective compute parallelism for the modeled deserialization
    /// cost: WLCG client/server jobs are single-threaded (1.0); the
    /// DPU filters across its 16 ARM cores (paper Fig. 5a: ClientOpt
    /// deserialize 16.8 s vs DPU 4.1 s on identical output ⇒ effective
    /// ≈ 4× after Amdahl losses).
    pub parallelism: f64,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            two_phase: true,
            use_pjrt: true,
            compute_node: Node::Client,
            decomp: DecompMode::Software,
            cache_bytes: Some(crate::xrootd::DEFAULT_CACHE_BYTES),
            output_codec: None,
            max_objects: 16,
            deser_model: Some(DeserModel::root_like()),
            parallelism: 1.0,
        }
    }
}

/// Modeled cost of ROOT deserialization: `entries × per_entry +
/// bytes / bytes_per_sec`, where an *entry* is one (branch, event)
/// `GetEntry` materialization.
///
/// Calibration: Figure 4b's 240.4 s to materialize ~116 branches ×
/// 1.8 M events from the 5 GB LZ4 file ⇒ ≈ 1.1 µs per entry, with a
/// ~60 MB/s streaming term for the value payloads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeserModel {
    /// Seconds per (branch, event) materialized.
    pub per_entry: f64,
    /// Payload streaming rate (bytes/s).
    pub bytes_per_sec: f64,
}

impl DeserModel {
    pub fn root_like() -> Self {
        DeserModel { per_entry: 1.1e-6, bytes_per_sec: 60e6 }
    }

    /// Modeled seconds for `entries` entries covering `bytes` of raw
    /// payload at the given parallelism.
    pub fn cost(&self, entries: u64, bytes: u64, parallelism: f64) -> f64 {
        (entries as f64 * self.per_entry + bytes as f64 / self.bytes_per_sec)
            / parallelism.max(1.0)
    }
}

/// Outcome of one skim run (timings live on the caller's [`Timeline`]).
#[derive(Debug, Clone)]
pub struct SkimResult {
    pub n_events: u64,
    pub n_pass: u64,
    /// Cumulative survivors after (preselection, +object, +HT,
    /// +trigger) — the §3.2 funnel.
    pub stage_funnel: [u64; 4],
    pub output_path: std::path::PathBuf,
    pub output_bytes: u64,
    pub baskets_fetched: u64,
    pub fetched_bytes: u64,
    /// TTreeCache effectiveness if a cache was used.
    pub cache: Option<CacheStats>,
    /// True if the vectorized PJRT path evaluated the cuts.
    pub vectorized: bool,
    pub warnings: Vec<String>,
}

/// The filtering engine. Holds an optional reference to the loaded
/// PJRT runtime; without one, only the interpreter path is available.
pub struct SkimEngine<'rt> {
    runtime: Option<&'rt SkimRuntime>,
}

impl<'rt> SkimEngine<'rt> {
    pub fn new(runtime: Option<&'rt SkimRuntime>) -> Self {
        SkimEngine { runtime }
    }

    /// Run a skim: read from `store`, write the filtered file to
    /// `output_path` (local), account all stages on `timeline`.
    pub fn run(
        &self,
        store: Arc<dyn ReadAt>,
        query: &SkimQuery,
        timeline: &Timeline,
        opts: &EngineOpts,
        output_path: impl Into<std::path::PathBuf>,
    ) -> Result<SkimResult> {
        let output_path = output_path.into();

        // Optional TTreeCache in front of the store.
        let cache = opts
            .cache_bytes
            .map(|cap| Arc::new(TTreeCache::new(store.clone(), cap)));
        let eff_store: Arc<dyn ReadAt> = match &cache {
            Some(c) => c.clone(),
            None => store,
        };

        let reader = TRootReader::open(eff_store)?;
        let meta = reader.meta().clone();
        let plan = SkimPlan::build(query, &meta)?;
        let mut warnings = plan.warnings.clone();

        // --- evaluation strategy ---------------------------------------
        let vectorized = opts.use_pjrt && plan.program.fits_kernel() && self.runtime.is_some();
        if opts.use_pjrt && !vectorized {
            warnings.push("vectorized path unavailable; using interpreter".into());
        }
        let caps = self
            .runtime
            .map(|r| r.caps)
            .unwrap_or(Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 });
        let basket_events = meta.basket_events.max(1) as usize;
        let (batch_b, m, variant) = if vectorized {
            let rt = self.runtime.unwrap();
            let v = rt.variant_for(basket_events);
            (v.b, v.m, Some(v))
        } else {
            // The interpreter has no per-call overhead; size batches to
            // one cluster.
            (basket_events, opts.max_objects, None)
        };
        let params = if vectorized {
            Some(CutParams::pack(&plan.program, &caps)?)
        } else {
            None
        };

        let n_events = meta.n_events;
        let n_clusters = (n_events as usize).div_ceil(basket_events);

        // Branch metadata lookups.
        let branch_meta = |name: &str| -> Result<BranchMeta> { Ok(reader.branch(name)?.clone()) };
        let criteria: Vec<BranchMeta> = plan
            .criteria_branches
            .iter()
            .map(|b| branch_meta(b))
            .collect::<Result<_>>()?;
        let output_only: Vec<BranchMeta> = plan
            .output_only_branches
            .iter()
            .map(|b| branch_meta(b))
            .collect::<Result<_>>()?;

        // Phase-1 fetch set: criteria (+ all output branches in legacy
        // mode, fully decoded for every cluster — the baseline's cost).
        let phase1: Vec<&BranchMeta> = if opts.two_phase {
            criteria.iter().collect()
        } else {
            let mut v: Vec<&BranchMeta> = criteria.iter().collect();
            for b in &output_only {
                v.push(b);
            }
            v
        };
        // Branches gathered right after evaluation, from the decoded
        // baskets: criteria∩output in two-phase mode (already in
        // memory), all output branches in legacy mode.
        let gather_now: Vec<&BranchMeta> = if opts.two_phase {
            criteria
                .iter()
                .filter(|b| plan.output_branches.contains(&b.desc.name))
                .collect()
        } else {
            plan.output_branches
                .iter()
                .map(|name| {
                    phase1
                        .iter()
                        .find(|b| &b.desc.name == name)
                        .copied()
                        .expect("legacy phase1 contains all output branches")
                })
                .collect()
        };

        if let Some(c) = &cache {
            let mut ranges = Vec::new();
            for b in &phase1 {
                for k in &b.baskets {
                    ranges.push((k.offset, k.comp_len as usize));
                }
            }
            c.train(ranges);
        }

        // Output accumulators.
        let mut accs: HashMap<String, OutputAcc> = plan
            .output_branches
            .iter()
            .map(|name| {
                let bm = branch_meta(name)?;
                Ok((name.clone(), OutputAcc::new(bm.desc.clone())))
            })
            .collect::<Result<_>>()?;

        let mut stage_funnel = [0u64; 4];
        let mut pass_total = 0u64;
        let mut cluster_pass: Vec<Vec<u64>> = vec![Vec::new(); n_clusters];
        let mut counters = FetchCounters::default();

        // ---------------- phase 1 ---------------------------------------
        // Group consecutive clusters so one kernel call evaluates up to
        // `batch_b` events.
        let mut cluster = 0usize;
        while cluster < n_clusters {
            // Build the group: (cluster, lo, n) triples.
            let mut group: Vec<(usize, u64, usize)> = Vec::new();
            let mut total = 0usize;
            while cluster < n_clusters {
                let lo = (cluster * basket_events) as u64;
                let hi = ((cluster + 1) * basket_events).min(n_events as usize) as u64;
                let n = (hi - lo) as usize;
                if !group.is_empty() && total + n > batch_b {
                    break;
                }
                group.push((cluster, lo, n));
                total += n;
                cluster += 1;
                if total >= batch_b {
                    break;
                }
            }

            // Fetch + decompress + (fully) decode this group's baskets.
            let mut decoded: Vec<HashMap<String, DecodedBasket>> =
                Vec::with_capacity(group.len());
            for &(_, lo, _) in &group {
                let mut map = HashMap::new();
                for b in &phase1 {
                    let (raw, info) =
                        self.fetch_raw(&reader, b, lo, timeline, opts, &mut counters)?;
                    let dec = timeline.stage(Stage::Deserialize, opts.compute_node, || {
                        basket_codec::decode(
                            &b.desc,
                            &raw,
                            info.first_event,
                            info.n_events as usize,
                        )
                    })?;
                    // Modeled ROOT streamer cost: every event of this
                    // basket is materialized (one GetEntry per event).
                    if let Some(model) = opts.deser_model {
                        timeline.add_real(
                            Stage::Deserialize,
                            opts.compute_node,
                            model.cost(info.n_events as u64, raw.len() as u64, opts.parallelism),
                        );
                    }
                    map.insert(b.desc.name.clone(), dec);
                }
                decoded.push(map);
            }

            // Evaluate the whole group.
            if plan.criteria_branches.is_empty() {
                // No selection: everything passes.
                for (gi, &(cl, lo, n)) in group.iter().enumerate() {
                    for s in &mut stage_funnel {
                        *s += n as u64;
                    }
                    let passes: Vec<u64> = (lo..lo + n as u64).collect();
                    pass_total += passes.len() as u64;
                    self.gather_from_decoded(
                        &gather_now,
                        &decoded[gi],
                        &passes,
                        &mut accs,
                        timeline,
                        opts,
                    );
                    cluster_pass[cl] = passes;
                }
                continue;
            }

            // Sub-chunk only when a single cluster exceeds the batch.
            let chunks: Vec<(usize, u64, usize, usize)> = {
                // (group idx, chunk lo, chunk n, batch dst)
                let mut v = Vec::new();
                let mut dst = 0usize;
                for (gi, &(_, lo, n)) in group.iter().enumerate() {
                    let mut off = 0usize;
                    while off < n {
                        if dst == batch_b {
                            // flush boundary handled below by eval loop
                            dst = 0;
                        }
                        let take = (n - off).min(batch_b - dst);
                        v.push((gi, lo + off as u64, take, dst));
                        dst += take;
                        off += take;
                    }
                }
                v
            };

            // Fill + evaluate in batch_b windows.
            let mut batch = Batch::zeroed(&caps, batch_b, m);
            let mut window: Vec<(usize, u64, usize, usize)> = Vec::new();
            let mut fill = 0usize;
            let mut flush = |batch: &mut Batch,
                             window: &mut Vec<(usize, u64, usize, usize)>|
             -> Result<()> {
                if window.is_empty() {
                    return Ok(());
                }
                let result: MaskResult = if let Some(v) = variant {
                    let rt = self.runtime.unwrap();
                    let p = params.as_ref().unwrap();
                    timeline.stage(Stage::Filter, opts.compute_node, || rt.eval(v, batch, p))?
                } else {
                    timeline
                        .stage(Stage::Filter, opts.compute_node, || interp::eval(&plan.program, batch))
                };
                for &(gi, clo, cn, dst) in window.iter() {
                    let (cl, _, _) = group[gi];
                    let mut passes = Vec::new();
                    for ev in 0..cn {
                        let mut cum = 1.0f32;
                        for (s, stage) in result.stages.iter().enumerate() {
                            cum *= stage[dst + ev];
                            stage_funnel[s] += cum as u64;
                        }
                        if result.mask[dst + ev] > 0.5 {
                            passes.push(clo + ev as u64);
                        }
                    }
                    if passes.is_empty() {
                        continue;
                    }
                    pass_total += passes.len() as u64;
                    self.gather_from_decoded(
                        &gather_now,
                        &decoded[gi],
                        &passes,
                        &mut accs,
                        timeline,
                        opts,
                    );
                    cluster_pass[cl].extend_from_slice(&passes);
                }
                window.clear();
                *batch = Batch::zeroed(&caps, batch_b, m);
                Ok(())
            };

            for (gi, clo, cn, dst) in chunks {
                if dst == 0 && fill > 0 {
                    flush(&mut batch, &mut window)?;
                }
                timeline.stage(Stage::Deserialize, opts.compute_node, || {
                    batch::append(&plan.program, &decoded[gi], clo, cn, &mut batch, dst)
                })?;
                window.push((gi, clo, cn, dst));
                fill = dst + cn;
            }
            flush(&mut batch, &mut window)?;
        }

        // ---------------- phase 2 ---------------------------------------
        // Output-only branches, passing clusters only, **selective**
        // per-event deserialization.
        if opts.two_phase && !output_only.is_empty() && pass_total > 0 {
            if let Some(c) = &cache {
                let mut ranges = Vec::new();
                for (cluster, passes) in cluster_pass.iter().enumerate() {
                    if passes.is_empty() {
                        continue;
                    }
                    for b in &output_only {
                        let k = &b.baskets[cluster];
                        ranges.push((k.offset, k.comp_len as usize));
                    }
                }
                c.train(ranges);
            }
            for (cluster, passes) in cluster_pass.iter().enumerate() {
                if passes.is_empty() {
                    continue;
                }
                let lo = (cluster * basket_events) as u64;
                for b in &output_only {
                    let (raw, info) =
                        self.fetch_raw(&reader, b, lo, timeline, opts, &mut counters)?;
                    let acc = accs.get_mut(&b.desc.name).expect("acc exists");
                    let appended =
                        timeline.stage(Stage::Deserialize, opts.compute_node, || -> Result<usize> {
                            let mut n = 0;
                            for &ev in passes {
                                n += acc.push_event_raw(&raw, &info, ev)?;
                            }
                            Ok(n)
                        })?;
                    // Modeled GetEntry cost: only the passing events.
                    if let Some(model) = opts.deser_model {
                        timeline.add_real(
                            Stage::Deserialize,
                            opts.compute_node,
                            model.cost(passes.len() as u64, appended as u64, opts.parallelism),
                        );
                    }
                }
            }
        }

        // ---------------- output ----------------------------------------
        let codec = opts.output_codec.unwrap_or(meta.codec);
        let summary = timeline.stage(Stage::OutputWrite, opts.compute_node, || {
            let mut writer = TRootWriter::new(&output_path, codec, meta.basket_events);
            for name in &plan.output_branches {
                let acc = accs.remove(name).expect("acc exists");
                let desc = acc.desc.clone();
                writer.add_branch(desc, acc.finish())?;
            }
            writer.finalize()
        })?;

        Ok(SkimResult {
            n_events,
            n_pass: pass_total,
            stage_funnel,
            output_path,
            output_bytes: summary.file_bytes,
            baskets_fetched: counters.baskets,
            fetched_bytes: counters.bytes,
            cache: cache.as_ref().map(|c| c.stats()),
            vectorized,
            warnings,
        })
    }

    fn gather_from_decoded(
        &self,
        branches: &[&BranchMeta],
        decoded: &HashMap<String, DecodedBasket>,
        passes: &[u64],
        accs: &mut HashMap<String, OutputAcc>,
        timeline: &Timeline,
        opts: &EngineOpts,
    ) {
        timeline.stage(Stage::Deserialize, opts.compute_node, || {
            for b in branches {
                let dec = &decoded[&b.desc.name];
                let acc = accs.get_mut(&b.desc.name).expect("acc exists");
                for &ev in passes {
                    acc.push_event(dec, ev);
                }
            }
        });
    }

    /// Fetch + decompress the basket of `branch` covering event `lo`.
    /// Deserialization is the caller's business (full vs selective).
    fn fetch_raw<R: ReadAt>(
        &self,
        reader: &TRootReader<R>,
        branch: &BranchMeta,
        lo: u64,
        timeline: &Timeline,
        opts: &EngineOpts,
        counters: &mut FetchCounters,
    ) -> Result<(Vec<u8>, BasketInfo)> {
        let idx = branch.basket_for_event(lo).ok_or_else(|| {
            Error::Engine(format!("branch {} has no basket for event {lo}", branch.desc.name))
        })?;
        let info = branch.baskets[idx];

        // Fetch: transport time is charged virtually by the store
        // (wire/disk model); we track volume here.
        let frame = reader.fetch_basket(branch, idx)?;
        counters.baskets += 1;
        counters.bytes += info.comp_len as u64;

        // Decompress: real compute, attributed per DecompMode.
        let t0 = Instant::now();
        let raw = crate::compress::decompress(&frame)?;
        let dt = t0.elapsed().as_secs_f64();
        match opts.decomp {
            DecompMode::Software => timeline.add_real(Stage::Decompress, opts.compute_node, dt),
            DecompMode::HwEngine { speedup } => {
                timeline.add_real(Stage::Decompress, Node::DpuEngine, dt / speedup.max(1e-9))
            }
        }
        timeline.add_bytes(Stage::Decompress, raw.len() as u64);
        Ok((raw, info))
    }
}

#[derive(Default)]
struct FetchCounters {
    baskets: u64,
    bytes: u64,
}

/// Accumulates one output branch's values for passing events.
struct OutputAcc {
    desc: crate::troot::BranchDesc,
    offsets: Vec<u32>,
    values: ColumnValues,
}

impl OutputAcc {
    fn new(desc: crate::troot::BranchDesc) -> Self {
        let values = ColumnValues::empty(desc.dtype);
        OutputAcc { desc, offsets: vec![0], values }
    }

    /// Gather from an already-decoded basket (cheap copy).
    fn push_event(&mut self, basket: &DecodedBasket, ev: u64) {
        match self.desc.kind {
            BranchKind::Scalar => {
                let i = (ev - basket.first_event) as usize;
                self.values.push_from(&basket.values, i);
            }
            BranchKind::Jagged => {
                let r = basket.jagged_range(ev);
                self.values.extend_from_range(&basket.values, r);
                self.offsets.push(self.values.len() as u32);
            }
        }
    }

    /// Selectively deserialize one event straight from the raw basket
    /// payload (the per-event `GetEntry` path used by phase 2).
    /// Returns the number of raw bytes materialized.
    fn push_event_raw(&mut self, raw: &[u8], info: &BasketInfo, ev: u64) -> Result<usize> {
        let local = (ev - info.first_event) as usize;
        let before = self.values.len();
        basket_codec::append_event(
            &self.desc,
            raw,
            info.n_events as usize,
            local,
            &mut self.offsets,
            &mut self.values,
        )?;
        Ok((self.values.len() - before) * self.desc.dtype.size())
    }

    fn finish(self) -> ColumnData {
        match self.desc.kind {
            BranchKind::Scalar => ColumnData::Scalar(self.values),
            BranchKind::Jagged => ColumnData::Jagged { offsets: self.offsets, values: self.values },
        }
    }
}
