//! Batch assembly: decoded baskets → the padded `[C,B,M]` arrays the
//! kernel (and the interpreter) consume.
//!
//! This is the deserialize-side half of the paper's "deserialization"
//! stage: typed basket values are scattered into the fixed-capacity
//! batch layout, jagged collections padded/truncated to `M` object
//! slots (selection semantics are defined over the first `M` objects;
//! see DESIGN.md §Hardware-Adaptation).
//!
//! Column membership comes from the compiled [`CutProgram`]: both the
//! fixed-function banks and any residual IR expressions register the
//! branches they read in `obj_columns`/`scalar_columns`, so a batch
//! assembled here always carries every column the evaluator (kernel or
//! interpreter) will touch.

use crate::query::plan::CutProgram;
use crate::runtime::{Batch, Capacities};
use crate::troot::{BranchKind, ColumnValues, DecodedBasket};
use crate::{Error, Result};
use std::collections::HashMap;

/// Append events `[lo, lo + n)` (global ids) into `batch` starting at
/// event slot `dst`. `baskets` maps branch name → decoded basket
/// covering that range. Used to *fill* a batch across cluster
/// boundaries so one kernel invocation evaluates many clusters
/// (amortizing PJRT call overhead).
pub fn append(
    program: &CutProgram,
    baskets: &HashMap<String, DecodedBasket>,
    lo: u64,
    n: usize,
    batch: &mut Batch,
    dst: usize,
) -> Result<()> {
    let (b, m) = (batch.b, batch.m);
    if dst + n > b {
        return Err(Error::Engine(format!(
            "append of {n} events at {dst} exceeds batch capacity {b}"
        )));
    }

    for (c, name) in program.obj_columns.iter().enumerate() {
        let basket = baskets
            .get(name)
            .ok_or_else(|| Error::Engine(format!("missing decoded basket for '{name}'")))?;
        if basket.kind != BranchKind::Jagged {
            return Err(Error::Engine(format!("column '{name}' is not jagged")));
        }
        let values = basket.values_f32();
        for ev in 0..n {
            let global = lo + ev as u64;
            let r = basket.jagged_range(global);
            let take = (r.end - r.start).min(m);
            let at = (c * b + dst + ev) * m;
            batch.cols[at..at + take].copy_from_slice(&values[r.start..r.start + take]);
            batch.nobj[c * b + dst + ev] = take as f32;
        }
    }

    for (s, name) in program.scalar_columns.iter().enumerate() {
        let basket = baskets
            .get(name)
            .ok_or_else(|| Error::Engine(format!("missing decoded basket for '{name}'")))?;
        if basket.kind != BranchKind::Scalar {
            return Err(Error::Engine(format!("column '{name}' is not scalar")));
        }
        for ev in 0..n {
            let global = lo + ev as u64;
            let i = (global - basket.first_event) as usize;
            let v = match &basket.values {
                ColumnValues::F32(v) => v[i],
                ColumnValues::F64(v) => v[i] as f32,
                ColumnValues::I32(v) => v[i] as f32,
                ColumnValues::I64(v) => v[i] as f32,
                ColumnValues::U8(v) => v[i] as f32,
            };
            batch.scalars[s * b + dst + ev] = v;
        }
    }
    batch.n_valid = batch.n_valid.max(dst + n);
    Ok(())
}

/// Assemble events `[lo, lo + n)` into a fresh padded batch.
pub fn assemble(
    program: &CutProgram,
    caps: &Capacities,
    baskets: &HashMap<String, DecodedBasket>,
    lo: u64,
    n: usize,
    b: usize,
    m: usize,
) -> Result<Batch> {
    if n > b {
        return Err(Error::Engine(format!("chunk of {n} events exceeds batch capacity {b}")));
    }
    let mut batch = Batch::zeroed(caps, b, m);
    append(program, baskets, lo, n, &mut batch, 0)?;
    batch.n_valid = n;
    Ok(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::CutProgram;
    use crate::troot::{basket, BranchDesc, ColumnData, DType};

    fn caps() -> Capacities {
        Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 }
    }

    fn decode_jagged(per_event: &[Vec<f32>], first_event: u64) -> DecodedBasket {
        let col = ColumnData::jagged_f32(per_event);
        let raw = basket::encode(&col, 0, per_event.len());
        basket::decode(
            &BranchDesc::jagged("j", DType::F32, "J"),
            &raw,
            first_event,
            per_event.len(),
        )
        .unwrap()
    }

    fn decode_scalar_u8(values: &[u8], first_event: u64) -> DecodedBasket {
        let col = ColumnData::Scalar(ColumnValues::U8(values.to_vec()));
        let raw = basket::encode(&col, 0, values.len());
        basket::decode(&BranchDesc::scalar("s", DType::U8), &raw, first_event, values.len())
            .unwrap()
    }

    #[test]
    fn assembles_jagged_with_padding_and_truncation() {
        let mut program = CutProgram::default();
        program.obj_columns.push("Electron_pt".into());
        let mut baskets = HashMap::new();
        baskets.insert(
            "Electron_pt".to_string(),
            decode_jagged(&[vec![1.0, 2.0], vec![], vec![3.0, 4.0, 5.0, 6.0, 7.0]], 100),
        );
        let b = 8;
        let m = 4; // truncates the 5-object event
        let batch = assemble(&program, &caps(), &baskets, 100, 3, b, m).unwrap();
        assert_eq!(batch.n_valid, 3);
        assert_eq!(&batch.cols[0..2], &[1.0, 2.0]);
        assert_eq!(batch.nobj[0], 2.0);
        assert_eq!(batch.nobj[1], 0.0);
        assert_eq!(batch.nobj[2], 4.0); // clamped from 5
        assert_eq!(&batch.cols[2 * m..2 * m + 4], &[3.0, 4.0, 5.0, 6.0]);
        // padding slots stay zero
        assert_eq!(batch.cols[m], 0.0);
    }

    #[test]
    fn assembles_scalars_with_dtype_conversion() {
        let mut program = CutProgram::default();
        program.scalar_columns.push("HLT_IsoMu24".into());
        let mut baskets = HashMap::new();
        baskets.insert("HLT_IsoMu24".to_string(), decode_scalar_u8(&[1, 0, 1], 50));
        let batch = assemble(&program, &caps(), &baskets, 50, 3, 4, 2).unwrap();
        assert_eq!(&batch.scalars[0..3], &[1.0, 0.0, 1.0]);
    }

    #[test]
    fn mid_basket_offset() {
        // Assemble a chunk that starts mid-basket (lo > first_event).
        let mut program = CutProgram::default();
        program.obj_columns.push("J".into());
        let mut baskets = HashMap::new();
        baskets.insert(
            "J".to_string(),
            decode_jagged(&[vec![1.0], vec![2.0, 2.5], vec![3.0], vec![4.0]], 0),
        );
        let batch = assemble(&program, &caps(), &baskets, 2, 2, 4, 2).unwrap();
        assert_eq!(batch.cols[0], 3.0);
        assert_eq!(batch.cols[2], 4.0);
    }

    #[test]
    fn errors_on_missing_or_mismatched() {
        let mut program = CutProgram::default();
        program.obj_columns.push("nope".into());
        let baskets = HashMap::new();
        assert!(assemble(&program, &caps(), &baskets, 0, 1, 4, 2).is_err());

        let mut program2 = CutProgram::default();
        program2.obj_columns.push("s".into());
        let mut baskets2 = HashMap::new();
        baskets2.insert("s".to_string(), decode_scalar_u8(&[1], 0));
        assert!(assemble(&program2, &caps(), &baskets2, 0, 1, 4, 2).is_err());
    }

    #[test]
    fn chunk_larger_than_batch_rejected() {
        let program = CutProgram::default();
        let baskets = HashMap::new();
        assert!(assemble(&program, &caps(), &baskets, 0, 10, 4, 2).is_err());
    }
}
