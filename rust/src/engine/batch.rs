//! Batch assembly: decoded baskets → the padded `[C,B,M]` arrays the
//! kernel (and the interpreter) consume.
//!
//! This is the deserialize-side half of the paper's "deserialization"
//! stage: typed basket values are scattered into the fixed-capacity
//! batch layout, jagged collections padded/truncated to `M` object
//! slots (selection semantics are defined over the first `M` objects;
//! see DESIGN.md §Hardware-Adaptation).
//!
//! Column membership comes from the compiled [`CutProgram`]: both the
//! fixed-function banks and any residual IR expressions register the
//! branches they read in `obj_columns`/`scalar_columns`. Since the
//! branch-interning refactor, basket *lookup* is positional: the
//! caller passes the group's decoded baskets as a `Vec` indexed by
//! [`BranchId`] plus the plan's column→branch maps
//! ([`crate::query::plan::SkimPlan::obj_col_branch`]) — no string
//! hashing per basket on the hot path.
//!
//! Each column's destination region in the batch is disjoint from
//! every other column's, so [`append_par`] can fan the per-column
//! fills across a scoped worker pool (the `Batch` arrays are split
//! into per-column `&mut` chunks before spawning).

use crate::query::plan::{BranchId, CutProgram};
use crate::runtime::{Batch, Capacities};
use crate::troot::{BranchKind, ColumnValues, DType, DecodedBasket};
use crate::{Error, Result};

/// One column's fill work: the disjoint destination slices plus the
/// source basket. Built after validation, so execution is infallible
/// (workers can't early-return an error mid-scope).
enum ColumnTask<'x> {
    Obj { cols: &'x mut [f32], nobj: &'x mut [f32], basket: &'x DecodedBasket },
    Scalar { vals: &'x mut [f32], basket: &'x DecodedBasket },
}

impl ColumnTask<'_> {
    /// Fill events `[lo, lo + n)` at batch slot `dst`.
    fn run(self, lo: u64, n: usize, dst: usize, m: usize) {
        match self {
            ColumnTask::Obj { cols, nobj, basket } => {
                let values = basket.values_f32();
                for ev in 0..n {
                    let r = basket.jagged_range(lo + ev as u64);
                    let take = (r.end - r.start).min(m);
                    let at = (dst + ev) * m;
                    cols[at..at + take].copy_from_slice(&values[r.start..r.start + take]);
                    nobj[dst + ev] = take as f32;
                }
            }
            ColumnTask::Scalar { vals, basket } => {
                let base = (lo - basket.first_event) as usize;
                // One dtype dispatch per column, not per event. The
                // f32/i32 accessors are variant-transparent, so
                // zero-copy view baskets take the same fast paths as
                // owned ones.
                if let Some(v) = basket.values.as_f32() {
                    vals[dst..dst + n].copy_from_slice(&v[base..base + n]);
                } else if let Some(v) = basket.values.as_i32() {
                    for ev in 0..n {
                        vals[dst + ev] = v[base + ev] as f32;
                    }
                } else {
                    match &basket.values {
                        ColumnValues::F64(v) => {
                            for ev in 0..n {
                                vals[dst + ev] = v[base + ev] as f32;
                            }
                        }
                        ColumnValues::I64(v) => {
                            for ev in 0..n {
                                vals[dst + ev] = v[base + ev] as f32;
                            }
                        }
                        ColumnValues::U8(v) => {
                            for ev in 0..n {
                                vals[dst + ev] = v[base + ev] as f32;
                            }
                        }
                        _ => unreachable!("f32/i32 handled by the accessor fast paths"),
                    }
                }
            }
        }
    }
}

/// Validate sources and slice the batch into per-column tasks.
fn column_tasks<'x>(
    program: &CutProgram,
    decoded: &'x [DecodedBasket],
    obj_src: &[BranchId],
    scalar_src: &[BranchId],
    batch: &'x mut Batch,
) -> Result<Vec<ColumnTask<'x>>> {
    let (b, m) = (batch.b, batch.m);
    if obj_src.len() != program.obj_columns.len()
        || scalar_src.len() != program.scalar_columns.len()
    {
        return Err(Error::Engine(
            "column source maps do not match the cut program".into(),
        ));
    }
    let fetch = |id: BranchId, name: &str| -> Result<&'x DecodedBasket> {
        decoded.get(id.idx()).ok_or_else(|| {
            Error::Engine(format!("missing decoded basket for '{name}'"))
        })
    };
    let mut tasks = Vec::with_capacity(obj_src.len() + scalar_src.len());

    // Per-obj-column slices: cols in [C,B,M] blocks, nobj in [C,B] rows.
    let mut col_chunks = batch.cols.chunks_mut(b * m.max(1));
    let mut nobj_chunks = batch.nobj.chunks_mut(b);
    for (c, name) in program.obj_columns.iter().enumerate() {
        let basket = fetch(obj_src[c], name)?;
        if basket.kind != BranchKind::Jagged {
            return Err(Error::Engine(format!("column '{name}' is not jagged")));
        }
        if basket.values.dtype() != DType::F32 {
            return Err(Error::Engine(format!("jagged column '{name}' is not f32")));
        }
        let cols = col_chunks
            .next()
            .ok_or_else(|| Error::Engine(format!("batch has no slot for column '{name}'")))?;
        let nobj = nobj_chunks
            .next()
            .ok_or_else(|| Error::Engine(format!("batch has no slot for column '{name}'")))?;
        tasks.push(ColumnTask::Obj { cols, nobj, basket });
    }

    let mut scalar_chunks = batch.scalars.chunks_mut(b);
    for (s, name) in program.scalar_columns.iter().enumerate() {
        let basket = fetch(scalar_src[s], name)?;
        if basket.kind != BranchKind::Scalar {
            return Err(Error::Engine(format!("column '{name}' is not scalar")));
        }
        let vals = scalar_chunks
            .next()
            .ok_or_else(|| Error::Engine(format!("batch has no slot for column '{name}'")))?;
        tasks.push(ColumnTask::Scalar { vals, basket });
    }
    Ok(tasks)
}

/// Append events `[lo, lo + n)` (global ids) into `batch` starting at
/// event slot `dst`. `decoded` holds the group's decoded baskets
/// indexed by [`BranchId`]; `obj_src`/`scalar_src` map program columns
/// to those ids (see [`crate::query::plan::SkimPlan`]). Used to *fill*
/// a batch across cluster boundaries so one kernel invocation
/// evaluates many clusters (amortizing PJRT call overhead).
pub fn append(
    program: &CutProgram,
    decoded: &[DecodedBasket],
    obj_src: &[BranchId],
    scalar_src: &[BranchId],
    lo: u64,
    n: usize,
    batch: &mut Batch,
    dst: usize,
) -> Result<()> {
    append_par(program, decoded, obj_src, scalar_src, lo, n, batch, dst, 1)
}

/// [`append`] with the per-column fills fanned across up to `workers`
/// scoped threads. Column destinations are disjoint, so the split is
/// a plain partition of `&mut` chunks; output is bit-identical to the
/// serial path regardless of worker count.
#[allow(clippy::too_many_arguments)]
pub fn append_par(
    program: &CutProgram,
    decoded: &[DecodedBasket],
    obj_src: &[BranchId],
    scalar_src: &[BranchId],
    lo: u64,
    n: usize,
    batch: &mut Batch,
    dst: usize,
    workers: usize,
) -> Result<()> {
    let (b, m) = (batch.b, batch.m);
    if dst + n > b {
        return Err(Error::Engine(format!(
            "append of {n} events at {dst} exceeds batch capacity {b}"
        )));
    }
    let tasks = column_tasks(program, decoded, obj_src, scalar_src, batch)?;
    // Threading pays off only when there is real per-column work;
    // small windows run inline to avoid spawn overhead.
    let fan_out = workers.min(tasks.len());
    if fan_out <= 1 || n * tasks.len() < 4096 {
        for task in tasks {
            task.run(lo, n, dst, m);
        }
    } else {
        // Round-robin columns across workers; each worker owns its
        // tasks (and their disjoint &mut slices) for the scope.
        let mut shards: Vec<Vec<ColumnTask>> = Vec::new();
        shards.resize_with(fan_out, Vec::new);
        for (i, task) in tasks.into_iter().enumerate() {
            shards[i % fan_out].push(task);
        }
        std::thread::scope(|scope| {
            for shard in shards {
                scope.spawn(move || {
                    for task in shard {
                        task.run(lo, n, dst, m);
                    }
                });
            }
        });
    }
    batch.n_valid = batch.n_valid.max(dst + n);
    Ok(())
}

/// Assemble events `[lo, lo + n)` into a fresh padded batch.
#[allow(clippy::too_many_arguments)]
pub fn assemble(
    program: &CutProgram,
    caps: &Capacities,
    decoded: &[DecodedBasket],
    obj_src: &[BranchId],
    scalar_src: &[BranchId],
    lo: u64,
    n: usize,
    b: usize,
    m: usize,
) -> Result<Batch> {
    if n > b {
        return Err(Error::Engine(format!("chunk of {n} events exceeds batch capacity {b}")));
    }
    let mut batch = Batch::zeroed(caps, b, m);
    append(program, decoded, obj_src, scalar_src, lo, n, &mut batch, 0)?;
    batch.n_valid = n;
    Ok(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::CutProgram;
    use crate::troot::{basket, BranchDesc, ColumnData, DType};

    fn caps() -> Capacities {
        Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 }
    }

    fn decode_jagged(per_event: &[Vec<f32>], first_event: u64) -> DecodedBasket {
        let col = ColumnData::jagged_f32(per_event);
        let raw = basket::encode(&col, 0, per_event.len());
        basket::decode(
            &BranchDesc::jagged("j", DType::F32, "J"),
            &raw,
            first_event,
            per_event.len(),
            0,
        )
        .unwrap()
    }

    fn decode_scalar_u8(values: &[u8], first_event: u64) -> DecodedBasket {
        let col = ColumnData::Scalar(ColumnValues::U8(values.to_vec()));
        let raw = basket::encode(&col, 0, values.len());
        basket::decode(&BranchDesc::scalar("s", DType::U8), &raw, first_event, values.len(), 0)
            .unwrap()
    }

    #[test]
    fn assembles_jagged_with_padding_and_truncation() {
        let mut program = CutProgram::default();
        program.obj_columns.push("Electron_pt".into());
        let decoded =
            vec![decode_jagged(&[vec![1.0, 2.0], vec![], vec![3.0, 4.0, 5.0, 6.0, 7.0]], 100)];
        let b = 8;
        let m = 4; // truncates the 5-object event
        let batch =
            assemble(&program, &caps(), &decoded, &[BranchId(0)], &[], 100, 3, b, m).unwrap();
        assert_eq!(batch.n_valid, 3);
        assert_eq!(&batch.cols[0..2], &[1.0, 2.0]);
        assert_eq!(batch.nobj[0], 2.0);
        assert_eq!(batch.nobj[1], 0.0);
        assert_eq!(batch.nobj[2], 4.0); // clamped from 5
        assert_eq!(&batch.cols[2 * m..2 * m + 4], &[3.0, 4.0, 5.0, 6.0]);
        // padding slots stay zero
        assert_eq!(batch.cols[m], 0.0);
    }

    #[test]
    fn assembles_scalars_with_dtype_conversion() {
        let mut program = CutProgram::default();
        program.scalar_columns.push("HLT_IsoMu24".into());
        let decoded = vec![decode_scalar_u8(&[1, 0, 1], 50)];
        let batch =
            assemble(&program, &caps(), &decoded, &[], &[BranchId(0)], 50, 3, 4, 2).unwrap();
        assert_eq!(&batch.scalars[0..3], &[1.0, 0.0, 1.0]);
    }

    #[test]
    fn mid_basket_offset() {
        // Assemble a chunk that starts mid-basket (lo > first_event).
        let mut program = CutProgram::default();
        program.obj_columns.push("J".into());
        let decoded =
            vec![decode_jagged(&[vec![1.0], vec![2.0, 2.5], vec![3.0], vec![4.0]], 0)];
        let batch =
            assemble(&program, &caps(), &decoded, &[BranchId(0)], &[], 2, 2, 4, 2).unwrap();
        assert_eq!(batch.cols[0], 3.0);
        assert_eq!(batch.cols[2], 4.0);
    }

    #[test]
    fn errors_on_missing_or_mismatched() {
        let mut program = CutProgram::default();
        program.obj_columns.push("nope".into());
        // BranchId points past the decoded set.
        assert!(assemble(&program, &caps(), &[], &[BranchId(0)], &[], 0, 1, 4, 2).is_err());

        let mut program2 = CutProgram::default();
        program2.obj_columns.push("s".into());
        let decoded2 = vec![decode_scalar_u8(&[1], 0)];
        assert!(
            assemble(&program2, &caps(), &decoded2, &[BranchId(0)], &[], 0, 1, 4, 2).is_err()
        );
    }

    #[test]
    fn chunk_larger_than_batch_rejected() {
        let program = CutProgram::default();
        assert!(assemble(&program, &caps(), &[], &[], &[], 0, 10, 4, 2).is_err());
    }

    #[test]
    fn view_backed_baskets_assemble_identically() {
        // A zero-copy decoded basket must fill the batch exactly like
        // its owned twin (same bytes, same fast path).
        let mut program = CutProgram::default();
        program.scalar_columns.push("met".into());
        let col = ColumnData::scalar_f32(vec![5.0, 6.5, 7.0]);
        let desc = BranchDesc::scalar("met", DType::F32);
        let raw = basket::encode(&col, 0, 3);
        let owned = basket::decode(&desc, &raw, 0, 3, 0).unwrap();
        let shared: crate::troot::SharedBytes = std::sync::Arc::new(raw);
        let viewed = basket::decode_shared(&desc, &shared, 0, 0, 3, 0).unwrap();
        let a =
            assemble(&program, &caps(), &[owned], &[], &[BranchId(0)], 0, 3, 4, 2).unwrap();
        let b =
            assemble(&program, &caps(), &[viewed], &[], &[BranchId(0)], 0, 3, 4, 2).unwrap();
        assert_eq!(a.scalars, b.scalars);
    }

    #[test]
    fn parallel_append_matches_serial() {
        // Many columns, enough events to clear the inline threshold:
        // the fanned fill must be bit-identical to the serial one.
        let mut program = CutProgram::default();
        let n_ev = 600usize;
        let per_event: Vec<Vec<f32>> = (0..n_ev)
            .map(|i| (0..(i % 5)).map(|k| (i * 10 + k) as f32).collect())
            .collect();
        let mut decoded = Vec::new();
        let mut obj_src = Vec::new();
        for c in 0..6 {
            program.obj_columns.push(format!("J{c}"));
            decoded.push(decode_jagged(&per_event, 0));
            obj_src.push(BranchId(c as u32));
        }
        let mut scalar_src = Vec::new();
        for s in 0..4 {
            program.scalar_columns.push(format!("S{s}"));
            let vals: Vec<u8> = (0..n_ev).map(|i| ((i + s) % 7) as u8).collect();
            decoded.push(decode_scalar_u8(&vals, 0));
            scalar_src.push(BranchId((6 + s) as u32));
        }
        let (b, m) = (1024, 3);
        let mut serial = Batch::zeroed(&caps(), b, m);
        append(&program, &decoded, &obj_src, &scalar_src, 0, n_ev, &mut serial, 0).unwrap();
        let mut fanned = Batch::zeroed(&caps(), b, m);
        append_par(&program, &decoded, &obj_src, &scalar_src, 0, n_ev, &mut fanned, 0, 4)
            .unwrap();
        assert_eq!(serial.cols, fanned.cols);
        assert_eq!(serial.nobj, fanned.nobj);
        assert_eq!(serial.scalars, fanned.scalars);
        assert_eq!(serial.n_valid, fanned.n_valid);
    }
}
