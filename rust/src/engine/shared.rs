//! Shared-scan executor: one basket pass serves N concurrent queries.
//!
//! The execution half of the multi-query optimizer ([`crate::mqo`]).
//! Given K compatible queries over the **same input file**, this module
//! drives exactly one fetch → decompress → deserialize pass per
//! surviving basket of the *union* phase-1 fetch set, then evaluates
//! every member's cut program columnar against its own remapped view of
//! the shared decoded baskets. Member masks, funnels, phase-2 selective
//! fetches and output files are **byte-identical** to running each job
//! alone — sharing changes where bytes are decoded once, never what any
//! member computes.
//!
//! # How byte-identity is preserved
//!
//! Each member gets its own full [`StageCtx`] (plan, funnel,
//! accumulators, phase-2 state, output writer) over its own store and
//! timeline, driven in lockstep through the same `begin_group` /
//! `eval_group` / `commit_group` sequence the solo pipeline uses. On
//! the two-phase interpreter path the group packing depends only on the
//! file's cluster layout and `basket_events` — identical for every
//! member — so groups align 1:1 across members. The executor replaces
//! only the *fetch + decompress + deserialize* of each group: baskets
//! are decoded once from the union branch set, and each member's
//! [`GroupState::decoded`] rows are assembled by indexing the union row
//! through its [`crate::mqo::MemberMap::slot_map`] (decoded baskets are
//! cheap-to-clone column data). `eval_group` then sees exactly the
//! bytes a solo run would have produced.
//!
//! # Zone-map pruning under sharing
//!
//! Each member prunes by its **own** [`crate::query::ZonePredicate`]s —
//! via [`StageCtx::zone_dead`] — so its funnel and mask stay identical
//! to its solo run. The shared pass skips a cluster's baskets only when
//! the cluster is provably dead for *every* member.
//!
//! # Counter and virtual-time attribution
//!
//! The one shared pass charges its transport, decompression and
//! deserialization to the **batch timeline** (and its
//! `baskets_scanned` / `baskets_pruned` / cache counters, once). Each
//! member timeline records only its own eval, phase-2 and output work,
//! plus a `scan_shared` counter (baskets whose decode it received from
//! the shared pass). At the end, [`crate::mqo::amortize`] folds the
//! batch accounting into the members as exact integer counter shares
//! and `1/N` virtual-time slices — so per-member numbers stay
//! meaningful in aggregate instead of a first toucher absorbing the
//! whole scan.

use super::pipeline::{decompress_attributed, GroupState, StageCtx};
use super::{EngineOpts, SkimResult};
use crate::lifecycle::JobCtl;
use crate::metrics::{Stage, Timeline};
use crate::mqo::{self, SharedScanPlan};
use crate::query::plan::SkimPlan;
use crate::query::SkimQuery;
use crate::serve::cache::BasketKey;
use crate::troot::{basket as basket_codec, BranchMeta, DecodedBasket, ReadAt, TRootReader};
use crate::{Error, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Run K compatible queries over one input file as a single shared
/// scan.
///
/// * `scan_store` — the store the one shared pass reads phase-1
///   baskets from; its (virtual) transport charges go to
///   `batch_timeline`. When [`EngineOpts::basket_cache`] is set, scan
///   baskets load through the shared cache under the same keys solo
///   runs use, so batches and solo jobs warm each other.
/// * `member_stores` / `member_timelines` / `out_paths` — one per
///   query, in member order. Phase-2 selective fetches and output
///   writes run per member against the member's own store and are
///   charged to the member's own timeline, exactly as solo.
///
/// Requirements (the caller — [`crate::coordinator::Coordinator::run_shared`]
/// — checks the deployment-level predicate first, this function
/// re-validates the engine-level part): every query targets the same
/// file, `opts.two_phase`, `!opts.use_pjrt` (interpreter path, so
/// member group packing is layout-determined and identical), and no
/// `opts.event_range` shard.
///
/// Returns one `Result<SkimResult>` per member, in member order: `Ok`
/// for members that completed, `Err` for members that **detached** —
/// their [`JobCtl`] was cancelled or their virtual-time deadline
/// expired at a group boundary. A detached member stops receiving
/// decoded baskets and writes no output, while the rest of the batch
/// completes normally; batch-level failures (divergence, scan-store
/// errors) still fail the whole call. `ctls` carries one control block
/// per member, or is empty (no controls — every member completes or
/// the batch fails). Note: `baskets_fetched` / `fetched_bytes` in a
/// member's result cover only its phase-2 fetches — the shared phase-1
/// volume lives on the batch timeline and is amortized onto member
/// timelines, not results.
#[allow(clippy::too_many_arguments)]
pub fn run_shared(
    scan_store: Arc<dyn ReadAt>,
    member_stores: &[Arc<dyn ReadAt>],
    queries: &[SkimQuery],
    member_timelines: &[Timeline],
    batch_timeline: &Timeline,
    opts: &EngineOpts,
    out_paths: &[PathBuf],
    ctls: &[JobCtl],
) -> Result<Vec<Result<SkimResult>>> {
    let n = queries.len();
    if n == 0 {
        return Err(Error::Engine("shared scan: no member queries".into()));
    }
    if member_stores.len() != n || member_timelines.len() != n || out_paths.len() != n {
        return Err(Error::Engine(format!(
            "shared scan: {} queries but {} stores / {} timelines / {} outputs",
            n,
            member_stores.len(),
            member_timelines.len(),
            out_paths.len()
        )));
    }
    if !ctls.is_empty() && ctls.len() != n {
        return Err(Error::Engine(format!(
            "shared scan: {} queries but {} lifecycle controls",
            n,
            ctls.len()
        )));
    }
    if !opts.two_phase {
        return Err(Error::Engine(
            "shared scan requires two-phase mode (legacy mode folds outputs into phase 1)"
                .into(),
        ));
    }
    if opts.use_pjrt {
        return Err(Error::Engine(
            "shared scan requires the interpreter path (kernel batch shapes differ per member)"
                .into(),
        ));
    }
    if opts.event_range.is_some() {
        return Err(Error::Engine("shared scan cannot run on an event-range shard".into()));
    }

    // One full per-member context each: plan, funnel, accumulators,
    // phase-2 state, output writer. Members never fetch phase 1
    // themselves (their TTreeCache training is lazy), so building the
    // contexts costs metadata reads only.
    let mut ctxs: Vec<StageCtx> = Vec::with_capacity(n);
    for i in 0..n {
        ctxs.push(StageCtx::new(
            None,
            member_stores[i].clone(),
            &queries[i],
            &member_timelines[i],
            opts,
            out_paths[i].clone(),
        )?);
    }

    // Merge the members' phase-1 fetch sets into the union scan plan.
    let plans: Vec<&SkimPlan> = ctxs.iter().map(|c| &c.plan).collect();
    let shared = SharedScanPlan::from_plans(&plans);
    let union_len = shared.union_len();

    // The one scan-side reader. Branch metadata is resolved once per
    // union slot; transport charges go to the batch timeline via
    // whatever model wraps `scan_store`.
    let scan_reader = TRootReader::open(scan_store)?;
    let mut scan_branches: Vec<BranchMeta> = Vec::with_capacity(union_len);
    for name in &shared.union_branches {
        scan_branches.push(scan_reader.branch(name)?.clone());
    }
    let cache = opts.basket_cache.clone();
    // Same key shape solo jobs intern, so shared and solo runs hit
    // each other's cache entries.
    let scan_file_key: Arc<str> = queries[0].input.to_string().into();
    let scan_branch_keys: Vec<Arc<str>> =
        shared.union_branches.iter().map(|b| b.as_str().into()).collect();

    // A member whose cancel token fires — or whose virtual-time
    // deadline expires — detaches: its slot records the terminal
    // error, it keeps driving `begin_group` (lockstep must not
    // diverge) but votes every cluster dead and skips eval/commit.
    let mut detached: Vec<Option<Error>> = Vec::with_capacity(n);
    detached.resize_with(n, || None);
    let ctl_for = |i: usize| -> Option<&JobCtl> { ctls.get(i) };

    loop {
        // Lockstep group formation: identical cluster layout + opts
        // mean every member packs the same clusters. Verified, not
        // assumed.
        let more: Vec<bool> = ctxs.iter_mut().map(|c| c.begin_group()).collect();
        if more.iter().any(|&m| m != more[0]) {
            return Err(Error::Engine("shared scan: member group iteration diverged".into()));
        }
        if !more[0] {
            break;
        }
        let mut groups: Vec<GroupState> = ctxs
            .iter_mut()
            .map(|c| c.group.take().expect("begin_group set the group"))
            .collect();
        let clusters = groups[0].clusters.clone();
        for g in &groups[1..] {
            if g.clusters != clusters {
                return Err(Error::Engine("shared scan: member group packing diverged".into()));
            }
        }

        // Lifecycle checkpoint at the group boundary: a cancelled or
        // past-deadline member detaches here, without killing the
        // batch for the remaining members.
        for i in 0..n {
            if detached[i].is_none() {
                if let Some(ctl) = ctl_for(i) {
                    if let Err(e) = ctl.check(&member_timelines[i]) {
                        detached[i] = Some(e);
                    }
                }
            }
        }

        // Per-member cluster liveness under each member's own zone
        // predicates; the scan skips a cluster only when every member
        // refutes it. Detached members vote every cluster dead — the
        // scan never fetches on their behalf again.
        let keeps: Vec<Vec<bool>> = ctxs
            .iter()
            .enumerate()
            .map(|(i, ctx)| {
                if detached[i].is_some() {
                    return vec![false; clusters.len()];
                }
                clusters.iter().map(|&(cl, _, _)| !ctx.zone_dead(cl)).collect()
            })
            .collect();

        // The one shared pass: fetch + decompress + deserialize each
        // union basket of each surviving cluster exactly once, charged
        // to the batch timeline.
        let mut decoded: Vec<Option<Vec<DecodedBasket>>> = Vec::with_capacity(clusters.len());
        decoded.resize_with(clusters.len(), || None);
        let (mut live, mut dead) = (0u64, 0u64);
        let (mut hits, mut misses) = (0u64, 0u64);
        for (pos, &(_, lo, _)) in clusters.iter().enumerate() {
            if !keeps.iter().any(|k| k[pos]) {
                dead += 1;
                continue;
            }
            live += 1;
            let mut row = Vec::with_capacity(union_len);
            for (slot, bm) in scan_branches.iter().enumerate() {
                let idx = bm.basket_for_event(lo).ok_or_else(|| {
                    Error::Engine(format!(
                        "branch {} has no basket for event {lo}",
                        bm.desc.name
                    ))
                })?;
                let info = bm.baskets[idx];
                // Keep the decompressed bytes behind their Arc so the
                // zero-copy decode path can borrow them: a cache hit
                // shares the cached allocation outright instead of
                // cloning the Vec out of it.
                let raw: crate::troot::SharedBytes = match &cache {
                    Some(cache) => {
                        let key = BasketKey {
                            file: scan_file_key.clone(),
                            branch: scan_branch_keys[slot].clone(),
                            basket: idx as u32,
                        };
                        let (data, hit) = cache.get_or_load(key, || {
                            let frame = scan_reader.fetch_basket(bm, idx)?;
                            decompress_attributed(batch_timeline, opts, &frame)
                        })?;
                        if hit {
                            hits += 1;
                        } else {
                            misses += 1;
                        }
                        data
                    }
                    None => {
                        let frame = scan_reader.fetch_basket(bm, idx)?;
                        Arc::new(decompress_attributed(batch_timeline, opts, &frame)?)
                    }
                };
                let t0 = Instant::now();
                let dec = basket_codec::decode_shared(
                    &bm.desc,
                    &raw,
                    0,
                    info.first_event,
                    info.n_events as usize,
                    idx,
                )?;
                batch_timeline.add_real(
                    Stage::Deserialize,
                    opts.compute_node,
                    t0.elapsed().as_secs_f64(),
                );
                if let Some(model) = opts.deser_model {
                    batch_timeline.add_real(
                        Stage::Deserialize,
                        opts.compute_node,
                        model.cost(info.n_events as u64, raw.len() as u64, opts.parallelism),
                    );
                }
                row.push(dec);
            }
            decoded[pos] = Some(row);
        }
        batch_timeline.count("baskets_scanned", live * union_len as u64);
        if dead > 0 {
            batch_timeline.count("baskets_pruned", dead * union_len as u64);
        }
        if cache.is_some() {
            batch_timeline.count("basket_cache_hits", hits);
            batch_timeline.count("basket_cache_misses", misses);
        }

        // Per member: retain the clusters *it* keeps, inject its
        // remapped decoded view, evaluate and commit — the same
        // eval/commit code a solo run executes, over identical bytes.
        // Detached members drop their group uncommitted (the solo
        // abort path) and do no further work.
        for (mi, (ctx, mut g)) in ctxs.iter_mut().zip(groups).enumerate() {
            if detached[mi].is_some() {
                drop(g);
                continue;
            }
            let keep = &keeps[mi];
            let mut it = keep.iter();
            g.clusters.retain(|_| *it.next().unwrap());
            let mut it = keep.iter();
            g.passes.retain(|_| *it.next().unwrap());
            let map = &shared.members[mi].slot_map;
            for (pos, &k) in keep.iter().enumerate() {
                if !k {
                    continue;
                }
                let row = decoded[pos].as_ref().expect("surviving cluster was decoded");
                g.decoded.push(map.iter().map(|&u| row[u].clone()).collect());
            }
            member_timelines[mi]
                .count("scan_shared", (g.clusters.len() * map.len()) as u64);
            ctx.eval_group(&mut g)?;
            ctx.group = Some(g);
            ctx.commit_group()?;
        }
    }

    // Per-member tail: phase-2 selective fetch over the member's own
    // store (charged to the member), output write, result assembly.
    // Detached members surface their terminal error instead; a final
    // checkpoint catches cancels/deadlines raised after the last
    // group but before the (potentially expensive) phase-2 fetch.
    let mut results: Vec<Result<SkimResult>> = Vec::with_capacity(n);
    for (i, mut ctx) in ctxs.into_iter().enumerate() {
        if detached[i].is_none() {
            if let Some(ctl) = ctl_for(i) {
                if let Err(e) = ctl.check(&member_timelines[i]) {
                    detached[i] = Some(e);
                }
            }
        }
        if let Some(e) = detached[i].take() {
            results.push(Err(e));
            continue;
        }
        let member = (move || {
            ctx.run_phase2()?;
            ctx.write_output()?;
            ctx.finish()
        })();
        match member {
            Ok(result) => results.push(Ok(result)),
            // Member-tail lifecycle errors detach that member; any
            // other tail failure is batch-fatal, exactly as before.
            Err(e) if crate::lifecycle::is_terminal(&e) => results.push(Err(e)),
            Err(e) => return Err(e),
        }
    }

    // Fold the once-charged scan accounting into the members: exact
    // integer counter shares + 1/N virtual-time slices.
    mqo::amortize(batch_timeline, member_timelines);
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::engine::SkimEngine;
    use crate::gen::{self, GenConfig};
    use crate::serve::cache::BasketCache;
    use crate::troot::LocalFile;
    use crate::util::Pcg32;

    fn dataset() -> PathBuf {
        static PATH: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
        PATH.get_or_init(|| {
            let dir = std::env::temp_dir().join(format!("shared_test_{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let path = dir.join("events.troot");
            let cfg = GenConfig {
                n_events: 900,
                target_branches: 170,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 33,
            };
            gen::generate(&cfg, &path).unwrap();
            path
        })
        .clone()
    }

    fn query_for(cut: &str, outname: &str) -> SkimQuery {
        SkimQuery::new("events.troot", outname)
            .keep(&["MET_pt", "event", "nJet", "Jet_pt", "nMuon", "Muon_pt"])
            .with_cut_str(cut)
            .unwrap()
    }

    fn interp_opts() -> EngineOpts {
        EngineOpts { use_pjrt: false, ..Default::default() }
    }

    /// Solo reference run of one cut; returns the result, timeline and
    /// output bytes.
    fn solo(cut: &str, outname: &str, opts: &EngineOpts) -> (SkimResult, Timeline, Vec<u8>) {
        let path = dataset();
        let store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&path).unwrap());
        let tl = Timeline::new();
        let out = path.parent().unwrap().join(outname);
        let res = SkimEngine::new(None)
            .run(store, &query_for(cut, outname), &tl, opts, &out)
            .unwrap();
        let bytes = std::fs::read(&out).unwrap();
        (res, tl, bytes)
    }

    /// Shared run of several cuts; returns per-member (result, output
    /// bytes), the member timelines and the batch timeline.
    #[allow(clippy::type_complexity)]
    fn shared(
        cuts: &[&str],
        tag: &str,
        opts: &EngineOpts,
    ) -> (Vec<(SkimResult, Vec<u8>)>, Vec<Timeline>, Timeline) {
        let path = dataset();
        let dir = path.parent().unwrap();
        let n = cuts.len();
        let scan_store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&path).unwrap());
        let member_stores: Vec<Arc<dyn ReadAt>> = (0..n)
            .map(|_| Arc::new(LocalFile::open(&path).unwrap()) as Arc<dyn ReadAt>)
            .collect();
        let outnames: Vec<String> =
            (0..n).map(|i| format!("{tag}_m{i}.troot")).collect();
        let queries: Vec<SkimQuery> = cuts
            .iter()
            .zip(&outnames)
            .map(|(cut, out)| query_for(cut, out))
            .collect();
        let out_paths: Vec<PathBuf> = outnames.iter().map(|o| dir.join(o)).collect();
        let member_tls: Vec<Timeline> = (0..n).map(|_| Timeline::new()).collect();
        let batch_tl = Timeline::new();
        let results = run_shared(
            scan_store,
            &member_stores,
            &queries,
            &member_tls,
            &batch_tl,
            opts,
            &out_paths,
            &[],
        )
        .unwrap();
        let paired = results
            .into_iter()
            .zip(&out_paths)
            .map(|(r, p)| (r.unwrap(), std::fs::read(p).unwrap()))
            .collect();
        (paired, member_tls, batch_tl)
    }

    #[test]
    fn shared_outputs_masks_and_funnels_match_solo() {
        let cuts =
            ["MET_pt > 25 || max(Jet_pt) > 60", "nMuon >= 1 && max(Muon_pt) > 30", "MET_pt > 60"];
        let (members, _tls, _batch) = shared(&cuts, "id3", &interp_opts());
        for (i, cut) in cuts.iter().enumerate() {
            let (sres, _stl, sbytes) = solo(cut, &format!("id3_solo{i}.troot"), &interp_opts());
            let (res, bytes) = &members[i];
            assert_eq!(res.n_pass, sres.n_pass, "member {i} mask diverged");
            assert_eq!(res.stage_funnel, sres.stage_funnel, "member {i} funnel diverged");
            assert_eq!(res.n_events, sres.n_events);
            assert_eq!(bytes, &sbytes, "member {i} output bytes diverged");
        }
    }

    #[test]
    fn fused_shared_scan_matches_unfused_solo() {
        // The shared-scan × --fuse cell: every member funnels through
        // its own StageCtx, so fused kernels engage per member exactly
        // as in a solo run — masks, funnels and output bytes must
        // match the *unfused* solo references bit-for-bit.
        let cuts = [
            "MET_pt > 25 && nJet >= 1",
            "count(Electron_pt > 25) >= 1 && MET_pt > 20",
            "MET_pt > 60",
        ];
        let fused_opts = EngineOpts { use_pjrt: false, fuse: true, ..Default::default() };
        let (members, _tls, _batch) = shared(&cuts, "fuse3", &fused_opts);
        for (i, cut) in cuts.iter().enumerate() {
            let (sres, _stl, sbytes) = solo(cut, &format!("fuse3_solo{i}.troot"), &interp_opts());
            let (res, bytes) = &members[i];
            assert_eq!(res.n_pass, sres.n_pass, "member {i} mask diverged under fusion");
            assert_eq!(res.stage_funnel, sres.stage_funnel, "member {i} funnel diverged");
            assert_eq!(bytes, &sbytes, "member {i} output bytes diverged under fusion");
        }
    }

    #[test]
    fn shared_scan_fetches_each_union_basket_exactly_once() {
        // 900 events / 200-event baskets = 5 clusters. A cold shared
        // cache observes every (branch, basket) load exactly once —
        // that *is* the "one pass serves N queries" guarantee.
        let cache = Arc::new(BasketCache::new(64 << 20));
        let opts = EngineOpts {
            use_pjrt: false,
            basket_cache: Some(cache.clone()),
            ..Default::default()
        };
        let cuts = ["MET_pt > 25", "MET_pt > 60", "MET_pt > 25 && nJet >= 2"];
        let (members, tls, batch) = shared(&cuts, "once", &opts);
        // Union criteria = {MET_pt, nJet} → 2 branches × 5 clusters.
        assert_eq!(batch.counter("baskets_scanned"), 10);
        assert_eq!(batch.counter("basket_cache_misses"), 10, "each union basket loads once");
        assert_eq!(batch.counter("basket_cache_hits"), 0);
        // Amortized member shares sum back to the batch totals.
        let scanned: u64 = tls.iter().map(|t| t.counter("baskets_scanned")).sum();
        let misses: u64 = tls.iter().map(|t| t.counter("basket_cache_misses")).sum();
        assert_eq!(scanned, 10);
        assert_eq!(misses, 10);
        // Every member saw the shared scan: cuts 1 and 3 read 1 and 2
        // phase-1 branches × 5 clusters respectively.
        assert_eq!(tls[0].counter("scan_shared"), 5);
        assert_eq!(tls[2].counter("scan_shared"), 10);
        assert!(members.iter().all(|(r, _)| r.n_events == 900));
    }

    #[test]
    fn zone_pruning_is_per_member_and_scan_skips_only_all_dead_clusters() {
        // `event` = 1_000_000 + ev over five 200-event baskets:
        // "event >= 1000400" kills clusters 0-1; "event >= 1000700"
        // kills clusters 0-2. Scan-dead = intersection {0,1} → 3 of 5
        // clusters scanned; member B additionally skips cluster 2 on
        // its own predicate (scan_shared 2, not 3).
        let zm = Arc::new(crate::index::FileIndex::build_from_file(dataset()).unwrap());
        let opts = EngineOpts {
            use_pjrt: false,
            zone_map: Some(zm.clone()),
            ..Default::default()
        };
        let cuts = ["event >= 1000400", "event >= 1000700"];
        let (members, tls, batch) = shared(&cuts, "zm", &opts);
        // Union criteria = {event} → 1 branch.
        assert_eq!(batch.counter("baskets_scanned"), 3);
        assert_eq!(batch.counter("baskets_pruned"), 2);
        assert_eq!(tls[0].counter("scan_shared"), 3);
        assert_eq!(tls[1].counter("scan_shared"), 2);
        // Byte-identical to solo *unpruned* runs (pruning is an
        // optimization, never a semantic change) — and funnels match
        // solo *pruned* runs.
        for (i, cut) in cuts.iter().enumerate() {
            let (_u, _utl, ubytes) = solo(cut, &format!("zm_flat{i}.troot"), &interp_opts());
            let (pres, _ptl, pbytes) = solo(cut, &format!("zm_solo{i}.troot"), &opts);
            assert_eq!(ubytes, pbytes);
            let (res, bytes) = &members[i];
            assert_eq!(bytes, &ubytes, "member {i} output bytes diverged");
            assert_eq!(res.stage_funnel, pres.stage_funnel);
            assert!(res.warnings.is_empty(), "{:?}", res.warnings);
        }
    }

    #[test]
    fn random_cut_pairs_and_triples_are_byte_identical_across_parallelism() {
        let pool = [
            "MET_pt > 25",
            "MET_pt > 60",
            "nJet >= 2",
            "max(Jet_pt) > 40",
            "MET_pt > 25 || max(Jet_pt) > 60",
            "nMuon >= 1 && (HLT_IsoMu24 || max(Muon_pt) > 30)",
            "event >= 1000400",
            "MET_pt > 100 && nElectron >= 1",
        ];
        let mut rng = Pcg32::new(0x5ca1_ab1e);
        for trial in 0..4 {
            let k = 2 + rng.below(2) as usize;
            let cuts: Vec<&str> =
                (0..k).map(|_| pool[rng.below(pool.len() as u32) as usize]).collect();
            // Solo references once, at parallelism 1 (solo outputs are
            // config-invariant; see the pipeline's bit-identity tests).
            let refs: Vec<(SkimResult, Vec<u8>)> = cuts
                .iter()
                .enumerate()
                .map(|(i, cut)| {
                    let (r, _tl, b) =
                        solo(cut, &format!("prop{trial}_solo{i}.troot"), &interp_opts());
                    (r, b)
                })
                .collect();
            for par in [1.0, 2.0, 4.0] {
                let opts = EngineOpts { use_pjrt: false, parallelism: par, ..Default::default() };
                let (members, _tls, _batch) =
                    shared(&cuts, &format!("prop{trial}_p{par}"), &opts);
                for (i, ((res, bytes), (rres, rbytes))) in
                    members.iter().zip(&refs).enumerate()
                {
                    assert_eq!(
                        res.n_pass, rres.n_pass,
                        "trial {trial} par {par} member {i} ({})",
                        cuts[i]
                    );
                    assert_eq!(res.stage_funnel, rres.stage_funnel);
                    assert_eq!(bytes, rbytes, "trial {trial} par {par} member {i} bytes");
                }
            }
        }
    }

    #[test]
    fn shared_run_rejects_incompatible_opts() {
        let path = dataset();
        let store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&path).unwrap());
        let q = query_for("MET_pt > 25", "rej.troot");
        let tl = Timeline::new();
        let out = path.parent().unwrap().join("rej.troot");
        for bad in [
            EngineOpts { use_pjrt: true, ..Default::default() },
            EngineOpts { use_pjrt: false, two_phase: false, ..Default::default() },
            EngineOpts {
                use_pjrt: false,
                event_range: Some((0, 100)),
                ..Default::default()
            },
        ] {
            let err = run_shared(
                store.clone(),
                &[store.clone()],
                std::slice::from_ref(&q),
                std::slice::from_ref(&tl),
                &Timeline::new(),
                &bad,
                std::slice::from_ref(&out),
                &[],
            );
            assert!(err.is_err());
        }
    }

    #[test]
    fn cancelled_member_detaches_without_killing_the_batch() {
        let path = dataset();
        let dir = path.parent().unwrap();
        let cuts = ["MET_pt > 25", "MET_pt > 60", "nJet >= 2"];
        let n = cuts.len();
        let scan_store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&path).unwrap());
        let member_stores: Vec<Arc<dyn ReadAt>> = (0..n)
            .map(|_| Arc::new(LocalFile::open(&path).unwrap()) as Arc<dyn ReadAt>)
            .collect();
        let queries: Vec<SkimQuery> = cuts
            .iter()
            .enumerate()
            .map(|(i, c)| query_for(c, &format!("detach_m{i}.troot")))
            .collect();
        let out_paths: Vec<PathBuf> =
            (0..n).map(|i| dir.join(format!("detach_m{i}.troot"))).collect();
        let member_tls: Vec<Timeline> = (0..n).map(|_| Timeline::new()).collect();
        // Member 1 is cancelled before the batch starts; 0 and 2 run.
        let ctls: Vec<JobCtl> = (0..n).map(|_| JobCtl::with_deadline_ms(0)).collect();
        ctls[1].cancel.as_ref().unwrap().cancel();
        let _ = std::fs::remove_file(&out_paths[1]);
        let results = run_shared(
            scan_store,
            &member_stores,
            &queries,
            &member_tls,
            &Timeline::new(),
            &interp_opts(),
            &out_paths,
            &ctls,
        )
        .unwrap();
        assert!(matches!(results[1], Err(Error::Cancelled(_))), "{:?}", results[1]);
        assert!(!out_paths[1].exists(), "detached member must write no output");
        for i in [0usize, 2] {
            let res = results[i].as_ref().unwrap();
            let (sres, _tl, sbytes) =
                solo(cuts[i], &format!("detach_solo{i}.troot"), &interp_opts());
            assert_eq!(res.n_pass, sres.n_pass, "member {i}");
            assert_eq!(
                std::fs::read(&out_paths[i]).unwrap(),
                sbytes,
                "surviving member {i} output diverged"
            );
        }
    }
}
