//! Cut-program interpreters: the batch-vectorized **columnar**
//! evaluator the engine runs ([`eval_columnar`]), and the per-event
//! **scalar** reference evaluator ([`eval`]) — the loop a hand-written
//! ROOT macro performs (and the baseline the paper's "inefficient
//! filtering logic" runs), retained as the oracle the columnar path is
//! property-tested against.
//!
//! Both operate on the same padded [`Batch`] arrays as the kernel,
//! with identical semantics (op codes, group counting over the first
//! `M` objects, HT, trigger OR) — property tests assert bit-identical
//! masks against the PJRT path and between the two interpreters.
//!
//! The columnar evaluator runs each stage over whole batch columns in
//! tight loops (one program-structure dispatch per *column*, not per
//! event), skips events already dead in the cumulative funnel in its
//! per-event stage loops (residual IR expressions sweep whole columns
//! for all events — branchless vectors beat a compaction pass at
//! typical survival rates), and stops outright once every event is
//! dead. Its per-stage vectors
//! therefore record `0` for events already dead — the cumulative
//! funnel and the final mask are bit-identical to the scalar oracle's
//! (which evaluates every stage for every event), but raw per-stage
//! verdicts of dead events are not preserved. Everything downstream
//! (the §3.2 funnel, pass lists) consumes only cumulative products, so
//! the two are interchangeable.
//!
//! Beyond the kernel's fixed-function stages, the interpreters
//! evaluate the **full query IR**: residual [`CExpr`] expressions
//! (arbitrary arithmetic, boolean structure and jagged aggregations
//! compiled from [`crate::query::expr::Expr`]) run here, folded into
//! the event-level funnel stage. Anything expressible in the IR is
//! executable on this path; the kernel accelerates the subset that
//! fits its capacity ([`CutProgram::fits_kernel`]).

use crate::query::expr::{AggOp, BinOp, UnaryOp};
use crate::query::plan::{CExpr, CutProgram};
use crate::query::stats::{Conjunct, ConjunctKind, ConjunctStats};
use crate::runtime::{Batch, MaskResult};
use std::collections::HashMap;

/// Fixed lane width of the explicit-chunk sweeps here and in
/// [`crate::engine::fused`]: wide enough to fill a 256-bit vector of
/// `f32`, portable (no nightly SIMD types — the chunking alone lets
/// the autovectorizer emit packed compares).
pub(crate) const LANES: usize = 8;

#[inline]
pub(crate) fn cmp(x: f32, op: u8, abs: bool, value: f32) -> bool {
    let x = if abs { x.abs() } else { x };
    match op {
        0 => x > value,
        1 => x >= value,
        2 => x < value,
        3 => x <= value,
        4 => x == value,
        5 => x != value,
        _ => false,
    }
}

/// TCut truthiness: nonzero is true.
#[inline]
fn truthy(x: f32) -> bool {
    x != 0.0
}

#[inline]
fn bool_f32(b: bool) -> f32 {
    b as u8 as f32
}

fn eval_unary(op: UnaryOp, x: f32) -> f32 {
    match op {
        UnaryOp::Neg => -x,
        UnaryOp::Not => bool_f32(!truthy(x)),
        UnaryOp::Abs => x.abs(),
    }
}

fn eval_binary(op: BinOp, a: f32, b: f32) -> f32 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
        BinOp::Lt => bool_f32(a < b),
        BinOp::Le => bool_f32(a <= b),
        BinOp::Gt => bool_f32(a > b),
        BinOp::Ge => bool_f32(a >= b),
        BinOp::Eq => bool_f32(a == b),
        BinOp::Ne => bool_f32(a != b),
        BinOp::And => bool_f32(truthy(a) && truthy(b)),
        BinOp::Or => bool_f32(truthy(a) || truthy(b)),
        BinOp::Min => a.min(b),
        BinOp::Max => a.max(b),
    }
}

/// Evaluate an event-shaped compiled expression for event `ev`.
/// Jagged references only occur inside aggregations (shape-checked at
/// compile time); a stray one evaluates as 0.
pub fn eval_event_expr(e: &CExpr, batch: &Batch, ev: usize) -> f32 {
    match e {
        CExpr::Num(v) => *v,
        CExpr::Scalar(s) => batch.scalars[s * batch.b + ev],
        CExpr::Jagged(_) => 0.0,
        CExpr::Unary(op, x) => eval_unary(*op, eval_event_expr(x, batch, ev)),
        CExpr::Binary(op, a, b) => {
            eval_binary(*op, eval_event_expr(a, batch, ev), eval_event_expr(b, batch, ev))
        }
        CExpr::Agg { op, nobj, arg, pred } => {
            // Selection semantics cover the first M object slots, like
            // the kernel's group counting; validity comes from the
            // representative column's multiplicity.
            let n = (batch.nobj[nobj * batch.b + ev] as usize).min(batch.m);
            let selected = |slot: usize| match pred {
                Some(p) => truthy(eval_obj_expr(p, batch, ev, slot)),
                None => true,
            };
            match op {
                AggOp::Count => {
                    let mut c = 0u32;
                    for slot in 0..n {
                        if selected(slot) && truthy(eval_obj_expr(arg, batch, ev, slot)) {
                            c += 1;
                        }
                    }
                    c as f32
                }
                AggOp::Any => {
                    let mut any = false;
                    for slot in 0..n {
                        if selected(slot) && truthy(eval_obj_expr(arg, batch, ev, slot)) {
                            any = true;
                            break;
                        }
                    }
                    bool_f32(any)
                }
                AggOp::All => {
                    let mut all = true;
                    for slot in 0..n {
                        if selected(slot) && !truthy(eval_obj_expr(arg, batch, ev, slot)) {
                            all = false;
                            break;
                        }
                    }
                    bool_f32(all)
                }
                AggOp::Sum => {
                    let mut total = 0.0f32;
                    for slot in 0..n {
                        if selected(slot) {
                            total += eval_obj_expr(arg, batch, ev, slot);
                        }
                    }
                    total
                }
                AggOp::Max => {
                    let mut best = f32::NEG_INFINITY;
                    for slot in 0..n {
                        if selected(slot) {
                            best = best.max(eval_obj_expr(arg, batch, ev, slot));
                        }
                    }
                    best
                }
                AggOp::Min => {
                    let mut best = f32::INFINITY;
                    for slot in 0..n {
                        if selected(slot) {
                            best = best.min(eval_obj_expr(arg, batch, ev, slot));
                        }
                    }
                    best
                }
            }
        }
        // The scalar oracle recomputes shared subtrees at every
        // occurrence — same operations, bit-identical to the memoized
        // batch path.
        CExpr::Shared(x) => eval_event_expr(x, batch, ev),
    }
}

/// Evaluate an object-shaped expression at object `slot` of event
/// `ev`. Event-shaped parts (scalars, literals, nested aggregations)
/// broadcast over slots.
fn eval_obj_expr(e: &CExpr, batch: &Batch, ev: usize, slot: usize) -> f32 {
    match e {
        CExpr::Num(v) => *v,
        CExpr::Scalar(s) => batch.scalars[s * batch.b + ev],
        CExpr::Jagged(c) => batch.cols[(c * batch.b + ev) * batch.m + slot],
        CExpr::Unary(op, x) => eval_unary(*op, eval_obj_expr(x, batch, ev, slot)),
        CExpr::Binary(op, a, b) => eval_binary(
            *op,
            eval_obj_expr(a, batch, ev, slot),
            eval_obj_expr(b, batch, ev, slot),
        ),
        // A nested aggregation is event-shaped (slot-invariant) but is
        // re-reduced per slot here: O(M²) for cuts like
        // `any(Muon_pt > max(Jet_pt))`. Acceptable at M ≤ 16; hoist
        // event-shaped subtrees before the slot loop if this ever
        // shows up hot.
        CExpr::Agg { .. } => eval_event_expr(e, batch, ev),
        CExpr::Shared(x) => eval_obj_expr(x, batch, ev, slot),
    }
}

/// Evaluate `program` over the batch, one event at a time.
pub fn eval(program: &CutProgram, batch: &Batch) -> MaskResult {
    let (b, m, n) = (batch.b, batch.m, batch.n_valid);
    let mut mask = vec![0.0f32; n];
    let mut stages = vec![vec![0.0f32; n]; 4];

    for ev in 0..n {
        // stage 1: preselection
        let mut pre = true;
        for cut in &program.scalar_cuts {
            let x = batch.scalars[cut.col * b + ev];
            pre &= cmp(x, cut.op, cut.abs, cut.value);
        }

        // stage 2: object groups
        let mut obj = true;
        for group in &program.groups {
            let mut count = 0u32;
            for slot in 0..m {
                if group.cut_range.is_empty() {
                    break;
                }
                let mut ok = true;
                for k in group.cut_range.clone() {
                    let cut = &program.obj_cuts[k];
                    let valid = (slot as f32) < batch.nobj[cut.col * b + ev];
                    let x = batch.cols[(cut.col * b + ev) * m + slot];
                    ok &= valid && cmp(x, cut.op, cut.abs, cut.value);
                }
                if ok {
                    count += 1;
                }
            }
            obj &= count >= group.min_count;
        }

        // stage 3: event-level — HT unit plus residual IR expressions
        // (anything beyond the kernel's fixed-function stages).
        let mut event_ok = true;
        if let Some(ht) = &program.ht {
            let nv = batch.nobj[ht.col * b + ev] as usize;
            let mut total = 0.0f32;
            for slot in 0..nv.min(m) {
                let x = batch.cols[(ht.col * b + ev) * m + slot];
                if x > ht.object_pt_min {
                    total += x;
                }
            }
            event_ok = total >= ht.min_ht;
        }
        for e in &program.exprs {
            event_ok &= truthy(eval_event_expr(e, batch, ev));
        }

        // stage 4: trigger OR
        let trig_ok = if program.triggers.is_empty() {
            true
        } else {
            program
                .triggers
                .iter()
                .any(|&s| batch.scalars[s * b + ev] > 0.5)
        };

        stages[0][ev] = pre as u8 as f32;
        stages[1][ev] = obj as u8 as f32;
        stages[2][ev] = event_ok as u8 as f32;
        stages[3][ev] = trig_ok as u8 as f32;
        mask[ev] = (pre && obj && event_ok && trig_ok) as u8 as f32;
    }

    MaskResult { mask, stages }
}

// ---------------- columnar (batch-vectorized) evaluator ---------------

/// Per-batch scratch columns for CSE-shared subtrees, keyed by the
/// shared node's address. Event-shape and object-shape results are
/// memoized separately: the same subtree can evaluate at both shapes
/// with different values (a jagged read is 0 at event shape). One
/// scratch lives for exactly one batch evaluation — addresses are only
/// stable, and values only valid, within it.
#[derive(Default)]
struct SharedScratch {
    event: HashMap<usize, Vec<f32>>,
    obj: HashMap<usize, Vec<f32>>,
}

/// Evaluate an event-shaped compiled expression for **all** events at
/// once, returning one value per event. Per-event results are
/// bit-identical to [`eval_event_expr`] (same operations in the same
/// order per event; only the loop nesting differs). Shared subtrees
/// compute once into `scratch` and replay from it at every other
/// occurrence.
fn eval_event_expr_batch(
    e: &CExpr,
    batch: &Batch,
    n: usize,
    scratch: &mut SharedScratch,
) -> Vec<f32> {
    let b = batch.b;
    match e {
        CExpr::Num(v) => vec![*v; n],
        CExpr::Scalar(s) => batch.scalars[s * b..s * b + n].to_vec(),
        // Stray jagged reference at event shape evaluates as 0, like
        // the scalar path.
        CExpr::Jagged(_) => vec![0.0; n],
        CExpr::Unary(op, x) => {
            let mut v = eval_event_expr_batch(x, batch, n, scratch);
            for xv in &mut v {
                *xv = eval_unary(*op, *xv);
            }
            v
        }
        CExpr::Binary(op, x, y) => {
            let mut vx = eval_event_expr_batch(x, batch, n, scratch);
            let vy = eval_event_expr_batch(y, batch, n, scratch);
            for (a, &bv) in vx.iter_mut().zip(&vy) {
                *a = eval_binary(*op, *a, bv);
            }
            vx
        }
        CExpr::Shared(x) => {
            let key = std::sync::Arc::as_ptr(x) as usize;
            if let Some(v) = scratch.event.get(&key) {
                return v.clone();
            }
            let v = eval_event_expr_batch(x, batch, n, scratch);
            scratch.event.insert(key, v.clone());
            v
        }
        CExpr::Agg { op, nobj, arg, pred } => {
            let m = batch.m;
            let va = eval_obj_expr_batch(arg, batch, n, scratch);
            let vp = pred.as_ref().map(|p| eval_obj_expr_batch(p, batch, n, scratch));
            let mut out = vec![0.0f32; n];
            for (ev, o) in out.iter_mut().enumerate() {
                let nv = (batch.nobj[nobj * b + ev] as usize).min(m);
                let row = &va[ev * m..ev * m + nv];
                let sel = |slot: usize| match &vp {
                    Some(p) => truthy(p[ev * m + slot]),
                    None => true,
                };
                // Accumulation order and initial values mirror the
                // scalar evaluator exactly (float-identical results).
                *o = match op {
                    AggOp::Count => {
                        let mut c = 0u32;
                        for (slot, &x) in row.iter().enumerate() {
                            if sel(slot) && truthy(x) {
                                c += 1;
                            }
                        }
                        c as f32
                    }
                    AggOp::Any => {
                        bool_f32(row.iter().enumerate().any(|(s, &x)| sel(s) && truthy(x)))
                    }
                    AggOp::All => {
                        bool_f32(row.iter().enumerate().all(|(s, &x)| !sel(s) || truthy(x)))
                    }
                    AggOp::Sum => {
                        let mut total = 0.0f32;
                        for (slot, &x) in row.iter().enumerate() {
                            if sel(slot) {
                                total += x;
                            }
                        }
                        total
                    }
                    AggOp::Max => {
                        let mut best = f32::NEG_INFINITY;
                        for (slot, &x) in row.iter().enumerate() {
                            if sel(slot) {
                                best = best.max(x);
                            }
                        }
                        best
                    }
                    AggOp::Min => {
                        let mut best = f32::INFINITY;
                        for (slot, &x) in row.iter().enumerate() {
                            if sel(slot) {
                                best = best.min(x);
                            }
                        }
                        best
                    }
                };
            }
            out
        }
    }
}

/// Evaluate an object-shaped expression for all `(event, slot)` pairs,
/// returning an event-major `[n × M]` matrix. Event-shaped parts
/// (scalars, literals, nested aggregations) broadcast over slots,
/// matching [`eval_obj_expr`] per element.
fn eval_obj_expr_batch(
    e: &CExpr,
    batch: &Batch,
    n: usize,
    scratch: &mut SharedScratch,
) -> Vec<f32> {
    let (b, m) = (batch.b, batch.m);
    match e {
        CExpr::Num(v) => vec![*v; n * m],
        CExpr::Scalar(s) => {
            let mut out = vec![0.0f32; n * m];
            for ev in 0..n {
                out[ev * m..(ev + 1) * m].fill(batch.scalars[s * b + ev]);
            }
            out
        }
        CExpr::Jagged(c) => {
            let mut out = vec![0.0f32; n * m];
            for ev in 0..n {
                let at = (c * b + ev) * m;
                out[ev * m..(ev + 1) * m].copy_from_slice(&batch.cols[at..at + m]);
            }
            out
        }
        CExpr::Unary(op, x) => {
            let mut v = eval_obj_expr_batch(x, batch, n, scratch);
            for xv in &mut v {
                *xv = eval_unary(*op, *xv);
            }
            v
        }
        CExpr::Binary(op, x, y) => {
            let mut vx = eval_obj_expr_batch(x, batch, n, scratch);
            let vy = eval_obj_expr_batch(y, batch, n, scratch);
            for (a, &bv) in vx.iter_mut().zip(&vy) {
                *a = eval_binary(*op, *a, bv);
            }
            vx
        }
        CExpr::Shared(x) => {
            let key = std::sync::Arc::as_ptr(x) as usize;
            if let Some(v) = scratch.obj.get(&key) {
                return v.clone();
            }
            let v = eval_obj_expr_batch(x, batch, n, scratch);
            scratch.obj.insert(key, v.clone());
            v
        }
        // A nested aggregation is event-shaped: evaluate once per
        // event, broadcast across slots (the scalar path re-reduces it
        // per slot to the same value).
        CExpr::Agg { .. } => {
            let per_event = eval_event_expr_batch(e, batch, n, scratch);
            let mut out = vec![0.0f32; n * m];
            for (ev, &v) in per_event.iter().enumerate() {
                out[ev * m..(ev + 1) * m].fill(v);
            }
            out
        }
    }
}

/// Inclusive upper bound on slots satisfying `(slot as f32) < nobj`,
/// clamped to `m` — the exact slot-validity predicate of the scalar
/// evaluator, hoisted out of the slot loop. (`ceil` handles fractional
/// `nobj`; non-finite/negative values saturate to 0, matching the
/// per-slot float comparison.)
#[inline]
pub(crate) fn valid_slots(nobj: f32, m: usize) -> usize {
    if nobj.is_nan() || nobj <= 0.0 {
        return 0;
    }
    if nobj >= m as f32 {
        return m;
    }
    nobj.ceil() as usize
}

/// One preselection comparison swept over a whole column into the
/// running conjunction `ok`. Restructured for autovectorization: the
/// opcode dispatch is hoisted out of the loop (one monomorphized sweep
/// per comparison kind) and the body runs in fixed [`LANES`]-wide
/// chunks combined with non-short-circuiting `&`, so each chunk is a
/// branch-free elementwise kernel the compiler can emit as packed
/// compares. Semantics are exactly `ok[i] &= cmp(col[i], ..)`.
pub(crate) fn sweep_cmp_into(ok: &mut [bool], col: &[f32], op: u8, abs: bool, value: f32) {
    #[inline(always)]
    fn sweep(ok: &mut [bool], col: &[f32], pred: impl Fn(f32) -> bool) {
        let n = ok.len().min(col.len());
        let main = n - n % LANES;
        for base in (0..main).step_by(LANES) {
            let os = &mut ok[base..base + LANES];
            let xs = &col[base..base + LANES];
            for i in 0..LANES {
                os[i] &= pred(xs[i]);
            }
        }
        for i in main..n {
            ok[i] &= pred(col[i]);
        }
    }
    debug_assert_eq!(ok.len(), col.len());
    match (op, abs) {
        (0, false) => sweep(ok, col, |x| x > value),
        (1, false) => sweep(ok, col, |x| x >= value),
        (2, false) => sweep(ok, col, |x| x < value),
        (3, false) => sweep(ok, col, |x| x <= value),
        (4, false) => sweep(ok, col, |x| x == value),
        (5, false) => sweep(ok, col, |x| x != value),
        _ => sweep(ok, col, |x| cmp(x, op, abs, value)),
    }
}

/// Evaluate `program` over the batch column-by-column: stages run in
/// funnel order over whole columns, each visiting only events still
/// alive, with a hard stop once the cumulative mask is dead. Masks and
/// cumulative stage funnels are bit-identical to [`eval`]; per-stage
/// raw verdicts of already-dead events are reported as `0` (see module
/// docs).
pub fn eval_columnar(program: &CutProgram, batch: &Batch) -> MaskResult {
    let (b, m, n) = (batch.b, batch.m, batch.n_valid);
    let mut mask = vec![0.0f32; n];
    let mut stages = vec![vec![0.0f32; n]; 4];
    let mut alive = vec![true; n];
    let mut n_alive = n;

    // --- stage 1: preselection — one tight pass per cut column ------
    {
        let s0 = &mut stages[0];
        if program.scalar_cuts.is_empty() {
            s0.fill(1.0);
        } else {
            let mut ok = vec![true; n];
            for cut in &program.scalar_cuts {
                let col = &batch.scalars[cut.col * b..cut.col * b + n];
                sweep_cmp_into(&mut ok, col, cut.op, cut.abs, cut.value);
            }
            for ev in 0..n {
                if ok[ev] {
                    s0[ev] = 1.0;
                } else {
                    alive[ev] = false;
                    n_alive -= 1;
                }
            }
        }
    }
    if n_alive == 0 {
        return MaskResult { mask, stages };
    }

    // --- stage 2: object groups — alive events only, valid-prefix
    // slot loops with early exit at min_count ------------------------
    {
        let s1 = &mut stages[1];
        if program.groups.is_empty() {
            for ev in 0..n {
                if alive[ev] {
                    s1[ev] = 1.0;
                }
            }
        } else {
            for ev in 0..n {
                if !alive[ev] {
                    continue;
                }
                let mut obj = true;
                for group in &program.groups {
                    let cuts = &program.obj_cuts[group.cut_range.clone()];
                    // Slots past any cut column's multiplicity fail that
                    // cut's validity test; bound the loop by the
                    // tightest column.
                    let mut bound = if cuts.is_empty() { 0 } else { m };
                    for cut in cuts {
                        bound = bound.min(valid_slots(batch.nobj[cut.col * b + ev], m));
                    }
                    let mut count = 0u32;
                    for slot in 0..bound {
                        let pass = cuts.iter().all(|cut| {
                            let x = batch.cols[(cut.col * b + ev) * m + slot];
                            cmp(x, cut.op, cut.abs, cut.value)
                        });
                        if pass {
                            count += 1;
                            if count >= group.min_count {
                                break;
                            }
                        }
                    }
                    if count < group.min_count {
                        obj = false;
                        break;
                    }
                }
                if obj {
                    s1[ev] = 1.0;
                } else {
                    alive[ev] = false;
                    n_alive -= 1;
                }
            }
        }
    }
    if n_alive == 0 {
        return MaskResult { mask, stages };
    }

    // --- stage 3: event level — HT unit + batched residual IR -------
    {
        // Residuals evaluate in whole-column passes (one tree walk per
        // expression, not per event); value per event is identical to
        // the scalar path's. They deliberately cover *all* events, not
        // just survivors: the sweep is branchless and a compaction
        // gather/scatter would cost more than it saves unless nearly
        // everything died — and in that case the stage-level early
        // exits above have already returned.
        let mut residual_ok: Option<Vec<bool>> = None;
        if !program.exprs.is_empty() {
            // One scratch across all residual conjuncts: CSE-shared
            // subtrees evaluate once per batch even when the repeats
            // span expressions.
            let mut scratch = SharedScratch::default();
            let mut ok = vec![true; n];
            for e in &program.exprs {
                let v = eval_event_expr_batch(e, batch, n, &mut scratch);
                for (o, &x) in ok.iter_mut().zip(&v) {
                    *o = *o && truthy(x);
                }
            }
            residual_ok = Some(ok);
        }
        let s2 = &mut stages[2];
        for ev in 0..n {
            if !alive[ev] {
                continue;
            }
            let mut event_ok = true;
            if let Some(ht) = &program.ht {
                let nv = (batch.nobj[ht.col * b + ev] as usize).min(m);
                let mut total = 0.0f32;
                for slot in 0..nv {
                    let x = batch.cols[(ht.col * b + ev) * m + slot];
                    if x > ht.object_pt_min {
                        total += x;
                    }
                }
                event_ok = total >= ht.min_ht;
            }
            if let Some(ok) = &residual_ok {
                event_ok &= ok[ev];
            }
            if event_ok {
                s2[ev] = 1.0;
            } else {
                alive[ev] = false;
                n_alive -= 1;
            }
        }
    }
    if n_alive == 0 {
        return MaskResult { mask, stages };
    }

    // --- stage 4: trigger OR ----------------------------------------
    {
        let s3 = &mut stages[3];
        for ev in 0..n {
            if !alive[ev] {
                continue;
            }
            let trig_ok = program.triggers.is_empty()
                || program.triggers.iter().any(|&s| batch.scalars[s * b + ev] > 0.5);
            if trig_ok {
                s3[ev] = 1.0;
                mask[ev] = 1.0;
            }
        }
    }

    MaskResult { mask, stages }
}

// ---------------- adaptive (reorderable) evaluator ---------------------

/// Evaluate one conjunct over the surviving events of `batch`: an
/// event that fails gets its entry in the conjunct's own funnel
/// `stage` row zeroed, its `alive` flag cleared and `n_alive`
/// decremented. This is the shared per-conjunct sweep of
/// [`eval_adaptive`] and the unfused-fallback path of
/// [`crate::engine::fused::eval_fused`] — the two agree per event by
/// construction.
pub(crate) fn eval_conjunct(
    program: &CutProgram,
    batch: &Batch,
    conj: &Conjunct,
    stage: &mut [f32],
    alive: &mut [bool],
    n_alive: &mut usize,
) {
    let (b, m, n) = (batch.b, batch.m, batch.n_valid);
    match conj.kind {
        ConjunctKind::Scalar(i) => {
            let cut = &program.scalar_cuts[i];
            for ev in 0..n {
                if !alive[ev] {
                    continue;
                }
                let x = batch.scalars[cut.col * b + ev];
                if !cmp(x, cut.op, cut.abs, cut.value) {
                    stage[ev] = 0.0;
                    alive[ev] = false;
                    *n_alive -= 1;
                }
            }
        }
        ConjunctKind::Group(i) => {
            let group = &program.groups[i];
            let cuts = &program.obj_cuts[group.cut_range.clone()];
            for ev in 0..n {
                if !alive[ev] {
                    continue;
                }
                let mut bound = if cuts.is_empty() { 0 } else { m };
                for cut in cuts {
                    bound = bound.min(valid_slots(batch.nobj[cut.col * b + ev], m));
                }
                let mut count = 0u32;
                for slot in 0..bound {
                    let pass = cuts.iter().all(|cut| {
                        let x = batch.cols[(cut.col * b + ev) * m + slot];
                        cmp(x, cut.op, cut.abs, cut.value)
                    });
                    if pass {
                        count += 1;
                        if count >= group.min_count {
                            break;
                        }
                    }
                }
                if count < group.min_count {
                    stage[ev] = 0.0;
                    alive[ev] = false;
                    *n_alive -= 1;
                }
            }
        }
        ConjunctKind::Ht => {
            let ht = program.ht.as_ref().expect("HT conjunct without an HT unit");
            for ev in 0..n {
                if !alive[ev] {
                    continue;
                }
                let nv = (batch.nobj[ht.col * b + ev] as usize).min(m);
                let mut total = 0.0f32;
                for slot in 0..nv {
                    let x = batch.cols[(ht.col * b + ev) * m + slot];
                    if x > ht.object_pt_min {
                        total += x;
                    }
                }
                if total < ht.min_ht {
                    stage[ev] = 0.0;
                    alive[ev] = false;
                    *n_alive -= 1;
                }
            }
        }
        ConjunctKind::Residual(i) => {
            // Per-event scalar walk over survivors only (the batch
            // sweep covers all events — wasted exactly when this
            // conjunct was reordered late because little survives).
            let e = &program.exprs[i];
            for ev in 0..n {
                if !alive[ev] {
                    continue;
                }
                if !truthy(eval_event_expr(e, batch, ev)) {
                    stage[ev] = 0.0;
                    alive[ev] = false;
                    *n_alive -= 1;
                }
            }
        }
        ConjunctKind::Trigger => {
            for ev in 0..n {
                if !alive[ev] {
                    continue;
                }
                let ok = program.triggers.iter().any(|&s| batch.scalars[s * b + ev] > 0.5);
                if !ok {
                    stage[ev] = 0.0;
                    alive[ev] = false;
                    *n_alive -= 1;
                }
            }
        }
    }
}

/// Evaluate `program` conjunct-by-conjunct in the caller-chosen
/// `order` (a permutation of `0..conjuncts.len()`, from
/// [`crate::query::stats::rank_order`]), visiting only events still
/// alive and stopping outright once every event is dead. Per-conjunct
/// tallies (events visited/passed, wall-clock cost) accumulate into
/// `stats`, parallel to `conjuncts`.
///
/// **Mask invariant**: ANDed conjuncts commute, so the final mask is
/// bit-identical to [`eval`] / [`eval_columnar`] under *any* order —
/// each conjunct's per-event verdict is order-independent (comparisons
/// and aggregations over the same batch values). Per-stage vectors
/// start at `1.0` and a killing conjunct zeroes its own funnel stage,
/// so the *cumulative* funnel product still equals the mask and the
/// final survivor count matches the oracle exactly; raw per-stage
/// counts may drift from the fixed order (a stage-2 conjunct may kill
/// an event the fixed order would have killed at stage 0) — the
/// documented, allowed divergence.
pub fn eval_adaptive(
    program: &CutProgram,
    batch: &Batch,
    conjuncts: &[Conjunct],
    order: &[usize],
    stats: &mut [ConjunctStats],
) -> MaskResult {
    debug_assert_eq!(conjuncts.len(), stats.len());
    debug_assert_eq!(conjuncts.len(), order.len());
    let n = batch.n_valid;
    let mut stages = vec![vec![1.0f32; n]; 4];
    let mut alive = vec![true; n];
    let mut n_alive = n;

    for &ci in order {
        if n_alive == 0 {
            break;
        }
        let conj = &conjuncts[ci];
        let started = std::time::Instant::now();
        let visited = n_alive as u64;
        eval_conjunct(
            program,
            batch,
            conj,
            &mut stages[conj.stage as usize],
            &mut alive,
            &mut n_alive,
        );
        let st = &mut stats[ci];
        st.visited += visited;
        st.passed += n_alive as u64;
        st.cost_us += started.elapsed().as_micros() as u64;
    }

    let mut mask = vec![0.0f32; n];
    for ev in 0..n {
        if alive[ev] {
            mask[ev] = 1.0;
        }
    }
    MaskResult { mask, stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::{CutProgram, HtParam, ObjCutParam, ObjGroup, ScalarCutParam};
    use crate::runtime::Capacities;

    fn caps() -> Capacities {
        Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 }
    }

    #[test]
    fn empty_program_accepts_all() {
        let mut batch = Batch::zeroed(&caps(), 4, 2);
        batch.n_valid = 3;
        let out = eval(&CutProgram::default(), &batch);
        assert_eq!(out.mask, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn object_group_counting() {
        let mut program = CutProgram::default();
        program.obj_columns.push("pt".into());
        program.obj_cuts.push(ObjCutParam { col: 0, op: 0, abs: false, value: 25.0 });
        program.groups.push(ObjGroup {
            collection: "E".into(),
            cut_range: 0..1,
            min_count: 2,
        });
        let (b, m) = (4, 3);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 3;
        // ev0: [30, 26, 10] n=3 → 2 pass → ok
        batch.cols[0..3].copy_from_slice(&[30.0, 26.0, 10.0]);
        batch.nobj[0] = 3.0;
        // ev1: [30, 26] but n=1 → only 1 valid → fail
        batch.cols[m..m + 2].copy_from_slice(&[30.0, 26.0]);
        batch.nobj[1] = 1.0;
        // ev2: no objects → fail
        let out = eval(&program, &batch);
        assert_eq!(out.mask, vec![1.0, 0.0, 0.0]);
        assert_eq!(out.stages[1], vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn preselection_ht_trigger() {
        let mut program = CutProgram::default();
        program.scalar_columns = vec!["nE".into(), "HLT_X".into()];
        program.scalar_cuts.push(ScalarCutParam { col: 0, op: 1, abs: false, value: 1.0 });
        program.obj_columns.push("Jet_pt".into());
        program.ht = Some(HtParam { col: 0, object_pt_min: 30.0, min_ht: 100.0 });
        program.triggers.push(1);
        let (b, m) = (2, 4);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 2;
        // ev0: nE=1, jets [60, 50], trigger on → pass (HT 110)
        batch.scalars[0] = 1.0;
        batch.scalars[b] = 1.0;
        batch.cols[0..2].copy_from_slice(&[60.0, 50.0]);
        batch.nobj[0] = 2.0;
        // ev1: nE=1, jets [60, 20] (20 below pt_min), trigger off → fail both
        batch.scalars[1] = 1.0;
        batch.scalars[b + 1] = 0.0;
        batch.cols[m..m + 2].copy_from_slice(&[60.0, 20.0]);
        batch.nobj[1] = 2.0;
        let out = eval(&program, &batch);
        assert_eq!(out.stages[0], vec![1.0, 1.0]);
        assert_eq!(out.stages[2], vec![1.0, 0.0]);
        assert_eq!(out.stages[3], vec![1.0, 0.0]);
        assert_eq!(out.mask, vec![1.0, 0.0]);
    }

    #[test]
    fn abs_comparisons() {
        let mut program = CutProgram::default();
        program.obj_columns.push("eta".into());
        program.obj_cuts.push(ObjCutParam { col: 0, op: 2, abs: true, value: 2.4 });
        program.groups.push(ObjGroup { collection: "E".into(), cut_range: 0..1, min_count: 1 });
        let (b, m) = (3, 1);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 3;
        batch.cols[0] = -1.0; // |.| < 2.4 ok
        batch.cols[1] = -3.0; // fail
        batch.cols[2] = 2.4; // boundary: not <
        batch.nobj[0] = 1.0;
        batch.nobj[1] = 1.0;
        batch.nobj[2] = 1.0;
        let out = eval(&program, &batch);
        assert_eq!(out.mask, vec![1.0, 0.0, 0.0]);
    }

    // ---------------- residual IR expressions -------------------------

    /// Batch with one jagged column (2 slots/object cap) and one scalar
    /// column over 3 events: jagged [[40, 10], [5], []], scalar
    /// [120, 50, 120].
    fn ir_batch() -> Batch {
        let (b, m) = (3, 2);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 3;
        batch.cols[0..2].copy_from_slice(&[40.0, 10.0]);
        batch.nobj[0] = 2.0;
        batch.cols[m] = 5.0;
        batch.nobj[1] = 1.0;
        batch.nobj[2] = 0.0;
        batch.scalars[0..3].copy_from_slice(&[120.0, 50.0, 120.0]);
        batch
    }

    #[test]
    fn aggregation_semantics_over_jagged_slots() {
        let batch = ir_batch();
        let jag = || Box::new(CExpr::Jagged(0));
        let gt20 = || {
            Box::new(CExpr::Binary(
                BinOp::Gt,
                Box::new(CExpr::Jagged(0)),
                Box::new(CExpr::Num(20.0)),
            ))
        };
        let count =
            CExpr::Agg { op: AggOp::Count, nobj: 0, arg: gt20(), pred: None };
        assert_eq!(eval_event_expr(&count, &batch, 0), 1.0);
        assert_eq!(eval_event_expr(&count, &batch, 1), 0.0);
        assert_eq!(eval_event_expr(&count, &batch, 2), 0.0);

        let sum_all = CExpr::Agg { op: AggOp::Sum, nobj: 0, arg: jag(), pred: None };
        assert_eq!(eval_event_expr(&sum_all, &batch, 0), 50.0);
        assert_eq!(eval_event_expr(&sum_all, &batch, 2), 0.0);

        let sum_sel = CExpr::Agg { op: AggOp::Sum, nobj: 0, arg: jag(), pred: Some(gt20()) };
        assert_eq!(eval_event_expr(&sum_sel, &batch, 0), 40.0);
        assert_eq!(eval_event_expr(&sum_sel, &batch, 1), 0.0);

        let max = CExpr::Agg { op: AggOp::Max, nobj: 0, arg: jag(), pred: None };
        assert_eq!(eval_event_expr(&max, &batch, 0), 40.0);
        assert_eq!(eval_event_expr(&max, &batch, 1), 5.0);
        assert_eq!(eval_event_expr(&max, &batch, 2), f32::NEG_INFINITY);

        let min = CExpr::Agg { op: AggOp::Min, nobj: 0, arg: jag(), pred: None };
        assert_eq!(eval_event_expr(&min, &batch, 0), 10.0);
        assert_eq!(eval_event_expr(&min, &batch, 2), f32::INFINITY);

        let any = CExpr::Agg { op: AggOp::Any, nobj: 0, arg: gt20(), pred: None };
        assert_eq!(eval_event_expr(&any, &batch, 0), 1.0);
        assert_eq!(eval_event_expr(&any, &batch, 1), 0.0);
        assert_eq!(eval_event_expr(&any, &batch, 2), 0.0);

        let all = CExpr::Agg { op: AggOp::All, nobj: 0, arg: gt20(), pred: None };
        assert_eq!(eval_event_expr(&all, &batch, 0), 0.0);
        assert_eq!(eval_event_expr(&all, &batch, 2), 1.0); // vacuous
    }

    #[test]
    fn arithmetic_and_boolean_ops() {
        let batch = ir_batch();
        // (scalar / 2 + 10) > 60 → ev0: 70 > 60 true; ev1: 35 false.
        let e = CExpr::Binary(
            BinOp::Gt,
            Box::new(CExpr::Binary(
                BinOp::Add,
                Box::new(CExpr::Binary(
                    BinOp::Div,
                    Box::new(CExpr::Scalar(0)),
                    Box::new(CExpr::Num(2.0)),
                )),
                Box::new(CExpr::Num(10.0)),
            )),
            Box::new(CExpr::Num(60.0)),
        );
        assert_eq!(eval_event_expr(&e, &batch, 0), 1.0);
        assert_eq!(eval_event_expr(&e, &batch, 1), 0.0);

        let not = CExpr::Unary(UnaryOp::Not, Box::new(e.clone()));
        assert_eq!(eval_event_expr(&not, &batch, 0), 0.0);
        assert_eq!(eval_event_expr(&not, &batch, 1), 1.0);

        let neg_abs = CExpr::Unary(
            UnaryOp::Abs,
            Box::new(CExpr::Unary(UnaryOp::Neg, Box::new(CExpr::Scalar(0)))),
        );
        assert_eq!(eval_event_expr(&neg_abs, &batch, 1), 50.0);

        let minmax = CExpr::Binary(
            BinOp::Max,
            Box::new(CExpr::Num(7.0)),
            Box::new(CExpr::Binary(
                BinOp::Min,
                Box::new(CExpr::Scalar(0)),
                Box::new(CExpr::Num(3.0)),
            )),
        );
        assert_eq!(eval_event_expr(&minmax, &batch, 0), 7.0);
    }

    #[test]
    fn residual_exprs_fold_into_event_stage() {
        // mask = scalar > 100 || any(jagged > 20): ev0 both, ev1
        // neither, ev2 scalar only.
        let mut program = CutProgram::default();
        program.scalar_columns.push("MET_pt".into());
        program.obj_columns.push("Jet_pt".into());
        program.exprs.push(CExpr::Binary(
            BinOp::Or,
            Box::new(CExpr::Binary(
                BinOp::Gt,
                Box::new(CExpr::Scalar(0)),
                Box::new(CExpr::Num(100.0)),
            )),
            Box::new(CExpr::Agg {
                op: AggOp::Any,
                nobj: 0,
                arg: Box::new(CExpr::Binary(
                    BinOp::Gt,
                    Box::new(CExpr::Jagged(0)),
                    Box::new(CExpr::Num(20.0)),
                )),
                pred: None,
            }),
        ));
        let batch = ir_batch();
        let out = eval(&program, &batch);
        assert_eq!(out.mask, vec![1.0, 0.0, 1.0]);
        // Residuals are event-stage (index 2) decisions; other stages
        // stay open.
        assert_eq!(out.stages[2], vec![1.0, 0.0, 1.0]);
        assert_eq!(out.stages[0], vec![1.0, 1.0, 1.0]);
        assert_eq!(out.stages[3], vec![1.0, 1.0, 1.0]);
    }

    // ---------------- columnar evaluator ------------------------------

    use crate::util::{prop_check, Pcg32};

    /// The §3.2 funnel of a result: cumulative survivors per stage —
    /// the quantity the engine consumes, and the equivalence contract
    /// between the two interpreters.
    fn funnel_of(r: &MaskResult) -> [u64; 4] {
        let n = r.mask.len();
        let mut f = [0u64; 4];
        for ev in 0..n {
            let mut cum = 1.0f32;
            for (s, fs) in f.iter_mut().enumerate() {
                cum *= r.stages[s][ev];
                *fs += cum as u64;
            }
        }
        f
    }

    fn assert_equivalent(program: &CutProgram, batch: &Batch) {
        let scalar = eval(program, batch);
        let columnar = eval_columnar(program, batch);
        assert_eq!(scalar.mask, columnar.mask, "masks diverge");
        assert_eq!(funnel_of(&scalar), funnel_of(&columnar), "funnels diverge");
    }

    #[test]
    fn columnar_matches_scalar_on_unit_cases() {
        // Re-run every deterministic scenario above through both paths.
        let mut empty_batch = Batch::zeroed(&caps(), 4, 2);
        empty_batch.n_valid = 3;
        assert_equivalent(&CutProgram::default(), &empty_batch);

        let mut program = CutProgram::default();
        program.scalar_columns = vec!["nE".into(), "HLT_X".into()];
        program.scalar_cuts.push(ScalarCutParam { col: 0, op: 1, abs: false, value: 1.0 });
        program.obj_columns.push("Jet_pt".into());
        program.ht = Some(HtParam { col: 0, object_pt_min: 30.0, min_ht: 100.0 });
        program.triggers.push(1);
        let (b, m) = (2, 4);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 2;
        batch.scalars[0] = 1.0;
        batch.scalars[b] = 1.0;
        batch.cols[0..2].copy_from_slice(&[60.0, 50.0]);
        batch.nobj[0] = 2.0;
        batch.scalars[1] = 1.0;
        batch.scalars[b + 1] = 0.0;
        batch.cols[m..m + 2].copy_from_slice(&[60.0, 20.0]);
        batch.nobj[1] = 2.0;
        assert_equivalent(&program, &batch);

        // Residual IR program over the shared fixture.
        let mut rp = CutProgram::default();
        rp.scalar_columns.push("MET_pt".into());
        rp.obj_columns.push("Jet_pt".into());
        rp.exprs.push(CExpr::Binary(
            BinOp::Or,
            Box::new(CExpr::Binary(
                BinOp::Gt,
                Box::new(CExpr::Scalar(0)),
                Box::new(CExpr::Num(100.0)),
            )),
            Box::new(CExpr::Agg {
                op: AggOp::Any,
                nobj: 0,
                arg: Box::new(CExpr::Binary(
                    BinOp::Gt,
                    Box::new(CExpr::Jagged(0)),
                    Box::new(CExpr::Num(20.0)),
                )),
                pred: None,
            }),
        ));
        assert_equivalent(&rp, &ir_batch());
    }

    #[test]
    fn columnar_early_exit_when_funnel_dies() {
        // Every event fails preselection: the columnar path stops after
        // stage 1 and reports later stages as dead — funnel-identical
        // to the oracle.
        let mut program = CutProgram::default();
        program.scalar_columns.push("x".into());
        program.scalar_cuts.push(ScalarCutParam { col: 0, op: 0, abs: false, value: 1e9 });
        program.triggers.push(0);
        let mut batch = Batch::zeroed(&caps(), 4, 2);
        batch.n_valid = 4;
        batch.scalars[0..4].copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let out = eval_columnar(&program, &batch);
        assert_eq!(out.mask, vec![0.0; 4]);
        assert_eq!(out.stages[0], vec![0.0; 4]);
        assert_equivalent(&program, &batch);
    }

    #[test]
    fn columnar_handles_fractional_and_oversized_multiplicities() {
        // nobj values beyond M and non-integral ones exercise the
        // hoisted valid-slot bound against the oracle's per-slot float
        // comparison.
        let mut program = CutProgram::default();
        program.obj_columns.push("pt".into());
        program.obj_cuts.push(ObjCutParam { col: 0, op: 0, abs: false, value: 10.0 });
        program.groups.push(ObjGroup { collection: "E".into(), cut_range: 0..1, min_count: 2 });
        let (b, m) = (4, 3);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 4;
        for ev in 0..4 {
            for slot in 0..m {
                batch.cols[ev * m + slot] = 20.0 + slot as f32;
            }
        }
        batch.nobj[0] = 2.5; // fractional: slots 0..3 valid per float cmp
        batch.nobj[1] = 7.0; // beyond M: clamps to M
        batch.nobj[2] = 0.0;
        batch.nobj[3] = -1.0;
        assert_equivalent(&program, &batch);
        assert_eq!(valid_slots(2.5, 3), 3);
        assert_eq!(valid_slots(3.0, 3), 3);
        assert_eq!(valid_slots(7.0, 3), 3);
        assert_eq!(valid_slots(0.0, 3), 0);
        assert_eq!(valid_slots(-1.0, 3), 0);
        assert_eq!(valid_slots(f32::NAN, 3), 0);
        assert_eq!(valid_slots(0.25, 3), 1);
    }

    // ---------------- randomized equivalence --------------------------

    fn gen_value(rng: &mut Pcg32) -> f32 {
        // Quarter-step grid: exact floats so `==`/`!=` cuts have real
        // hit probability.
        (rng.below(200) as f32 - 100.0) / 4.0
    }

    fn gen_obj_expr(rng: &mut Pcg32, depth: usize, n_obj: usize, n_sc: usize) -> CExpr {
        if depth == 0 {
            return CExpr::Jagged(rng.below(n_obj as u32) as usize);
        }
        match rng.below(6) {
            0 => CExpr::Jagged(rng.below(n_obj as u32) as usize),
            1 => CExpr::Num(gen_value(rng)),
            2 => CExpr::Scalar(rng.below(n_sc as u32) as usize),
            3 => CExpr::Unary(
                [UnaryOp::Neg, UnaryOp::Not, UnaryOp::Abs][rng.below(3) as usize],
                Box::new(gen_obj_expr(rng, depth - 1, n_obj, n_sc)),
            ),
            _ => {
                let ops = [
                    BinOp::Add,
                    BinOp::Sub,
                    BinOp::Mul,
                    BinOp::Div,
                    BinOp::Lt,
                    BinOp::Le,
                    BinOp::Gt,
                    BinOp::Ge,
                    BinOp::Eq,
                    BinOp::Ne,
                    BinOp::And,
                    BinOp::Or,
                    BinOp::Min,
                    BinOp::Max,
                ];
                CExpr::Binary(
                    ops[rng.below(ops.len() as u32) as usize],
                    Box::new(gen_obj_expr(rng, depth - 1, n_obj, n_sc)),
                    Box::new(gen_obj_expr(rng, depth - 1, n_obj, n_sc)),
                )
            }
        }
    }

    fn gen_event_expr(rng: &mut Pcg32, depth: usize, n_obj: usize, n_sc: usize) -> CExpr {
        let aggs = [AggOp::Count, AggOp::Any, AggOp::All, AggOp::Sum, AggOp::Max, AggOp::Min];
        if depth == 0 || rng.chance(0.3) {
            // Aggregations are the workhorse leaves: they bridge the
            // object shape back to event shape.
            return CExpr::Agg {
                op: aggs[rng.below(aggs.len() as u32) as usize],
                nobj: rng.below(n_obj as u32) as usize,
                arg: Box::new(gen_obj_expr(rng, depth.min(2), n_obj, n_sc)),
                pred: if rng.chance(0.4) {
                    Some(Box::new(gen_obj_expr(rng, 1, n_obj, n_sc)))
                } else {
                    None
                },
            };
        }
        match rng.below(5) {
            0 => CExpr::Num(gen_value(rng)),
            1 => CExpr::Scalar(rng.below(n_sc as u32) as usize),
            2 => CExpr::Unary(
                [UnaryOp::Neg, UnaryOp::Not, UnaryOp::Abs][rng.below(3) as usize],
                Box::new(gen_event_expr(rng, depth - 1, n_obj, n_sc)),
            ),
            _ => {
                let ops = [
                    BinOp::Add,
                    BinOp::Mul,
                    BinOp::Gt,
                    BinOp::Ge,
                    BinOp::Lt,
                    BinOp::And,
                    BinOp::Or,
                    BinOp::Min,
                    BinOp::Max,
                ];
                CExpr::Binary(
                    ops[rng.below(ops.len() as u32) as usize],
                    Box::new(gen_event_expr(rng, depth - 1, n_obj, n_sc)),
                    Box::new(gen_event_expr(rng, depth - 1, n_obj, n_sc)),
                )
            }
        }
    }

    fn gen_program(rng: &mut Pcg32, n_obj: usize, n_sc: usize) -> CutProgram {
        let mut p = CutProgram::default();
        for c in 0..n_obj {
            p.obj_columns.push(format!("o{c}"));
        }
        for s in 0..n_sc {
            p.scalar_columns.push(format!("s{s}"));
        }
        for _ in 0..rng.below(3) {
            p.scalar_cuts.push(ScalarCutParam {
                col: rng.below(n_sc as u32) as usize,
                op: rng.below(6) as u8,
                abs: rng.chance(0.3),
                value: gen_value(rng),
            });
        }
        for g in 0..rng.below(3) {
            let start = p.obj_cuts.len();
            for _ in 0..1 + rng.below(2) {
                p.obj_cuts.push(ObjCutParam {
                    col: rng.below(n_obj as u32) as usize,
                    op: rng.below(6) as u8,
                    abs: rng.chance(0.3),
                    value: gen_value(rng),
                });
            }
            p.groups.push(ObjGroup {
                collection: format!("G{g}"),
                cut_range: start..p.obj_cuts.len(),
                min_count: rng.below(3),
            });
        }
        if rng.chance(0.5) {
            p.ht = Some(HtParam {
                col: rng.below(n_obj as u32) as usize,
                object_pt_min: gen_value(rng),
                min_ht: gen_value(rng),
            });
        }
        if rng.chance(0.5) {
            for s in 0..n_sc {
                if rng.chance(0.5) {
                    p.triggers.push(s);
                }
            }
        }
        for _ in 0..rng.below(3) {
            p.exprs.push(gen_event_expr(rng, 1 + rng.below(3) as usize, n_obj, n_sc));
        }
        p
    }

    fn gen_batch(rng: &mut Pcg32, n_obj: usize, n_sc: usize) -> Batch {
        let m = 1 + rng.below(6) as usize;
        let n = 1 + rng.below(48) as usize;
        let b = n + rng.below(8) as usize;
        let caps = Capacities { c: n_obj, s: n_sc, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 };
        let mut batch = Batch::zeroed(&caps, b, m);
        batch.n_valid = n;
        for c in 0..n_obj {
            for ev in 0..n {
                // Multiplicities may exceed M and may be fractional.
                let mut nobj = rng.below(m as u32 + 3) as f32;
                if rng.chance(0.1) {
                    nobj += 0.5;
                }
                batch.nobj[c * b + ev] = nobj;
                for slot in 0..m {
                    batch.cols[(c * b + ev) * m + slot] = gen_value(rng);
                }
            }
        }
        for s in 0..n_sc {
            for ev in 0..n {
                // Mix flag-like 0/1 values (for triggers) with generic.
                batch.scalars[s * b + ev] = if rng.chance(0.5) {
                    rng.below(2) as f32
                } else {
                    gen_value(rng)
                };
            }
        }
        batch
    }

    #[test]
    fn prop_columnar_matches_scalar_evaluator() {
        prop_check("columnar ≡ scalar interpreter", 300, |rng| {
            let n_obj = 1 + rng.below(3) as usize;
            let n_sc = 1 + rng.below(4) as usize;
            let program = gen_program(rng, n_obj, n_sc);
            let batch = gen_batch(rng, n_obj, n_sc);
            assert_equivalent(&program, &batch);
        });
    }

    // ---------------- CSE shared subtrees ------------------------------

    #[test]
    fn shared_subtrees_evaluate_once_and_identically() {
        use std::sync::Arc;
        // shared = scalar0 * 2; expr = (shared > 100) || (shared < 20)
        let shared = Arc::new(CExpr::Binary(
            BinOp::Mul,
            Box::new(CExpr::Scalar(0)),
            Box::new(CExpr::Num(2.0)),
        ));
        let with_cse = CExpr::Binary(
            BinOp::Or,
            Box::new(CExpr::Binary(
                BinOp::Gt,
                Box::new(CExpr::Shared(shared.clone())),
                Box::new(CExpr::Num(100.0)),
            )),
            Box::new(CExpr::Binary(
                BinOp::Lt,
                Box::new(CExpr::Shared(shared)),
                Box::new(CExpr::Num(20.0)),
            )),
        );
        let plain = CExpr::Binary(
            BinOp::Or,
            Box::new(CExpr::Binary(
                BinOp::Gt,
                Box::new(CExpr::Binary(
                    BinOp::Mul,
                    Box::new(CExpr::Scalar(0)),
                    Box::new(CExpr::Num(2.0)),
                )),
                Box::new(CExpr::Num(100.0)),
            )),
            Box::new(CExpr::Binary(
                BinOp::Lt,
                Box::new(CExpr::Binary(
                    BinOp::Mul,
                    Box::new(CExpr::Scalar(0)),
                    Box::new(CExpr::Num(2.0)),
                )),
                Box::new(CExpr::Num(20.0)),
            )),
        );
        let batch = ir_batch();
        let mut scratch = SharedScratch::default();
        let v_cse = eval_event_expr_batch(&with_cse, &batch, 3, &mut scratch);
        let v_plain =
            eval_event_expr_batch(&plain, &batch, 3, &mut SharedScratch::default());
        assert_eq!(v_cse, v_plain);
        // The shared node landed in the scratch exactly once.
        assert_eq!(scratch.event.len(), 1);
        // Scalar path recurses transparently.
        for ev in 0..3 {
            assert_eq!(
                eval_event_expr(&with_cse, &batch, ev),
                eval_event_expr(&plain, &batch, ev)
            );
        }

        // Whole programs agree through both evaluators.
        let mut p_cse = CutProgram::default();
        p_cse.scalar_columns.push("MET_pt".into());
        p_cse.exprs.push(with_cse);
        let mut p_plain = CutProgram::default();
        p_plain.scalar_columns.push("MET_pt".into());
        p_plain.exprs.push(plain);
        let batch = ir_batch();
        assert_eq!(eval(&p_cse, &batch).mask, eval(&p_plain, &batch).mask);
        assert_eq!(
            eval_columnar(&p_cse, &batch).mask,
            eval_columnar(&p_plain, &batch).mask
        );
        assert_equivalent(&p_cse, &batch);
    }

    #[test]
    fn shared_subtree_memo_is_shape_keyed() {
        use std::sync::Arc;
        // A jagged read is 0.0 at event shape but real values at object
        // shape: one shared node used at both shapes must not leak one
        // shape's scratch column into the other.
        let shared = Arc::new(CExpr::Jagged(0));
        let e = CExpr::Binary(
            BinOp::Add,
            // Event shape: stray jagged → 0.
            Box::new(CExpr::Shared(shared.clone())),
            // Object shape via aggregation: max over real values.
            Box::new(CExpr::Agg {
                op: AggOp::Max,
                nobj: 0,
                arg: Box::new(CExpr::Shared(shared)),
                pred: None,
            }),
        );
        let batch = ir_batch();
        let mut scratch = SharedScratch::default();
        let v = eval_event_expr_batch(&e, &batch, 3, &mut scratch);
        assert_eq!(v[0], 40.0); // 0 + max([40, 10])
        assert_eq!(v[1], 5.0);
        assert_eq!(scratch.event.len(), 1);
        assert_eq!(scratch.obj.len(), 1);
        for ev in 0..3 {
            assert_eq!(eval_event_expr(&e, &batch, ev), v[ev]);
        }
    }

    // ---------------- adaptive evaluator -------------------------------

    use crate::query::stats::{conjuncts_of, rank_order};

    /// Run `eval_adaptive` under `order` and assert the adaptive
    /// contract against the oracle: identical mask, identical final
    /// survivor count through the cumulative funnel.
    fn assert_adaptive_matches(
        program: &CutProgram,
        batch: &Batch,
        order: &[usize],
        stats: &mut [ConjunctStats],
    ) {
        let conjuncts = conjuncts_of(program);
        let oracle = eval(program, batch);
        let adaptive = eval_adaptive(program, batch, &conjuncts, order, stats);
        assert_eq!(oracle.mask, adaptive.mask, "masks diverge under order {order:?}");
        let n_pass = oracle.mask.iter().filter(|&&x| x > 0.0).count() as u64;
        assert_eq!(
            funnel_of(&adaptive)[3],
            n_pass,
            "cumulative funnel tail diverges under order {order:?}"
        );
    }

    #[test]
    fn adaptive_matches_oracle_on_unit_cases_under_reversed_order() {
        let mut program = CutProgram::default();
        program.scalar_columns = vec!["nE".into(), "HLT_X".into()];
        program.scalar_cuts.push(ScalarCutParam { col: 0, op: 1, abs: false, value: 1.0 });
        program.obj_columns.push("Jet_pt".into());
        program.ht = Some(HtParam { col: 0, object_pt_min: 30.0, min_ht: 100.0 });
        program.triggers.push(1);
        let (b, m) = (2, 4);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 2;
        batch.scalars[0] = 1.0;
        batch.scalars[b] = 1.0;
        batch.cols[0..2].copy_from_slice(&[60.0, 50.0]);
        batch.nobj[0] = 2.0;
        batch.scalars[1] = 1.0;
        batch.scalars[b + 1] = 0.0;
        batch.cols[m..m + 2].copy_from_slice(&[60.0, 20.0]);
        batch.nobj[1] = 2.0;

        let conjuncts = conjuncts_of(&program);
        assert_eq!(conjuncts.len(), 3);
        let mut stats = vec![ConjunctStats::default(); conjuncts.len()];
        // Reversed order: trigger first, preselection last.
        assert_adaptive_matches(&program, &batch, &[2, 1, 0], &mut stats);
        // The trigger visited both events and killed one; the HT unit
        // only saw the survivor.
        assert_eq!(stats[2].visited, 2);
        assert_eq!(stats[2].passed, 1);
        assert_eq!(stats[1].visited, 1);
        // Trivial program: no conjuncts, everything passes.
        let trivial = CutProgram::default();
        let out = eval_adaptive(&trivial, &batch, &[], &[], &mut []);
        assert_eq!(out.mask, vec![1.0, 1.0]);
    }

    #[test]
    fn adaptive_stats_drive_rank_toward_selective_first() {
        // Scalar cut passes everything; trigger kills half. After one
        // measured batch the ranking must move the trigger ahead of
        // the (now provably useless) scalar cut.
        let mut program = CutProgram::default();
        program.scalar_columns = vec!["x".into(), "flag".into()];
        program.scalar_cuts.push(ScalarCutParam { col: 0, op: 0, abs: false, value: -1e9 });
        program.triggers.push(1);
        let mut batch = Batch::zeroed(&caps(), 8, 2);
        batch.n_valid = 8;
        for ev in 0..8 {
            batch.scalars[ev] = ev as f32;
            batch.scalars[8 + ev] = (ev % 2) as f32;
        }
        let conjuncts = conjuncts_of(&program);
        let mut stats = vec![ConjunctStats::default(); conjuncts.len()];
        let identity: Vec<usize> = (0..conjuncts.len()).collect();
        assert_adaptive_matches(&program, &batch, &identity, &mut stats);
        assert_eq!(stats[0].visited, 8);
        assert_eq!(stats[0].passed, 8);
        assert_eq!(stats[1].visited, 8);
        assert_eq!(stats[1].passed, 4);
        let ranked = rank_order(&conjuncts, &stats);
        assert_eq!(ranked, vec![1, 0], "selective trigger must rank first");
        // And the re-ranked order still matches the oracle.
        assert_adaptive_matches(&program, &batch, &ranked, &mut stats);
    }

    #[test]
    fn prop_adaptive_matches_scalar_evaluator_under_any_order() {
        prop_check("adaptive ≡ scalar interpreter", 200, |rng| {
            let n_obj = 1 + rng.below(3) as usize;
            let n_sc = 1 + rng.below(4) as usize;
            let program = gen_program(rng, n_obj, n_sc);
            let batch = gen_batch(rng, n_obj, n_sc);
            let conjuncts = conjuncts_of(&program);
            let mut stats = vec![ConjunctStats::default(); conjuncts.len()];
            // Identity order.
            let mut order: Vec<usize> = (0..conjuncts.len()).collect();
            assert_adaptive_matches(&program, &batch, &order, &mut stats);
            // Random shuffle (Fisher–Yates off the case's rng).
            for i in (1..order.len()).rev() {
                let j = rng.below(i as u32 + 1) as usize;
                order.swap(i, j);
            }
            assert_adaptive_matches(&program, &batch, &order, &mut stats);
            // The measured, ranked order — what the engine actually
            // runs after warm-up.
            let ranked = rank_order(&conjuncts, &stats);
            assert_adaptive_matches(&program, &batch, &ranked, &mut stats);
        });
    }
}
