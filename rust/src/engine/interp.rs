//! Scalar cut-program interpreter: the per-event evaluation loop a
//! hand-written ROOT macro performs (and the baseline the paper's
//! "inefficient filtering logic" runs), plus the fallback for programs
//! exceeding the AOT kernel's capacity.
//!
//! Operates on the same padded [`Batch`] arrays as the kernel, with
//! identical semantics (op codes, group counting over the first `M`
//! objects, HT, trigger OR) — property tests in `rust/tests/` assert
//! bit-identical masks against the PJRT path.
//!
//! Beyond the kernel's fixed-function stages, the interpreter
//! evaluates the **full query IR**: residual [`CExpr`] expressions
//! (arbitrary arithmetic, boolean structure and jagged aggregations
//! compiled from [`crate::query::expr::Expr`]) run here, folded into
//! the event-level funnel stage. Anything expressible in the IR is
//! executable on this path; the kernel accelerates the subset that
//! fits its capacity ([`CutProgram::fits_kernel`]).

use crate::query::expr::{AggOp, BinOp, UnaryOp};
use crate::query::plan::{CExpr, CutProgram};
use crate::runtime::{Batch, MaskResult};

#[inline]
fn cmp(x: f32, op: u8, abs: bool, value: f32) -> bool {
    let x = if abs { x.abs() } else { x };
    match op {
        0 => x > value,
        1 => x >= value,
        2 => x < value,
        3 => x <= value,
        4 => x == value,
        5 => x != value,
        _ => false,
    }
}

/// TCut truthiness: nonzero is true.
#[inline]
fn truthy(x: f32) -> bool {
    x != 0.0
}

#[inline]
fn bool_f32(b: bool) -> f32 {
    b as u8 as f32
}

fn eval_unary(op: UnaryOp, x: f32) -> f32 {
    match op {
        UnaryOp::Neg => -x,
        UnaryOp::Not => bool_f32(!truthy(x)),
        UnaryOp::Abs => x.abs(),
    }
}

fn eval_binary(op: BinOp, a: f32, b: f32) -> f32 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
        BinOp::Lt => bool_f32(a < b),
        BinOp::Le => bool_f32(a <= b),
        BinOp::Gt => bool_f32(a > b),
        BinOp::Ge => bool_f32(a >= b),
        BinOp::Eq => bool_f32(a == b),
        BinOp::Ne => bool_f32(a != b),
        BinOp::And => bool_f32(truthy(a) && truthy(b)),
        BinOp::Or => bool_f32(truthy(a) || truthy(b)),
        BinOp::Min => a.min(b),
        BinOp::Max => a.max(b),
    }
}

/// Evaluate an event-shaped compiled expression for event `ev`.
/// Jagged references only occur inside aggregations (shape-checked at
/// compile time); a stray one evaluates as 0.
pub fn eval_event_expr(e: &CExpr, batch: &Batch, ev: usize) -> f32 {
    match e {
        CExpr::Num(v) => *v,
        CExpr::Scalar(s) => batch.scalars[s * batch.b + ev],
        CExpr::Jagged(_) => 0.0,
        CExpr::Unary(op, x) => eval_unary(*op, eval_event_expr(x, batch, ev)),
        CExpr::Binary(op, a, b) => {
            eval_binary(*op, eval_event_expr(a, batch, ev), eval_event_expr(b, batch, ev))
        }
        CExpr::Agg { op, nobj, arg, pred } => {
            // Selection semantics cover the first M object slots, like
            // the kernel's group counting; validity comes from the
            // representative column's multiplicity.
            let n = (batch.nobj[nobj * batch.b + ev] as usize).min(batch.m);
            let selected = |slot: usize| match pred {
                Some(p) => truthy(eval_obj_expr(p, batch, ev, slot)),
                None => true,
            };
            match op {
                AggOp::Count => {
                    let mut c = 0u32;
                    for slot in 0..n {
                        if selected(slot) && truthy(eval_obj_expr(arg, batch, ev, slot)) {
                            c += 1;
                        }
                    }
                    c as f32
                }
                AggOp::Any => {
                    let mut any = false;
                    for slot in 0..n {
                        if selected(slot) && truthy(eval_obj_expr(arg, batch, ev, slot)) {
                            any = true;
                            break;
                        }
                    }
                    bool_f32(any)
                }
                AggOp::All => {
                    let mut all = true;
                    for slot in 0..n {
                        if selected(slot) && !truthy(eval_obj_expr(arg, batch, ev, slot)) {
                            all = false;
                            break;
                        }
                    }
                    bool_f32(all)
                }
                AggOp::Sum => {
                    let mut total = 0.0f32;
                    for slot in 0..n {
                        if selected(slot) {
                            total += eval_obj_expr(arg, batch, ev, slot);
                        }
                    }
                    total
                }
                AggOp::Max => {
                    let mut best = f32::NEG_INFINITY;
                    for slot in 0..n {
                        if selected(slot) {
                            best = best.max(eval_obj_expr(arg, batch, ev, slot));
                        }
                    }
                    best
                }
                AggOp::Min => {
                    let mut best = f32::INFINITY;
                    for slot in 0..n {
                        if selected(slot) {
                            best = best.min(eval_obj_expr(arg, batch, ev, slot));
                        }
                    }
                    best
                }
            }
        }
    }
}

/// Evaluate an object-shaped expression at object `slot` of event
/// `ev`. Event-shaped parts (scalars, literals, nested aggregations)
/// broadcast over slots.
fn eval_obj_expr(e: &CExpr, batch: &Batch, ev: usize, slot: usize) -> f32 {
    match e {
        CExpr::Num(v) => *v,
        CExpr::Scalar(s) => batch.scalars[s * batch.b + ev],
        CExpr::Jagged(c) => batch.cols[(c * batch.b + ev) * batch.m + slot],
        CExpr::Unary(op, x) => eval_unary(*op, eval_obj_expr(x, batch, ev, slot)),
        CExpr::Binary(op, a, b) => eval_binary(
            *op,
            eval_obj_expr(a, batch, ev, slot),
            eval_obj_expr(b, batch, ev, slot),
        ),
        // A nested aggregation is event-shaped (slot-invariant) but is
        // re-reduced per slot here: O(M²) for cuts like
        // `any(Muon_pt > max(Jet_pt))`. Acceptable at M ≤ 16; hoist
        // event-shaped subtrees before the slot loop if this ever
        // shows up hot.
        CExpr::Agg { .. } => eval_event_expr(e, batch, ev),
    }
}

/// Evaluate `program` over the batch, one event at a time.
pub fn eval(program: &CutProgram, batch: &Batch) -> MaskResult {
    let (b, m, n) = (batch.b, batch.m, batch.n_valid);
    let mut mask = vec![0.0f32; n];
    let mut stages = vec![vec![0.0f32; n]; 4];

    for ev in 0..n {
        // stage 1: preselection
        let mut pre = true;
        for cut in &program.scalar_cuts {
            let x = batch.scalars[cut.col * b + ev];
            pre &= cmp(x, cut.op, cut.abs, cut.value);
        }

        // stage 2: object groups
        let mut obj = true;
        for group in &program.groups {
            let mut count = 0u32;
            for slot in 0..m {
                if group.cut_range.is_empty() {
                    break;
                }
                let mut ok = true;
                for k in group.cut_range.clone() {
                    let cut = &program.obj_cuts[k];
                    let valid = (slot as f32) < batch.nobj[cut.col * b + ev];
                    let x = batch.cols[(cut.col * b + ev) * m + slot];
                    ok &= valid && cmp(x, cut.op, cut.abs, cut.value);
                }
                if ok {
                    count += 1;
                }
            }
            obj &= count >= group.min_count;
        }

        // stage 3: event-level — HT unit plus residual IR expressions
        // (anything beyond the kernel's fixed-function stages).
        let mut event_ok = true;
        if let Some(ht) = &program.ht {
            let nv = batch.nobj[ht.col * b + ev] as usize;
            let mut total = 0.0f32;
            for slot in 0..nv.min(m) {
                let x = batch.cols[(ht.col * b + ev) * m + slot];
                if x > ht.object_pt_min {
                    total += x;
                }
            }
            event_ok = total >= ht.min_ht;
        }
        for e in &program.exprs {
            event_ok &= truthy(eval_event_expr(e, batch, ev));
        }

        // stage 4: trigger OR
        let trig_ok = if program.triggers.is_empty() {
            true
        } else {
            program
                .triggers
                .iter()
                .any(|&s| batch.scalars[s * b + ev] > 0.5)
        };

        stages[0][ev] = pre as u8 as f32;
        stages[1][ev] = obj as u8 as f32;
        stages[2][ev] = event_ok as u8 as f32;
        stages[3][ev] = trig_ok as u8 as f32;
        mask[ev] = (pre && obj && event_ok && trig_ok) as u8 as f32;
    }

    MaskResult { mask, stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::{CutProgram, HtParam, ObjCutParam, ObjGroup, ScalarCutParam};
    use crate::runtime::Capacities;

    fn caps() -> Capacities {
        Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 }
    }

    #[test]
    fn empty_program_accepts_all() {
        let mut batch = Batch::zeroed(&caps(), 4, 2);
        batch.n_valid = 3;
        let out = eval(&CutProgram::default(), &batch);
        assert_eq!(out.mask, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn object_group_counting() {
        let mut program = CutProgram::default();
        program.obj_columns.push("pt".into());
        program.obj_cuts.push(ObjCutParam { col: 0, op: 0, abs: false, value: 25.0 });
        program.groups.push(ObjGroup {
            collection: "E".into(),
            cut_range: 0..1,
            min_count: 2,
        });
        let (b, m) = (4, 3);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 3;
        // ev0: [30, 26, 10] n=3 → 2 pass → ok
        batch.cols[0..3].copy_from_slice(&[30.0, 26.0, 10.0]);
        batch.nobj[0] = 3.0;
        // ev1: [30, 26] but n=1 → only 1 valid → fail
        batch.cols[m..m + 2].copy_from_slice(&[30.0, 26.0]);
        batch.nobj[1] = 1.0;
        // ev2: no objects → fail
        let out = eval(&program, &batch);
        assert_eq!(out.mask, vec![1.0, 0.0, 0.0]);
        assert_eq!(out.stages[1], vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn preselection_ht_trigger() {
        let mut program = CutProgram::default();
        program.scalar_columns = vec!["nE".into(), "HLT_X".into()];
        program.scalar_cuts.push(ScalarCutParam { col: 0, op: 1, abs: false, value: 1.0 });
        program.obj_columns.push("Jet_pt".into());
        program.ht = Some(HtParam { col: 0, object_pt_min: 30.0, min_ht: 100.0 });
        program.triggers.push(1);
        let (b, m) = (2, 4);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 2;
        // ev0: nE=1, jets [60, 50], trigger on → pass (HT 110)
        batch.scalars[0] = 1.0;
        batch.scalars[b] = 1.0;
        batch.cols[0..2].copy_from_slice(&[60.0, 50.0]);
        batch.nobj[0] = 2.0;
        // ev1: nE=1, jets [60, 20] (20 below pt_min), trigger off → fail both
        batch.scalars[1] = 1.0;
        batch.scalars[b + 1] = 0.0;
        batch.cols[m..m + 2].copy_from_slice(&[60.0, 20.0]);
        batch.nobj[1] = 2.0;
        let out = eval(&program, &batch);
        assert_eq!(out.stages[0], vec![1.0, 1.0]);
        assert_eq!(out.stages[2], vec![1.0, 0.0]);
        assert_eq!(out.stages[3], vec![1.0, 0.0]);
        assert_eq!(out.mask, vec![1.0, 0.0]);
    }

    #[test]
    fn abs_comparisons() {
        let mut program = CutProgram::default();
        program.obj_columns.push("eta".into());
        program.obj_cuts.push(ObjCutParam { col: 0, op: 2, abs: true, value: 2.4 });
        program.groups.push(ObjGroup { collection: "E".into(), cut_range: 0..1, min_count: 1 });
        let (b, m) = (3, 1);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 3;
        batch.cols[0] = -1.0; // |.| < 2.4 ok
        batch.cols[1] = -3.0; // fail
        batch.cols[2] = 2.4; // boundary: not <
        batch.nobj[0] = 1.0;
        batch.nobj[1] = 1.0;
        batch.nobj[2] = 1.0;
        let out = eval(&program, &batch);
        assert_eq!(out.mask, vec![1.0, 0.0, 0.0]);
    }

    // ---------------- residual IR expressions -------------------------

    /// Batch with one jagged column (2 slots/object cap) and one scalar
    /// column over 3 events: jagged [[40, 10], [5], []], scalar
    /// [120, 50, 120].
    fn ir_batch() -> Batch {
        let (b, m) = (3, 2);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 3;
        batch.cols[0..2].copy_from_slice(&[40.0, 10.0]);
        batch.nobj[0] = 2.0;
        batch.cols[m] = 5.0;
        batch.nobj[1] = 1.0;
        batch.nobj[2] = 0.0;
        batch.scalars[0..3].copy_from_slice(&[120.0, 50.0, 120.0]);
        batch
    }

    #[test]
    fn aggregation_semantics_over_jagged_slots() {
        let batch = ir_batch();
        let jag = || Box::new(CExpr::Jagged(0));
        let gt20 = || {
            Box::new(CExpr::Binary(
                BinOp::Gt,
                Box::new(CExpr::Jagged(0)),
                Box::new(CExpr::Num(20.0)),
            ))
        };
        let count =
            CExpr::Agg { op: AggOp::Count, nobj: 0, arg: gt20(), pred: None };
        assert_eq!(eval_event_expr(&count, &batch, 0), 1.0);
        assert_eq!(eval_event_expr(&count, &batch, 1), 0.0);
        assert_eq!(eval_event_expr(&count, &batch, 2), 0.0);

        let sum_all = CExpr::Agg { op: AggOp::Sum, nobj: 0, arg: jag(), pred: None };
        assert_eq!(eval_event_expr(&sum_all, &batch, 0), 50.0);
        assert_eq!(eval_event_expr(&sum_all, &batch, 2), 0.0);

        let sum_sel = CExpr::Agg { op: AggOp::Sum, nobj: 0, arg: jag(), pred: Some(gt20()) };
        assert_eq!(eval_event_expr(&sum_sel, &batch, 0), 40.0);
        assert_eq!(eval_event_expr(&sum_sel, &batch, 1), 0.0);

        let max = CExpr::Agg { op: AggOp::Max, nobj: 0, arg: jag(), pred: None };
        assert_eq!(eval_event_expr(&max, &batch, 0), 40.0);
        assert_eq!(eval_event_expr(&max, &batch, 1), 5.0);
        assert_eq!(eval_event_expr(&max, &batch, 2), f32::NEG_INFINITY);

        let min = CExpr::Agg { op: AggOp::Min, nobj: 0, arg: jag(), pred: None };
        assert_eq!(eval_event_expr(&min, &batch, 0), 10.0);
        assert_eq!(eval_event_expr(&min, &batch, 2), f32::INFINITY);

        let any = CExpr::Agg { op: AggOp::Any, nobj: 0, arg: gt20(), pred: None };
        assert_eq!(eval_event_expr(&any, &batch, 0), 1.0);
        assert_eq!(eval_event_expr(&any, &batch, 1), 0.0);
        assert_eq!(eval_event_expr(&any, &batch, 2), 0.0);

        let all = CExpr::Agg { op: AggOp::All, nobj: 0, arg: gt20(), pred: None };
        assert_eq!(eval_event_expr(&all, &batch, 0), 0.0);
        assert_eq!(eval_event_expr(&all, &batch, 2), 1.0); // vacuous
    }

    #[test]
    fn arithmetic_and_boolean_ops() {
        let batch = ir_batch();
        // (scalar / 2 + 10) > 60 → ev0: 70 > 60 true; ev1: 35 false.
        let e = CExpr::Binary(
            BinOp::Gt,
            Box::new(CExpr::Binary(
                BinOp::Add,
                Box::new(CExpr::Binary(
                    BinOp::Div,
                    Box::new(CExpr::Scalar(0)),
                    Box::new(CExpr::Num(2.0)),
                )),
                Box::new(CExpr::Num(10.0)),
            )),
            Box::new(CExpr::Num(60.0)),
        );
        assert_eq!(eval_event_expr(&e, &batch, 0), 1.0);
        assert_eq!(eval_event_expr(&e, &batch, 1), 0.0);

        let not = CExpr::Unary(UnaryOp::Not, Box::new(e.clone()));
        assert_eq!(eval_event_expr(&not, &batch, 0), 0.0);
        assert_eq!(eval_event_expr(&not, &batch, 1), 1.0);

        let neg_abs = CExpr::Unary(
            UnaryOp::Abs,
            Box::new(CExpr::Unary(UnaryOp::Neg, Box::new(CExpr::Scalar(0)))),
        );
        assert_eq!(eval_event_expr(&neg_abs, &batch, 1), 50.0);

        let minmax = CExpr::Binary(
            BinOp::Max,
            Box::new(CExpr::Num(7.0)),
            Box::new(CExpr::Binary(
                BinOp::Min,
                Box::new(CExpr::Scalar(0)),
                Box::new(CExpr::Num(3.0)),
            )),
        );
        assert_eq!(eval_event_expr(&minmax, &batch, 0), 7.0);
    }

    #[test]
    fn residual_exprs_fold_into_event_stage() {
        // mask = scalar > 100 || any(jagged > 20): ev0 both, ev1
        // neither, ev2 scalar only.
        let mut program = CutProgram::default();
        program.scalar_columns.push("MET_pt".into());
        program.obj_columns.push("Jet_pt".into());
        program.exprs.push(CExpr::Binary(
            BinOp::Or,
            Box::new(CExpr::Binary(
                BinOp::Gt,
                Box::new(CExpr::Scalar(0)),
                Box::new(CExpr::Num(100.0)),
            )),
            Box::new(CExpr::Agg {
                op: AggOp::Any,
                nobj: 0,
                arg: Box::new(CExpr::Binary(
                    BinOp::Gt,
                    Box::new(CExpr::Jagged(0)),
                    Box::new(CExpr::Num(20.0)),
                )),
                pred: None,
            }),
        ));
        let batch = ir_batch();
        let out = eval(&program, &batch);
        assert_eq!(out.mask, vec![1.0, 0.0, 1.0]);
        // Residuals are event-stage (index 2) decisions; other stages
        // stay open.
        assert_eq!(out.stages[2], vec![1.0, 0.0, 1.0]);
        assert_eq!(out.stages[0], vec![1.0, 1.0, 1.0]);
        assert_eq!(out.stages[3], vec![1.0, 1.0, 1.0]);
    }
}
