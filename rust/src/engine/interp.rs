//! Scalar cut-program interpreter: the per-event evaluation loop a
//! hand-written ROOT macro performs (and the baseline the paper's
//! "inefficient filtering logic" runs), plus the fallback for programs
//! exceeding the AOT kernel's capacity.
//!
//! Operates on the same padded [`Batch`] arrays as the kernel, with
//! identical semantics (op codes, group counting over the first `M`
//! objects, HT, trigger OR) — property tests in `rust/tests/` assert
//! bit-identical masks against the PJRT path.

use crate::query::plan::CutProgram;
use crate::runtime::{Batch, MaskResult};

#[inline]
fn cmp(x: f32, op: u8, abs: bool, value: f32) -> bool {
    let x = if abs { x.abs() } else { x };
    match op {
        0 => x > value,
        1 => x >= value,
        2 => x < value,
        3 => x <= value,
        4 => x == value,
        5 => x != value,
        _ => false,
    }
}

/// Evaluate `program` over the batch, one event at a time.
pub fn eval(program: &CutProgram, batch: &Batch) -> MaskResult {
    let (b, m, n) = (batch.b, batch.m, batch.n_valid);
    let mut mask = vec![0.0f32; n];
    let mut stages = vec![vec![0.0f32; n]; 4];

    for ev in 0..n {
        // stage 1: preselection
        let mut pre = true;
        for cut in &program.scalar_cuts {
            let x = batch.scalars[cut.col * b + ev];
            pre &= cmp(x, cut.op, cut.abs, cut.value);
        }

        // stage 2: object groups
        let mut obj = true;
        for group in &program.groups {
            let mut count = 0u32;
            for slot in 0..m {
                if group.cut_range.is_empty() {
                    break;
                }
                let mut ok = true;
                for k in group.cut_range.clone() {
                    let cut = &program.obj_cuts[k];
                    let valid = (slot as f32) < batch.nobj[cut.col * b + ev];
                    let x = batch.cols[(cut.col * b + ev) * m + slot];
                    ok &= valid && cmp(x, cut.op, cut.abs, cut.value);
                }
                if ok {
                    count += 1;
                }
            }
            obj &= count >= group.min_count;
        }

        // stage 3: HT
        let mut ht_ok = true;
        if let Some(ht) = &program.ht {
            let nv = batch.nobj[ht.col * b + ev] as usize;
            let mut total = 0.0f32;
            for slot in 0..nv.min(m) {
                let x = batch.cols[(ht.col * b + ev) * m + slot];
                if x > ht.object_pt_min {
                    total += x;
                }
            }
            ht_ok = total >= ht.min_ht;
        }

        // stage 4: trigger OR
        let trig_ok = if program.triggers.is_empty() {
            true
        } else {
            program
                .triggers
                .iter()
                .any(|&s| batch.scalars[s * b + ev] > 0.5)
        };

        stages[0][ev] = pre as u8 as f32;
        stages[1][ev] = obj as u8 as f32;
        stages[2][ev] = ht_ok as u8 as f32;
        stages[3][ev] = trig_ok as u8 as f32;
        mask[ev] = (pre && obj && ht_ok && trig_ok) as u8 as f32;
    }

    MaskResult { mask, stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::{CutProgram, HtParam, ObjCutParam, ObjGroup, ScalarCutParam};
    use crate::runtime::Capacities;

    fn caps() -> Capacities {
        Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 }
    }

    #[test]
    fn empty_program_accepts_all() {
        let mut batch = Batch::zeroed(&caps(), 4, 2);
        batch.n_valid = 3;
        let out = eval(&CutProgram::default(), &batch);
        assert_eq!(out.mask, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn object_group_counting() {
        let mut program = CutProgram::default();
        program.obj_columns.push("pt".into());
        program.obj_cuts.push(ObjCutParam { col: 0, op: 0, abs: false, value: 25.0 });
        program.groups.push(ObjGroup {
            collection: "E".into(),
            cut_range: 0..1,
            min_count: 2,
        });
        let (b, m) = (4, 3);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 3;
        // ev0: [30, 26, 10] n=3 → 2 pass → ok
        batch.cols[0..3].copy_from_slice(&[30.0, 26.0, 10.0]);
        batch.nobj[0] = 3.0;
        // ev1: [30, 26] but n=1 → only 1 valid → fail
        batch.cols[m..m + 2].copy_from_slice(&[30.0, 26.0]);
        batch.nobj[1] = 1.0;
        // ev2: no objects → fail
        let out = eval(&program, &batch);
        assert_eq!(out.mask, vec![1.0, 0.0, 0.0]);
        assert_eq!(out.stages[1], vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn preselection_ht_trigger() {
        let mut program = CutProgram::default();
        program.scalar_columns = vec!["nE".into(), "HLT_X".into()];
        program.scalar_cuts.push(ScalarCutParam { col: 0, op: 1, abs: false, value: 1.0 });
        program.obj_columns.push("Jet_pt".into());
        program.ht = Some(HtParam { col: 0, object_pt_min: 30.0, min_ht: 100.0 });
        program.triggers.push(1);
        let (b, m) = (2, 4);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 2;
        // ev0: nE=1, jets [60, 50], trigger on → pass (HT 110)
        batch.scalars[0] = 1.0;
        batch.scalars[b] = 1.0;
        batch.cols[0..2].copy_from_slice(&[60.0, 50.0]);
        batch.nobj[0] = 2.0;
        // ev1: nE=1, jets [60, 20] (20 below pt_min), trigger off → fail both
        batch.scalars[1] = 1.0;
        batch.scalars[b + 1] = 0.0;
        batch.cols[m..m + 2].copy_from_slice(&[60.0, 20.0]);
        batch.nobj[1] = 2.0;
        let out = eval(&program, &batch);
        assert_eq!(out.stages[0], vec![1.0, 1.0]);
        assert_eq!(out.stages[2], vec![1.0, 0.0]);
        assert_eq!(out.stages[3], vec![1.0, 0.0]);
        assert_eq!(out.mask, vec![1.0, 0.0]);
    }

    #[test]
    fn abs_comparisons() {
        let mut program = CutProgram::default();
        program.obj_columns.push("eta".into());
        program.obj_cuts.push(ObjCutParam { col: 0, op: 2, abs: true, value: 2.4 });
        program.groups.push(ObjGroup { collection: "E".into(), cut_range: 0..1, min_count: 1 });
        let (b, m) = (3, 1);
        let mut batch = Batch::zeroed(&caps(), b, m);
        batch.n_valid = 3;
        batch.cols[0] = -1.0; // |.| < 2.4 ok
        batch.cols[1] = -3.0; // fail
        batch.cols[2] = 2.4; // boundary: not <
        batch.nobj[0] = 1.0;
        batch.nobj[1] = 1.0;
        batch.nobj[2] = 1.0;
        let out = eval(&program, &batch);
        assert_eq!(out.mask, vec![1.0, 0.0, 0.0]);
    }
}
