//! Fused cut kernels: the execution half of profile-guided fusion
//! (planning lives in [`crate::query::fuse`]).
//!
//! [`eval_fused`] walks a [`FusePlan`]'s straight-line steps over a
//! word-packed alive set. Fused scalar chains evaluate 2–3 compares
//! per 64-event word in one pass — fully-dead words are skipped by a
//! single `u64` test, fully-alive words take a branch-free
//! `LANES`-wide passmask path, and ragged words fall back to
//! per-set-bit evaluation. Count and sum kernels run branchless over
//! the valid slot prefix. Conjuncts the planner left unfused run
//! through the interpreter's own `eval_conjunct` sweep.
//!
//! # Bit-identity contract
//!
//! The fused evaluator is a drop-in for
//! [`eval_adaptive`](crate::engine::interp::eval_adaptive) under the
//! same conjunct order:
//!
//! * **Masks and funnels**: stage rows start at `1.0` and a killing
//!   conjunct zeroes its own row — the cumulative funnel product and
//!   the final mask are bit-identical to the adaptive evaluator (and
//!   therefore to the scalar oracle) for every order.
//! * **Tallies**: per-conjunct `visited`/`passed` match
//!   `eval_adaptive` exactly. Inside a chain, link *k*'s visited count
//!   is the number of events that survived links *1..k-1* — summed
//!   over words this equals the adaptive evaluator's whole-batch
//!   sweep, including its `n_alive == 0` early break (a starved link
//!   tallies `+0/+0`, indistinguishable from being skipped). Only
//!   `cost_us` may differ (a chain's wall-clock is split evenly across
//!   its links); it is reporting-only and never asserted.
//! * **Verdicts**: the branchless count kernel counts the full valid
//!   prefix where the interpreter early-exits at `min_count` — the
//!   `count >= min_count` verdict is unchanged. The sum kernel adds
//!   `0.0` for excluded slots instead of branching; starting from
//!   `0.0` the running total is never `-0.0`, so every intermediate
//!   sum is bit-identical.

use crate::query::fuse::{ChainLink, FusePlan, FuseStep, FusedKernel, MAX_CHAIN};
use crate::query::plan::CutProgram;
use crate::query::stats::{Conjunct, ConjunctStats};
use crate::runtime::{Batch, MaskResult};

use super::interp::{cmp, eval_conjunct, valid_slots, LANES};

/// The alive set in two synchronized representations: per-event bools
/// (what the interpreter fallback mutates) and 64-event words (what
/// the fused sweeps test and update). Bits past `n` are permanently
/// zero.
struct AliveSet {
    bools: Vec<bool>,
    words: Vec<u64>,
    n_alive: usize,
}

impl AliveSet {
    fn new(n: usize) -> AliveSet {
        let nw = n.div_ceil(64);
        let mut words = vec![!0u64; nw];
        if n % 64 != 0 {
            words[nw - 1] = (1u64 << (n % 64)) - 1;
        }
        AliveSet { bools: vec![true; n], words, n_alive: n }
    }

    /// Rebuild the word mirror after the bools were mutated behind our
    /// back (by an interpreter-fallback conjunct).
    fn resync(&mut self) {
        let mut n_alive = 0usize;
        for (w, word) in self.words.iter_mut().enumerate() {
            let mut bits = 0u64;
            let base = w * 64;
            let lim = (self.bools.len() - base).min(64);
            for i in 0..lim {
                bits |= (self.bools[base + i] as u64) << i;
            }
            *word = bits;
            n_alive += bits.count_ones() as usize;
        }
        self.n_alive = n_alive;
    }
}

/// Branch-free passmask of one compare over exactly 64 column values:
/// bit *i* set iff `cmp(col[i], ..)`. The opcode dispatch is hoisted
/// out of the sweep and the body runs in [`LANES`]-wide chunks, like
/// [`sweep_cmp_into`](crate::engine::interp::sweep_cmp_into).
#[inline(always)]
fn passmask64(col: &[f32], op: u8, abs: bool, value: f32) -> u64 {
    #[inline(always)]
    fn mask(col: &[f32], pred: impl Fn(f32) -> bool) -> u64 {
        debug_assert_eq!(col.len(), 64);
        let mut pm = 0u64;
        for (c, chunk) in col.chunks_exact(LANES).enumerate() {
            let mut bits = 0u64;
            for i in 0..LANES {
                bits |= (pred(chunk[i]) as u64) << i;
            }
            pm |= bits << (c * LANES);
        }
        pm
    }
    match (op, abs) {
        (0, false) => mask(col, |x| x > value),
        (1, false) => mask(col, |x| x >= value),
        (2, false) => mask(col, |x| x < value),
        (3, false) => mask(col, |x| x <= value),
        (4, false) => mask(col, |x| x == value),
        (5, false) => mask(col, |x| x != value),
        _ => mask(col, |x| cmp(x, op, abs, value)),
    }
}

/// Run one fused scalar chain (1–[`MAX_CHAIN`] compares) over the
/// alive set in a single word-wise pass.
fn run_chain(
    program: &CutProgram,
    batch: &Batch,
    links: &[ChainLink],
    conjuncts: &[Conjunct],
    stages: &mut [Vec<f32>],
    alive: &mut AliveSet,
    stats: &mut [ConjunctStats],
) {
    debug_assert!(!links.is_empty() && links.len() <= MAX_CHAIN);
    let started = std::time::Instant::now();
    let (b, n) = (batch.b, batch.n_valid);
    let mut visited = [0u64; MAX_CHAIN];
    let mut passed = [0u64; MAX_CHAIN];

    for w in 0..alive.words.len() {
        let word = alive.words[w];
        if word == 0 {
            continue;
        }
        let base = w * 64;
        if word == !0u64 && base + 64 <= n {
            // Fully-alive word: branch-free passmask per link, kills
            // applied wholesale from the surviving-bit delta.
            let mut sv = word;
            for (li, link) in links.iter().enumerate() {
                if sv == 0 {
                    break;
                }
                let cut = &program.scalar_cuts[link.cut];
                let start = cut.col * b + base;
                let pm =
                    passmask64(&batch.scalars[start..start + 64], cut.op, cut.abs, cut.value);
                visited[li] += sv.count_ones() as u64;
                let killed = sv & !pm;
                sv &= pm;
                passed[li] += sv.count_ones() as u64;
                if killed != 0 {
                    let stage = &mut stages[conjuncts[link.ci].stage as usize];
                    let mut bits = killed;
                    while bits != 0 {
                        let i = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        stage[base + i] = 0.0;
                        alive.bools[base + i] = false;
                    }
                    alive.n_alive -= killed.count_ones() as usize;
                }
            }
            alive.words[w] = sv;
        } else {
            // Ragged word (holes or the tail past n): per-set-bit.
            let mut bits = word;
            let mut new_word = word;
            while bits != 0 {
                let i = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let ev = base + i;
                for (li, link) in links.iter().enumerate() {
                    visited[li] += 1;
                    let cut = &program.scalar_cuts[link.cut];
                    let x = batch.scalars[cut.col * b + ev];
                    if cmp(x, cut.op, cut.abs, cut.value) {
                        passed[li] += 1;
                    } else {
                        stages[conjuncts[link.ci].stage as usize][ev] = 0.0;
                        alive.bools[ev] = false;
                        new_word &= !(1u64 << i);
                        alive.n_alive -= 1;
                        break;
                    }
                }
            }
            alive.words[w] = new_word;
        }
    }

    // One sweep's wall-clock, split evenly across the fused links
    // (cost_us is reporting-only; visited/passed carry the semantics).
    let per_link = started.elapsed().as_micros() as u64 / links.len() as u64;
    for (li, link) in links.iter().enumerate() {
        let st = &mut stats[link.ci];
        st.visited += visited[li];
        st.passed += passed[li];
        st.cost_us += per_link;
    }
}

/// Run a single-conjunct kernel (`count` or `sum`) over the alive set:
/// the per-event verdict closure returns `true` to keep the event.
fn run_event_kernel(
    ci: usize,
    conjuncts: &[Conjunct],
    stages: &mut [Vec<f32>],
    alive: &mut AliveSet,
    stats: &mut [ConjunctStats],
    verdict: impl Fn(usize) -> bool,
) {
    let started = std::time::Instant::now();
    let visited = alive.n_alive as u64;
    let stage = &mut stages[conjuncts[ci].stage as usize];
    for w in 0..alive.words.len() {
        let word = alive.words[w];
        if word == 0 {
            continue;
        }
        let base = w * 64;
        let mut bits = word;
        let mut new_word = word;
        while bits != 0 {
            let i = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let ev = base + i;
            if !verdict(ev) {
                stage[ev] = 0.0;
                alive.bools[ev] = false;
                new_word &= !(1u64 << i);
                alive.n_alive -= 1;
            }
        }
        alive.words[w] = new_word;
    }
    let st = &mut stats[ci];
    st.visited += visited;
    st.passed += alive.n_alive as u64;
    st.cost_us += started.elapsed().as_micros() as u64;
}

/// Evaluate `program` through `plan`'s fused steps, a drop-in for
/// [`eval_adaptive`](crate::engine::interp::eval_adaptive) under the
/// same order (see the module docs for the bit-identity contract).
/// Tallies accumulate into `stats`, parallel to `conjuncts`.
pub fn eval_fused(
    program: &CutProgram,
    batch: &Batch,
    conjuncts: &[Conjunct],
    plan: &FusePlan,
    stats: &mut [ConjunctStats],
) -> MaskResult {
    debug_assert_eq!(conjuncts.len(), stats.len());
    let (b, m, n) = (batch.b, batch.m, batch.n_valid);
    let mut stages = vec![vec![1.0f32; n]; 4];
    let mut alive = AliveSet::new(n);

    for step in &plan.steps {
        if alive.n_alive == 0 {
            break;
        }
        match step {
            FuseStep::Interp(ci) => {
                let conj = &conjuncts[*ci];
                let started = std::time::Instant::now();
                let visited = alive.n_alive as u64;
                let mut n_alive = alive.n_alive;
                eval_conjunct(
                    program,
                    batch,
                    conj,
                    &mut stages[conj.stage as usize],
                    &mut alive.bools,
                    &mut n_alive,
                );
                alive.resync();
                debug_assert_eq!(alive.n_alive, n_alive);
                let st = &mut stats[*ci];
                st.visited += visited;
                st.passed += n_alive as u64;
                st.cost_us += started.elapsed().as_micros() as u64;
            }
            FuseStep::Kernel(FusedKernel::Chain(links)) => {
                run_chain(program, batch, links, conjuncts, &mut stages, &mut alive, stats);
            }
            FuseStep::Kernel(FusedKernel::CountGe { ci, group }) => {
                let g = &program.groups[*group];
                let cut = &program.obj_cuts[g.cut_range.start];
                let min_count = g.min_count;
                run_event_kernel(*ci, conjuncts, &mut stages, &mut alive, stats, |ev| {
                    let bound = valid_slots(batch.nobj[cut.col * b + ev], m);
                    let at = (cut.col * b + ev) * m;
                    let row = &batch.cols[at..at + bound];
                    // Branchless count over the valid prefix in
                    // LANES-wide chunks — no early exit; the
                    // `>= min_count` verdict is unchanged.
                    let mut count = 0u32;
                    let main = bound - bound % LANES;
                    for chunk in row[..main].chunks_exact(LANES) {
                        let mut c = 0u32;
                        for i in 0..LANES {
                            c += cmp(chunk[i], cut.op, cut.abs, cut.value) as u32;
                        }
                        count += c;
                    }
                    for &x in &row[main..] {
                        count += cmp(x, cut.op, cut.abs, cut.value) as u32;
                    }
                    count >= min_count
                });
            }
            FuseStep::Kernel(FusedKernel::SumGe { ci }) => {
                let ht = program.ht.as_ref().expect("sum kernel without an HT unit");
                run_event_kernel(*ci, conjuncts, &mut stages, &mut alive, stats, |ev| {
                    let nv = (batch.nobj[ht.col * b + ev] as usize).min(m);
                    let at = (ht.col * b + ev) * m;
                    let row = &batch.cols[at..at + nv];
                    // Branchless select-accumulate: excluded slots add
                    // 0.0, which preserves every intermediate total
                    // bit-for-bit (the total starts at 0.0 and can
                    // never be -0.0).
                    let mut total = 0.0f32;
                    for &x in row {
                        total += if x > ht.object_pt_min { x } else { 0.0 };
                    }
                    total >= ht.min_ht
                });
            }
        }
    }

    let mut mask = vec![0.0f32; n];
    for ev in 0..n {
        if alive.bools[ev] {
            mask[ev] = 1.0;
        }
    }
    MaskResult { mask, stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::fuse::fuse_plan;
    use crate::query::plan::{HtParam, ObjCutParam, ObjGroup, ScalarCutParam};
    use crate::query::stats::conjuncts_of;
    use crate::runtime::Capacities;

    fn caps() -> Capacities {
        Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 }
    }

    /// 3 scalar cuts + single-cut group + HT over a 200-event batch
    /// with pseudo-random values: every kernel shape engages.
    fn fixture() -> (CutProgram, Batch) {
        let mut p = CutProgram::default();
        p.scalar_columns = vec!["met".into(), "eta".into()];
        p.obj_columns = vec!["el_pt".into(), "jet_pt".into()];
        p.scalar_cuts.push(ScalarCutParam { col: 0, op: 0, abs: false, value: 20.0 });
        p.scalar_cuts.push(ScalarCutParam { col: 1, op: 1, abs: false, value: -1.0 });
        p.scalar_cuts.push(ScalarCutParam { col: 1, op: 2, abs: false, value: 1.5 });
        p.obj_cuts.push(ObjCutParam { col: 0, op: 0, abs: false, value: 15.0 });
        p.groups.push(ObjGroup {
            collection: "Electron".into(),
            cut_range: 0..1,
            min_count: 1,
        });
        p.ht = Some(HtParam { col: 1, object_pt_min: 10.0, min_ht: 60.0 });

        let n = 200usize;
        let mut batch = Batch::zeroed(&caps(), n, 4);
        batch.n_valid = n;
        let b = batch.b;
        let m = batch.m;
        let mut state = 0x1234_5678u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f32) / (u32::MAX >> 1) as f32
        };
        for ev in 0..n {
            batch.scalars[ev] = next() * 80.0;
            batch.scalars[b + ev] = next() * 4.0 - 2.0;
            for col in 0..2 {
                let nv = (next() * 4.9) as usize;
                batch.nobj[col * b + ev] = nv as f32;
                for slot in 0..nv.min(m) {
                    batch.cols[(col * b + ev) * m + slot] = next() * 50.0;
                }
            }
        }
        (p, batch)
    }

    #[test]
    fn fused_matches_adaptive_bit_for_bit() {
        let (p, batch) = fixture();
        let cs = conjuncts_of(&p);
        let order: Vec<usize> = (0..cs.len()).collect();
        let prior = vec![ConjunctStats::default(); cs.len()];
        let plan = fuse_plan(&p, &cs, &order, &prior);
        assert!(plan.any_fused(), "fixture must exercise fused kernels");

        let mut stats_a = vec![ConjunctStats::default(); cs.len()];
        let adaptive =
            super::super::interp::eval_adaptive(&p, &batch, &cs, &order, &mut stats_a);
        let mut stats_f = vec![ConjunctStats::default(); cs.len()];
        let fused = eval_fused(&p, &batch, &cs, &plan, &mut stats_f);

        assert_eq!(fused.mask, adaptive.mask);
        assert_eq!(fused.stages, adaptive.stages);
        for (ci, (a, f)) in stats_a.iter().zip(&stats_f).enumerate() {
            assert_eq!(a.visited, f.visited, "conjunct {ci} visited");
            assert_eq!(a.passed, f.passed, "conjunct {ci} passed");
        }
    }

    #[test]
    fn fused_matches_adaptive_under_permuted_orders() {
        let (p, batch) = fixture();
        let cs = conjuncts_of(&p);
        let prior = vec![ConjunctStats::default(); cs.len()];
        // The HT-first order exercises an event kernel ahead of the
        // scalar chain; the reversed order exercises ragged words.
        for order in [
            vec![4, 0, 1, 2, 3],
            vec![3, 0, 1, 2, 4],
            vec![4, 3, 2, 1, 0],
            vec![1, 2, 0, 3, 4],
        ] {
            let plan = fuse_plan(&p, &cs, &order, &prior);
            let mut stats_a = vec![ConjunctStats::default(); cs.len()];
            let adaptive =
                super::super::interp::eval_adaptive(&p, &batch, &cs, &order, &mut stats_a);
            let mut stats_f = vec![ConjunctStats::default(); cs.len()];
            let fused = eval_fused(&p, &batch, &cs, &plan, &mut stats_f);
            assert_eq!(fused.mask, adaptive.mask, "order {order:?}");
            assert_eq!(fused.stages, adaptive.stages, "order {order:?}");
            for (ci, (a, f)) in stats_a.iter().zip(&stats_f).enumerate() {
                assert_eq!(a.visited, f.visited, "order {order:?} conjunct {ci}");
                assert_eq!(a.passed, f.passed, "order {order:?} conjunct {ci}");
            }
        }
    }

    #[test]
    fn alive_set_words_mirror_bools() {
        let mut a = AliveSet::new(70);
        assert_eq!(a.words.len(), 2);
        assert_eq!(a.words[1], (1u64 << 6) - 1);
        a.bools[0] = false;
        a.bools[65] = false;
        a.resync();
        assert_eq!(a.n_alive, 68);
        assert_eq!(a.words[0], !1u64);
        assert_eq!(a.words[1], ((1u64 << 6) - 1) & !(1 << 1));

        // Empty batch: no words, nothing alive.
        let e = AliveSet::new(0);
        assert_eq!(e.words.len(), 0);
        assert_eq!(e.n_alive, 0);
    }

    #[test]
    fn passmask_matches_scalar_cmp_for_all_ops() {
        let col: Vec<f32> =
            (0..64).map(|i| (i as f32) - 31.5 + if i % 7 == 0 { 0.5 } else { 0.0 }).collect();
        for op in 0u8..6 {
            for abs in [false, true] {
                let pm = passmask64(&col, op, abs, 3.0);
                for (i, &x) in col.iter().enumerate() {
                    assert_eq!(
                        pm >> i & 1 == 1,
                        cmp(x, op, abs, 3.0),
                        "op={op} abs={abs} i={i}"
                    );
                }
            }
        }
    }
}
