//! The pluggable filter pipeline: SkimROOT's execution stages as
//! netfilter-style hooks.
//!
//! The engine used to inline its phases (criteria fetch → decompress →
//! deserialize/batch → cut-eval → phase-2 selective fetch → output
//! write) in one monolithic `run`. They are now **built-in stages** of
//! a [`Pipeline`], and users can register custom [`FilterStage`]s
//! around them — per-branch byte accounting, sampling, extra vetoes —
//! without forking the engine.
//!
//! Two hook points, mirroring the engine's execution granularity:
//!
//! * [`Hook::Group`] — runs once per *cluster group* (the batching unit
//!   that packs consecutive event clusters up to the kernel's batch
//!   capacity). Built-ins, in `after`-DAG order:
//!   `fetch` → `decompress` → `deserialize` → `eval`.
//! * [`Hook::Job`] — runs once after all groups. Built-ins:
//!   `phase2` (selective output-only fetch for passing events) →
//!   `output` (write the filtered file).
//!
//! Stage ordering is name-based with `after` dependencies (a DAG, not
//! numeric priorities); ties are broken by registration order.
//! Verdict semantics follow netfilter: [`Verdict::Continue`] means "no
//! objection", [`Verdict::Drop`] is a veto — at the Group hook it
//! rejects every event of the current group (remaining group stages are
//! skipped), at the Job hook it skips the remaining job stages, which
//! aborts the job if the `output` stage never runs.
//!
//! A custom stage observes and mutates the in-flight [`StageCtx`]: the
//! current [`GroupState`] (fetched frames, decompressed bytes, decoded
//! baskets, per-cluster pass lists), the plan, and the funnel. A stage
//! registered `after: ["eval"]` that thins `group.passes` implements
//! sampling; one registered `after: ["decompress"]` that sums
//! `group.raw` byte lengths implements per-branch byte accounting.
//!
//! # Hot-path execution model (since the parallel-engine refactor)
//!
//! * **Branch interning** — branch names are resolved to dense
//!   [`crate::query::plan::BranchId`]s at plan time; every per-cluster
//!   store in [`GroupState`] is a plain `Vec` indexed by phase-1 slot
//!   (see [`StageCtx::phase1_branches`]), so no string is hashed or
//!   cloned per basket.
//! * **Real threading** — `decompress` and `deserialize` fan the
//!   group's (cluster × branch) baskets across
//!   [`EngineOpts::workers`] scoped threads, and batch assembly fans
//!   per-column fills the same way. Each worker wall-clocks its own
//!   [`Timeline`]; afterwards the *critical* (slowest) worker is
//!   folded into the job timeline via [`Timeline::merge_from`] — the
//!   same max-over-workers attribution the DPU shard fan-out uses, so
//!   parallel hardware shows up as latency = max, not sum. The one
//!   exception is the DPU's hardware decompression engine
//!   ([`DecompMode::HwEngine`]): a single serial device drains all
//!   workers' frames back-to-back, so *every* worker's engine time is
//!   folded (sum), keeping the Figure 5a calibration independent of
//!   thread count. `parallelism = 1` takes the legacy in-line path and
//!   reproduces its timelines exactly.
//! * **Columnar evaluation** — the interpreter fallback runs
//!   [`super::interp::eval_columnar`], which sweeps whole batch
//!   columns per stage and stops once the cumulative funnel is dead;
//!   masks and funnels are bit-identical to the retained scalar
//!   oracle ([`super::interp::eval`]).

use super::{DecompMode, EngineOpts, SkimResult};
use crate::metrics::{Node, Stage, Timeline};
use crate::query::fuse::{fuse_plan, FusePlan};
use crate::query::plan::{
    SkimPlan, KERNEL_MAX_GROUPS, KERNEL_MAX_OBJ_CUTS, KERNEL_MAX_SCALAR_CUTS,
};
use crate::query::stats::{conjuncts_of, rank_order, Conjunct, ConjunctStats};
use crate::query::SkimQuery;
use crate::runtime::{Batch, Capacities, CutParams, MaskResult, SkimRuntime, Variant};
use crate::serve::cache::{BasketCache, BasketKey};
use crate::troot::{
    basket as basket_codec, BasketInfo, BranchKind, BranchMeta, ColumnData, ColumnValues,
    DecodedBasket, FileMeta, ReadAt, SharedBytes, TRootReader,
};
use crate::xrootd::TTreeCache;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Netfilter-style stage outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// "No objection": continue with the next stage.
    Continue,
    /// Veto. At [`Hook::Group`] the current group's events are all
    /// rejected and its remaining stages are skipped; at [`Hook::Job`]
    /// the remaining job stages are skipped.
    Drop,
}

/// Where a stage is attached.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Hook {
    /// Once per cluster group (the engine's batching unit).
    Group,
    /// Once per job, after every group has been processed.
    Job,
}

/// One pipeline stage. Implementations must be `Send + Sync` so the
/// same engine can be shared across worker threads.
pub trait FilterStage: Send + Sync {
    /// Unique (per hook) stage name used for `after` ordering.
    fn name(&self) -> &str;
    /// Run over the in-flight job/group state.
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict>;
}

/// A registered stage plus its ordering constraints.
pub(crate) struct Registration {
    pub(crate) name: String,
    pub(crate) after: Vec<String>,
    pub(crate) stage: Arc<dyn FilterStage>,
}

/// A portable stage registration (hook + ordering + stage), used to
/// carry custom stages through [`crate::coordinator::Coordinator`] /
/// [`crate::SkimJob`] into every engine a deployment spins up.
#[derive(Clone)]
pub struct StageReg {
    /// Which hook the stage attaches to.
    pub hook: Hook,
    /// Names of stages this one must run after.
    pub after: Vec<String>,
    /// The stage itself.
    pub stage: Arc<dyn FilterStage>,
}

impl StageReg {
    /// A portable registration of `stage` at `hook`, ordered after the
    /// named stages.
    pub fn new(hook: Hook, after: &[&str], stage: Arc<dyn FilterStage>) -> Self {
        StageReg { hook, after: after.iter().map(|s| s.to_string()).collect(), stage }
    }
}

/// The stage registry for one engine: built-ins plus user stages.
pub struct Pipeline {
    group: Vec<Registration>,
    job: Vec<Registration>,
}

impl Pipeline {
    /// The standard SkimROOT pipeline (the refactored engine phases).
    pub fn builtin() -> Pipeline {
        let mut p = Pipeline::empty();
        p.register(Hook::Group, &[], Arc::new(FetchStage)).expect("builtin");
        p.register(Hook::Group, &["fetch"], Arc::new(DecompressStage)).expect("builtin");
        p.register(Hook::Group, &["decompress"], Arc::new(DeserializeStage)).expect("builtin");
        p.register(Hook::Group, &["deserialize"], Arc::new(EvalStage)).expect("builtin");
        p.register(Hook::Job, &[], Arc::new(Phase2Stage)).expect("builtin");
        p.register(Hook::Job, &["phase2"], Arc::new(OutputStage)).expect("builtin");
        p
    }

    /// A pipeline with no stages at all (build-your-own; mostly tests).
    pub fn empty() -> Pipeline {
        Pipeline { group: Vec::new(), job: Vec::new() }
    }

    /// Register `stage` at `hook`, ordered after the named stages.
    /// Names must be unique per hook; `after` references are resolved
    /// (and cycles detected) when the pipeline is ordered at job start,
    /// so forward references between custom stages are allowed.
    pub fn register(
        &mut self,
        hook: Hook,
        after: &[&str],
        stage: Arc<dyn FilterStage>,
    ) -> Result<()> {
        let name = stage.name().to_string();
        if name.is_empty() {
            return Err(Error::Config("stage name must not be empty".into()));
        }
        let regs = match hook {
            Hook::Group => &mut self.group,
            Hook::Job => &mut self.job,
        };
        if regs.iter().any(|r| r.name == name) {
            return Err(Error::Config(format!(
                "duplicate stage '{name}' at {hook:?} hook"
            )));
        }
        regs.push(Registration {
            name,
            after: after.iter().map(|s| s.to_string()).collect(),
            stage,
        });
        Ok(())
    }

    /// Registered stage names at `hook`, in registration order.
    pub fn names(&self, hook: Hook) -> Vec<String> {
        let regs = match hook {
            Hook::Group => &self.group,
            Hook::Job => &self.job,
        };
        regs.iter().map(|r| r.name.clone()).collect()
    }

    /// Execution order at `hook` (topological over `after`, ties broken
    /// by registration order). Errors on unknown `after` names and on
    /// dependency cycles.
    pub fn order(&self, hook: Hook) -> Result<Vec<String>> {
        Ok(self.ordered(hook)?.iter().map(|r| r.name.clone()).collect())
    }

    /// Validate both hooks' DAGs without running anything.
    pub fn validate(&self) -> Result<()> {
        self.ordered(Hook::Group)?;
        self.ordered(Hook::Job)?;
        Ok(())
    }

    pub(crate) fn ordered(&self, hook: Hook) -> Result<Vec<&Registration>> {
        let regs = match hook {
            Hook::Group => &self.group,
            Hook::Job => &self.job,
        };
        let index: HashMap<&str, usize> = regs
            .iter()
            .enumerate()
            .map(|(i, r)| (r.name.as_str(), i))
            .collect();
        let mut indegree = vec![0usize; regs.len()];
        let mut edges: Vec<Vec<usize>> = vec![Vec::new(); regs.len()];
        for (i, r) in regs.iter().enumerate() {
            for a in &r.after {
                let &j = index.get(a.as_str()).ok_or_else(|| {
                    Error::Config(format!(
                        "stage '{}' is ordered after '{}', which is not registered at the {hook:?} hook",
                        r.name, a
                    ))
                })?;
                edges[j].push(i);
                indegree[i] += 1;
            }
        }
        let mut ready: Vec<usize> =
            (0..regs.len()).filter(|&i| indegree[i] == 0).collect();
        let mut out = Vec::with_capacity(regs.len());
        while !ready.is_empty() {
            ready.sort_unstable();
            let i = ready.remove(0);
            out.push(i);
            for &k in &edges[i] {
                indegree[k] -= 1;
                if indegree[k] == 0 {
                    ready.push(k);
                }
            }
        }
        if out.len() != regs.len() {
            let stuck: Vec<&str> = regs
                .iter()
                .enumerate()
                .filter(|(i, _)| !out.contains(i))
                .map(|(_, r)| r.name.as_str())
                .collect();
            return Err(Error::Config(format!(
                "stage dependency cycle at {hook:?} hook involving: {}",
                stuck.join(", ")
            )));
        }
        Ok(out.into_iter().map(|i| &regs[i]).collect())
    }
}

/// Per-group scratch state flowing through the [`Hook::Group`] stages.
///
/// All per-cluster basket stores are `Vec`s indexed by **phase-1
/// slot** (the fetch order, [`StageCtx::phase1_branches`]); criteria
/// branches occupy the leading slots, positioned by their plan-time
/// [`crate::query::plan::BranchId`]s. No name lookup happens per
/// basket on the hot path — resolve names through
/// [`StageCtx::phase1_branches`] when observing.
pub struct GroupState {
    /// `(cluster index, first event id, event count)` per cluster in
    /// this group. Event ids are global; counts respect any
    /// [`EngineOpts::event_range`] restriction at range boundaries.
    pub clusters: Vec<(usize, u64, usize)>,
    /// Per cluster: phase-1 slot → compressed basket frame (after the
    /// built-in `fetch` stage). **Drained by `decompress`** — custom
    /// stages cannot order between the built-ins, so nothing observes
    /// frames; per-branch compressed sizes survive in each entry's
    /// [`BasketInfo`].
    pub frames: Vec<Vec<(Vec<u8>, BasketInfo)>>,
    /// Per cluster: phase-1 slot → raw decompressed bytes (after
    /// `decompress`). Retained until the group commits so custom
    /// stages can audit them — the memory cost of the observability
    /// API (≈ one group's decompressed working set). The buffers are
    /// [`SharedBytes`]: `deserialize` hands zero-copy f32/i32 views
    /// into them to the decoded baskets, and cache hits share the
    /// cache's buffer outright instead of copying it.
    pub raw: Vec<Vec<(SharedBytes, BasketInfo)>>,
    /// Per cluster: phase-1 slot → typed decoded basket (after
    /// `deserialize`).
    pub decoded: Vec<Vec<DecodedBasket>>,
    /// Passing event ids per cluster in this group (after `eval`).
    /// Custom stages may thin these lists (sampling, extra vetoes);
    /// whatever remains when the group commits is gathered into the
    /// output.
    pub passes: Vec<Vec<u64>>,
    /// Compressed bytes fetched for this group.
    pub fetched_bytes: u64,
}

#[derive(Default)]
pub(crate) struct FetchCounters {
    pub(crate) baskets: u64,
    pub(crate) bytes: u64,
}

/// Accumulates one output branch's values for passing events.
pub(crate) struct OutputAcc {
    pub(crate) desc: crate::troot::BranchDesc,
    offsets: Vec<u32>,
    values: ColumnValues,
}

impl OutputAcc {
    fn new(desc: crate::troot::BranchDesc) -> Self {
        let values = ColumnValues::empty(desc.dtype);
        OutputAcc { desc, offsets: vec![0], values }
    }

    /// Gather from an already-decoded basket (cheap copy).
    fn push_event(&mut self, basket: &DecodedBasket, ev: u64) {
        match self.desc.kind {
            BranchKind::Scalar => {
                let i = (ev - basket.first_event) as usize;
                self.values.push_from(&basket.values, i);
            }
            BranchKind::Jagged => {
                let r = basket.jagged_range(ev);
                self.values.extend_from_range(&basket.values, r);
                self.offsets.push(self.values.len() as u32);
            }
        }
    }

    /// Selectively deserialize one event straight from the raw basket
    /// payload (the per-event `GetEntry` path used by phase 2).
    /// Returns the number of raw bytes materialized.
    fn push_event_raw(&mut self, raw: &[u8], info: &BasketInfo, ev: u64) -> Result<usize> {
        let local = (ev - info.first_event) as usize;
        let before = self.values.len();
        basket_codec::append_event(
            &self.desc,
            raw,
            info.n_events as usize,
            local,
            &mut self.offsets,
            &mut self.values,
        )?;
        Ok((self.values.len() - before) * self.desc.dtype.size())
    }

    fn finish(self) -> ColumnData {
        match self.desc.kind {
            BranchKind::Scalar => ColumnData::Scalar(self.values),
            BranchKind::Jagged => {
                ColumnData::Jagged { offsets: self.offsets, values: self.values }
            }
        }
    }
}

/// Attribute `dt` seconds of decompression per [`DecompMode`]: the
/// compute node's CPU, or the DPU's hardware engine at its calibrated
/// speedup. The single source of truth for decompression cost
/// accounting — the serial path, the worker pool and the phase-2
/// selective path all go through here.
fn attribute_decomp_time(timeline: &Timeline, opts: &EngineOpts, dt: f64) {
    match opts.decomp {
        DecompMode::Software => timeline.add_real(Stage::Decompress, opts.compute_node, dt),
        DecompMode::HwEngine { speedup } => {
            timeline.add_real(Stage::Decompress, Node::DpuEngine, dt / speedup.max(1e-9))
        }
    }
}

/// Decompress one basket frame, wall-clocking the work and attributing
/// it via [`attribute_decomp_time`] (plus the decompressed-byte
/// count).
pub(crate) fn decompress_attributed(
    timeline: &Timeline,
    opts: &EngineOpts,
    frame: &[u8],
) -> Result<Vec<u8>> {
    let t0 = Instant::now();
    let raw = crate::compress::decompress(frame)?;
    attribute_decomp_time(timeline, opts, t0.elapsed().as_secs_f64());
    timeline.add_bytes(Stage::Decompress, raw.len() as u64);
    Ok(raw)
}

/// Fold per-worker timelines into the job timeline.
///
/// CPU workers run in parallel, so only the *critical* (slowest)
/// worker's accounting is merged — latency = max over workers, the
/// same attribution precedent as the DPU shard fan-out
/// ([`crate::dpu::DpuCluster`]). A **serial device** (the DPU's
/// hardware decompression engine) drains every worker's frames
/// back-to-back, so all workers fold (sum) — keeping the engine's
/// Figure 5a calibration independent of thread count.
fn fold_worker_timelines(job: &Timeline, workers: &[Timeline], serial_device: bool) {
    if serial_device {
        for w in workers {
            job.merge_from(w);
        }
    } else if let Some(critical) = workers
        .iter()
        .max_by(|a, b| a.elapsed().partial_cmp(&b.elapsed()).expect("finite"))
    {
        job.merge_from(critical);
    }
}

/// Fetch + decompress the basket of `branch` covering event `lo` into
/// the reusable `scratch` buffer, charging transport virtually (via
/// the store) and decompression via [`attribute_decomp_time`]. Free
/// function over disjoint ctx fields so callers can hold other
/// borrows.
fn fetch_decompress_into(
    reader: &TRootReader<Arc<dyn ReadAt>>,
    counters: &mut FetchCounters,
    timeline: &Timeline,
    opts: &EngineOpts,
    branch: &BranchMeta,
    lo: u64,
    scratch: &mut Vec<u8>,
) -> Result<BasketInfo> {
    let idx = branch.basket_for_event(lo).ok_or_else(|| {
        Error::Engine(format!(
            "branch {} has no basket for event {lo}",
            branch.desc.name
        ))
    })?;
    let info = branch.baskets[idx];
    let frame = reader.fetch_basket(branch, idx)?;
    counters.baskets += 1;
    counters.bytes += info.comp_len as u64;
    let t0 = Instant::now();
    crate::compress::decompress_into(&frame, scratch)?;
    attribute_decomp_time(timeline, opts, t0.elapsed().as_secs_f64());
    timeline.add_bytes(Stage::Decompress, scratch.len() as u64);
    Ok(info)
}

/// Mutable state of the selectivity-adaptive interpreter path
/// ([`crate::engine::AdaptiveOpts`]): the program's conjunct
/// inventory, running per-conjunct tallies, and the current evaluation
/// order. The order is re-ranked only on group boundaries (after the
/// warm-up window, then every `replan_every` groups), so every batch
/// inside a flush window sees one fixed order — and because
/// [`rank_order`] ranks on structural cost (never wall-clock), the
/// chosen order is a deterministic function of the data alone.
struct AdaptiveState {
    /// The ANDed conjuncts of the compiled program, in fixed order.
    conjuncts: Vec<Conjunct>,
    /// Running tallies, indexed like `conjuncts`.
    stats: Vec<ConjunctStats>,
    /// Current evaluation order (indices into `conjuncts`).
    order: Vec<usize>,
    /// Cluster groups evaluated so far (the re-plan cadence clock).
    groups_done: u64,
    /// Re-plans that actually changed the order.
    replans: u64,
    /// Fusion plan over the current order ([`EngineOpts::fuse`]):
    /// `Some` routes evaluation through
    /// [`super::fused::eval_fused`], rebuilt at every replan
    /// checkpoint so fused kernels track the adaptive order. `None`
    /// keeps the per-conjunct [`super::interp::eval_adaptive`] sweep.
    fuse: Option<FusePlan>,
}

/// The in-flight state of one skim job, visible to every stage.
///
/// Immutable job context (`plan`, `opts`, `timeline`, `meta`) is
/// exposed read-only; mutable job state (`stage_funnel`, `warnings`,
/// the current `group`) is public for stages to inspect and adjust.
pub struct StageCtx<'a> {
    /// The engine options this job runs under.
    pub opts: &'a EngineOpts,
    /// The job timeline every stage accounts onto.
    pub timeline: &'a Timeline,
    /// The compiled execution plan.
    pub plan: SkimPlan,
    /// The §3.2 funnel: cumulative survivors after (preselection,
    /// +object, +HT, +trigger).
    pub stage_funnel: [u64; 4],
    /// Events committed as passing so far (updated at group commit).
    pub pass_total: u64,
    /// Warnings accumulated so far (stages may append).
    pub warnings: Vec<String>,
    /// The active cluster group, `Some` between `begin_group` and
    /// commit. Group-hook stages operate on this.
    pub group: Option<GroupState>,

    reader: TRootReader<Arc<dyn ReadAt>>,
    meta: FileMeta,
    cache: Option<Arc<TTreeCache<Arc<dyn ReadAt>>>>,
    /// Digest-validated zone map ([`EngineOpts::zone_map`] after the
    /// staleness check): `None` when no sidecar was supplied, the
    /// digest mismatched the input's metadata (stale — a warning was
    /// pushed and the job full-scans), or the plan compiled no
    /// [`crate::query::ZonePredicate`]s to prune with.
    zone_map: Option<Arc<crate::index::FileIndex>>,
    runtime: Option<&'a SkimRuntime>,
    vectorized: bool,
    caps: Capacities,
    batch_b: usize,
    m: usize,
    variant: Option<&'a Variant>,
    params: Option<CutParams>,
    basket_events: usize,
    /// Events covered by this job (the whole file, or the
    /// `event_range` shard of it).
    range_events: u64,
    /// `(cluster, lo, n)` windows this job iterates, range-restricted.
    cluster_window: Vec<(usize, u64, usize)>,
    next_window: usize,
    /// Branches read in phase 1 (criteria — whose positions are the
    /// plan's dense `BranchId`s — plus all output branches in legacy
    /// single-phase mode). Position in this list is the slot every
    /// [`GroupState`] per-cluster `Vec` is indexed by.
    phase1: Vec<BranchMeta>,
    /// Output-only branches (phase 2).
    output_only: Vec<BranchMeta>,
    /// `(phase-1 slot, accumulator index)` pairs gathered from decoded
    /// baskets at group commit — interned once at job start.
    gather_now: Vec<(usize, usize)>,
    /// Output accumulators, in `plan.output_branches` order.
    accs: Vec<OutputAcc>,
    /// Accumulator index of each `output_only` branch (phase 2).
    output_only_accs: Vec<usize>,
    /// Reusable batch scratch for `eval` (one allocation per job, not
    /// per flush window).
    scratch_batch: Option<Batch>,
    /// Passing events per absolute cluster id (feeds phase 2).
    cluster_pass: Vec<Vec<u64>>,
    counters: FetchCounters,
    output_path: PathBuf,
    output_summary: Option<crate::troot::writer::WriteSummary>,
    /// Interned [`BasketKey`] components for the shared basket cache
    /// (empty when [`EngineOpts::basket_cache`] is `None`): the input
    /// file name, plus one branch name per phase-1 slot and per
    /// output-only branch — so key construction on the hot path is
    /// refcount bumps, not string clones.
    cache_file_key: Arc<str>,
    cache_branch_keys: Vec<Arc<str>>,
    cache_output_keys: Vec<Arc<str>>,
    /// Selectivity-adaptive interpreter state: `Some` only when
    /// [`crate::engine::AdaptiveOpts::enabled`] and this job evaluates
    /// on the interpreter with a non-trivial program. `None` leaves
    /// the fixed-order [`super::interp::eval_columnar`] path (and its
    /// per-stage funnel counts) untouched.
    adaptive: Option<AdaptiveState>,
}

impl<'a> StageCtx<'a> {
    pub(crate) fn new(
        runtime: Option<&'a SkimRuntime>,
        store: Arc<dyn ReadAt>,
        query: &SkimQuery,
        timeline: &'a Timeline,
        opts: &'a EngineOpts,
        output_path: PathBuf,
    ) -> Result<StageCtx<'a>> {
        // Optional TTreeCache in front of the store.
        let cache = opts
            .cache_bytes
            .map(|cap| Arc::new(TTreeCache::new(store.clone(), cap)));
        let eff_store: Arc<dyn ReadAt> = match &cache {
            Some(c) => c.clone(),
            None => store,
        };

        let reader = TRootReader::open(eff_store)?;
        let meta = reader.meta().clone();
        let plan = SkimPlan::build(query, &meta)?;
        let mut warnings = plan.warnings.clone();

        // --- zone map (basket pruning) -------------------------------
        // Validate the sidecar against *this* input before trusting a
        // single summary: a digest mismatch means the data file was
        // rewritten after the index was built, so the sidecar is
        // ignored (full scan) rather than risking a wrong answer.
        let zone_map = match &opts.zone_map {
            Some(zm) if zm.digest != crate::index::meta_digest(&meta) => {
                warnings.push(
                    "stale zone-map sidecar ignored (digest mismatch); running a full scan"
                        .into(),
                );
                None
            }
            Some(zm) if !plan.zone_predicates.is_empty() => Some(zm.clone()),
            _ => None,
        };

        // --- evaluation strategy -------------------------------------
        let vectorized = opts.use_pjrt && plan.program.fits_kernel() && runtime.is_some();
        if opts.use_pjrt && !vectorized {
            warnings.push("vectorized path unavailable; using interpreter".into());
        }
        let caps = if vectorized {
            runtime.expect("vectorized implies runtime").caps
        } else {
            // Interpreter batches are sized to the *program*, not the
            // kernel's fixed banks: cut programs beyond kernel
            // capacity (the fallback's whole point) still assemble
            // without overflowing the column arrays. The cut-bank
            // fields are unused on this path (CutParams::pack is
            // vectorized-only); fill them with the kernel constants.
            Capacities {
                c: plan.program.obj_columns.len(),
                s: plan.program.scalar_columns.len(),
                k_obj: KERNEL_MAX_OBJ_CUTS,
                k_sc: KERNEL_MAX_SCALAR_CUTS,
                g: KERNEL_MAX_GROUPS,
                n_stages: 4,
            }
        };
        let basket_events = meta.basket_events.max(1) as usize;
        let (batch_b, m, variant) = if vectorized {
            let rt = runtime.unwrap();
            let v = rt.variant_for(basket_events);
            (v.b, v.m, Some(v))
        } else {
            // The interpreter has no per-call overhead; size batches to
            // one cluster.
            (basket_events, opts.max_objects, None)
        };
        let params = if vectorized {
            Some(CutParams::pack(&plan.program, &caps)?)
        } else {
            None
        };

        // --- selectivity-adaptive / fused interpreter state ----------
        // Strictly opt-in, interpreter-only: the vectorized kernel's
        // stage order is baked into its AOT program, and a trivial
        // program has nothing to reorder or fuse. The conjunct-level
        // state is shared by both features: `--adaptive` reorders it,
        // `--fuse` compiles fused kernels over it (under the identity
        // order when adaptive is off). A seed profile (warm start from
        // a prior run of the same query) ranks the order immediately —
        // and informs the initial fusion plan — but seeding, ranking
        // and the replan cadence stay gated on `adaptive.enabled`, so
        // fuse-only runs keep the fixed conjunct order and report no
        // profile.
        let adaptive = if (opts.adaptive.enabled || opts.fuse)
            && !vectorized
            && !plan.program.is_trivial()
        {
            let conjuncts = conjuncts_of(&plan.program);
            let mut stats = vec![ConjunctStats::default(); conjuncts.len()];
            let mut seeded = false;
            if opts.adaptive.enabled {
                if let Some(seed) = &opts.adaptive.seed {
                    for (c, st) in conjuncts.iter().zip(stats.iter_mut()) {
                        if let Some(prev) = seed.get(&c.key) {
                            *st = *prev;
                            seeded = true;
                        }
                    }
                }
            }
            let order: Vec<usize> = if seeded {
                rank_order(&conjuncts, &stats)
            } else {
                (0..conjuncts.len()).collect()
            };
            let fuse = if opts.fuse {
                Some(fuse_plan(&plan.program, &conjuncts, &order, &stats))
            } else {
                None
            };
            // Seeded tallies informed the starting order and fusion
            // plan; the profile this job reports should count only its
            // own events.
            stats.fill(ConjunctStats::default());
            Some(AdaptiveState { conjuncts, stats, order, groups_done: 0, replans: 0, fuse })
        } else {
            None
        };

        // --- event range (whole file, or one shard of it) ------------
        let (start, end) = {
            let (s, e) = opts.event_range.unwrap_or((0, meta.n_events));
            (s.min(meta.n_events), e.min(meta.n_events))
        };
        let range_events = end.saturating_sub(start);
        let n_clusters_total = (meta.n_events as usize).div_ceil(basket_events);
        let mut cluster_window = Vec::new();
        if start < end {
            let first = (start / basket_events as u64) as usize;
            let last = (end as usize).div_ceil(basket_events);
            for cluster in first..last {
                let lo = ((cluster * basket_events) as u64).max(start);
                let hi = (((cluster + 1) * basket_events) as u64).min(end);
                if lo < hi {
                    cluster_window.push((cluster, lo, (hi - lo) as usize));
                }
            }
        }

        // --- branch sets ---------------------------------------------
        let branch_meta =
            |name: &str| -> Result<BranchMeta> { Ok(reader.branch(name)?.clone()) };
        let criteria: Vec<BranchMeta> = plan
            .criteria_branches
            .iter()
            .map(|b| branch_meta(b))
            .collect::<Result<_>>()?;
        let output_only: Vec<BranchMeta> = plan
            .output_only_branches
            .iter()
            .map(|b| branch_meta(b))
            .collect::<Result<_>>()?;

        // Phase-1 fetch set: criteria (+ all output branches in legacy
        // mode, fully decoded for every cluster — the baseline's cost).
        // Criteria occupy the leading slots, so their positions equal
        // the plan's dense `BranchId`s.
        let mut phase1: Vec<BranchMeta> = criteria.clone();
        if !opts.two_phase {
            phase1.extend(output_only.iter().cloned());
        }

        if let Some(c) = &cache {
            let mut ranges = Vec::new();
            for b in &phase1 {
                for ki in b.baskets_for_range(start, end) {
                    let k = &b.baskets[ki];
                    ranges.push((k.offset, k.comp_len as usize));
                }
            }
            c.train(ranges);
        }

        // Output accumulators, in output schema order.
        let accs: Vec<OutputAcc> = plan
            .output_branches
            .iter()
            .map(|name| {
                let bm = branch_meta(name)?;
                Ok(OutputAcc::new(bm.desc.clone()))
            })
            .collect::<Result<_>>()?;

        // Intern the gather and phase-2 lookups once: names resolve to
        // (phase-1 slot, accumulator index) pairs here, never on the
        // per-group hot path. Gathered right after evaluation from the
        // decoded baskets: criteria∩output in two-phase mode (already
        // in memory), all output branches in legacy mode.
        let phase1_slot: HashMap<&str, usize> = phase1
            .iter()
            .enumerate()
            .map(|(i, b)| (b.desc.name.as_str(), i))
            .collect();
        let acc_index: HashMap<&str, usize> = plan
            .output_branches
            .iter()
            .enumerate()
            .map(|(i, n)| (n.as_str(), i))
            .collect();
        let gather_now: Vec<(usize, usize)> = if opts.two_phase {
            criteria
                .iter()
                .filter_map(|b| {
                    let name = b.desc.name.as_str();
                    acc_index.get(name).map(|&ai| (phase1_slot[name], ai))
                })
                .collect()
        } else {
            plan.output_branches
                .iter()
                .map(|n| (phase1_slot[n.as_str()], acc_index[n.as_str()]))
                .collect()
        };
        let output_only_accs: Vec<usize> = output_only
            .iter()
            .map(|b| acc_index[b.desc.name.as_str()])
            .collect();

        // Intern shared-cache key components once per job.
        let (cache_file_key, cache_branch_keys, cache_output_keys) =
            if opts.basket_cache.is_some() {
                (
                    // Single-file key: dataset jobs are decomposed into
                    // per-file queries before they reach the engine.
                    Arc::<str>::from(query.input.to_string()),
                    phase1
                        .iter()
                        .map(|b| Arc::<str>::from(b.desc.name.as_str()))
                        .collect(),
                    output_only
                        .iter()
                        .map(|b| Arc::<str>::from(b.desc.name.as_str()))
                        .collect(),
                )
            } else {
                (Arc::<str>::from(""), Vec::new(), Vec::new())
            };

        Ok(StageCtx {
            opts,
            timeline,
            plan,
            stage_funnel: [0; 4],
            pass_total: 0,
            warnings,
            group: None,
            reader,
            meta,
            cache,
            zone_map,
            runtime,
            vectorized,
            caps,
            batch_b,
            m,
            variant,
            params,
            basket_events,
            range_events,
            cluster_window,
            next_window: 0,
            phase1,
            output_only,
            gather_now,
            accs,
            output_only_accs,
            scratch_batch: None,
            cluster_pass: vec![Vec::new(); n_clusters_total],
            counters: FetchCounters::default(),
            output_path,
            output_summary: None,
            cache_file_key,
            cache_branch_keys,
            cache_output_keys,
            adaptive,
        })
    }

    /// File metadata of the input being skimmed.
    pub fn meta(&self) -> &FileMeta {
        &self.meta
    }

    /// Events this job covers (whole file or the shard's range).
    pub fn n_events(&self) -> u64 {
        self.range_events
    }

    /// Did the vectorized PJRT path evaluate this job's cuts?
    pub fn vectorized(&self) -> bool {
        self.vectorized
    }

    /// The phase-1 branch set, in fetch order. Per-cluster rows of
    /// [`GroupState::frames`]/[`GroupState::raw`]/[`GroupState::decoded`]
    /// are indexed by position in this slice (criteria branches lead —
    /// their positions are the plan's dense
    /// [`crate::query::plan::BranchId`]s — followed, in legacy
    /// single-phase mode, by the output-only branches). Custom stages
    /// use this to resolve slot → branch name.
    pub fn phase1_branches(&self) -> &[BranchMeta] {
        &self.phase1
    }

    /// Start the next cluster group: pack consecutive clusters until
    /// the batch capacity is reached. Returns false when exhausted.
    pub(crate) fn begin_group(&mut self) -> bool {
        if self.next_window >= self.cluster_window.len() {
            return false;
        }
        let mut clusters = Vec::new();
        let mut total = 0usize;
        while self.next_window < self.cluster_window.len() {
            let (cl, lo, n) = self.cluster_window[self.next_window];
            if !clusters.is_empty() && total + n > self.batch_b {
                break;
            }
            clusters.push((cl, lo, n));
            total += n;
            self.next_window += 1;
            if total >= self.batch_b {
                break;
            }
        }
        let k = clusters.len();
        self.group = Some(GroupState {
            clusters,
            frames: Vec::with_capacity(k),
            raw: Vec::with_capacity(k),
            decoded: Vec::with_capacity(k),
            passes: vec![Vec::new(); k],
            fetched_bytes: 0,
        });
        true
    }

    /// Discard the active group without committing (a stage vetoed it).
    pub(crate) fn abort_group(&mut self) {
        self.group = None;
    }

    /// Fold the active group's surviving passes into the job: gather
    /// criteria∩output values from decoded baskets, record per-cluster
    /// pass lists for phase 2.
    pub(crate) fn commit_group(&mut self) -> Result<()> {
        let group = match self.group.take() {
            Some(g) => g,
            None => return Ok(()),
        };
        let timeline = self.timeline;
        let node = self.opts.compute_node;
        for (gi, &(cl, _, _)) in group.clusters.iter().enumerate() {
            let passes = &group.passes[gi];
            if passes.is_empty() {
                continue;
            }
            self.pass_total += passes.len() as u64;
            let t0 = Instant::now();
            for &(slot, acc_idx) in &self.gather_now {
                let dec = group.decoded.get(gi).and_then(|row| row.get(slot)).ok_or_else(
                    || {
                        Error::Engine(format!(
                            "gather: missing decoded basket '{}'",
                            self.phase1[slot].desc.name
                        ))
                    },
                )?;
                let acc = &mut self.accs[acc_idx];
                for &ev in passes {
                    acc.push_event(dec, ev);
                }
            }
            timeline.add_real(Stage::Deserialize, node, t0.elapsed().as_secs_f64());
            self.cluster_pass[cl].extend_from_slice(passes);
        }
        Ok(())
    }

    // ---------------- built-in stage bodies --------------------------

    /// Drop provably-dead clusters from the group *before any I/O*:
    /// a cluster whose zone-map summaries refute one of the plan's
    /// [`crate::query::ZonePredicate`]s (each a necessary condition of
    /// the full selection) cannot contain a passing event, so its
    /// baskets are never fetched, decompressed or deserialized.
    /// Cluster index == basket index for every branch (the writer
    /// emits cluster-aligned baskets; the digest check pins
    /// `basket_events`), and a summary covers the whole basket, so
    /// pruning stays sound under [`EngineOpts::event_range`] shards.
    /// `passes` is retained in lockstep (all entries are still empty
    /// at fetch time); `cluster_pass` rows of pruned clusters simply
    /// stay empty, so phase 2 skips them too.
    fn prune_group(&mut self, group: &mut GroupState) {
        let zm = match &self.zone_map {
            Some(z) => z,
            None => return,
        };
        let preds = &self.plan.zone_predicates;
        let keep: Vec<bool> = group
            .clusters
            .iter()
            .map(|&(cl, _, _)| !preds.iter().any(|p| p.dead(zm, cl)))
            .collect();
        let dead = keep.iter().filter(|&&k| !k).count();
        if dead == 0 {
            return;
        }
        let mut it = keep.iter();
        group.clusters.retain(|_| *it.next().unwrap());
        let mut it = keep.iter();
        group.passes.retain(|_| *it.next().unwrap());
        self.timeline
            .count("baskets_pruned", (dead * self.phase1.len()) as u64);
    }

    /// Is `cluster` provably dead for *this* query's zone predicates?
    /// The same liveness test [`Self::prune_group`] applies, exposed
    /// per cluster so the shared-scan executor
    /// ([`crate::engine::run_shared`]) can skip a basket only when it
    /// is dead for **every** batch member while each member still
    /// prunes by its own predicates (keeping funnels and masks
    /// byte-identical to a solo run). Always `false` without a
    /// digest-validated zone-map sidecar.
    pub(crate) fn zone_dead(&self, cluster: usize) -> bool {
        match &self.zone_map {
            Some(zm) => self
                .plan
                .zone_predicates
                .iter()
                .any(|p| p.dead(zm, cluster)),
            None => false,
        }
    }

    fn fetch_group(&mut self, group: &mut GroupState) -> Result<()> {
        self.prune_group(group);
        // Phase-1 baskets this group will actually read (post-prune);
        // `baskets_pruned + baskets_scanned` is the full criteria scan.
        self.timeline.count(
            "baskets_scanned",
            (group.clusters.len() * self.phase1.len()) as u64,
        );
        if let Some(cache) = self.opts.basket_cache.clone() {
            return self.fetch_group_cached(group, &cache);
        }
        for &(_, lo, _) in &group.clusters {
            let mut row = Vec::with_capacity(self.phase1.len());
            for b in &self.phase1 {
                let idx = b.basket_for_event(lo).ok_or_else(|| {
                    Error::Engine(format!(
                        "branch {} has no basket for event {lo}",
                        b.desc.name
                    ))
                })?;
                let info = b.baskets[idx];
                // Fetch: transport time is charged virtually by the
                // store (wire/disk model); we track volume here.
                let frame = self.reader.fetch_basket(b, idx)?;
                self.counters.baskets += 1;
                self.counters.bytes += info.comp_len as u64;
                group.fetched_bytes += info.comp_len as u64;
                row.push((frame, info));
            }
            group.frames.push(row);
        }
        Ok(())
    }

    /// Fetch + decompress one basket through the shared
    /// [`BasketCache`] (single-flight). `phase2` selects the branch
    /// table: `false` = phase-1 slot, `true` = output-only index. A
    /// miss loads through the cache — charging this job's timeline for
    /// transport and decompression exactly as the uncached path would
    /// — and bumps the fetch counters; a hit charges nothing. Returns
    /// the decompressed bytes, the basket's metadata and the hit flag.
    fn fetch_basket_cached(
        &mut self,
        cache: &BasketCache,
        phase2: bool,
        slot: usize,
        lo: u64,
        hits: &mut u64,
        misses: &mut u64,
    ) -> Result<(Arc<Vec<u8>>, BasketInfo, bool)> {
        let (b, branch_key) = if phase2 {
            (&self.output_only[slot], &self.cache_output_keys[slot])
        } else {
            (&self.phase1[slot], &self.cache_branch_keys[slot])
        };
        let idx = b.basket_for_event(lo).ok_or_else(|| {
            Error::Engine(format!(
                "branch {} has no basket for event {lo}",
                b.desc.name
            ))
        })?;
        let info = b.baskets[idx];
        let key = BasketKey {
            file: self.cache_file_key.clone(),
            branch: branch_key.clone(),
            basket: idx as u32,
        };
        let reader = &self.reader;
        let timeline = self.timeline;
        let opts = self.opts;
        let (raw, hit) = cache.get_or_load(key, || {
            let frame = reader.fetch_basket(b, idx)?;
            decompress_attributed(timeline, opts, &frame)
        })?;
        if hit {
            *hits += 1;
        } else {
            *misses += 1;
            self.counters.baskets += 1;
            self.counters.bytes += info.comp_len as u64;
        }
        Ok((raw, info, hit))
    }

    /// Shared-cache fetch path: fetch **and decompress** through the
    /// service-wide [`BasketCache`], filling [`GroupState::raw`]
    /// directly (the built-in `decompress` stage then has no frames
    /// left to chew). Hits skip both the store read and the
    /// decompression — and charge nothing to this job's timeline;
    /// misses load single-flight, with the loading job paying the
    /// transport + decompress charges exactly as on the uncached path.
    fn fetch_group_cached(&mut self, group: &mut GroupState, cache: &BasketCache) -> Result<()> {
        let mut hits = 0u64;
        let mut misses = 0u64;
        for &(_, lo, _) in &group.clusters {
            let mut row = Vec::with_capacity(self.phase1.len());
            for slot in 0..self.phase1.len() {
                let (raw, info, hit) =
                    self.fetch_basket_cached(cache, false, slot, lo, &mut hits, &mut misses)?;
                if !hit {
                    group.fetched_bytes += info.comp_len as u64;
                }
                // The cache hands out shared `Arc`ed bytes and the
                // per-group stores are `SharedBytes` too, so a hit is
                // a refcount bump — no memcpy, no fetch, no
                // decompress.
                row.push((raw, info));
            }
            group.raw.push(row);
        }
        self.timeline.count("basket_cache_hits", hits);
        self.timeline.count("basket_cache_misses", misses);
        Ok(())
    }

    fn decompress_group(&mut self, group: &mut GroupState) -> Result<()> {
        // Frames are *consumed* here: custom stages always order after
        // the built-in chain (ties break by registration order), so
        // nothing can observe `frames` between `fetch` and
        // `decompress` — retaining compressed alongside raw bytes
        // would be pure memory waste at paper scale (1749 branches).
        let frames = std::mem::take(&mut group.frames);
        let n_baskets: usize = frames.iter().map(|f| f.len()).sum();
        // Never spawn more workers than there are baskets to chew.
        let workers = self.opts.workers().min(n_baskets);
        if workers <= 1 || n_baskets < 2 {
            // Legacy in-line path: `parallelism = 1` reproduces the
            // single-threaded timelines exactly.
            for cluster in frames {
                let mut row = Vec::with_capacity(cluster.len());
                for (frame, info) in cluster {
                    let raw = decompress_attributed(self.timeline, self.opts, &frame)?;
                    row.push((Arc::new(raw), info));
                }
                group.raw.push(row);
            }
            return Ok(());
        }

        // Fan the group's (cluster × branch) frames round-robin across
        // scoped workers. Each worker owns its frames and wall-clocks
        // its own timeline; decompressed bytes are tallied on the job
        // timeline in full (they are a volume, not a latency).
        let shape: Vec<usize> = frames.iter().map(|f| f.len()).collect();
        let mut shards: Vec<Vec<(usize, usize, Vec<u8>, BasketInfo)>> = Vec::new();
        shards.resize_with(workers, Vec::new);
        let mut i = 0usize;
        for (ci, cluster) in frames.into_iter().enumerate() {
            for (slot, (frame, info)) in cluster.into_iter().enumerate() {
                shards[i % workers].push((ci, slot, frame, info));
                i += 1;
            }
        }
        let opts = self.opts;
        type DecompOut = (Timeline, u64, Vec<(usize, usize, Vec<u8>, BasketInfo)>);
        let results: Vec<Result<DecompOut>> = std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .into_iter()
                .map(|shard| {
                    scope.spawn(move || -> Result<DecompOut> {
                        let tl = Timeline::new();
                        let mut bytes = 0u64;
                        let mut out = Vec::with_capacity(shard.len());
                        for (ci, slot, frame, info) in shard {
                            let t0 = Instant::now();
                            let raw = crate::compress::decompress(&frame)?;
                            attribute_decomp_time(&tl, opts, t0.elapsed().as_secs_f64());
                            bytes += raw.len() as u64;
                            out.push((ci, slot, raw, info));
                        }
                        Ok((tl, bytes, out))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("decompress worker panicked"))
                .collect()
        });

        let mut rows: Vec<Vec<Option<(SharedBytes, BasketInfo)>>> =
            shape.iter().map(|&len| vec![None; len]).collect();
        let mut worker_tls = Vec::with_capacity(workers);
        let mut total_bytes = 0u64;
        for r in results {
            let (tl, bytes, items) = r?;
            worker_tls.push(tl);
            total_bytes += bytes;
            for (ci, slot, raw, info) in items {
                rows[ci][slot] = Some((Arc::new(raw), info));
            }
        }
        fold_worker_timelines(
            self.timeline,
            &worker_tls,
            matches!(self.opts.decomp, DecompMode::HwEngine { .. }),
        );
        self.timeline.add_bytes(Stage::Decompress, total_bytes);
        group.raw = rows
            .into_iter()
            .map(|row| {
                row.into_iter()
                    .map(|o| o.expect("every basket decompressed"))
                    .collect()
            })
            .collect();
        Ok(())
    }

    fn deserialize_group(&mut self, group: &mut GroupState) -> Result<()> {
        let timeline = self.timeline;
        let node = self.opts.compute_node;
        for row in &group.raw {
            if row.len() != self.phase1.len() {
                return Err(Error::Engine(format!(
                    "deserialize: expected {} baskets per cluster, found {}",
                    self.phase1.len(),
                    row.len()
                )));
            }
        }
        let n_baskets: usize = group.raw.iter().map(|r| r.len()).sum();
        // Never spawn more workers than there are baskets to chew.
        let workers = self.opts.workers().min(n_baskets);
        if workers <= 1 || n_baskets < 2 {
            // Legacy in-line path: `parallelism = 1` reproduces the
            // single-threaded timelines exactly (including the modeled
            // cost's `parallelism` divisor).
            for row in &group.raw {
                let mut decs = Vec::with_capacity(row.len());
                for (bm, (raw, info)) in self.phase1.iter().zip(row) {
                    let t0 = Instant::now();
                    // Zero-copy decode: f32/i32 values are views into
                    // the shared raw buffer when aligned; the basket
                    // index (recovered by binary search) gives decode
                    // errors a locus.
                    let bidx = bm.basket_for_event(info.first_event).unwrap_or(0);
                    let dec = basket_codec::decode_shared(
                        &bm.desc,
                        raw,
                        0,
                        info.first_event,
                        info.n_events as usize,
                        bidx,
                    )?;
                    timeline.add_real(Stage::Deserialize, node, t0.elapsed().as_secs_f64());
                    // Modeled ROOT streamer cost: every event of this
                    // basket is materialized (one GetEntry per event).
                    if let Some(model) = self.opts.deser_model {
                        timeline.add_real(
                            Stage::Deserialize,
                            node,
                            model.cost(
                                info.n_events as u64,
                                raw.len() as u64,
                                self.opts.parallelism,
                            ),
                        );
                    }
                    decs.push(dec);
                }
                group.decoded.push(decs);
            }
            return Ok(());
        }

        // Fan (cluster × branch) baskets across scoped workers reading
        // the retained raw bytes in place. The modeled GetEntry cost is
        // charged per worker at `workers / parallelism` of the base
        // rate: folding the critical worker then yields the same
        // modeled total as the legacy `/ parallelism` divisor (exactly,
        // up to round-robin imbalance) while attributing it to a real
        // thread's critical path.
        let scale = workers as f64 / self.opts.parallelism.max(1.0);
        let items: Vec<(usize, usize)> = group
            .raw
            .iter()
            .enumerate()
            .flat_map(|(ci, row)| (0..row.len()).map(move |slot| (ci, slot)))
            .collect();
        let mut shards: Vec<Vec<(usize, usize)>> = vec![Vec::new(); workers];
        for (i, item) in items.into_iter().enumerate() {
            shards[i % workers].push(item);
        }
        let raw_rows = &group.raw;
        let phase1 = &self.phase1;
        let model = self.opts.deser_model;
        type DeserOut = (Timeline, Vec<(usize, usize, DecodedBasket)>);
        let results: Vec<Result<DeserOut>> = std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .into_iter()
                .map(|shard| {
                    scope.spawn(move || -> Result<DeserOut> {
                        let tl = Timeline::new();
                        let mut out = Vec::with_capacity(shard.len());
                        for (ci, slot) in shard {
                            let (raw, info) = &raw_rows[ci][slot];
                            let t0 = Instant::now();
                            let bidx = phase1[slot]
                                .basket_for_event(info.first_event)
                                .unwrap_or(0);
                            let dec = basket_codec::decode_shared(
                                &phase1[slot].desc,
                                raw,
                                0,
                                info.first_event,
                                info.n_events as usize,
                                bidx,
                            )?;
                            tl.add_real(Stage::Deserialize, node, t0.elapsed().as_secs_f64());
                            if let Some(model) = model {
                                tl.add_real(
                                    Stage::Deserialize,
                                    node,
                                    model.cost(info.n_events as u64, raw.len() as u64, 1.0)
                                        * scale,
                                );
                            }
                            out.push((ci, slot, dec));
                        }
                        Ok((tl, out))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("deserialize worker panicked"))
                .collect()
        });

        let mut rows: Vec<Vec<Option<DecodedBasket>>> =
            group.raw.iter().map(|r| vec![None; r.len()]).collect();
        let mut worker_tls = Vec::with_capacity(workers);
        for r in results {
            let (tl, items) = r?;
            worker_tls.push(tl);
            for (ci, slot, dec) in items {
                rows[ci][slot] = Some(dec);
            }
        }
        fold_worker_timelines(timeline, &worker_tls, false);
        group.decoded = rows
            .into_iter()
            .map(|row| {
                row.into_iter().map(|o| o.expect("every basket decoded")).collect()
            })
            .collect();
        Ok(())
    }

    pub(crate) fn eval_group(&mut self, group: &mut GroupState) -> Result<()> {
        if self.plan.program.is_trivial() {
            // No cuts at all: everything passes. (Checked on the
            // program, not the criteria list — a constant-only IR cut
            // references no branches but still filters.)
            for (gi, &(_, lo, n)) in group.clusters.iter().enumerate() {
                group.passes[gi] = (lo..lo + n as u64).collect();
            }
            for &(_, _, n) in &group.clusters {
                for s in &mut self.stage_funnel {
                    *s += n as u64;
                }
            }
            return Ok(());
        }

        // Sub-chunk only when a single cluster exceeds the batch:
        // (group idx, chunk lo, chunk n, batch dst).
        let chunks: Vec<(usize, u64, usize, usize)> = {
            let mut v = Vec::new();
            let mut dst = 0usize;
            for (gi, &(_, lo, n)) in group.clusters.iter().enumerate() {
                let mut off = 0usize;
                while off < n {
                    if dst == self.batch_b {
                        // Flush boundary handled below by the window loop.
                        dst = 0;
                    }
                    let take = (n - off).min(self.batch_b - dst);
                    v.push((gi, lo + off as u64, take, dst));
                    dst += take;
                    off += take;
                }
            }
            v
        };

        // Fill + evaluate in batch_b windows, reusing one batch
        // allocation for the whole job.
        let mut batch = match self.scratch_batch.take() {
            Some(mut b) => {
                b.reset();
                b
            }
            None => Batch::zeroed(&self.caps, self.batch_b, self.m),
        };
        let workers = self.opts.workers();
        let mut window: Vec<(usize, u64, usize, usize)> = Vec::new();
        for (gi, clo, cn, dst) in chunks {
            if dst == 0 && !window.is_empty() {
                self.flush_window(&mut batch, &mut window, group)?;
            }
            let timeline = self.timeline;
            let node = self.opts.compute_node;
            let t0 = Instant::now();
            // Interned column fill: baskets indexed by BranchId, fanned
            // per column across the worker pool. Wall-clocked on the
            // driving thread, so the parallel section is charged at its
            // critical path.
            super::batch::append_par(
                &self.plan.program,
                &group.decoded[gi],
                &self.plan.obj_col_branch,
                &self.plan.scalar_col_branch,
                clo,
                cn,
                &mut batch,
                dst,
                workers,
            )?;
            timeline.add_real(Stage::Deserialize, node, t0.elapsed().as_secs_f64());
            window.push((gi, clo, cn, dst));
        }
        self.flush_window(&mut batch, &mut window, group)?;
        self.scratch_batch = Some(batch);

        // Group boundary: tick the adaptive cadence and re-rank the
        // order once the warm-up window has elapsed, then every
        // `replan_every` groups. Never inside a window — every batch
        // of a group is evaluated under one fixed order. Fuse-only
        // runs (adaptive off) never replan: the identity order and its
        // fusion plan hold for the whole job.
        if let Some(st) = self.adaptive.as_mut() {
            if self.opts.adaptive.enabled {
                st.groups_done += 1;
                let a = &self.opts.adaptive;
                let warmed = st.groups_done >= a.warmup_groups.max(1);
                let since = st.groups_done.saturating_sub(a.warmup_groups.max(1));
                if warmed
                    && (since == 0 || (a.replan_every > 0 && since % a.replan_every == 0))
                {
                    let next = rank_order(&st.conjuncts, &st.stats);
                    if next != st.order {
                        st.replans += 1;
                    }
                    st.order = next;
                    // The fusion plan is a function of the order (and
                    // the now-measured tallies): rebuild it at every
                    // replan checkpoint so fused kernels keep tracking
                    // the leading, selective conjuncts.
                    if st.fuse.is_some() {
                        st.fuse = Some(fuse_plan(
                            &self.plan.program,
                            &st.conjuncts,
                            &st.order,
                            &st.stats,
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    fn flush_window(
        &mut self,
        batch: &mut Batch,
        window: &mut Vec<(usize, u64, usize, usize)>,
        group: &mut GroupState,
    ) -> Result<()> {
        if window.is_empty() {
            return Ok(());
        }
        let result = self.eval_batch(batch)?;
        for &(gi, clo, cn, dst) in window.iter() {
            for ev in 0..cn {
                let mut cum = 1.0f32;
                for (s, stage) in result.stages.iter().enumerate() {
                    cum *= stage[dst + ev];
                    self.stage_funnel[s] += cum as u64;
                }
                if result.mask[dst + ev] > 0.5 {
                    group.passes[gi].push(clo + ev as u64);
                }
            }
        }
        window.clear();
        batch.reset();
        Ok(())
    }

    fn eval_batch(&mut self, batch: &Batch) -> Result<MaskResult> {
        if self.vectorized {
            let rt = self.runtime.expect("vectorized implies runtime");
            let v = self.variant.expect("vectorized implies variant");
            let p = self.params.as_ref().expect("vectorized implies params");
            let timeline = self.timeline;
            return timeline.stage(Stage::Filter, self.opts.compute_node, || {
                rt.eval(v, batch, p)
            });
        }
        let timeline = self.timeline;
        let node = self.opts.compute_node;
        let program = &self.plan.program;
        if let Some(st) = self.adaptive.as_mut() {
            // Adaptive order with per-conjunct tallies, optionally
            // through the fused kernels. The final mask is
            // bit-identical to the fixed-order oracle; only per-stage
            // funnel counts may shift with the order. (Destructure the
            // state so the closure borrows the plan and the tallies
            // disjointly.)
            let AdaptiveState { conjuncts, stats, order, fuse, .. } = st;
            if let Some(plan) = fuse {
                return Ok(timeline.stage(Stage::Filter, node, || {
                    super::fused::eval_fused(program, batch, conjuncts, plan, stats)
                }));
            }
            return Ok(timeline.stage(Stage::Filter, node, || {
                super::interp::eval_adaptive(program, batch, conjuncts, order, stats)
            }));
        }
        Ok(timeline.stage(Stage::Filter, node, || {
            super::interp::eval_columnar(program, batch)
        }))
    }

    pub(crate) fn run_phase2(&mut self) -> Result<()> {
        if !(self.opts.two_phase && !self.output_only.is_empty() && self.pass_total > 0) {
            return Ok(());
        }
        if let Some(c) = &self.cache {
            let mut ranges = Vec::new();
            for (cluster, passes) in self.cluster_pass.iter().enumerate() {
                if passes.is_empty() {
                    continue;
                }
                for b in &self.output_only {
                    let k = &b.baskets[cluster];
                    ranges.push((k.offset, k.comp_len as usize));
                }
            }
            c.train(ranges);
        }
        // One reusable decompression scratch for the whole selective
        // pass (the raw basket is only read event-by-event here).
        // With a shared basket cache the scratch is bypassed: phase-2
        // baskets are served (and shared) through the cache too.
        let cache_opt = self.opts.basket_cache.clone();
        let mut hits = 0u64;
        let mut misses = 0u64;
        // Pre-size the reusable scratch to the largest output-only
        // basket (the frame headers record raw_len), so the selective
        // pass never grows the buffer geometrically on first touch.
        let max_raw = self
            .output_only
            .iter()
            .flat_map(|b| b.baskets.iter().map(|k| k.raw_len as usize))
            .max()
            .unwrap_or(0);
        let mut scratch = Vec::with_capacity(max_raw);
        for cluster in 0..self.cluster_pass.len() {
            if self.cluster_pass[cluster].is_empty() {
                continue;
            }
            let lo = (cluster * self.basket_events) as u64;
            for oi in 0..self.output_only.len() {
                let raw_arc: Arc<Vec<u8>>;
                let info: BasketInfo;
                let raw_slice: &[u8] = if let Some(cache) = &cache_opt {
                    let (data, inf, _hit) =
                        self.fetch_basket_cached(cache, true, oi, lo, &mut hits, &mut misses)?;
                    info = inf;
                    raw_arc = data;
                    raw_arc.as_slice()
                } else {
                    info = fetch_decompress_into(
                        &self.reader,
                        &mut self.counters,
                        self.timeline,
                        self.opts,
                        &self.output_only[oi],
                        lo,
                        &mut scratch,
                    )?;
                    scratch.as_slice()
                };
                let acc = &mut self.accs[self.output_only_accs[oi]];
                let t0 = Instant::now();
                let mut appended = 0usize;
                for &ev in &self.cluster_pass[cluster] {
                    appended += acc.push_event_raw(raw_slice, &info, ev)?;
                }
                self.timeline.add_real(
                    Stage::Deserialize,
                    self.opts.compute_node,
                    t0.elapsed().as_secs_f64(),
                );
                // Modeled GetEntry cost: only the passing events.
                if let Some(model) = self.opts.deser_model {
                    self.timeline.add_real(
                        Stage::Deserialize,
                        self.opts.compute_node,
                        model.cost(
                            self.cluster_pass[cluster].len() as u64,
                            appended as u64,
                            self.opts.parallelism,
                        ),
                    );
                }
            }
        }
        if cache_opt.is_some() {
            self.timeline.count("basket_cache_hits", hits);
            self.timeline.count("basket_cache_misses", misses);
        }
        Ok(())
    }

    pub(crate) fn write_output(&mut self) -> Result<()> {
        let codec = self.opts.output_codec.unwrap_or(self.meta.codec);
        let timeline = self.timeline;
        let node = self.opts.compute_node;
        let t0 = Instant::now();
        let mut writer = crate::troot::TRootWriter::new(
            self.output_path.clone(),
            codec,
            self.meta.basket_events,
        );
        // Accumulators were built in output schema order; drain them
        // straight through.
        for acc in std::mem::take(&mut self.accs) {
            let desc = acc.desc.clone();
            writer.add_branch(desc, acc.finish())?;
        }
        let summary = writer.finalize()?;
        timeline.add_real(Stage::OutputWrite, node, t0.elapsed().as_secs_f64());
        self.output_summary = Some(summary);
        Ok(())
    }

    /// Close the job and produce the [`SkimResult`]. Errors if no
    /// `output` stage ran (e.g. a Job-hook stage vetoed it).
    pub(crate) fn finish(self) -> Result<SkimResult> {
        let summary = self.output_summary.ok_or_else(|| {
            Error::Engine(
                "pipeline finished without writing output (job vetoed, or no 'output' stage)"
                    .into(),
            )
        })?;
        // Dump the adaptive tallies onto the timeline so they ride
        // `JobReport → JobStatus → wire → HTTP JSON` unchanged. Gated
        // on `adaptive.enabled`, not on the state existing: fuse-only
        // runs share the conjunct state but report no profile —
        // `--fuse` alone must not change any reporting surface.
        if self.opts.adaptive.enabled {
            if let Some(st) = &self.adaptive {
                for (c, s) in st.conjuncts.iter().zip(&st.stats) {
                    self.timeline
                        .record_profile(&c.key, c.stage, s.visited, s.passed, s.cost_us);
                }
                if st.replans > 0 {
                    self.timeline.count("adaptive_replans", st.replans);
                }
            }
        }
        Ok(SkimResult {
            n_events: self.range_events,
            n_pass: self.pass_total,
            stage_funnel: self.stage_funnel,
            output_path: self.output_path,
            output_bytes: summary.file_bytes,
            baskets_fetched: self.counters.baskets,
            fetched_bytes: self.counters.bytes,
            cache: self.cache.as_ref().map(|c| c.stats()),
            vectorized: self.vectorized,
            warnings: self.warnings,
        })
    }
}

// ---------------- built-in stages ------------------------------------

/// Built-in: fetch this group's criteria baskets (compressed frames).
struct FetchStage;
impl FilterStage for FetchStage {
    fn name(&self) -> &str {
        "fetch"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        let mut group = match ctx.group.take() {
            Some(g) => g,
            None => return Ok(Verdict::Continue),
        };
        let r = ctx.fetch_group(&mut group);
        ctx.group = Some(group);
        r?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: decompress fetched frames (software CPU or DPU engine).
struct DecompressStage;
impl FilterStage for DecompressStage {
    fn name(&self) -> &str {
        "decompress"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        let mut group = match ctx.group.take() {
            Some(g) => g,
            None => return Ok(Verdict::Continue),
        };
        let r = ctx.decompress_group(&mut group);
        ctx.group = Some(group);
        r?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: deserialize raw baskets into typed columns (plus the
/// modeled ROOT `GetEntry` cost).
struct DeserializeStage;
impl FilterStage for DeserializeStage {
    fn name(&self) -> &str {
        "deserialize"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        let mut group = match ctx.group.take() {
            Some(g) => g,
            None => return Ok(Verdict::Continue),
        };
        let r = ctx.deserialize_group(&mut group);
        ctx.group = Some(group);
        r?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: batch assembly + cut evaluation (PJRT kernel or the
/// scalar interpreter), populating per-cluster pass lists + the funnel.
struct EvalStage;
impl FilterStage for EvalStage {
    fn name(&self) -> &str {
        "eval"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        let mut group = match ctx.group.take() {
            Some(g) => g,
            None => return Ok(Verdict::Continue),
        };
        let r = ctx.eval_group(&mut group);
        ctx.group = Some(group);
        r?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: phase-2 selective fetch — output-only branches, passing
/// clusters only, per-event deserialization of passers.
struct Phase2Stage;
impl FilterStage for Phase2Stage {
    fn name(&self) -> &str {
        "phase2"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        ctx.run_phase2()?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: encode + write the filtered output file.
struct OutputStage;
impl FilterStage for OutputStage {
    fn name(&self) -> &str {
        "output"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        ctx.write_output()?;
        Ok(Verdict::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::engine::{EngineOpts, SkimEngine};
    use crate::gen::{self, GenConfig};
    use crate::troot::LocalFile;
    use std::sync::Mutex;

    // ---------------- ordering / registration ------------------------

    struct Named(&'static str);
    impl FilterStage for Named {
        fn name(&self) -> &str {
            self.0
        }
        fn run(&self, _ctx: &mut StageCtx) -> Result<Verdict> {
            Ok(Verdict::Continue)
        }
    }

    #[test]
    fn builtin_order_matches_paper_phases() {
        let p = Pipeline::builtin();
        assert_eq!(
            p.order(Hook::Group).unwrap(),
            vec!["fetch", "decompress", "deserialize", "eval"]
        );
        assert_eq!(p.order(Hook::Job).unwrap(), vec!["phase2", "output"]);
    }

    #[test]
    fn custom_stage_ordered_by_after() {
        let mut p = Pipeline::builtin();
        p.register(Hook::Group, &["eval"], Arc::new(Named("sample"))).unwrap();
        p.register(Hook::Group, &["decompress"], Arc::new(Named("audit"))).unwrap();
        let order = p.order(Hook::Group).unwrap();
        let pos = |n: &str| order.iter().position(|x| x == n).unwrap();
        assert!(pos("sample") > pos("eval"));
        assert!(pos("audit") > pos("decompress"));
    }

    #[test]
    fn duplicate_name_rejected() {
        let mut p = Pipeline::builtin();
        assert!(p.register(Hook::Group, &[], Arc::new(Named("eval"))).is_err());
        // Same name at the *other* hook is fine.
        assert!(p.register(Hook::Job, &[], Arc::new(Named("eval"))).is_ok());
    }

    #[test]
    fn unknown_after_is_error() {
        let mut p = Pipeline::builtin();
        p.register(Hook::Group, &["nonexistent"], Arc::new(Named("x"))).unwrap();
        let err = p.order(Hook::Group).unwrap_err();
        assert!(format!("{err}").contains("nonexistent"));
    }

    #[test]
    fn cycle_is_error() {
        let mut p = Pipeline::empty();
        p.register(Hook::Group, &["b"], Arc::new(Named("a"))).unwrap();
        p.register(Hook::Group, &["a"], Arc::new(Named("b"))).unwrap();
        let err = p.validate().unwrap_err();
        assert!(format!("{err}").contains("cycle"));
    }

    #[test]
    fn forward_reference_between_custom_stages_resolves() {
        let mut p = Pipeline::builtin();
        // "late" is registered before "early" but ordered after it.
        p.register(Hook::Group, &["early"], Arc::new(Named("late"))).unwrap();
        p.register(Hook::Group, &["eval"], Arc::new(Named("early"))).unwrap();
        let order = p.order(Hook::Group).unwrap();
        let pos = |n: &str| order.iter().position(|x| x == n).unwrap();
        assert!(pos("late") > pos("early"));
    }

    // ---------------- end-to-end with custom stages -------------------

    fn dataset() -> std::path::PathBuf {
        static PATH: std::sync::OnceLock<std::path::PathBuf> = std::sync::OnceLock::new();
        PATH.get_or_init(|| {
            let dir = std::env::temp_dir().join(format!("pipe_test_{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let path = dir.join("events.troot");
            let cfg = GenConfig {
                n_events: 900,
                target_branches: 170,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 21,
            };
            gen::generate(&cfg, &path).unwrap();
            path
        })
        .clone()
    }

    fn run_skim(engine: &SkimEngine, outname: &str, opts: &EngineOpts) -> SkimResult {
        let path = dataset();
        let store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&path).unwrap());
        let tl = Timeline::new();
        let out = path.parent().unwrap().join(outname);
        engine
            .run(store, &gen::higgs_query("events.troot", outname), &tl, opts, &out)
            .unwrap()
    }

    /// A sampling stage: keeps only even event ids after `eval`.
    struct EvenSampler;
    impl FilterStage for EvenSampler {
        fn name(&self) -> &str {
            "even-sampler"
        }
        fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
            if let Some(group) = &mut ctx.group {
                for passes in &mut group.passes {
                    passes.retain(|ev| ev % 2 == 0);
                }
            }
            Ok(Verdict::Continue)
        }
    }

    /// A per-branch byte-accounting stage hooked after `decompress`.
    /// Branch names resolve through the interned phase-1 slot order.
    struct ByteAudit {
        bytes: Mutex<std::collections::BTreeMap<String, u64>>,
    }
    impl FilterStage for ByteAudit {
        fn name(&self) -> &str {
            "byte-audit"
        }
        fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
            if let Some(group) = &ctx.group {
                let mut tab = self.bytes.lock().unwrap();
                for row in &group.raw {
                    for (bm, (raw, _)) in ctx.phase1_branches().iter().zip(row) {
                        *tab.entry(bm.desc.name.clone()).or_insert(0) += raw.len() as u64;
                    }
                }
            }
            Ok(Verdict::Continue)
        }
    }

    /// Vetoes every group.
    struct VetoAll;
    impl FilterStage for VetoAll {
        fn name(&self) -> &str {
            "veto-all"
        }
        fn run(&self, _ctx: &mut StageCtx) -> Result<Verdict> {
            Ok(Verdict::Drop)
        }
    }

    fn interp_opts() -> EngineOpts {
        EngineOpts { use_pjrt: false, ..Default::default() }
    }

    #[test]
    fn sampling_stage_thins_passes() {
        let baseline = run_skim(&SkimEngine::new(None), "pipe_base.troot", &interp_opts());
        assert!(baseline.n_pass > 0);

        let mut engine = SkimEngine::new(None);
        engine
            .pipeline_mut()
            .register(Hook::Group, &["eval"], Arc::new(EvenSampler))
            .unwrap();
        let sampled = run_skim(&engine, "pipe_sampled.troot", &interp_opts());
        assert!(sampled.n_pass < baseline.n_pass);
        // The output file is consistent with the thinned selection.
        let r = TRootReader::open(
            LocalFile::open(dataset().parent().unwrap().join("pipe_sampled.troot")).unwrap(),
        )
        .unwrap();
        assert_eq!(r.n_events(), sampled.n_pass);
    }

    #[test]
    fn byte_audit_stage_observes_decompressed_bytes() {
        let audit = Arc::new(ByteAudit { bytes: Mutex::new(Default::default()) });
        let mut engine = SkimEngine::new(None);
        engine
            .pipeline_mut()
            .register(Hook::Group, &["decompress"], audit.clone())
            .unwrap();
        let res = run_skim(&engine, "pipe_audit.troot", &interp_opts());
        assert!(res.n_pass > 0);
        let tab = audit.bytes.lock().unwrap();
        // Every criteria branch shows up with nonzero raw bytes.
        assert!(!tab.is_empty());
        assert!(tab.values().all(|&b| b > 0));
        assert!(tab.contains_key("Jet_pt"));
    }

    #[test]
    fn group_veto_drops_every_event() {
        let mut engine = SkimEngine::new(None);
        engine
            .pipeline_mut()
            .register(Hook::Group, &["eval"], Arc::new(VetoAll))
            .unwrap();
        let res = run_skim(&engine, "pipe_veto.troot", &interp_opts());
        assert_eq!(res.n_pass, 0);
        let r = TRootReader::open(
            LocalFile::open(dataset().parent().unwrap().join("pipe_veto.troot")).unwrap(),
        )
        .unwrap();
        assert_eq!(r.n_events(), 0);
    }

    #[test]
    fn worker_pool_is_bit_identical_to_single_thread() {
        // The threaded engine (decompress/deserialize/append fan-out)
        // must produce the same selection, funnel and output file as
        // the legacy in-line path — threading changes attribution, not
        // results.
        let base = run_skim(&SkimEngine::new(None), "pipe_par1.troot", &interp_opts());
        for par in [2.0f64, 4.0] {
            let opts = EngineOpts { use_pjrt: false, parallelism: par, ..Default::default() };
            let name = format!("pipe_par{par}.troot");
            let res = run_skim(&SkimEngine::new(None), &name, &opts);
            assert_eq!(res.n_pass, base.n_pass, "parallelism {par}");
            assert_eq!(res.stage_funnel, base.stage_funnel, "parallelism {par}");
            assert_eq!(res.fetched_bytes, base.fetched_bytes, "parallelism {par}");
            let a = std::fs::read(dataset().parent().unwrap().join("pipe_par1.troot")).unwrap();
            let b = std::fs::read(dataset().parent().unwrap().join(&name)).unwrap();
            assert_eq!(a, b, "output diverges at parallelism {par}");
        }
    }

    #[test]
    fn shared_basket_cache_is_transparent_and_hits_on_reuse() {
        let base = run_skim(&SkimEngine::new(None), "pipe_nocache.troot", &interp_opts());
        let cache = Arc::new(crate::serve::BasketCache::new(256 * 1000 * 1000));
        let opts = EngineOpts {
            use_pjrt: false,
            basket_cache: Some(cache.clone()),
            ..Default::default()
        };
        let first = run_skim(&SkimEngine::new(None), "pipe_cached1.troot", &opts);
        let second = run_skim(&SkimEngine::new(None), "pipe_cached2.troot", &opts);
        assert_eq!(first.n_pass, base.n_pass);
        assert_eq!(second.stage_funnel, base.stage_funnel);
        let dir = dataset().parent().unwrap().to_path_buf();
        let a = std::fs::read(dir.join("pipe_nocache.troot")).unwrap();
        let b = std::fs::read(dir.join("pipe_cached1.troot")).unwrap();
        let c = std::fs::read(dir.join("pipe_cached2.troot")).unwrap();
        assert_eq!(a, b, "cache must not change the output bytes");
        assert_eq!(a, c, "hits must not change the output bytes");
        let stats = cache.stats();
        assert!(stats.misses > 0);
        assert!(stats.hits >= stats.misses, "second run must hit everywhere");
        // The second run was served entirely from the shared cache.
        assert_eq!(second.baskets_fetched, 0);
        assert_eq!(second.fetched_bytes, 0);
    }

    #[test]
    fn event_range_shards_partition_the_selection() {
        let full = run_skim(&SkimEngine::new(None), "pipe_full.troot", &interp_opts());
        let half = 450u64;
        let lo_opts =
            EngineOpts { use_pjrt: false, event_range: Some((0, half)), ..Default::default() };
        let hi_opts =
            EngineOpts { use_pjrt: false, event_range: Some((half, u64::MAX)), ..Default::default() };
        let lo = run_skim(&SkimEngine::new(None), "pipe_lo.troot", &lo_opts);
        let hi = run_skim(&SkimEngine::new(None), "pipe_hi.troot", &hi_opts);
        assert_eq!(lo.n_events + hi.n_events, full.n_events);
        assert_eq!(lo.n_pass + hi.n_pass, full.n_pass);
        for s in 0..4 {
            assert_eq!(lo.stage_funnel[s] + hi.stage_funnel[s], full.stage_funnel[s]);
        }
    }

    // ---------------- zone-map basket pruning -------------------------

    /// Run a cut-string skim over the shared fixture, returning the
    /// result *and* the timeline (for the prune counters).
    fn run_cut(outname: &str, cut: &str, opts: &EngineOpts) -> (SkimResult, Timeline) {
        let path = dataset();
        let store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&path).unwrap());
        let tl = Timeline::new();
        let out = path.parent().unwrap().join(outname);
        let query = SkimQuery::new("events.troot", outname)
            .keep(&["MET_pt", "event", "nJet", "Jet_pt"])
            .with_cut_str(cut)
            .unwrap();
        let res = SkimEngine::new(None).run(store, &query, &tl, opts, &out).unwrap();
        (res, tl)
    }

    /// The fixture's zone map, derived once from the data file (the
    /// legacy `skimroot index` path — byte-identical to writer-derived).
    fn dataset_index() -> Arc<crate::index::FileIndex> {
        static IDX: std::sync::OnceLock<Arc<crate::index::FileIndex>> =
            std::sync::OnceLock::new();
        IDX.get_or_init(|| {
            Arc::new(crate::index::FileIndex::build_from_file(dataset()).unwrap())
        })
        .clone()
    }

    #[test]
    fn zone_map_prunes_dead_baskets_and_output_is_byte_identical() {
        // The `event` counter is 1_000_000 + ev over 900 events in five
        // 200-event baskets, so this cut provably kills baskets 0-1 and
        // provably keeps 2-4 — deterministic prune counts.
        let cut = "event >= 1000400";
        let (base, base_tl) = run_cut("pipe_zm_base.troot", cut, &interp_opts());
        assert_eq!(base.n_pass, 500);
        assert_eq!(base_tl.counter("baskets_pruned"), 0);
        assert_eq!(base_tl.counter("baskets_scanned"), 5);

        let opts = EngineOpts {
            use_pjrt: false,
            zone_map: Some(dataset_index()),
            ..Default::default()
        };
        let (pruned, tl) = run_cut("pipe_zm_pruned.troot", cut, &opts);
        assert_eq!(pruned.n_pass, base.n_pass);
        assert_eq!(pruned.n_events, base.n_events);
        // One criteria branch (`event`) × 2 dead clusters / 3 live.
        assert_eq!(tl.counter("baskets_pruned"), 2);
        assert_eq!(tl.counter("baskets_scanned"), 3);
        assert!(pruned.fetched_bytes < base.fetched_bytes);
        assert!(pruned.warnings.is_empty(), "{:?}", pruned.warnings);

        let dir = dataset().parent().unwrap().to_path_buf();
        let a = std::fs::read(dir.join("pipe_zm_base.troot")).unwrap();
        let b = std::fs::read(dir.join("pipe_zm_pruned.troot")).unwrap();
        assert_eq!(a, b, "pruning must not change the output bytes");
    }

    #[test]
    fn zone_map_pruning_matches_the_oracle_across_cut_shapes() {
        // Property check against the scalar-oracle path: for a spread
        // of operators (>, <, >=, ==, !=, conjunctions, trigger-style
        // flags) the pruned run must be byte-identical to the full
        // scan, whatever the zone maps happened to refute.
        let opts_zm = EngineOpts {
            use_pjrt: false,
            zone_map: Some(dataset_index()),
            ..Default::default()
        };
        let dir = dataset().parent().unwrap().to_path_buf();
        for (i, cut) in [
            "MET_pt > 200",
            "MET_pt < 1.0",
            "MET_pt >= 150 && nJet >= 3",
            "event == 1000513",
            "event != 1000000",
            "HLT_IsoMu24 > 0.5 && event < 1000200",
            "PV_z < -0.1",
        ]
        .iter()
        .enumerate()
        {
            let base_name = format!("pipe_zmo_{i}_base.troot");
            let zm_name = format!("pipe_zmo_{i}_zm.troot");
            let (base, _) = run_cut(&base_name, cut, &interp_opts());
            let (zm, _) = run_cut(&zm_name, cut, &opts_zm);
            assert_eq!(zm.n_pass, base.n_pass, "cut {cut}");
            let a = std::fs::read(dir.join(&base_name)).unwrap();
            let b = std::fs::read(dir.join(&zm_name)).unwrap();
            assert_eq!(a, b, "cut {cut} diverges under pruning");
        }
    }

    // ---------------- selectivity-adaptive execution ------------------

    #[test]
    fn adaptive_execution_is_byte_identical_and_profiles_conjuncts() {
        let cut = "MET_pt > 25 && nJet >= 1 && HLT_IsoMu24 > 0.5";
        let (base, base_tl) = run_cut("pipe_ad_base.troot", cut, &interp_opts());
        assert!(base_tl.profile().is_empty(), "fixed path must not profile");

        let mut opts = interp_opts();
        opts.adaptive.enabled = true;
        opts.adaptive.warmup_groups = 1;
        opts.adaptive.replan_every = 1;
        let (ad, tl) = run_cut("pipe_ad_on.troot", cut, &opts);
        assert_eq!(ad.n_pass, base.n_pass);
        assert_eq!(ad.n_events, base.n_events);
        // The last funnel stage is the final survivor count — invariant
        // under reordering (earlier stages may legitimately shift).
        assert_eq!(ad.stage_funnel[3], base.stage_funnel[3]);
        let dir = dataset().parent().unwrap().to_path_buf();
        let a = std::fs::read(dir.join("pipe_ad_base.troot")).unwrap();
        let b = std::fs::read(dir.join("pipe_ad_on.troot")).unwrap();
        assert_eq!(a, b, "adaptive order must not change the output bytes");

        let prof = tl.profile();
        assert!(!prof.is_empty(), "adaptive run must report a profile");
        assert!(prof.iter().any(|p| p.key == "MET_pt > 25"), "{prof:?}");
        assert!(prof.iter().all(|p| p.passed <= p.visited));
        // Every event is visited by whichever conjunct ran first in its
        // group, so the tallies cover the file at least once.
        let visited: u64 = prof.iter().map(|p| p.visited).sum();
        assert!(visited >= ad.n_events, "{visited} < {}", ad.n_events);
    }

    #[test]
    fn adaptive_seed_profile_ranks_the_order_from_group_one() {
        // A seed claiming MET_pt is all-pass and the trigger maximally
        // selective must flip the starting order — and still produce
        // byte-identical output.
        let cut = "MET_pt > 25 && HLT_IsoMu24 > 0.5";
        let (base, _) = run_cut("pipe_ad_seed_base.troot", cut, &interp_opts());
        let mut seed = crate::query::SelectivityProfile::default();
        seed.record("MET_pt > 25", 1000, 1000, 10);
        let mut opts = interp_opts();
        opts.adaptive.enabled = true;
        opts.adaptive.seed = Some(seed);
        let (ad, tl) = run_cut("pipe_ad_seed.troot", cut, &opts);
        assert_eq!(ad.n_pass, base.n_pass);
        let dir = dataset().parent().unwrap().to_path_buf();
        let a = std::fs::read(dir.join("pipe_ad_seed_base.troot")).unwrap();
        let b = std::fs::read(dir.join("pipe_ad_seed.troot")).unwrap();
        assert_eq!(a, b, "seeded order must not change the output bytes");
        // The reported profile counts only this job's events, not the
        // seed's.
        let prof = tl.profile();
        let met = prof.iter().find(|p| p.key == "MET_pt > 25").unwrap();
        assert!(met.visited <= ad.n_events, "{met:?}");
    }

    #[test]
    fn stale_zone_map_warns_and_degrades_to_a_full_scan() {
        let cut = "event >= 1000400";
        let (base, _) = run_cut("pipe_zm_full.troot", cut, &interp_opts());
        let mut stale = (*dataset_index()).clone();
        stale.digest ^= 0xdead_beef;
        let opts = EngineOpts {
            use_pjrt: false,
            zone_map: Some(Arc::new(stale)),
            ..Default::default()
        };
        let (res, tl) = run_cut("pipe_zm_stale.troot", cut, &opts);
        assert!(
            res.warnings.iter().any(|w| w.contains("stale zone-map")),
            "{:?}",
            res.warnings
        );
        assert_eq!(tl.counter("baskets_pruned"), 0);
        assert_eq!(tl.counter("baskets_scanned"), 5);
        assert_eq!(res.n_pass, base.n_pass);
        let dir = dataset().parent().unwrap().to_path_buf();
        let a = std::fs::read(dir.join("pipe_zm_full.troot")).unwrap();
        let b = std::fs::read(dir.join("pipe_zm_stale.troot")).unwrap();
        assert_eq!(a, b, "a stale sidecar must not change results");
    }
}
