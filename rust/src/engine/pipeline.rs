//! The pluggable filter pipeline: SkimROOT's execution stages as
//! netfilter-style hooks.
//!
//! The engine used to inline its phases (criteria fetch → decompress →
//! deserialize/batch → cut-eval → phase-2 selective fetch → output
//! write) in one monolithic `run`. They are now **built-in stages** of
//! a [`Pipeline`], and users can register custom [`FilterStage`]s
//! around them — per-branch byte accounting, sampling, extra vetoes —
//! without forking the engine.
//!
//! Two hook points, mirroring the engine's execution granularity:
//!
//! * [`Hook::Group`] — runs once per *cluster group* (the batching unit
//!   that packs consecutive event clusters up to the kernel's batch
//!   capacity). Built-ins, in `after`-DAG order:
//!   `fetch` → `decompress` → `deserialize` → `eval`.
//! * [`Hook::Job`] — runs once after all groups. Built-ins:
//!   `phase2` (selective output-only fetch for passing events) →
//!   `output` (write the filtered file).
//!
//! Stage ordering is name-based with `after` dependencies (a DAG, not
//! numeric priorities); ties are broken by registration order.
//! Verdict semantics follow netfilter: [`Verdict::Continue`] means "no
//! objection", [`Verdict::Drop`] is a veto — at the Group hook it
//! rejects every event of the current group (remaining group stages are
//! skipped), at the Job hook it skips the remaining job stages, which
//! aborts the job if the `output` stage never runs.
//!
//! A custom stage observes and mutates the in-flight [`StageCtx`]: the
//! current [`GroupState`] (fetched frames, decompressed bytes, decoded
//! baskets, per-cluster pass lists), the plan, and the funnel. A stage
//! registered `after: ["eval"]` that thins `group.passes` implements
//! sampling; one registered `after: ["decompress"]` that sums
//! `group.raw` byte lengths implements per-branch byte accounting.

use super::{DecompMode, EngineOpts, SkimResult};
use crate::metrics::{Node, Stage, Timeline};
use crate::query::plan::{
    SkimPlan, KERNEL_MAX_GROUPS, KERNEL_MAX_OBJ_CUTS, KERNEL_MAX_SCALAR_CUTS,
};
use crate::query::SkimQuery;
use crate::runtime::{Batch, Capacities, CutParams, MaskResult, SkimRuntime, Variant};
use crate::troot::{
    basket as basket_codec, BasketInfo, BranchKind, BranchMeta, ColumnData, ColumnValues,
    DecodedBasket, FileMeta, ReadAt, TRootReader,
};
use crate::xrootd::TTreeCache;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Netfilter-style stage outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// "No objection": continue with the next stage.
    Continue,
    /// Veto. At [`Hook::Group`] the current group's events are all
    /// rejected and its remaining stages are skipped; at [`Hook::Job`]
    /// the remaining job stages are skipped.
    Drop,
}

/// Where a stage is attached.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Hook {
    /// Once per cluster group (the engine's batching unit).
    Group,
    /// Once per job, after every group has been processed.
    Job,
}

/// One pipeline stage. Implementations must be `Send + Sync` so the
/// same engine can be shared across worker threads.
pub trait FilterStage: Send + Sync {
    /// Unique (per hook) stage name used for `after` ordering.
    fn name(&self) -> &str;
    /// Run over the in-flight job/group state.
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict>;
}

/// A registered stage plus its ordering constraints.
pub(crate) struct Registration {
    pub(crate) name: String,
    pub(crate) after: Vec<String>,
    pub(crate) stage: Arc<dyn FilterStage>,
}

/// A portable stage registration (hook + ordering + stage), used to
/// carry custom stages through [`crate::coordinator::Coordinator`] /
/// [`crate::SkimJob`] into every engine a deployment spins up.
#[derive(Clone)]
pub struct StageReg {
    pub hook: Hook,
    pub after: Vec<String>,
    pub stage: Arc<dyn FilterStage>,
}

impl StageReg {
    pub fn new(hook: Hook, after: &[&str], stage: Arc<dyn FilterStage>) -> Self {
        StageReg { hook, after: after.iter().map(|s| s.to_string()).collect(), stage }
    }
}

/// The stage registry for one engine: built-ins plus user stages.
pub struct Pipeline {
    group: Vec<Registration>,
    job: Vec<Registration>,
}

impl Pipeline {
    /// The standard SkimROOT pipeline (the refactored engine phases).
    pub fn builtin() -> Pipeline {
        let mut p = Pipeline::empty();
        p.register(Hook::Group, &[], Arc::new(FetchStage)).expect("builtin");
        p.register(Hook::Group, &["fetch"], Arc::new(DecompressStage)).expect("builtin");
        p.register(Hook::Group, &["decompress"], Arc::new(DeserializeStage)).expect("builtin");
        p.register(Hook::Group, &["deserialize"], Arc::new(EvalStage)).expect("builtin");
        p.register(Hook::Job, &[], Arc::new(Phase2Stage)).expect("builtin");
        p.register(Hook::Job, &["phase2"], Arc::new(OutputStage)).expect("builtin");
        p
    }

    /// A pipeline with no stages at all (build-your-own; mostly tests).
    pub fn empty() -> Pipeline {
        Pipeline { group: Vec::new(), job: Vec::new() }
    }

    /// Register `stage` at `hook`, ordered after the named stages.
    /// Names must be unique per hook; `after` references are resolved
    /// (and cycles detected) when the pipeline is ordered at job start,
    /// so forward references between custom stages are allowed.
    pub fn register(
        &mut self,
        hook: Hook,
        after: &[&str],
        stage: Arc<dyn FilterStage>,
    ) -> Result<()> {
        let name = stage.name().to_string();
        if name.is_empty() {
            return Err(Error::Config("stage name must not be empty".into()));
        }
        let regs = match hook {
            Hook::Group => &mut self.group,
            Hook::Job => &mut self.job,
        };
        if regs.iter().any(|r| r.name == name) {
            return Err(Error::Config(format!(
                "duplicate stage '{name}' at {hook:?} hook"
            )));
        }
        regs.push(Registration {
            name,
            after: after.iter().map(|s| s.to_string()).collect(),
            stage,
        });
        Ok(())
    }

    /// Registered stage names at `hook`, in registration order.
    pub fn names(&self, hook: Hook) -> Vec<String> {
        let regs = match hook {
            Hook::Group => &self.group,
            Hook::Job => &self.job,
        };
        regs.iter().map(|r| r.name.clone()).collect()
    }

    /// Execution order at `hook` (topological over `after`, ties broken
    /// by registration order). Errors on unknown `after` names and on
    /// dependency cycles.
    pub fn order(&self, hook: Hook) -> Result<Vec<String>> {
        Ok(self.ordered(hook)?.iter().map(|r| r.name.clone()).collect())
    }

    /// Validate both hooks' DAGs without running anything.
    pub fn validate(&self) -> Result<()> {
        self.ordered(Hook::Group)?;
        self.ordered(Hook::Job)?;
        Ok(())
    }

    pub(crate) fn ordered(&self, hook: Hook) -> Result<Vec<&Registration>> {
        let regs = match hook {
            Hook::Group => &self.group,
            Hook::Job => &self.job,
        };
        let index: HashMap<&str, usize> = regs
            .iter()
            .enumerate()
            .map(|(i, r)| (r.name.as_str(), i))
            .collect();
        let mut indegree = vec![0usize; regs.len()];
        let mut edges: Vec<Vec<usize>> = vec![Vec::new(); regs.len()];
        for (i, r) in regs.iter().enumerate() {
            for a in &r.after {
                let &j = index.get(a.as_str()).ok_or_else(|| {
                    Error::Config(format!(
                        "stage '{}' is ordered after '{}', which is not registered at the {hook:?} hook",
                        r.name, a
                    ))
                })?;
                edges[j].push(i);
                indegree[i] += 1;
            }
        }
        let mut ready: Vec<usize> =
            (0..regs.len()).filter(|&i| indegree[i] == 0).collect();
        let mut out = Vec::with_capacity(regs.len());
        while !ready.is_empty() {
            ready.sort_unstable();
            let i = ready.remove(0);
            out.push(i);
            for &k in &edges[i] {
                indegree[k] -= 1;
                if indegree[k] == 0 {
                    ready.push(k);
                }
            }
        }
        if out.len() != regs.len() {
            let stuck: Vec<&str> = regs
                .iter()
                .enumerate()
                .filter(|(i, _)| !out.contains(i))
                .map(|(_, r)| r.name.as_str())
                .collect();
            return Err(Error::Config(format!(
                "stage dependency cycle at {hook:?} hook involving: {}",
                stuck.join(", ")
            )));
        }
        Ok(out.into_iter().map(|i| &regs[i]).collect())
    }
}

/// Per-group scratch state flowing through the [`Hook::Group`] stages.
pub struct GroupState {
    /// `(cluster index, first event id, event count)` per cluster in
    /// this group. Event ids are global; counts respect any
    /// [`EngineOpts::event_range`] restriction at range boundaries.
    pub clusters: Vec<(usize, u64, usize)>,
    /// Per cluster: branch name → compressed basket frame (after the
    /// built-in `fetch` stage). **Drained by `decompress`** — custom
    /// stages cannot order between the built-ins, so nothing observes
    /// frames; per-branch compressed sizes survive in each entry's
    /// [`BasketInfo`].
    pub frames: Vec<HashMap<String, (Vec<u8>, BasketInfo)>>,
    /// Per cluster: branch name → raw decompressed bytes (after
    /// `decompress`). Retained until the group commits so custom
    /// stages can audit them — the memory cost of the observability
    /// API (≈ one group's decompressed working set).
    pub raw: Vec<HashMap<String, (Vec<u8>, BasketInfo)>>,
    /// Per cluster: branch name → typed decoded basket (after
    /// `deserialize`).
    pub decoded: Vec<HashMap<String, DecodedBasket>>,
    /// Passing event ids per cluster in this group (after `eval`).
    /// Custom stages may thin these lists (sampling, extra vetoes);
    /// whatever remains when the group commits is gathered into the
    /// output.
    pub passes: Vec<Vec<u64>>,
    /// Compressed bytes fetched for this group.
    pub fetched_bytes: u64,
}

#[derive(Default)]
pub(crate) struct FetchCounters {
    pub(crate) baskets: u64,
    pub(crate) bytes: u64,
}

/// Accumulates one output branch's values for passing events.
pub(crate) struct OutputAcc {
    pub(crate) desc: crate::troot::BranchDesc,
    offsets: Vec<u32>,
    values: ColumnValues,
}

impl OutputAcc {
    fn new(desc: crate::troot::BranchDesc) -> Self {
        let values = ColumnValues::empty(desc.dtype);
        OutputAcc { desc, offsets: vec![0], values }
    }

    /// Gather from an already-decoded basket (cheap copy).
    fn push_event(&mut self, basket: &DecodedBasket, ev: u64) {
        match self.desc.kind {
            BranchKind::Scalar => {
                let i = (ev - basket.first_event) as usize;
                self.values.push_from(&basket.values, i);
            }
            BranchKind::Jagged => {
                let r = basket.jagged_range(ev);
                self.values.extend_from_range(&basket.values, r);
                self.offsets.push(self.values.len() as u32);
            }
        }
    }

    /// Selectively deserialize one event straight from the raw basket
    /// payload (the per-event `GetEntry` path used by phase 2).
    /// Returns the number of raw bytes materialized.
    fn push_event_raw(&mut self, raw: &[u8], info: &BasketInfo, ev: u64) -> Result<usize> {
        let local = (ev - info.first_event) as usize;
        let before = self.values.len();
        basket_codec::append_event(
            &self.desc,
            raw,
            info.n_events as usize,
            local,
            &mut self.offsets,
            &mut self.values,
        )?;
        Ok((self.values.len() - before) * self.desc.dtype.size())
    }

    fn finish(self) -> ColumnData {
        match self.desc.kind {
            BranchKind::Scalar => ColumnData::Scalar(self.values),
            BranchKind::Jagged => {
                ColumnData::Jagged { offsets: self.offsets, values: self.values }
            }
        }
    }
}

/// Decompress one basket frame, wall-clocking the work and attributing
/// it per [`DecompMode`] (compute node's CPU, or the DPU's hardware
/// engine at its calibrated speedup). The single source of truth for
/// decompression cost accounting — both the group `decompress` stage
/// and the phase-2 selective path go through here.
fn decompress_attributed(timeline: &Timeline, opts: &EngineOpts, frame: &[u8]) -> Result<Vec<u8>> {
    let t0 = Instant::now();
    let raw = crate::compress::decompress(frame)?;
    let dt = t0.elapsed().as_secs_f64();
    match opts.decomp {
        DecompMode::Software => timeline.add_real(Stage::Decompress, opts.compute_node, dt),
        DecompMode::HwEngine { speedup } => {
            timeline.add_real(Stage::Decompress, Node::DpuEngine, dt / speedup.max(1e-9))
        }
    }
    timeline.add_bytes(Stage::Decompress, raw.len() as u64);
    Ok(raw)
}

/// Fetch + decompress the basket of `branch` covering event `lo`,
/// charging transport virtually (via the store) and decompression via
/// [`decompress_attributed`]. Free function over disjoint ctx fields
/// so callers can hold other borrows.
fn fetch_decompress(
    reader: &TRootReader<Arc<dyn ReadAt>>,
    counters: &mut FetchCounters,
    timeline: &Timeline,
    opts: &EngineOpts,
    branch: &BranchMeta,
    lo: u64,
) -> Result<(Vec<u8>, BasketInfo)> {
    let idx = branch.basket_for_event(lo).ok_or_else(|| {
        Error::Engine(format!(
            "branch {} has no basket for event {lo}",
            branch.desc.name
        ))
    })?;
    let info = branch.baskets[idx];
    let frame = reader.fetch_basket(branch, idx)?;
    counters.baskets += 1;
    counters.bytes += info.comp_len as u64;
    let raw = decompress_attributed(timeline, opts, &frame)?;
    Ok((raw, info))
}

/// The in-flight state of one skim job, visible to every stage.
///
/// Immutable job context (`plan`, `opts`, `timeline`, `meta`) is
/// exposed read-only; mutable job state (`stage_funnel`, `warnings`,
/// the current `group`) is public for stages to inspect and adjust.
pub struct StageCtx<'a> {
    pub opts: &'a EngineOpts,
    pub timeline: &'a Timeline,
    pub plan: SkimPlan,
    /// The §3.2 funnel: cumulative survivors after (preselection,
    /// +object, +HT, +trigger).
    pub stage_funnel: [u64; 4],
    /// Events committed as passing so far (updated at group commit).
    pub pass_total: u64,
    pub warnings: Vec<String>,
    /// The active cluster group, `Some` between `begin_group` and
    /// commit. Group-hook stages operate on this.
    pub group: Option<GroupState>,

    reader: TRootReader<Arc<dyn ReadAt>>,
    meta: FileMeta,
    cache: Option<Arc<TTreeCache<Arc<dyn ReadAt>>>>,
    runtime: Option<&'a SkimRuntime>,
    vectorized: bool,
    caps: Capacities,
    batch_b: usize,
    m: usize,
    variant: Option<&'a Variant>,
    params: Option<CutParams>,
    basket_events: usize,
    /// Events covered by this job (the whole file, or the
    /// `event_range` shard of it).
    range_events: u64,
    /// `(cluster, lo, n)` windows this job iterates, range-restricted.
    cluster_window: Vec<(usize, u64, usize)>,
    next_window: usize,
    /// Branches read in phase 1 (criteria; plus all output branches in
    /// legacy single-phase mode).
    phase1: Vec<BranchMeta>,
    /// Output-only branches (phase 2).
    output_only: Vec<BranchMeta>,
    /// Branch names gathered from decoded phase-1 baskets at commit.
    gather_now: Vec<String>,
    accs: HashMap<String, OutputAcc>,
    /// Passing events per absolute cluster id (feeds phase 2).
    cluster_pass: Vec<Vec<u64>>,
    counters: FetchCounters,
    output_path: PathBuf,
    output_summary: Option<crate::troot::writer::WriteSummary>,
}

impl<'a> StageCtx<'a> {
    pub(crate) fn new(
        runtime: Option<&'a SkimRuntime>,
        store: Arc<dyn ReadAt>,
        query: &SkimQuery,
        timeline: &'a Timeline,
        opts: &'a EngineOpts,
        output_path: PathBuf,
    ) -> Result<StageCtx<'a>> {
        // Optional TTreeCache in front of the store.
        let cache = opts
            .cache_bytes
            .map(|cap| Arc::new(TTreeCache::new(store.clone(), cap)));
        let eff_store: Arc<dyn ReadAt> = match &cache {
            Some(c) => c.clone(),
            None => store,
        };

        let reader = TRootReader::open(eff_store)?;
        let meta = reader.meta().clone();
        let plan = SkimPlan::build(query, &meta)?;
        let mut warnings = plan.warnings.clone();

        // --- evaluation strategy -------------------------------------
        let vectorized = opts.use_pjrt && plan.program.fits_kernel() && runtime.is_some();
        if opts.use_pjrt && !vectorized {
            warnings.push("vectorized path unavailable; using interpreter".into());
        }
        let caps = if vectorized {
            runtime.expect("vectorized implies runtime").caps
        } else {
            // Interpreter batches are sized to the *program*, not the
            // kernel's fixed banks: cut programs beyond kernel
            // capacity (the fallback's whole point) still assemble
            // without overflowing the column arrays. The cut-bank
            // fields are unused on this path (CutParams::pack is
            // vectorized-only); fill them with the kernel constants.
            Capacities {
                c: plan.program.obj_columns.len(),
                s: plan.program.scalar_columns.len(),
                k_obj: KERNEL_MAX_OBJ_CUTS,
                k_sc: KERNEL_MAX_SCALAR_CUTS,
                g: KERNEL_MAX_GROUPS,
                n_stages: 4,
            }
        };
        let basket_events = meta.basket_events.max(1) as usize;
        let (batch_b, m, variant) = if vectorized {
            let rt = runtime.unwrap();
            let v = rt.variant_for(basket_events);
            (v.b, v.m, Some(v))
        } else {
            // The interpreter has no per-call overhead; size batches to
            // one cluster.
            (basket_events, opts.max_objects, None)
        };
        let params = if vectorized {
            Some(CutParams::pack(&plan.program, &caps)?)
        } else {
            None
        };

        // --- event range (whole file, or one shard of it) ------------
        let (start, end) = {
            let (s, e) = opts.event_range.unwrap_or((0, meta.n_events));
            (s.min(meta.n_events), e.min(meta.n_events))
        };
        let range_events = end.saturating_sub(start);
        let n_clusters_total = (meta.n_events as usize).div_ceil(basket_events);
        let mut cluster_window = Vec::new();
        if start < end {
            let first = (start / basket_events as u64) as usize;
            let last = (end as usize).div_ceil(basket_events);
            for cluster in first..last {
                let lo = ((cluster * basket_events) as u64).max(start);
                let hi = (((cluster + 1) * basket_events) as u64).min(end);
                if lo < hi {
                    cluster_window.push((cluster, lo, (hi - lo) as usize));
                }
            }
        }

        // --- branch sets ---------------------------------------------
        let branch_meta =
            |name: &str| -> Result<BranchMeta> { Ok(reader.branch(name)?.clone()) };
        let criteria: Vec<BranchMeta> = plan
            .criteria_branches
            .iter()
            .map(|b| branch_meta(b))
            .collect::<Result<_>>()?;
        let output_only: Vec<BranchMeta> = plan
            .output_only_branches
            .iter()
            .map(|b| branch_meta(b))
            .collect::<Result<_>>()?;

        // Phase-1 fetch set: criteria (+ all output branches in legacy
        // mode, fully decoded for every cluster — the baseline's cost).
        let mut phase1: Vec<BranchMeta> = criteria.clone();
        if !opts.two_phase {
            phase1.extend(output_only.iter().cloned());
        }
        // Branch names gathered right after evaluation from the decoded
        // baskets: criteria∩output in two-phase mode (already in
        // memory), all output branches in legacy mode.
        let gather_now: Vec<String> = if opts.two_phase {
            criteria
                .iter()
                .map(|b| b.desc.name.clone())
                .filter(|n| plan.output_branches.contains(n))
                .collect()
        } else {
            plan.output_branches.clone()
        };

        if let Some(c) = &cache {
            let mut ranges = Vec::new();
            for b in &phase1 {
                for ki in b.baskets_for_range(start, end) {
                    let k = &b.baskets[ki];
                    ranges.push((k.offset, k.comp_len as usize));
                }
            }
            c.train(ranges);
        }

        // Output accumulators.
        let accs: HashMap<String, OutputAcc> = plan
            .output_branches
            .iter()
            .map(|name| {
                let bm = branch_meta(name)?;
                Ok((name.clone(), OutputAcc::new(bm.desc.clone())))
            })
            .collect::<Result<_>>()?;

        Ok(StageCtx {
            opts,
            timeline,
            plan,
            stage_funnel: [0; 4],
            pass_total: 0,
            warnings,
            group: None,
            reader,
            meta,
            cache,
            runtime,
            vectorized,
            caps,
            batch_b,
            m,
            variant,
            params,
            basket_events,
            range_events,
            cluster_window,
            next_window: 0,
            phase1,
            output_only,
            gather_now,
            accs,
            cluster_pass: vec![Vec::new(); n_clusters_total],
            counters: FetchCounters::default(),
            output_path,
            output_summary: None,
        })
    }

    /// File metadata of the input being skimmed.
    pub fn meta(&self) -> &FileMeta {
        &self.meta
    }

    /// Events this job covers (whole file or the shard's range).
    pub fn n_events(&self) -> u64 {
        self.range_events
    }

    /// Did the vectorized PJRT path evaluate this job's cuts?
    pub fn vectorized(&self) -> bool {
        self.vectorized
    }

    /// Start the next cluster group: pack consecutive clusters until
    /// the batch capacity is reached. Returns false when exhausted.
    pub(crate) fn begin_group(&mut self) -> bool {
        if self.next_window >= self.cluster_window.len() {
            return false;
        }
        let mut clusters = Vec::new();
        let mut total = 0usize;
        while self.next_window < self.cluster_window.len() {
            let (cl, lo, n) = self.cluster_window[self.next_window];
            if !clusters.is_empty() && total + n > self.batch_b {
                break;
            }
            clusters.push((cl, lo, n));
            total += n;
            self.next_window += 1;
            if total >= self.batch_b {
                break;
            }
        }
        let k = clusters.len();
        self.group = Some(GroupState {
            clusters,
            frames: Vec::with_capacity(k),
            raw: Vec::with_capacity(k),
            decoded: Vec::with_capacity(k),
            passes: vec![Vec::new(); k],
            fetched_bytes: 0,
        });
        true
    }

    /// Discard the active group without committing (a stage vetoed it).
    pub(crate) fn abort_group(&mut self) {
        self.group = None;
    }

    /// Fold the active group's surviving passes into the job: gather
    /// criteria∩output values from decoded baskets, record per-cluster
    /// pass lists for phase 2.
    pub(crate) fn commit_group(&mut self) -> Result<()> {
        let group = match self.group.take() {
            Some(g) => g,
            None => return Ok(()),
        };
        let timeline = self.timeline;
        let node = self.opts.compute_node;
        for (gi, &(cl, _, _)) in group.clusters.iter().enumerate() {
            let passes = &group.passes[gi];
            if passes.is_empty() {
                continue;
            }
            self.pass_total += passes.len() as u64;
            let t0 = Instant::now();
            for name in &self.gather_now {
                let dec = group.decoded[gi].get(name).ok_or_else(|| {
                    Error::Engine(format!("gather: missing decoded basket '{name}'"))
                })?;
                let acc = self.accs.get_mut(name).expect("acc exists");
                for &ev in passes {
                    acc.push_event(dec, ev);
                }
            }
            timeline.add_real(Stage::Deserialize, node, t0.elapsed().as_secs_f64());
            self.cluster_pass[cl].extend_from_slice(passes);
        }
        Ok(())
    }

    // ---------------- built-in stage bodies --------------------------

    fn fetch_group(&mut self, group: &mut GroupState) -> Result<()> {
        for &(_, lo, _) in &group.clusters {
            let mut map = HashMap::new();
            for b in &self.phase1 {
                let idx = b.basket_for_event(lo).ok_or_else(|| {
                    Error::Engine(format!(
                        "branch {} has no basket for event {lo}",
                        b.desc.name
                    ))
                })?;
                let info = b.baskets[idx];
                // Fetch: transport time is charged virtually by the
                // store (wire/disk model); we track volume here.
                let frame = self.reader.fetch_basket(b, idx)?;
                self.counters.baskets += 1;
                self.counters.bytes += info.comp_len as u64;
                group.fetched_bytes += info.comp_len as u64;
                map.insert(b.desc.name.clone(), (frame, info));
            }
            group.frames.push(map);
        }
        Ok(())
    }

    fn decompress_group(&mut self, group: &mut GroupState) -> Result<()> {
        let timeline = self.timeline;
        // Frames are *consumed* here: custom stages always order after
        // the built-in chain (ties break by registration order), so
        // nothing can observe `frames` between `fetch` and
        // `decompress` — retaining compressed alongside raw bytes
        // would be pure memory waste at paper scale (1749 branches).
        for frames in std::mem::take(&mut group.frames) {
            let mut map = HashMap::new();
            for (name, (frame, info)) in frames {
                let raw = decompress_attributed(timeline, self.opts, &frame)?;
                map.insert(name, (raw, info));
            }
            group.raw.push(map);
        }
        Ok(())
    }

    fn deserialize_group(&mut self, group: &mut GroupState) -> Result<()> {
        let timeline = self.timeline;
        let node = self.opts.compute_node;
        for raw_maps in &group.raw {
            let mut map = HashMap::new();
            for bm in &self.phase1 {
                let desc = &bm.desc;
                let (raw, info) = raw_maps.get(&desc.name).ok_or_else(|| {
                    Error::Engine(format!(
                        "deserialize: missing raw basket '{}'",
                        desc.name
                    ))
                })?;
                let t0 = Instant::now();
                let dec = basket_codec::decode(
                    desc,
                    raw,
                    info.first_event,
                    info.n_events as usize,
                )?;
                timeline.add_real(Stage::Deserialize, node, t0.elapsed().as_secs_f64());
                // Modeled ROOT streamer cost: every event of this
                // basket is materialized (one GetEntry per event).
                if let Some(model) = self.opts.deser_model {
                    timeline.add_real(
                        Stage::Deserialize,
                        node,
                        model.cost(info.n_events as u64, raw.len() as u64, self.opts.parallelism),
                    );
                }
                map.insert(desc.name.clone(), dec);
            }
            group.decoded.push(map);
        }
        Ok(())
    }

    fn eval_group(&mut self, group: &mut GroupState) -> Result<()> {
        if self.plan.program.is_trivial() {
            // No cuts at all: everything passes. (Checked on the
            // program, not the criteria list — a constant-only IR cut
            // references no branches but still filters.)
            for (gi, &(_, lo, n)) in group.clusters.iter().enumerate() {
                group.passes[gi] = (lo..lo + n as u64).collect();
            }
            for &(_, _, n) in &group.clusters {
                for s in &mut self.stage_funnel {
                    *s += n as u64;
                }
            }
            return Ok(());
        }

        // Sub-chunk only when a single cluster exceeds the batch:
        // (group idx, chunk lo, chunk n, batch dst).
        let chunks: Vec<(usize, u64, usize, usize)> = {
            let mut v = Vec::new();
            let mut dst = 0usize;
            for (gi, &(_, lo, n)) in group.clusters.iter().enumerate() {
                let mut off = 0usize;
                while off < n {
                    if dst == self.batch_b {
                        // Flush boundary handled below by the window loop.
                        dst = 0;
                    }
                    let take = (n - off).min(self.batch_b - dst);
                    v.push((gi, lo + off as u64, take, dst));
                    dst += take;
                    off += take;
                }
            }
            v
        };

        // Fill + evaluate in batch_b windows.
        let mut batch = Batch::zeroed(&self.caps, self.batch_b, self.m);
        let mut window: Vec<(usize, u64, usize, usize)> = Vec::new();
        for (gi, clo, cn, dst) in chunks {
            if dst == 0 && !window.is_empty() {
                self.flush_window(&mut batch, &mut window, group)?;
            }
            let timeline = self.timeline;
            let node = self.opts.compute_node;
            let t0 = Instant::now();
            super::batch::append(&self.plan.program, &group.decoded[gi], clo, cn, &mut batch, dst)?;
            timeline.add_real(Stage::Deserialize, node, t0.elapsed().as_secs_f64());
            window.push((gi, clo, cn, dst));
        }
        self.flush_window(&mut batch, &mut window, group)?;
        Ok(())
    }

    fn flush_window(
        &mut self,
        batch: &mut Batch,
        window: &mut Vec<(usize, u64, usize, usize)>,
        group: &mut GroupState,
    ) -> Result<()> {
        if window.is_empty() {
            return Ok(());
        }
        let result = self.eval_batch(batch)?;
        for &(gi, clo, cn, dst) in window.iter() {
            for ev in 0..cn {
                let mut cum = 1.0f32;
                for (s, stage) in result.stages.iter().enumerate() {
                    cum *= stage[dst + ev];
                    self.stage_funnel[s] += cum as u64;
                }
                if result.mask[dst + ev] > 0.5 {
                    group.passes[gi].push(clo + ev as u64);
                }
            }
        }
        window.clear();
        *batch = Batch::zeroed(&self.caps, self.batch_b, self.m);
        Ok(())
    }

    fn eval_batch(&self, batch: &Batch) -> Result<MaskResult> {
        if self.vectorized {
            let rt = self.runtime.expect("vectorized implies runtime");
            let v = self.variant.expect("vectorized implies variant");
            let p = self.params.as_ref().expect("vectorized implies params");
            let timeline = self.timeline;
            return timeline.stage(Stage::Filter, self.opts.compute_node, || {
                rt.eval(v, batch, p)
            });
        }
        let timeline = self.timeline;
        Ok(timeline.stage(Stage::Filter, self.opts.compute_node, || {
            super::interp::eval(&self.plan.program, batch)
        }))
    }

    fn run_phase2(&mut self) -> Result<()> {
        if !(self.opts.two_phase && !self.output_only.is_empty() && self.pass_total > 0) {
            return Ok(());
        }
        if let Some(c) = &self.cache {
            let mut ranges = Vec::new();
            for (cluster, passes) in self.cluster_pass.iter().enumerate() {
                if passes.is_empty() {
                    continue;
                }
                for b in &self.output_only {
                    let k = &b.baskets[cluster];
                    ranges.push((k.offset, k.comp_len as usize));
                }
            }
            c.train(ranges);
        }
        for cluster in 0..self.cluster_pass.len() {
            if self.cluster_pass[cluster].is_empty() {
                continue;
            }
            let lo = (cluster * self.basket_events) as u64;
            for b in &self.output_only {
                let (raw, info) = fetch_decompress(
                    &self.reader,
                    &mut self.counters,
                    self.timeline,
                    self.opts,
                    b,
                    lo,
                )?;
                let acc = self.accs.get_mut(&b.desc.name).expect("acc exists");
                let t0 = Instant::now();
                let mut appended = 0usize;
                for &ev in &self.cluster_pass[cluster] {
                    appended += acc.push_event_raw(&raw, &info, ev)?;
                }
                self.timeline.add_real(
                    Stage::Deserialize,
                    self.opts.compute_node,
                    t0.elapsed().as_secs_f64(),
                );
                // Modeled GetEntry cost: only the passing events.
                if let Some(model) = self.opts.deser_model {
                    self.timeline.add_real(
                        Stage::Deserialize,
                        self.opts.compute_node,
                        model.cost(
                            self.cluster_pass[cluster].len() as u64,
                            appended as u64,
                            self.opts.parallelism,
                        ),
                    );
                }
            }
        }
        Ok(())
    }

    fn write_output(&mut self) -> Result<()> {
        let codec = self.opts.output_codec.unwrap_or(self.meta.codec);
        let timeline = self.timeline;
        let node = self.opts.compute_node;
        let t0 = Instant::now();
        let mut writer = crate::troot::TRootWriter::new(
            self.output_path.clone(),
            codec,
            self.meta.basket_events,
        );
        for name in &self.plan.output_branches {
            let acc = self.accs.remove(name).expect("acc exists");
            let desc = acc.desc.clone();
            writer.add_branch(desc, acc.finish())?;
        }
        let summary = writer.finalize()?;
        timeline.add_real(Stage::OutputWrite, node, t0.elapsed().as_secs_f64());
        self.output_summary = Some(summary);
        Ok(())
    }

    /// Close the job and produce the [`SkimResult`]. Errors if no
    /// `output` stage ran (e.g. a Job-hook stage vetoed it).
    pub(crate) fn finish(self) -> Result<SkimResult> {
        let summary = self.output_summary.ok_or_else(|| {
            Error::Engine(
                "pipeline finished without writing output (job vetoed, or no 'output' stage)"
                    .into(),
            )
        })?;
        Ok(SkimResult {
            n_events: self.range_events,
            n_pass: self.pass_total,
            stage_funnel: self.stage_funnel,
            output_path: self.output_path,
            output_bytes: summary.file_bytes,
            baskets_fetched: self.counters.baskets,
            fetched_bytes: self.counters.bytes,
            cache: self.cache.as_ref().map(|c| c.stats()),
            vectorized: self.vectorized,
            warnings: self.warnings,
        })
    }
}

// ---------------- built-in stages ------------------------------------

/// Built-in: fetch this group's criteria baskets (compressed frames).
struct FetchStage;
impl FilterStage for FetchStage {
    fn name(&self) -> &str {
        "fetch"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        let mut group = match ctx.group.take() {
            Some(g) => g,
            None => return Ok(Verdict::Continue),
        };
        let r = ctx.fetch_group(&mut group);
        ctx.group = Some(group);
        r?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: decompress fetched frames (software CPU or DPU engine).
struct DecompressStage;
impl FilterStage for DecompressStage {
    fn name(&self) -> &str {
        "decompress"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        let mut group = match ctx.group.take() {
            Some(g) => g,
            None => return Ok(Verdict::Continue),
        };
        let r = ctx.decompress_group(&mut group);
        ctx.group = Some(group);
        r?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: deserialize raw baskets into typed columns (plus the
/// modeled ROOT `GetEntry` cost).
struct DeserializeStage;
impl FilterStage for DeserializeStage {
    fn name(&self) -> &str {
        "deserialize"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        let mut group = match ctx.group.take() {
            Some(g) => g,
            None => return Ok(Verdict::Continue),
        };
        let r = ctx.deserialize_group(&mut group);
        ctx.group = Some(group);
        r?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: batch assembly + cut evaluation (PJRT kernel or the
/// scalar interpreter), populating per-cluster pass lists + the funnel.
struct EvalStage;
impl FilterStage for EvalStage {
    fn name(&self) -> &str {
        "eval"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        let mut group = match ctx.group.take() {
            Some(g) => g,
            None => return Ok(Verdict::Continue),
        };
        let r = ctx.eval_group(&mut group);
        ctx.group = Some(group);
        r?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: phase-2 selective fetch — output-only branches, passing
/// clusters only, per-event deserialization of passers.
struct Phase2Stage;
impl FilterStage for Phase2Stage {
    fn name(&self) -> &str {
        "phase2"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        ctx.run_phase2()?;
        Ok(Verdict::Continue)
    }
}

/// Built-in: encode + write the filtered output file.
struct OutputStage;
impl FilterStage for OutputStage {
    fn name(&self) -> &str {
        "output"
    }
    fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
        ctx.write_output()?;
        Ok(Verdict::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::engine::{EngineOpts, SkimEngine};
    use crate::gen::{self, GenConfig};
    use crate::troot::LocalFile;
    use std::sync::Mutex;

    // ---------------- ordering / registration ------------------------

    struct Named(&'static str);
    impl FilterStage for Named {
        fn name(&self) -> &str {
            self.0
        }
        fn run(&self, _ctx: &mut StageCtx) -> Result<Verdict> {
            Ok(Verdict::Continue)
        }
    }

    #[test]
    fn builtin_order_matches_paper_phases() {
        let p = Pipeline::builtin();
        assert_eq!(
            p.order(Hook::Group).unwrap(),
            vec!["fetch", "decompress", "deserialize", "eval"]
        );
        assert_eq!(p.order(Hook::Job).unwrap(), vec!["phase2", "output"]);
    }

    #[test]
    fn custom_stage_ordered_by_after() {
        let mut p = Pipeline::builtin();
        p.register(Hook::Group, &["eval"], Arc::new(Named("sample"))).unwrap();
        p.register(Hook::Group, &["decompress"], Arc::new(Named("audit"))).unwrap();
        let order = p.order(Hook::Group).unwrap();
        let pos = |n: &str| order.iter().position(|x| x == n).unwrap();
        assert!(pos("sample") > pos("eval"));
        assert!(pos("audit") > pos("decompress"));
    }

    #[test]
    fn duplicate_name_rejected() {
        let mut p = Pipeline::builtin();
        assert!(p.register(Hook::Group, &[], Arc::new(Named("eval"))).is_err());
        // Same name at the *other* hook is fine.
        assert!(p.register(Hook::Job, &[], Arc::new(Named("eval"))).is_ok());
    }

    #[test]
    fn unknown_after_is_error() {
        let mut p = Pipeline::builtin();
        p.register(Hook::Group, &["nonexistent"], Arc::new(Named("x"))).unwrap();
        let err = p.order(Hook::Group).unwrap_err();
        assert!(format!("{err}").contains("nonexistent"));
    }

    #[test]
    fn cycle_is_error() {
        let mut p = Pipeline::empty();
        p.register(Hook::Group, &["b"], Arc::new(Named("a"))).unwrap();
        p.register(Hook::Group, &["a"], Arc::new(Named("b"))).unwrap();
        let err = p.validate().unwrap_err();
        assert!(format!("{err}").contains("cycle"));
    }

    #[test]
    fn forward_reference_between_custom_stages_resolves() {
        let mut p = Pipeline::builtin();
        // "late" is registered before "early" but ordered after it.
        p.register(Hook::Group, &["early"], Arc::new(Named("late"))).unwrap();
        p.register(Hook::Group, &["eval"], Arc::new(Named("early"))).unwrap();
        let order = p.order(Hook::Group).unwrap();
        let pos = |n: &str| order.iter().position(|x| x == n).unwrap();
        assert!(pos("late") > pos("early"));
    }

    // ---------------- end-to-end with custom stages -------------------

    fn dataset() -> std::path::PathBuf {
        static PATH: std::sync::OnceLock<std::path::PathBuf> = std::sync::OnceLock::new();
        PATH.get_or_init(|| {
            let dir = std::env::temp_dir().join(format!("pipe_test_{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let path = dir.join("events.troot");
            let cfg = GenConfig {
                n_events: 900,
                target_branches: 170,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 21,
            };
            gen::generate(&cfg, &path).unwrap();
            path
        })
        .clone()
    }

    fn run_skim(engine: &SkimEngine, outname: &str, opts: &EngineOpts) -> SkimResult {
        let path = dataset();
        let store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&path).unwrap());
        let tl = Timeline::new();
        let out = path.parent().unwrap().join(outname);
        engine
            .run(store, &gen::higgs_query("events.troot", outname), &tl, opts, &out)
            .unwrap()
    }

    /// A sampling stage: keeps only even event ids after `eval`.
    struct EvenSampler;
    impl FilterStage for EvenSampler {
        fn name(&self) -> &str {
            "even-sampler"
        }
        fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
            if let Some(group) = &mut ctx.group {
                for passes in &mut group.passes {
                    passes.retain(|ev| ev % 2 == 0);
                }
            }
            Ok(Verdict::Continue)
        }
    }

    /// A per-branch byte-accounting stage hooked after `decompress`.
    struct ByteAudit {
        bytes: Mutex<std::collections::BTreeMap<String, u64>>,
    }
    impl FilterStage for ByteAudit {
        fn name(&self) -> &str {
            "byte-audit"
        }
        fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
            if let Some(group) = &ctx.group {
                let mut tab = self.bytes.lock().unwrap();
                for map in &group.raw {
                    for (name, (raw, _)) in map {
                        *tab.entry(name.clone()).or_insert(0) += raw.len() as u64;
                    }
                }
            }
            Ok(Verdict::Continue)
        }
    }

    /// Vetoes every group.
    struct VetoAll;
    impl FilterStage for VetoAll {
        fn name(&self) -> &str {
            "veto-all"
        }
        fn run(&self, _ctx: &mut StageCtx) -> Result<Verdict> {
            Ok(Verdict::Drop)
        }
    }

    fn interp_opts() -> EngineOpts {
        EngineOpts { use_pjrt: false, ..Default::default() }
    }

    #[test]
    fn sampling_stage_thins_passes() {
        let baseline = run_skim(&SkimEngine::new(None), "pipe_base.troot", &interp_opts());
        assert!(baseline.n_pass > 0);

        let mut engine = SkimEngine::new(None);
        engine
            .pipeline_mut()
            .register(Hook::Group, &["eval"], Arc::new(EvenSampler))
            .unwrap();
        let sampled = run_skim(&engine, "pipe_sampled.troot", &interp_opts());
        assert!(sampled.n_pass < baseline.n_pass);
        // The output file is consistent with the thinned selection.
        let r = TRootReader::open(
            LocalFile::open(dataset().parent().unwrap().join("pipe_sampled.troot")).unwrap(),
        )
        .unwrap();
        assert_eq!(r.n_events(), sampled.n_pass);
    }

    #[test]
    fn byte_audit_stage_observes_decompressed_bytes() {
        let audit = Arc::new(ByteAudit { bytes: Mutex::new(Default::default()) });
        let mut engine = SkimEngine::new(None);
        engine
            .pipeline_mut()
            .register(Hook::Group, &["decompress"], audit.clone())
            .unwrap();
        let res = run_skim(&engine, "pipe_audit.troot", &interp_opts());
        assert!(res.n_pass > 0);
        let tab = audit.bytes.lock().unwrap();
        // Every criteria branch shows up with nonzero raw bytes.
        assert!(!tab.is_empty());
        assert!(tab.values().all(|&b| b > 0));
        assert!(tab.contains_key("Jet_pt"));
    }

    #[test]
    fn group_veto_drops_every_event() {
        let mut engine = SkimEngine::new(None);
        engine
            .pipeline_mut()
            .register(Hook::Group, &["eval"], Arc::new(VetoAll))
            .unwrap();
        let res = run_skim(&engine, "pipe_veto.troot", &interp_opts());
        assert_eq!(res.n_pass, 0);
        let r = TRootReader::open(
            LocalFile::open(dataset().parent().unwrap().join("pipe_veto.troot")).unwrap(),
        )
        .unwrap();
        assert_eq!(r.n_events(), 0);
    }

    #[test]
    fn event_range_shards_partition_the_selection() {
        let full = run_skim(&SkimEngine::new(None), "pipe_full.troot", &interp_opts());
        let half = 450u64;
        let lo_opts =
            EngineOpts { use_pjrt: false, event_range: Some((0, half)), ..Default::default() };
        let hi_opts =
            EngineOpts { use_pjrt: false, event_range: Some((half, u64::MAX)), ..Default::default() };
        let lo = run_skim(&SkimEngine::new(None), "pipe_lo.troot", &lo_opts);
        let hi = run_skim(&SkimEngine::new(None), "pipe_hi.troot", &hi_opts);
        assert_eq!(lo.n_events + hi.n_events, full.n_events);
        assert_eq!(lo.n_pass + hi.n_pass, full.n_pass);
        for s in 0..4 {
            assert_eq!(lo.stage_funnel[s] + hi.stage_funnel[s], full.stage_funnel[s]);
        }
    }
}
