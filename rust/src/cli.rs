//! Minimal command-line argument parser (`clap` is not available
//! offline). Supports `--key value`, `--key=value`, boolean switches
//! and positional arguments.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Tokens that were not `--flags` (subcommand operands).
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `switch_names` lists flags that take no
    /// value (everything else with `--` consumes the next token unless
    /// written as `--key=value`).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, switch_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&stripped) {
                    out.switches.push(stripped.to_string());
                } else {
                    let v = iter
                        .next()
                        .ok_or_else(|| Error::Config(format!("--{stripped} needs a value")))?;
                    out.flags.insert(stripped.to_string(), v);
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Value of `--key`, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Value of `--key`, erroring when absent.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key)
            .ok_or_else(|| Error::Config(format!("missing required flag --{key}")))
    }

    /// Parse `--key` as a number, with a default when absent.
    pub fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: cannot parse '{v}'"))),
        }
    }

    /// Whether the boolean switch `--name` was passed.
    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str], switches: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string()), switches).unwrap()
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(
            &["gen", "--out", "f.troot", "--events=100", "--force", "extra"],
            &["force"],
        );
        assert_eq!(a.positional, vec!["gen", "extra"]);
        assert_eq!(a.get("out"), Some("f.troot"));
        assert_eq!(a.parse_num::<u64>("events", 0).unwrap(), 100);
        assert!(a.switch("force"));
        assert!(!a.switch("other"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--out".to_string()], &[]).is_err());
    }

    #[test]
    fn require_and_defaults() {
        let a = parse(&["--x", "1"], &[]);
        assert_eq!(a.require("x").unwrap(), "1");
        assert!(a.require("y").is_err());
        assert_eq!(a.get_or("y", "z"), "z");
        assert!(a.parse_num::<u32>("x", 0).unwrap() == 1);
        assert!(a.parse_num::<u32>("q", 7).unwrap() == 7);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--n", "abc"], &[]);
        assert!(a.parse_num::<u32>("n", 0).is_err());
    }
}
