//! Multi-query optimizer: merge K compatible skim plans into one
//! shared scan.
//!
//! SkimROOT's scarce resource is data movement at the storage server,
//! yet N tenants skimming the same hot dataset still paid one full
//! fetch + decompress + deserialize pass *per job* — the
//! [`crate::serve::BasketCache`] amortizes read + decompress, but not
//! deserialize + eval-side batch assembly. The classic answer (shared
//! scans / multi-query optimization) is to run **one** scan and fan its
//! decoded baskets out to every subscribed query.
//!
//! This module is the planning half of that move:
//!
//! * [`SharedScanPlan::from_plans`] merges the members' phase-1 fetch
//!   sets into a **union** branch list with a shared interned slot
//!   space, and records a per-member `slot_map` so each member's
//!   decoded-basket view (indexed by its own dense
//!   [`crate::query::plan::BranchId`]s) can be assembled from the union
//!   row by plain `Vec` indexing. Member cut programs, funnels and
//!   residual `CExpr`s stay separate — sharing changes *where bytes are
//!   decoded once*, never what any member computes.
//! * [`amortized_share`] / [`amortize`] implement the counter-attribution
//!   rule: shared-scan costs are charged **once** to the batch timeline,
//!   then folded into the members as exact integer shares (counters) and
//!   `1/N` virtual-time slices (stage totals) — so sums across members
//!   remain meaningful instead of the first toucher absorbing the whole
//!   scan.
//! * [`deployment_incompatibility`] is the compatibility predicate the
//!   scheduler consults before batching jobs at all.
//!
//! The execution half lives in `engine/shared.rs`
//! ([`crate::engine::run_shared`]); batch formation lives in
//! [`crate::serve::SkimScheduler`].

use crate::coordinator::{Deployment, Placement};
use crate::metrics::{Stage, Timeline};
use crate::query::plan::SkimPlan;
use std::collections::HashMap;

/// Identity of one formed batch: attached to every member's
/// [`crate::coordinator::JobReport`] and surfaced as `batched_with`
/// through every status surface (JobStatus → wire → HTTP).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchInfo {
    /// Service-unique batch id (0 is never assigned).
    pub id: u64,
    /// Number of member jobs the batch's one scan served.
    pub members: u32,
}

/// One member's remapping from its private phase-1 slot space into the
/// union scan's slot space.
#[derive(Debug, Clone)]
pub struct MemberMap {
    /// Member phase-1 slot (the member plan's dense
    /// [`crate::query::plan::BranchId`], i.e. its position in that
    /// plan's `criteria_branches`) → union slot.
    pub slot_map: Vec<usize>,
}

/// The merged phase-1 plan of K compatible [`SkimPlan`]s over one
/// resolved dataset: the union fetch set plus per-member remappings.
#[derive(Debug, Clone)]
pub struct SharedScanPlan {
    /// Union of every member's `criteria_branches`, interned in
    /// first-use order (member 0's branches lead). Position in this
    /// list is the union slot id.
    pub union_branches: Vec<String>,
    /// Per-member slot maps, in member order.
    pub members: Vec<MemberMap>,
}

impl SharedScanPlan {
    /// Merge the members' phase-1 fetch sets. Branch names are interned
    /// into one shared slot space in first-use order; each member gets
    /// a dense `slot_map` from its own `BranchId`s into that space.
    pub fn from_plans(plans: &[&SkimPlan]) -> SharedScanPlan {
        let mut union_branches: Vec<String> = Vec::new();
        let mut interned: HashMap<String, usize> = HashMap::new();
        let mut members = Vec::with_capacity(plans.len());
        for plan in plans {
            let slot_map = plan
                .criteria_branches
                .iter()
                .map(|name| {
                    *interned.entry(name.clone()).or_insert_with(|| {
                        union_branches.push(name.clone());
                        union_branches.len() - 1
                    })
                })
                .collect::<Vec<usize>>();
            members.push(MemberMap { slot_map });
        }
        SharedScanPlan { union_branches, members }
    }

    /// Number of branches the one shared pass fetches per cluster.
    pub fn union_len(&self) -> usize {
        self.union_branches.len()
    }
}

/// Counters the shared scan charges once to the batch timeline and
/// then reports per member as amortized shares (see [`amortize`]).
pub const SHARED_COUNTERS: [&str; 5] = [
    "baskets_scanned",
    "baskets_pruned",
    "basket_cache_hits",
    "basket_cache_misses",
    "xrd_bytes_served",
];

/// Exact integer split of a shared total across `n` members: member
/// `i` gets `total / n`, with the remainder going to the first
/// `total % n` members — shares always sum back to `total`, so
/// per-member counters stay meaningful in aggregate.
pub fn amortized_share(total: u64, n: usize, i: usize) -> u64 {
    if n == 0 {
        return 0;
    }
    let n64 = n as u64;
    total / n64 + u64::from((i as u64) < total % n64)
}

/// Fold the batch timeline's shared-scan accounting into the member
/// timelines:
///
/// * every [`SHARED_COUNTERS`] counter splits into exact integer
///   shares via [`amortized_share`] (sums across members == the batch
///   total — the scan is counted once, not once per member);
/// * every stage's batch total (virtual transport + real compute of
///   the one shared pass) is charged to each member at `1/N` as
///   virtual time, so member latencies reflect "my slice of the scan
///   plus my own eval/phase-2/output work".
///
/// The batch timeline itself keeps the actual once-charged totals —
/// callers that want the unamortized truth read it before dropping it.
pub fn amortize(batch: &Timeline, members: &[Timeline]) {
    let n = members.len();
    if n == 0 {
        return;
    }
    for name in SHARED_COUNTERS {
        let total = batch.counter(name);
        if total == 0 {
            continue;
        }
        for (i, member) in members.iter().enumerate() {
            let share = amortized_share(total, n, i);
            if share > 0 {
                member.count(name, share);
            }
        }
    }
    for stage in Stage::ALL {
        let share = batch.stage_total(stage) / n as f64;
        if share > 0.0 {
            for member in members {
                member.charge(stage, share);
            }
        }
    }
}

/// The static half of the batch-compatibility predicate: can this
/// service deployment host shared scans at all? Returns the reason it
/// cannot, or `None` when it can. The dynamic half — "same resolved
/// single-file dataset" — is checked per batch by the scheduler and
/// re-checked by [`crate::coordinator::Coordinator::run_shared`].
///
/// Shared scans require two-phase execution (so member batch grouping
/// is identical and per-member masks/funnels/outputs are
/// byte-identical to solo runs) on a client or server placement with
/// no fault injection; anything else falls back to solo runs. A
/// `use_pjrt` preference is *not* disqualifying: member programs have
/// per-member kernel shapes, so the shared pass always evaluates on
/// the scalar interpreter — which is bit-identical to the kernel, so
/// outputs still match the member's solo run.
pub fn deployment_incompatibility(dep: &Deployment) -> Option<&'static str> {
    if matches!(dep.placement, Placement::Dpu(_)) {
        return Some("DPU placements shard by event range, not by query");
    }
    if dep.fan_out > 1 {
        return Some("fan_out > 1 shards the scan");
    }
    if !dep.two_phase {
        return Some("legacy single-phase mode folds outputs into phase 1");
    }
    if dep.fault.active() {
        return Some("fault injection needs per-job retry streams");
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;

    fn plan_for(cut: &str, keep: &[&str]) -> SkimPlan {
        let dir = std::env::temp_dir().join(format!("mqo_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.troot");
        if !path.exists() {
            let cfg = crate::gen::GenConfig {
                n_events: 400,
                target_branches: 160,
                n_hlt: 40,
                basket_events: 200,
                codec: crate::compress::Codec::Lz4,
                seed: 31,
            };
            crate::gen::generate(&cfg, &path).unwrap();
        }
        let reader = crate::troot::TRootReader::open(
            crate::troot::LocalFile::open(&path).unwrap(),
        )
        .unwrap();
        let q = crate::query::SkimQuery::new("events.troot", "o.troot")
            .keep(keep)
            .with_cut_str(cut)
            .unwrap();
        SkimPlan::build(&q, reader.meta()).unwrap()
    }

    #[test]
    fn union_interns_in_first_use_order_and_slot_maps_round_trip() {
        let a = plan_for("MET_pt > 20 && nJet >= 2", &["MET_pt"]);
        let b = plan_for("nJet >= 1 && max(Jet_pt) > 30", &["Jet_pt"]);
        let shared = SharedScanPlan::from_plans(&[&a, &b]);
        // Member 0's criteria lead the union, member 1 adds only its
        // novel branches.
        assert_eq!(
            &shared.union_branches[..a.criteria_branches.len()],
            &a.criteria_branches[..]
        );
        let novel: Vec<&String> = b
            .criteria_branches
            .iter()
            .filter(|n| !a.criteria_branches.contains(n))
            .collect();
        assert_eq!(
            shared.union_len(),
            a.criteria_branches.len() + novel.len(),
            "union must dedup overlapping criteria"
        );
        // Every member slot map points at its own branch name.
        for (plan, member) in [(&a, &shared.members[0]), (&b, &shared.members[1])] {
            assert_eq!(member.slot_map.len(), plan.criteria_branches.len());
            for (bid, &slot) in member.slot_map.iter().enumerate() {
                assert_eq!(shared.union_branches[slot], plan.criteria_branches[bid]);
            }
        }
        // The shared criteria branch maps to the same union slot.
        let overlap = "nJet";
        let sa = a.criteria_branches.iter().position(|n| n == overlap).unwrap();
        let sb = b.criteria_branches.iter().position(|n| n == overlap).unwrap();
        assert_eq!(shared.members[0].slot_map[sa], shared.members[1].slot_map[sb]);
    }

    #[test]
    fn identical_plans_share_every_slot() {
        let a = plan_for("MET_pt > 20", &["MET_pt", "nJet"]);
        let b = plan_for("MET_pt > 50", &["MET_pt", "nJet"]);
        let shared = SharedScanPlan::from_plans(&[&a, &b]);
        assert_eq!(shared.union_len(), a.criteria_branches.len());
        assert_eq!(shared.members[0].slot_map, shared.members[1].slot_map);
    }

    #[test]
    fn amortized_shares_sum_to_the_total() {
        for (total, n) in [(0u64, 3usize), (1, 3), (7, 3), (9, 3), (100, 7), (5, 1)] {
            let sum: u64 = (0..n).map(|i| amortized_share(total, n, i)).sum();
            assert_eq!(sum, total, "total {total} over {n} members");
            // Shares differ by at most one (fair split).
            let shares: Vec<u64> = (0..n).map(|i| amortized_share(total, n, i)).collect();
            let (min, max) = (shares.iter().min().unwrap(), shares.iter().max().unwrap());
            assert!(max - min <= 1, "{shares:?}");
        }
    }

    #[test]
    fn amortize_splits_counters_exactly_and_time_evenly() {
        let batch = Timeline::new();
        batch.count("baskets_scanned", 10);
        batch.count("basket_cache_misses", 7);
        batch.charge(Stage::BasketFetch, 3.0);
        let members = [Timeline::new(), Timeline::new(), Timeline::new()];
        amortize(&batch, &members);
        let scanned: u64 = members.iter().map(|m| m.counter("baskets_scanned")).sum();
        let misses: u64 = members.iter().map(|m| m.counter("basket_cache_misses")).sum();
        assert_eq!(scanned, 10);
        assert_eq!(misses, 7);
        for m in &members {
            assert!((m.stage_total(Stage::BasketFetch) - 1.0).abs() < 1e-9);
        }
        // The batch timeline keeps the unamortized truth.
        assert_eq!(batch.counter("baskets_scanned"), 10);
    }

    #[test]
    fn compatibility_predicate_rejects_unsupported_deployments() {
        // The stock presets prefer the kernel (`use_pjrt`), which is
        // fine: the shared pass just evaluates on the interpreter.
        assert!(deployment_incompatibility(&Deployment::server_side(LinkModel::local()))
            .is_none());
        assert!(deployment_incompatibility(&Deployment::client_opt(LinkModel::wan_1g()))
            .is_none());

        assert!(deployment_incompatibility(&Deployment::skim_root(LinkModel::wan_1g()))
            .is_some());
        assert!(deployment_incompatibility(&Deployment::client_legacy(LinkModel::wan_1g()))
            .is_some());
        let mut faulty = Deployment::server_side(LinkModel::local());
        faulty.fault.fail_prob = 0.5;
        assert!(deployment_incompatibility(&faulty).is_some());
        let mut fail_at = Deployment::server_side(LinkModel::local());
        fail_at.fault.fail_at_read = 2;
        assert!(deployment_incompatibility(&fail_at).is_some());
    }
}
