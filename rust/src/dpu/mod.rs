//! The DPU node model (§2.3, §3): a BlueField-3-like near-storage
//! processor in **separated-host mode**.
//!
//! The DPU:
//! * exposes an HTTP endpoint ([`http`]) accepting `POST /skim` with
//!   the JSON query payload (§3.1) — users drive it with `curl`;
//! * acts as an XRootD *client* toward the storage host over its PCIe
//!   link (128 Gb/s, microsecond latency — [`LinkModel::pcie_128g`]);
//! * runs the filtering engine on its ARM cores, with basket
//!   decompression offloaded to the **hardware decompression engine**
//!   ([`DecompMode::HwEngine`]; calibrated 1.4× over software LZ4 per
//!   Figure 5a's 3.1 s → 2.2 s);
//! * ships only the filtered output back to the requesting client.

pub mod http;

use crate::engine::{DecompMode, EngineOpts, SkimEngine, SkimResult};
use crate::metrics::{Node, Stage, Timeline};
use crate::net::LinkModel;
use crate::query::SkimQuery;
use crate::runtime::SkimRuntime;
use crate::troot::ReadAt;
use crate::xrootd::{LoopbackWire, XrdClient, XrdServer};
use crate::Result;
use std::sync::Arc;

/// DPU hardware/firmware parameters.
#[derive(Debug, Clone)]
pub struct DpuConfig {
    /// ARM cores available for filtering (BF-3: 16 Cortex-A78).
    pub arm_cores: usize,
    /// Hardware decompression engine speedup over one-core software
    /// decode (calibrated on the paper's 3.1 s → 2.2 s).
    pub decomp_speedup: f64,
    /// DPU ↔ storage-host link.
    pub pcie: LinkModel,
    /// TTreeCache capacity for the DPU's XRootD client.
    pub cache_bytes: usize,
    /// ARM-vs-host per-core compute scaling (paper §4: "BF-3's ARM
    /// cores perform comparably to host CPUs" → 1.0).
    pub core_slowdown: f64,
    /// Effective parallelism of the filtering pipeline across the ARM
    /// cores (calibrated on Fig. 5a's deserialize 16.8 s → 4.1 s ⇒ 4×).
    pub parallelism: f64,
}

impl Default for DpuConfig {
    fn default() -> Self {
        DpuConfig {
            arm_cores: 16,
            decomp_speedup: 1.4,
            pcie: LinkModel::pcie_128g(),
            cache_bytes: crate::xrootd::DEFAULT_CACHE_BYTES,
            core_slowdown: 1.0,
            parallelism: 4.0,
        }
    }
}

/// A DPU bound to one storage server (in-process model; the TCP/HTTP
/// deployment wraps this in [`http::DpuHttpServer`]).
pub struct DpuNode<'rt> {
    pub config: DpuConfig,
    storage: XrdServer,
    runtime: Option<&'rt SkimRuntime>,
    /// Where the DPU stages filtered outputs before shipping them.
    scratch_dir: std::path::PathBuf,
}

/// Outcome of one DPU-executed skim, including the bytes to ship back.
pub struct DpuJobOutput {
    pub result: SkimResult,
    /// The filtered file's bytes (read from DPU scratch, ready to
    /// transfer to the client).
    pub output: Vec<u8>,
}

impl<'rt> DpuNode<'rt> {
    pub fn new(
        config: DpuConfig,
        storage: XrdServer,
        runtime: Option<&'rt SkimRuntime>,
        scratch_dir: impl Into<std::path::PathBuf>,
    ) -> Self {
        DpuNode { config, storage, runtime, scratch_dir: scratch_dir.into() }
    }

    /// Execute a skim query on the DPU: fetch baskets from the storage
    /// host over PCIe, filter on ARM cores with engine-offloaded
    /// decompression, stage the output locally.
    pub fn run_query(&self, query: &SkimQuery, timeline: &Timeline) -> Result<DpuJobOutput> {
        // The DPU is an XRootD client of the storage host over PCIe.
        let wire = Arc::new(LoopbackWire::new(
            self.storage.clone(),
            self.config.pcie,
            timeline.clone(),
        ));
        let client = XrdClient::new(wire);
        let remote = Arc::new(client.open(&query.input)?);

        std::fs::create_dir_all(&self.scratch_dir)?;
        let out_path = self.scratch_dir.join(sanitize(&query.output));
        let opts = EngineOpts {
            two_phase: true,
            use_pjrt: true,
            compute_node: Node::Dpu,
            decomp: DecompMode::HwEngine { speedup: self.config.decomp_speedup },
            cache_bytes: Some(self.config.cache_bytes),
            output_codec: None,
            max_objects: 16,
            parallelism: self.config.parallelism,
            ..Default::default()
        };
        let engine = SkimEngine::new(self.runtime);
        let store: Arc<dyn ReadAt> = remote;
        let result = engine.run(store, query, timeline, &opts, &out_path)?;

        let output = std::fs::read(&out_path)?;
        timeline.count("dpu_jobs", 1);
        Ok(DpuJobOutput { result, output })
    }

    /// Model the final hop: ship the filtered file to the client over
    /// `client_link` (the paper's "filtered file fetch", ~0.02 s for
    /// the 5.2 MB output).
    pub fn ship_output(&self, output_len: usize, client_link: &LinkModel, timeline: &Timeline) {
        client_link.charge(timeline, Stage::OutputTransfer, output_len as u64);
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::gen::{self, GenConfig};
    use crate::net::DiskModel;

    fn setup() -> (XrdServer, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("dpu_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 600,
                target_branches: 180,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 7,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        (XrdServer::new(&dir, DiskModel::disk_pool()), dir)
    }

    #[test]
    fn dpu_runs_query_and_ships_small_output() {
        let (server, dir) = setup();
        let tl = Timeline::new();
        server.set_timeline(Some(tl.clone()));
        let dpu = DpuNode::new(DpuConfig::default(), server, None, dir.join("scratch"));
        let query = gen::higgs_query("events.troot", "skim_out.troot");
        let out = dpu.run_query(&query, &tl).unwrap();

        assert!(out.result.n_pass > 0);
        assert!(out.output.len() > 100);
        // The filtered output is much smaller than what was fetched.
        assert!((out.output.len() as u64) < out.result.fetched_bytes);
        // Decompression ran on the engine, not the ARM cores.
        assert!(tl.node_busy(Node::DpuEngine) > 0.0);
        // PCIe fetches are fast: total fetch time well under a second
        // for this small file.
        assert!(tl.stage_total(Stage::BasketFetch) < 1.0);

        // Ship to client over a 1 Gbps WAN: small output → small time.
        let before = tl.stage_total(Stage::OutputTransfer);
        dpu.ship_output(out.output.len(), &LinkModel::wan_1g(), &tl);
        let dt = tl.stage_total(Stage::OutputTransfer) - before;
        assert!(dt < 1.0, "output transfer {dt}");
    }

    #[test]
    fn scratch_name_sanitized() {
        assert_eq!(sanitize("../../etc/passwd"), ".._.._etc_passwd");
        assert_eq!(sanitize("ok-file.troot"), "ok-file.troot");
    }
}
