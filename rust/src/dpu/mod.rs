//! The DPU node model (§2.3, §3): a BlueField-3-like near-storage
//! processor in **separated-host mode**.
//!
//! The DPU:
//! * exposes an HTTP endpoint ([`http`]) accepting `POST /skim` with
//!   the JSON query payload (§3.1) — users drive it with `curl`;
//! * acts as an XRootD *client* toward the storage host over its PCIe
//!   link (128 Gb/s, microsecond latency — [`LinkModel::pcie_128g`]);
//! * runs the filtering engine on its ARM cores, with basket
//!   decompression offloaded to the **hardware decompression engine**
//!   ([`DecompMode::HwEngine`]; calibrated 1.4× over software LZ4 per
//!   Figure 5a's 3.1 s → 2.2 s);
//! * ships only the filtered output back to the requesting client.
//!
//! Beyond the paper's single-DPU testbed, [`DpuCluster`] fans one job
//! out across N DPU nodes sharing the same storage server: the event
//! range is split cluster-aligned, each node skims its shard through
//! its own engine (own PCIe wire, own TTreeCache), and the shard
//! outputs are merged into one filtered file. Selection results are
//! identical to the single-DPU path by construction.

pub mod http;

use crate::engine::{DecompMode, EngineOpts, SkimEngine, SkimResult, StageReg};
use crate::metrics::{Node, Stage, Timeline};
use crate::net::LinkModel;
use crate::query::SkimQuery;
use crate::runtime::SkimRuntime;
use crate::troot::{FileMeta, ReadAt, TRootReader};
use crate::xrootd::{LoopbackWire, XrdClient, XrdServer};
use crate::{Error, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// DPU hardware/firmware parameters.
#[derive(Debug, Clone)]
pub struct DpuConfig {
    /// ARM cores available for filtering (BF-3: 16 Cortex-A78).
    pub arm_cores: usize,
    /// Hardware decompression engine speedup over one-core software
    /// decode (calibrated on the paper's 3.1 s → 2.2 s).
    pub decomp_speedup: f64,
    /// DPU ↔ storage-host link.
    pub pcie: LinkModel,
    /// TTreeCache capacity for the DPU's XRootD client.
    pub cache_bytes: usize,
    /// ARM-vs-host per-core compute scaling (paper §4: "BF-3's ARM
    /// cores perform comparably to host CPUs" → 1.0).
    pub core_slowdown: f64,
    /// Effective parallelism of the filtering pipeline across the ARM
    /// cores (calibrated on Fig. 5a's deserialize 16.8 s → 4.1 s ⇒ 4×).
    /// Materialized by the engine as a real worker pool
    /// ([`EngineOpts::workers`]): decompress/deserialize/batch-append
    /// fan out across this many threads, with max-over-workers
    /// latency attribution (the hardware decompression engine stays a
    /// serial device regardless — see `engine/pipeline.rs`).
    pub parallelism: f64,
}

impl Default for DpuConfig {
    fn default() -> Self {
        DpuConfig {
            arm_cores: 16,
            decomp_speedup: 1.4,
            pcie: LinkModel::pcie_128g(),
            cache_bytes: crate::xrootd::DEFAULT_CACHE_BYTES,
            core_slowdown: 1.0,
            parallelism: 4.0,
        }
    }
}

/// A DPU bound to one storage server (in-process model; the TCP/HTTP
/// deployment wraps this in [`http::DpuHttpServer`]).
pub struct DpuNode<'rt> {
    /// Hardware/firmware parameters of this node.
    pub config: DpuConfig,
    storage: XrdServer,
    runtime: Option<&'rt SkimRuntime>,
    /// Where the DPU stages filtered outputs before shipping them.
    scratch_dir: PathBuf,
    /// Shared decompressed-basket cache (serving-layer deployments).
    basket_cache: Option<Arc<crate::serve::BasketCache>>,
    /// Zone-map sidecar of the input file (basket pruning); the engine
    /// digest-validates it, so a stale map degrades to a full scan.
    zone_map: Option<Arc<crate::index::FileIndex>>,
    /// Job lifecycle controls, checked at the engine's basket-group
    /// boundaries (cooperative cancel + virtual-time deadline).
    ctl: crate::lifecycle::JobCtl,
}

/// Outcome of one DPU-executed skim, including the bytes to ship back.
pub struct DpuJobOutput {
    /// The engine outcome (selection counts, funnel, output stats).
    pub result: SkimResult,
    /// The filtered file's bytes (read from DPU scratch, ready to
    /// transfer to the client).
    pub output: Vec<u8>,
}

impl<'rt> DpuNode<'rt> {
    /// A DPU node attached to `storage`, staging outputs under
    /// `scratch_dir`.
    pub fn new(
        config: DpuConfig,
        storage: XrdServer,
        runtime: Option<&'rt SkimRuntime>,
        scratch_dir: impl Into<PathBuf>,
    ) -> Self {
        DpuNode {
            config,
            storage,
            runtime,
            scratch_dir: scratch_dir.into(),
            basket_cache: None,
            zone_map: None,
            ctl: crate::lifecycle::JobCtl::none(),
        }
    }

    /// Install job lifecycle controls ([`crate::lifecycle::JobCtl`]):
    /// the node's engine checks them at every basket-group boundary.
    pub fn with_ctl(mut self, ctl: crate::lifecycle::JobCtl) -> Self {
        self.ctl = ctl;
        self
    }

    /// Install a shared [`crate::serve::BasketCache`]: every job this
    /// node runs consults it before fetching + decompressing a basket.
    pub fn with_basket_cache(mut self, cache: Arc<crate::serve::BasketCache>) -> Self {
        self.basket_cache = Some(cache);
        self
    }

    /// Install the input file's zone-map sidecar: the engine prunes
    /// provably-dead baskets before fetching them over PCIe.
    pub fn with_zone_map(mut self, zone_map: Arc<crate::index::FileIndex>) -> Self {
        self.zone_map = Some(zone_map);
        self
    }

    /// Execute a skim query on the DPU: fetch baskets from the storage
    /// host over PCIe, filter on ARM cores with engine-offloaded
    /// decompression, stage the output locally.
    pub fn run_query(&self, query: &SkimQuery, timeline: &Timeline) -> Result<DpuJobOutput> {
        self.run_query_with(query, timeline, None, &[])
    }

    /// [`DpuNode::run_query`] restricted to an event range (a fan-out
    /// shard) and/or with custom pipeline stages.
    pub fn run_query_with(
        &self,
        query: &SkimQuery,
        timeline: &Timeline,
        event_range: Option<(u64, u64)>,
        stages: &[StageReg],
    ) -> Result<DpuJobOutput> {
        // The DPU is an XRootD client of the storage host over PCIe.
        let wire = Arc::new(LoopbackWire::new(
            self.storage.clone(),
            self.config.pcie,
            timeline.clone(),
        ));
        let client = XrdClient::new(wire);
        let remote = Arc::new(client.open(query.input.single_path()?)?);

        std::fs::create_dir_all(&self.scratch_dir)?;
        let out_path = self.scratch_dir.join(sanitize(&query.output));
        let opts = EngineOpts {
            two_phase: true,
            use_pjrt: true,
            compute_node: Node::Dpu,
            decomp: DecompMode::HwEngine { speedup: self.config.decomp_speedup },
            cache_bytes: Some(self.config.cache_bytes),
            output_codec: None,
            max_objects: 16,
            parallelism: self.config.parallelism,
            event_range,
            basket_cache: self.basket_cache.clone(),
            zone_map: self.zone_map.clone(),
            ctl: self.ctl.clone(),
            ..Default::default()
        };
        let engine = SkimEngine::with_stages(self.runtime, stages)?;
        let store: Arc<dyn ReadAt> = remote;
        let result = engine.run(store, query, timeline, &opts, &out_path)?;

        let output = std::fs::read(&out_path)?;
        timeline.count("dpu_jobs", 1);
        Ok(DpuJobOutput { result, output })
    }

    /// Read just the input's metadata over the PCIe wire (used by
    /// [`DpuCluster`] to plan its event-range split).
    pub fn open_meta(&self, path: &str, timeline: &Timeline) -> Result<FileMeta> {
        let wire = Arc::new(LoopbackWire::new(
            self.storage.clone(),
            self.config.pcie,
            timeline.clone(),
        ));
        let client = XrdClient::new(wire);
        let remote = client.open(path)?;
        let reader = TRootReader::open(remote)?;
        Ok(reader.meta().clone())
    }

    /// Model the final hop: ship the filtered file to the client over
    /// `client_link` (the paper's "filtered file fetch", ~0.02 s for
    /// the 5.2 MB output).
    pub fn ship_output(&self, output_len: usize, client_link: &LinkModel, timeline: &Timeline) {
        client_link.charge(timeline, Stage::OutputTransfer, output_len as u64);
    }
}

/// N DPU nodes sharing one storage server — the multi-DPU fan-out
/// deployment (`Deployment::builder().fan_out(n)`), modeled after a
/// DPU-cluster abstraction: the cluster owns placement (which node
/// skims which event range) and data movement (merging shard outputs).
pub struct DpuCluster<'rt> {
    nodes: Vec<DpuNode<'rt>>,
    scratch_root: PathBuf,
}

impl<'rt> DpuCluster<'rt> {
    /// `fan_out` nodes with identical `config`, each with its own
    /// scratch directory under `scratch_root`.
    pub fn new(
        fan_out: usize,
        config: DpuConfig,
        storage: XrdServer,
        runtime: Option<&'rt SkimRuntime>,
        scratch_root: impl Into<PathBuf>,
    ) -> Self {
        let scratch_root = scratch_root.into();
        let nodes = (0..fan_out.max(1))
            .map(|i| {
                DpuNode::new(
                    config.clone(),
                    storage.clone(),
                    runtime,
                    scratch_root.join(format!("node{i}")),
                )
            })
            .collect();
        DpuCluster { nodes, scratch_root }
    }

    /// Install a shared [`crate::serve::BasketCache`] into every node
    /// of the cluster (shards share one server-side cache, exactly as
    /// concurrent jobs do).
    pub fn with_basket_cache(mut self, cache: Arc<crate::serve::BasketCache>) -> Self {
        for node in &mut self.nodes {
            node.basket_cache = Some(cache.clone());
        }
        self
    }

    /// Install the input file's zone-map sidecar into every node: each
    /// shard prunes its own provably-dead baskets (summaries cover
    /// whole baskets, so pruning stays sound under the cluster's
    /// event-range split).
    pub fn with_zone_map(mut self, zone_map: Arc<crate::index::FileIndex>) -> Self {
        for node in &mut self.nodes {
            node.zone_map = Some(zone_map.clone());
        }
        self
    }

    /// Install job lifecycle controls into every node of the cluster:
    /// one cancel token / deadline covers all shards of the job.
    pub fn with_ctl(mut self, ctl: crate::lifecycle::JobCtl) -> Self {
        for node in &mut self.nodes {
            node.ctl = ctl.clone();
        }
        self
    }

    /// Number of DPU nodes in the cluster.
    pub fn fan_out(&self) -> usize {
        self.nodes.len()
    }

    /// [`DpuCluster::run_query_with`] without custom stages.
    pub fn run_query(&self, query: &SkimQuery, timeline: &Timeline) -> Result<DpuJobOutput> {
        self.run_query_with(query, timeline, &[])
    }

    /// Split the input by event range (cluster-aligned), run one shard
    /// per node, merge the filtered shard files into one output.
    ///
    /// Shards model **parallel** hardware: each runs on a private
    /// timeline (its own PCIe wire, ARM cores, decompression engine),
    /// and only the *critical* (slowest) shard's accounting is folded
    /// into the job timeline — latency is max-over-shards, not the
    /// sum. The shared storage backend's disk charges land on the job
    /// timeline directly (one server serves every shard), as do the
    /// metadata probe and the merge.
    pub fn run_query_with(
        &self,
        query: &SkimQuery,
        timeline: &Timeline,
        stages: &[StageReg],
    ) -> Result<DpuJobOutput> {
        if self.nodes.len() == 1 {
            return self.nodes[0].run_query_with(query, timeline, None, stages);
        }
        let meta = self.nodes[0].open_meta(query.input.single_path()?, timeline)?;
        let n_events = meta.n_events;
        let be = meta.basket_events.max(1) as u64;
        let n_clusters = n_events.div_ceil(be);
        if n_clusters == 0 {
            return self.nodes[0].run_query_with(query, timeline, None, stages);
        }

        let n = self.nodes.len() as u64;
        let mut shards = Vec::new();
        let mut shard_timelines: Vec<Timeline> = Vec::new();
        let mut c0 = 0u64;
        for (i, node) in self.nodes.iter().enumerate() {
            let take = n_clusters / n + u64::from((i as u64) < n_clusters % n);
            if take == 0 {
                continue;
            }
            let c1 = c0 + take;
            let range = (c0 * be, (c1 * be).min(n_events));
            let shard_tl = Timeline::new();
            shards.push(node.run_query_with(query, &shard_tl, Some(range), stages)?);
            shard_timelines.push(shard_tl);
            c0 = c1;
        }
        // Fold the critical shard; the other shards ran concurrently
        // "underneath" it, so only their job count is kept.
        if let Some(critical) = shard_timelines
            .iter()
            .max_by(|a, b| a.elapsed().partial_cmp(&b.elapsed()).expect("finite"))
        {
            timeline.merge_from(critical);
        }
        timeline.count("dpu_jobs", shards.len().saturating_sub(1) as u64);
        timeline.count("dpu_shards", shards.len() as u64);
        self.merge(query, timeline, shards)
    }

    /// Concatenate shard outputs (in shard order, which is event
    /// order) into one filtered troot file, through the shared
    /// deterministic merge path ([`crate::troot::merge`]).
    fn merge(
        &self,
        query: &SkimQuery,
        timeline: &Timeline,
        shards: Vec<DpuJobOutput>,
    ) -> Result<DpuJobOutput> {
        if shards.len() == 1 {
            return Ok(shards.into_iter().next().expect("one shard"));
        }
        if shards.is_empty() {
            return Err(Error::Engine("dpu cluster produced no shards".into()));
        }

        // Aggregate shard stats (and the union of warnings) before the
        // output buffers are consumed by the merge readers.
        let mut result = SkimResult::merge_parts(shards.iter().map(|s| &s.result));

        let t0 = Instant::now();
        std::fs::create_dir_all(&self.scratch_root)?;
        let merged_path = self
            .scratch_root
            .join(format!("merged_{}", sanitize(&query.output)));
        let parts: Vec<Vec<u8>> = shards.into_iter().map(|s| s.output).collect();
        let summary = crate::troot::merge::concat_buffers(parts, &merged_path)?;
        // Merging is DPU-side compute (the cluster's data-movement
        // layer), attributed like the output write it replaces.
        timeline.add_real(Stage::OutputWrite, Node::Dpu, t0.elapsed().as_secs_f64());

        result.output_path = merged_path.clone();
        result.output_bytes = summary.file_bytes;
        let output = std::fs::read(&merged_path)?;
        Ok(DpuJobOutput { result, output })
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::gen::{self, GenConfig};
    use crate::net::DiskModel;
    use crate::troot::merge::MemStore;
    use crate::troot::LocalFile;

    fn setup() -> (XrdServer, std::path::PathBuf) {
        static DIR: std::sync::OnceLock<std::path::PathBuf> = std::sync::OnceLock::new();
        let dir = DIR
            .get_or_init(|| {
                let dir = std::env::temp_dir().join(format!("dpu_test_{}", std::process::id()));
                std::fs::create_dir_all(&dir).unwrap();
                let cfg = GenConfig {
                    n_events: 600,
                    target_branches: 180,
                    n_hlt: 40,
                    basket_events: 200,
                    codec: Codec::Lz4,
                    seed: 7,
                };
                gen::generate(&cfg, dir.join("events.troot")).unwrap();
                dir
            })
            .clone();
        (XrdServer::new(&dir, DiskModel::disk_pool()), dir)
    }

    #[test]
    fn dpu_runs_query_and_ships_small_output() {
        let (server, dir) = setup();
        let tl = Timeline::new();
        server.set_timeline(Some(tl.clone()));
        let dpu = DpuNode::new(DpuConfig::default(), server, None, dir.join("scratch"));
        let query = gen::higgs_query("events.troot", "skim_out.troot");
        let out = dpu.run_query(&query, &tl).unwrap();

        assert!(out.result.n_pass > 0);
        assert!(out.output.len() > 100);
        // The filtered output is much smaller than what was fetched.
        assert!((out.output.len() as u64) < out.result.fetched_bytes);
        // Decompression ran on the engine, not the ARM cores.
        assert!(tl.node_busy(Node::DpuEngine) > 0.0);
        // PCIe fetches are fast: total fetch time well under a second
        // for this small file.
        assert!(tl.stage_total(Stage::BasketFetch) < 1.0);

        // Ship to client over a 1 Gbps WAN: small output → small time.
        let before = tl.stage_total(Stage::OutputTransfer);
        dpu.ship_output(out.output.len(), &LinkModel::wan_1g(), &tl);
        let dt = tl.stage_total(Stage::OutputTransfer) - before;
        assert!(dt < 1.0, "output transfer {dt}");
    }

    #[test]
    fn cluster_fan_out_matches_single_node() {
        let (server, dir) = setup();
        let query = gen::higgs_query("events.troot", "cluster_skim.troot");

        let tl1 = Timeline::new();
        server.set_timeline(Some(tl1.clone()));
        let single = DpuNode::new(
            DpuConfig::default(),
            server.clone(),
            None,
            dir.join("scratch_single"),
        )
        .run_query(&query, &tl1)
        .unwrap();

        let tl3 = Timeline::new();
        server.set_timeline(Some(tl3.clone()));
        let cluster = DpuCluster::new(
            3,
            DpuConfig::default(),
            server.clone(),
            None,
            dir.join("scratch_cluster"),
        );
        assert_eq!(cluster.fan_out(), 3);
        let fanned = cluster.run_query(&query, &tl3).unwrap();

        assert_eq!(fanned.result.n_pass, single.result.n_pass);
        assert_eq!(fanned.result.n_events, single.result.n_events);
        assert_eq!(fanned.result.stage_funnel, single.result.stage_funnel);
        assert_eq!(tl3.counter("dpu_shards"), 3);
        assert_eq!(tl3.counter("dpu_jobs"), 3);
        // Parallel model: the job timeline folds only the critical
        // shard, so the fanned run's engine-decompress busy time is
        // roughly a third of the single node's (one cluster vs three).
        assert!(
            tl3.node_busy(Node::DpuEngine) < tl1.node_busy(Node::DpuEngine),
            "fanned engine busy {} vs single {}",
            tl3.node_busy(Node::DpuEngine),
            tl1.node_busy(Node::DpuEngine)
        );

        // The merged file holds exactly the passing events with the
        // same per-event values as the single-node output.
        let merged = TRootReader::open(MemStore(fanned.output.clone())).unwrap();
        let solo = TRootReader::open(MemStore(single.output.clone())).unwrap();
        assert_eq!(merged.n_events(), solo.n_events());
        assert_eq!(merged.meta().branches.len(), solo.meta().branches.len());
        let a = merged.read_branch_all("MET_pt").unwrap();
        let b = solo.read_branch_all("MET_pt").unwrap();
        assert_eq!(a, b);
        let ja = merged.read_branch_all("Electron_pt").unwrap();
        let jb = solo.read_branch_all("Electron_pt").unwrap();
        assert_eq!(ja, jb);
    }

    #[test]
    fn cluster_with_more_nodes_than_clusters_still_works() {
        let (server, dir) = setup();
        let query = gen::higgs_query("events.troot", "wide_skim.troot");
        let tl = Timeline::new();
        server.set_timeline(Some(tl.clone()));
        // 600 events / 200-event baskets = 3 clusters, 8 nodes.
        let cluster =
            DpuCluster::new(8, DpuConfig::default(), server, None, dir.join("scratch_wide"));
        let out = cluster.run_query(&query, &tl).unwrap();
        assert!(out.result.n_pass > 0);
        assert_eq!(out.result.n_events, 600);
        // Only as many shards as clusters actually ran.
        assert_eq!(tl.counter("dpu_shards"), 3);
    }

    #[test]
    fn open_meta_reads_schema_over_pcie() {
        let (server, dir) = setup();
        let tl = Timeline::new();
        let dpu = DpuNode::new(DpuConfig::default(), server, None, dir.join("scratch_meta"));
        let meta = dpu.open_meta("events.troot", &tl).unwrap();
        assert_eq!(meta.n_events, 600);
        assert!(!meta.branches.is_empty());
    }

    #[test]
    fn scratch_name_sanitized() {
        assert_eq!(sanitize("../../etc/passwd"), ".._.._etc_passwd");
        assert_eq!(sanitize("ok-file.troot"), "ok-file.troot");
    }

    #[test]
    fn local_file_still_reads_outputs() {
        // Sanity that shard outputs on disk stay valid troot files.
        let (server, dir) = setup();
        let tl = Timeline::new();
        server.set_timeline(Some(tl.clone()));
        let dpu = DpuNode::new(DpuConfig::default(), server, None, dir.join("scratch_file"));
        let query = gen::higgs_query("events.troot", "file_skim.troot");
        let out = dpu.run_query(&query, &tl).unwrap();
        let r = TRootReader::open(LocalFile::open(&out.result.output_path).unwrap()).unwrap();
        assert_eq!(r.n_events(), out.result.n_pass);
    }
}
