//! Minimal HTTP/1.1 service for the DPU's separated-host endpoint.
//!
//! Users interact with SkimROOT exactly as the paper describes: an
//! HTTP POST with the JSON selection payload (`curl -d @query.json
//! http://<dpu>/skim`). The response body is the filtered troot file;
//! job statistics come back in `X-Skim-*` headers.
//!
//! A server built with [`DpuHttpServer::with_scheduler`] additionally
//! exposes the multi-tenant **asynchronous job API** over a
//! [`SkimScheduler`]:
//!
//! * `POST /jobs` — submit a JSON query; `202 {"job": N}` on
//!   admission, `429` when the queue is full, `503` with `Retry-After`
//!   while the service drains. An `X-Skim-Deadline-Ms` request header
//!   attaches a virtual-time deadline to the job;
//! * `GET /jobs/<id>` — JSON status (state, events, pass counts,
//!   shared-cache hits/misses, zone-map baskets pruned/scanned, and
//!   the lifecycle counters: retries, faults injected, backoff time,
//!   cancelled / deadline-exceeded flags);
//! * `DELETE /jobs/<id>` — cancel the job (idempotent; returns the
//!   resulting status JSON);
//! * `GET /jobs/<id>/result` — the filtered troot bytes of a finished
//!   job (`409` while in flight, `500` with the status JSON when the
//!   job failed, was cancelled or exceeded its deadline).
//!
//! Hand-rolled request/response parsing (no HTTP crates offline):
//! request line + headers + `Content-Length` body; responses are
//! always `Connection: close`.

use crate::coordinator::Deployment;
use crate::job::SkimJob;
use crate::metrics::Timeline;
use crate::query::{Json, SkimQuery};
use crate::runtime::SkimRuntime;
use crate::serve::{JobState, SkimScheduler};
use crate::{Error, Result};
use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Upper bound on an accepted request body (query payloads are small).
pub const MAX_BODY: usize = 64 * 1024 * 1024;

/// A parsed HTTP request.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpRequest {
    /// Request method (`GET`, `POST`, ...).
    pub method: String,
    /// Request path (`/skim`, `/jobs/3`, ...).
    pub path: String,
    /// Headers, keys lower-cased.
    pub headers: HashMap<String, String>,
    /// Raw body bytes (`Content-Length`-framed).
    pub body: Vec<u8>,
}

/// Parse one HTTP/1.1 request from a stream.
pub fn read_request(stream: &mut impl Read) -> Result<HttpRequest> {
    // Read until CRLFCRLF (header terminator).
    let mut buf = Vec::with_capacity(1024);
    let mut byte = [0u8; 1];
    while !buf.ends_with(b"\r\n\r\n") {
        if buf.len() > 64 * 1024 {
            return Err(Error::protocol("http: header section too large"));
        }
        let n = stream.read(&mut byte)?;
        if n == 0 {
            return Err(Error::protocol("http: connection closed mid-header"));
        }
        buf.push(byte[0]);
    }
    let head = std::str::from_utf8(&buf[..buf.len() - 4])
        .map_err(|_| Error::protocol("http: non-utf8 header"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().ok_or_else(|| Error::protocol("http: empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| Error::protocol("http: no method"))?.to_string();
    let path = parts.next().ok_or_else(|| Error::protocol("http: no path"))?.to_string();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(Error::protocol(format!("http: unsupported version '{version}'")));
    }

    let mut headers = HashMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    let body_len: usize = headers
        .get("content-length")
        .map(|v| v.parse().map_err(|_| Error::protocol("http: bad content-length")))
        .transpose()?
        .unwrap_or(0);
    if body_len > MAX_BODY {
        return Err(Error::protocol("http: body too large"));
    }
    let mut body = vec![0u8; body_len];
    stream.read_exact(&mut body)?;
    Ok(HttpRequest { method, path, headers, body })
}

/// Write an HTTP/1.1 response.
pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    reason: &str,
    headers: &[(&str, String)],
    body: &[u8],
) -> Result<()> {
    write!(stream, "HTTP/1.1 {status} {reason}\r\n")?;
    for (k, v) in headers {
        write!(stream, "{k}: {v}\r\n")?;
    }
    write!(stream, "Content-Length: {}\r\nConnection: close\r\n\r\n", body.len())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// The DPU's HTTP front-end, generic over the job executor so the
/// in-process node model and tests can plug in.
pub struct DpuHttpServer<F> {
    handler: Arc<F>,
    scheduler: Option<Arc<SkimScheduler>>,
}

/// What the executor returns: the filtered file plus summary stats.
pub struct SkimHttpOutput {
    /// The filtered troot file's bytes (the HTTP response body).
    pub output: Vec<u8>,
    /// Events the job covered.
    pub n_events: u64,
    /// Events passing the selection.
    pub n_pass: u64,
    /// Modeled end-to-end latency in seconds.
    pub elapsed: f64,
}

impl<F> DpuHttpServer<F>
where
    F: Fn(&SkimQuery, &Timeline) -> Result<SkimHttpOutput> + Send + Sync + 'static,
{
    /// A server executing each synchronous `POST /skim` via `handler`.
    pub fn new(handler: F) -> Self {
        DpuHttpServer { handler: Arc::new(handler), scheduler: None }
    }

    /// Additionally expose the asynchronous `/jobs` API backed by
    /// `scheduler` (see the module docs).
    pub fn with_scheduler(mut self, scheduler: Arc<SkimScheduler>) -> Self {
        self.scheduler = Some(scheduler);
        self
    }

    /// Serve until `stop`; one thread per connection (the DPU has 16
    /// ARM cores; connection handling is not the bottleneck).
    pub fn serve(
        &self,
        listener: TcpListener,
        stop: Arc<AtomicBool>,
    ) -> std::thread::JoinHandle<()> {
        let handler = self.handler.clone();
        let scheduler = self.scheduler.clone();
        std::thread::spawn(move || {
            let mut conns = Vec::new();
            // Blocking accept (no poll interval); stop with
            // [`crate::xrootd::server::stop_serving`], which pokes the
            // listener so the kernel-blocked accept observes the flag.
            loop {
                let accepted = listener.accept();
                if stop.load(Ordering::SeqCst) {
                    break; // `accepted` may be the stop poke — drop it
                }
                // Reap finished connections: a long-lived service
                // polled over `Connection: close` requests must not
                // accumulate one dead JoinHandle per request.
                conns.retain(|c: &std::thread::JoinHandle<()>| !c.is_finished());
                match accepted {
                    Ok((stream, _)) => {
                        let handler = handler.clone();
                        let scheduler = scheduler.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_connection(stream, &*handler, scheduler.as_ref());
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::ConnectionAborted => continue,
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        })
    }
}

fn handle_connection<F>(
    mut stream: TcpStream,
    handler: &F,
    scheduler: Option<&Arc<SkimScheduler>>,
) -> Result<()>
where
    F: Fn(&SkimQuery, &Timeline) -> Result<SkimHttpOutput>,
{
    stream.set_nodelay(true).ok();
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let msg = error_json(&e);
            return write_response(&mut stream, 400, "Bad Request", &[], msg.as_bytes());
        }
    };
    if let Some(sched) = scheduler {
        if req.path == "/jobs" || req.path.starts_with("/jobs/") {
            return handle_jobs_route(&mut stream, &req, sched);
        }
    }
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => write_response(
            &mut stream,
            200,
            "OK",
            &[("Content-Type", "application/json".into())],
            b"{\"status\": \"ok\"}",
        ),
        ("POST", "/skim") => {
            let text = match std::str::from_utf8(&req.body) {
                Ok(t) => t,
                Err(_) => {
                    return write_response(&mut stream, 400, "Bad Request", &[], b"non-utf8 body")
                }
            };
            let query = match SkimQuery::from_json_text(text) {
                Ok(q) => q,
                Err(e) => {
                    let msg = error_json(&e);
                    return write_response(
                        &mut stream,
                        422,
                        "Unprocessable Entity",
                        &[("Content-Type", "application/json".into())],
                        msg.as_bytes(),
                    );
                }
            };
            let timeline = Timeline::new();
            match handler(&query, &timeline) {
                Ok(out) => write_response(
                    &mut stream,
                    200,
                    "OK",
                    &[
                        ("Content-Type", "application/octet-stream".into()),
                        ("X-Skim-Events", out.n_events.to_string()),
                        ("X-Skim-Pass", out.n_pass.to_string()),
                        ("X-Skim-Elapsed-Secs", format!("{:.6}", out.elapsed)),
                    ],
                    &out.output,
                ),
                Err(e) => {
                    let msg = error_json(&e);
                    write_response(
                        &mut stream,
                        500,
                        "Internal Server Error",
                        &[("Content-Type", "application/json".into())],
                        msg.as_bytes(),
                    )
                }
            }
        }
        _ => write_response(&mut stream, 404, "Not Found", &[], b"not found"),
    }
}

/// `{"error":"..."}` via the crate's JSON serializer (user-controlled
/// error text — quotes, backslashes, control characters — is escaped
/// by the shared `write_escaped`, not a second hand-rolled escaper).
fn error_json(msg: impl std::fmt::Display) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(obj).to_string()
}

/// Compact JSON rendering of one job status (sorted keys). Dataset
/// jobs additionally report `files_done`/`files_total` and any
/// fault-isolated per-file failures; shared-scan members additionally
/// report `batch_id`/`batch_members`; solo single-file statuses keep
/// their exact legacy shape plus the always-present `scan_shared`
/// counter (0 when the job fetched everything itself).
fn status_json(status: &crate::serve::JobStatus) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("job".to_string(), Json::Num(status.id as f64));
    obj.insert("state".to_string(), Json::Str(status.state.name().to_string()));
    obj.insert("events".to_string(), Json::Num(status.n_events as f64));
    obj.insert("pass".to_string(), Json::Num(status.n_pass as f64));
    obj.insert("latency_secs".to_string(), Json::Num(status.latency));
    obj.insert("cache_hits".to_string(), Json::Num(status.cache_hits as f64));
    obj.insert("cache_misses".to_string(), Json::Num(status.cache_misses as f64));
    obj.insert("baskets_pruned".to_string(), Json::Num(status.baskets_pruned as f64));
    obj.insert("baskets_scanned".to_string(), Json::Num(status.baskets_scanned as f64));
    obj.insert("scan_shared".to_string(), Json::Num(status.scan_shared as f64));
    obj.insert("retries".to_string(), Json::Num(status.retries as f64));
    obj.insert("faults_injected".to_string(), Json::Num(status.faults_injected as f64));
    obj.insert("backoff_us".to_string(), Json::Num(status.backoff_us as f64));
    obj.insert("cancelled".to_string(), Json::Num(status.cancelled as f64));
    obj.insert(
        "deadline_exceeded".to_string(),
        Json::Num(status.deadline_exceeded as f64),
    );
    if status.batch_members > 0 {
        obj.insert("batch_id".to_string(), Json::Num(status.batch_id as f64));
        obj.insert("batch_members".to_string(), Json::Num(status.batch_members as f64));
    }
    if status.files_total > 0 {
        obj.insert("files_done".to_string(), Json::Num(status.files_done as f64));
        obj.insert("files_total".to_string(), Json::Num(status.files_total as f64));
        if !status.file_errors.is_empty() {
            obj.insert(
                "file_errors".to_string(),
                Json::Arr(status.file_errors.iter().map(|e| Json::Str(e.clone())).collect()),
            );
        }
    }
    if !status.profile.is_empty() {
        // Adaptive-execution selectivity profile: one object per
        // conjunct, in the status's (key-sorted) order.
        obj.insert(
            "profile".to_string(),
            Json::Arr(
                status
                    .profile
                    .iter()
                    .map(|p| {
                        let mut e = BTreeMap::new();
                        e.insert("conjunct".to_string(), Json::Str(p.key.clone()));
                        e.insert("stage".to_string(), Json::Num(p.stage as f64));
                        e.insert("visited".to_string(), Json::Num(p.visited as f64));
                        e.insert("passed".to_string(), Json::Num(p.passed as f64));
                        e.insert("cost_us".to_string(), Json::Num(p.cost_us as f64));
                        Json::Obj(e)
                    })
                    .collect(),
            ),
        );
    }
    if let Some(e) = &status.error {
        obj.insert("error".to_string(), Json::Str(e.clone()));
    }
    Json::Obj(obj).to_string()
}

/// The asynchronous job API: `POST /jobs`, `GET /jobs/<id>`,
/// `GET /jobs/<id>/result`.
fn handle_jobs_route(
    stream: &mut TcpStream,
    req: &HttpRequest,
    sched: &Arc<SkimScheduler>,
) -> Result<()> {
    let json = || ("Content-Type", "application/json".to_string());
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/jobs") => {
            let text = match std::str::from_utf8(&req.body) {
                Ok(t) => t,
                Err(_) => {
                    return write_response(stream, 400, "Bad Request", &[], b"non-utf8 body")
                }
            };
            let query = match SkimQuery::from_json_text(text) {
                Ok(q) => q,
                Err(e) => {
                    let msg = error_json(&e);
                    return write_response(
                        stream,
                        422,
                        "Unprocessable Entity",
                        &[json()],
                        msg.as_bytes(),
                    );
                }
            };
            // Optional virtual-time deadline, in milliseconds.
            let deadline_ms: u64 = match req.headers.get("x-skim-deadline-ms") {
                None => 0,
                Some(v) => match v.parse() {
                    Ok(ms) => ms,
                    Err(_) => {
                        return write_response(
                            stream,
                            400,
                            "Bad Request",
                            &[],
                            b"bad X-Skim-Deadline-Ms header",
                        )
                    }
                },
            };
            match sched.submit_with_deadline(query, deadline_ms) {
                Ok(job) => {
                    let mut obj = BTreeMap::new();
                    obj.insert("job".to_string(), Json::Num(job as f64));
                    let msg = Json::Obj(obj).to_string();
                    write_response(stream, 202, "Accepted", &[json()], msg.as_bytes())
                }
                Err(e) => {
                    let msg = error_json(&e);
                    if sched.is_accepting() {
                        // Admission control: the queue is full.
                        write_response(stream, 429, "Too Many Requests", &[json()], msg.as_bytes())
                    } else {
                        // Draining or shutting down: the rejection is
                        // retriable against a restarted service.
                        let hdr = [json(), ("Retry-After", "1".to_string())];
                        write_response(stream, 503, "Service Unavailable", &hdr, msg.as_bytes())
                    }
                }
            }
        }
        ("DELETE", path) => {
            let id: u64 = match path["/jobs/".len().min(path.len())..].parse() {
                Ok(id) => id,
                Err(_) => {
                    return write_response(stream, 400, "Bad Request", &[], b"bad job id")
                }
            };
            match sched.cancel(id) {
                Ok(status) => {
                    let msg = status_json(&status);
                    write_response(stream, 200, "OK", &[json()], msg.as_bytes())
                }
                Err(_) => {
                    let msg = b"{\"error\": \"no such job\"}";
                    write_response(stream, 404, "Not Found", &[json()], msg)
                }
            }
        }
        ("GET", path) => {
            let rest = &path["/jobs/".len().min(path.len())..];
            let (id_str, want_result) = match rest.strip_suffix("/result") {
                Some(id) => (id, true),
                None => (rest, false),
            };
            let id: u64 = match id_str.parse() {
                Ok(id) => id,
                Err(_) => {
                    return write_response(stream, 400, "Bad Request", &[], b"bad job id")
                }
            };
            let Some(status) = sched.status(id) else {
                let msg = b"{\"error\": \"no such job\"}";
                return write_response(stream, 404, "Not Found", &[json()], msg);
            };
            if !want_result {
                let msg = status_json(&status);
                return write_response(stream, 200, "OK", &[json()], msg.as_bytes());
            }
            match status.state {
                JobState::Done => match sched.fetch_result(id) {
                    Ok(bytes) => write_response(
                        stream,
                        200,
                        "OK",
                        &[
                            ("Content-Type", "application/octet-stream".into()),
                            ("X-Skim-Events", status.n_events.to_string()),
                            ("X-Skim-Pass", status.n_pass.to_string()),
                        ],
                        &bytes,
                    ),
                    Err(e) => {
                        let msg = error_json(&e);
                        let hdr = [json()];
                        write_response(stream, 500, "Internal Server Error", &hdr, msg.as_bytes())
                    }
                },
                // Terminal without a product: the status JSON (which
                // names the state and carries the error) is the body.
                JobState::Failed | JobState::Cancelled | JobState::DeadlineExceeded => {
                    let msg = status_json(&status);
                    let hdr = [json()];
                    write_response(stream, 500, "Internal Server Error", &hdr, msg.as_bytes())
                }
                JobState::Queued | JobState::Running => {
                    let msg = status_json(&status);
                    write_response(stream, 409, "Conflict", &[json()], msg.as_bytes())
                }
            }
        }
        _ => write_response(stream, 404, "Not Found", &[], b"not found"),
    }
}

/// The standard separated-host executor: each `POST /skim` runs a
/// [`SkimJob`] under `deployment` against the `root` catalog — the
/// same facade the CLI and examples use, so HTTP-served skims and
/// in-process skims share one code path. A deployment with
/// `fan_out > 1` shards each request across a
/// [`crate::dpu::DpuCluster`].
///
/// Callers typically pass a DPU placement over
/// [`crate::net::LinkModel::local`] — the HTTP response *is* the real
/// output transfer, so no virtual output-transfer time should be
/// charged.
///
/// Concurrent requests are isolated: each one works in its own
/// subdirectory of `work_dir` (the server is thread-per-connection,
/// and two requests naming the same `output` must not race on one
/// file).
pub fn storage_handler(
    root: impl Into<PathBuf>,
    work_dir: impl Into<PathBuf>,
    runtime: Option<&'static SkimRuntime>,
    deployment: Deployment,
) -> impl Fn(&SkimQuery, &Timeline) -> Result<SkimHttpOutput> + Send + Sync + 'static {
    let root = root.into();
    let work = work_dir.into();
    let seq = AtomicU64::new(0);
    move |query: &SkimQuery, _timeline: &Timeline| {
        let req_dir = work.join(format!("req{}", seq.fetch_add(1, Ordering::Relaxed)));
        let report = SkimJob::new(query.clone())
            .storage(&root)
            .client_dir(&req_dir)
            .runtime(runtime)
            .deployment(deployment.clone())
            .run()?;
        let output = std::fs::read(&report.result.output_path)?;
        // The response body is the only product; a long-running service
        // must not accumulate one filtered file per request.
        let _ = std::fs::remove_dir_all(&req_dir);
        Ok(SkimHttpOutput {
            n_events: report.result.n_events,
            n_pass: report.result.n_pass,
            elapsed: report.latency,
            output,
        })
    }
}

/// Minimal HTTP client for posting skim queries (what `curl` does).
pub fn post_skim(addr: &str, query_json: &str) -> Result<(u16, HashMap<String, String>, Vec<u8>)> {
    http_request(addr, "POST", "/skim", query_json.as_bytes())
}

/// Minimal one-shot HTTP client: `method path` with `body`, returning
/// `(status, lower-cased headers, body)`. Used by the `/jobs` job API
/// and the `skim_farm` example; each call opens a fresh connection
/// (the server always answers `Connection: close`).
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<(u16, HashMap<String, String>, Vec<u8>)> {
    http_request_with_headers(addr, method, path, &[], body)
}

/// [`http_request`] with extra request headers (e.g.
/// `X-Skim-Deadline-Ms` on a `POST /jobs` submission).
pub fn http_request_with_headers(
    addr: &str,
    method: &str,
    path: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> Result<(u16, HashMap<String, String>, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Error::protocol(format!("connect {addr}: {e}")))?;
    stream.set_nodelay(true).ok();
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n"
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    // Parse response: status line, headers, body per Content-Length.
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    while !buf.ends_with(b"\r\n\r\n") {
        let n = stream.read(&mut byte)?;
        if n == 0 {
            return Err(Error::protocol("http: closed mid-response"));
        }
        buf.push(byte[0]);
    }
    let head = std::str::from_utf8(&buf[..buf.len() - 4])
        .map_err(|_| Error::protocol("http: non-utf8 response"))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::protocol("http: bad status line"))?;
    let mut headers = HashMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers.get("content-length").and_then(|v| v.parse().ok()).unwrap_or(0);
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok((status, headers, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_query_json() -> String {
        r#"{"input": "f.troot", "output": "o.troot", "branches": ["*"]}"#.to_string()
    }

    #[test]
    fn request_roundtrip() {
        let raw = b"POST /skim HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nbody";
        let req = read_request(&mut &raw[..]).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/skim");
        assert_eq!(req.body, b"body");
        assert_eq!(req.headers["host"], "x");
    }

    #[test]
    fn rejects_malformed_requests() {
        for raw in [
            &b"GARBAGE\r\n\r\n"[..],
            &b"GET /x SPDY/3\r\n\r\n"[..],
            &b"POST / HTTP/1.1\r\nContent-Length: zzz\r\n\r\n"[..],
        ] {
            assert!(read_request(&mut &raw[..]).is_err(), "{:?}", String::from_utf8_lossy(raw));
        }
    }

    #[test]
    fn response_format() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "OK", &[("X-Test", "1".into())], b"hi").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("X-Test: 1\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.ends_with("\r\n\r\nhi"));
    }

    #[test]
    fn end_to_end_post_skim() {
        let server = DpuHttpServer::new(|q: &SkimQuery, _tl: &Timeline| {
            assert_eq!(q.input, "f.troot");
            Ok(SkimHttpOutput {
                output: vec![1, 2, 3],
                n_events: 100,
                n_pass: 7,
                elapsed: 0.5,
            })
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = server.serve(listener, stop.clone());

        let (status, headers, body) = post_skim(&addr, &sample_query_json()).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, vec![1, 2, 3]);
        assert_eq!(headers["x-skim-pass"], "7");
        assert_eq!(headers["x-skim-events"], "100");

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    }

    #[test]
    fn jobs_api_end_to_end() {
        use crate::compress::Codec;
        use crate::gen::{self, GenConfig};
        let dir = std::env::temp_dir().join(format!("http_jobs_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 600,
                target_branches: 160,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 53,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        let mut cfg = crate::serve::ServeConfig::new(&dir);
        cfg.workers = 1;
        let sched = crate::serve::SkimScheduler::new(cfg).unwrap();

        let server = DpuHttpServer::new(|_q: &SkimQuery, _tl: &Timeline| {
            Err(crate::Error::Engine("sync path unused in this test".into()))
        })
        .with_scheduler(sched.clone());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = server.serve(listener, stop.clone());

        // Submit.
        let query = gen::higgs_query("events.troot", "http_jobs.troot");
        let payload = query.to_json().to_string();
        let (status, _, body) = http_request(&addr, "POST", "/jobs", payload.as_bytes()).unwrap();
        assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
        let text = String::from_utf8(body).unwrap();
        let id: u64 = text
            .trim_start_matches("{\"job\":")
            .trim_end_matches('}')
            .parse()
            .unwrap();

        // Poll status until done.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            let (status, _, body) =
                http_request(&addr, "GET", &format!("/jobs/{id}"), b"").unwrap();
            assert_eq!(status, 200);
            let text = String::from_utf8(body).unwrap();
            if text.contains("\"state\":\"done\"") {
                assert!(text.contains("\"cache_hits\""));
                assert!(text.contains("\"cache_misses\""));
                assert!(text.contains("\"baskets_pruned\""));
                assert!(text.contains("\"baskets_scanned\""));
                assert!(text.contains("\"scan_shared\""));
                // Solo run: batch identity stays off the wire.
                assert!(!text.contains("\"batch_id\""), "{text}");
                assert!(text.contains("\"latency_secs\""));
                break;
            }
            assert!(std::time::Instant::now() < deadline, "job never finished: {text}");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }

        // Fetch the result bytes.
        let (status, headers, bytes) =
            http_request(&addr, "GET", &format!("/jobs/{id}/result"), b"").unwrap();
        assert_eq!(status, 200);
        assert!(bytes.len() > 100);
        assert!(headers["x-skim-pass"].parse::<u64>().unwrap() > 0);

        // Unknown job id.
        let (status, _, _) = http_request(&addr, "GET", "/jobs/99999", b"").unwrap();
        assert_eq!(status, 404);

        // Malformed submission.
        let (status, _, _) = http_request(&addr, "POST", "/jobs", b"{nope").unwrap();
        assert_eq!(status, 422);

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
        sched.shutdown();
    }

    /// Pull the integer value of `key` out of a flat status JSON body.
    fn json_u64(text: &str, key: &str) -> u64 {
        let pat = format!("\"{key}\":");
        let start = text.find(&pat).unwrap_or_else(|| panic!("{key} missing in {text}"));
        let rest = &text[start + pat.len()..];
        let end = rest.find([',', '}']).unwrap();
        rest[..end].trim().parse().unwrap()
    }

    #[test]
    fn batched_http_jobs_report_batch_info_and_bytes_match_solo() {
        use crate::compress::Codec;
        use crate::gen::{self, GenConfig};
        let dir = std::env::temp_dir().join(format!("http_batch_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 600,
                target_branches: 160,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 53,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        let mut cfg = crate::serve::ServeConfig::new(&dir);
        cfg.deployment.disk = crate::net::DiskModel::ideal();
        // Generous window: both submissions must land inside it even
        // on a slow CI box.
        cfg.batch_window_ms = 150;
        let sched = crate::serve::SkimScheduler::new(cfg).unwrap();

        let server = DpuHttpServer::new(|_q: &SkimQuery, _tl: &Timeline| {
            Err(crate::Error::Engine("sync path unused in this test".into()))
        })
        .with_scheduler(sched.clone());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = server.serve(listener, stop.clone());

        let mk = |cut: &str, out: &str| {
            SkimQuery::new("events.troot", out)
                .keep(&["MET_pt", "nJet", "Jet_pt"])
                .with_cut_str(cut)
                .unwrap()
        };
        let cuts = ["MET_pt > 25", "MET_pt > 25 && nJet >= 2"];
        let ids: Vec<u64> = cuts
            .iter()
            .enumerate()
            .map(|(i, cut)| {
                let payload = mk(cut, &format!("hb{i}.troot")).to_json().to_string();
                let (status, _, body) =
                    http_request(&addr, "POST", "/jobs", payload.as_bytes()).unwrap();
                assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
                let text = String::from_utf8(body).unwrap();
                text.trim_start_matches("{\"job\":").trim_end_matches('}').parse().unwrap()
            })
            .collect();

        for (i, &id) in ids.iter().enumerate() {
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
            let text = loop {
                let (status, _, body) =
                    http_request(&addr, "GET", &format!("/jobs/{id}"), b"").unwrap();
                assert_eq!(status, 200);
                let text = String::from_utf8(body).unwrap();
                if text.contains("\"state\":\"done\"") {
                    break text;
                }
                assert!(!text.contains("\"state\":\"failed\""), "{text}");
                assert!(std::time::Instant::now() < deadline, "job never finished: {text}");
                std::thread::sleep(std::time::Duration::from_millis(5));
            };
            assert_eq!(json_u64(&text, "batch_members"), 2, "{text}");
            assert!(json_u64(&text, "batch_id") > 0, "{text}");
            assert!(json_u64(&text, "scan_shared") > 0, "member {i} saw no shared scan");

            // Byte-identity against the one-shot SkimJob facade.
            let (status, _, bytes) =
                http_request(&addr, "GET", &format!("/jobs/{id}/result"), b"").unwrap();
            assert_eq!(status, 200);
            let work =
                std::env::temp_dir().join(format!("http_batchref_{}_{i}", std::process::id()));
            std::fs::create_dir_all(&work).unwrap();
            let report = crate::job::SkimJob::new(mk(cuts[i], &format!("hr{i}.troot")))
                .storage(&dir)
                .client_dir(&work)
                .run()
                .unwrap();
            assert_eq!(
                bytes,
                std::fs::read(&report.result.output_path).unwrap(),
                "member {i} batched bytes differ from solo"
            );
        }

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
        sched.shutdown();
    }

    #[test]
    fn lifecycle_over_http_cancel_deadline_and_drain() {
        use crate::compress::Codec;
        use crate::gen::{self, GenConfig};
        let dir = std::env::temp_dir().join(format!("http_life_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 600,
                target_branches: 160,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 53,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        // One worker over a stalling disk (virtual time only): a
        // deadlined job expires deterministically, an undeadlined one
        // completes, and queued jobs can be cancelled over the wire.
        let mut cfg = crate::serve::ServeConfig::new(&dir);
        cfg.deployment.disk = crate::net::DiskModel::ideal();
        cfg.workers = 1;
        cfg.deployment.fault.kind = crate::coordinator::FaultKind::StallRead;
        cfg.deployment.fault.fail_prob = 1.0;
        cfg.deployment.fault.stall_s = 60.0;
        cfg.deployment.fault.seed = 13;
        let sched = crate::serve::SkimScheduler::new(cfg).unwrap();

        let server = DpuHttpServer::new(|_q: &SkimQuery, _tl: &Timeline| {
            Err(crate::Error::Engine("sync path unused in this test".into()))
        })
        .with_scheduler(sched.clone());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = server.serve(listener, stop.clone());

        // A malformed deadline header never reaches the scheduler.
        let payload = gen::higgs_query("events.troot", "hd.troot").to_json().to_string();
        let (status, _, _) = http_request_with_headers(
            &addr,
            "POST",
            "/jobs",
            &[("X-Skim-Deadline-Ms", "soon")],
            payload.as_bytes(),
        )
        .unwrap();
        assert_eq!(status, 400);

        // Deadline attached via header: the stalled job expires.
        let (status, _, body) = http_request_with_headers(
            &addr,
            "POST",
            "/jobs",
            &[("X-Skim-Deadline-Ms", "1000")],
            payload.as_bytes(),
        )
        .unwrap();
        assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
        let text = String::from_utf8(body).unwrap();
        let id: u64 =
            text.trim_start_matches("{\"job\":").trim_end_matches('}').parse().unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        let text = loop {
            let (status, _, body) =
                http_request(&addr, "GET", &format!("/jobs/{id}"), b"").unwrap();
            assert_eq!(status, 200);
            let text = String::from_utf8(body).unwrap();
            if text.contains("\"state\":\"deadline-exceeded\"") {
                break text;
            }
            assert!(!text.contains("\"state\":\"done\""), "{text}");
            assert!(std::time::Instant::now() < deadline, "never expired: {text}");
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        assert_eq!(json_u64(&text, "deadline_exceeded"), 1, "{text}");
        assert!(json_u64(&text, "faults_injected") > 0, "{text}");
        // Its result endpoint reports the terminal state, not 409.
        let (status, _, _) =
            http_request(&addr, "GET", &format!("/jobs/{id}/result"), b"").unwrap();
        assert_eq!(status, 500);

        // Cancel over the wire. Submit then DELETE: the single worker
        // may pick the job up first, so poll the DELETE until the job
        // is terminal — cancellation is cooperative and idempotent.
        let (status, _, body) =
            http_request(&addr, "POST", "/jobs", payload.as_bytes()).unwrap();
        assert_eq!(status, 202);
        let text = String::from_utf8(body).unwrap();
        let victim: u64 =
            text.trim_start_matches("{\"job\":").trim_end_matches('}').parse().unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        let text = loop {
            let (status, _, body) =
                http_request(&addr, "DELETE", &format!("/jobs/{victim}"), b"").unwrap();
            assert_eq!(status, 200);
            let text = String::from_utf8(body).unwrap();
            if !text.contains("\"state\":\"queued\"") && !text.contains("\"state\":\"running\"")
            {
                break text;
            }
            assert!(std::time::Instant::now() < deadline, "never terminal: {text}");
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        assert!(
            text.contains("\"state\":\"cancelled\"") || text.contains("\"state\":\"done\""),
            "{text}"
        );

        // Unknown ids and garbage ids.
        let (status, _, _) = http_request(&addr, "DELETE", "/jobs/99999", b"").unwrap();
        assert_eq!(status, 404);
        let (status, _, _) = http_request(&addr, "DELETE", "/jobs/zzz", b"").unwrap();
        assert_eq!(status, 400);

        // Drain: new submissions get a retriable 503.
        sched.drain(crate::serve::DrainPolicy::Cancel);
        let (status, headers, _) =
            http_request(&addr, "POST", "/jobs", payload.as_bytes()).unwrap();
        assert_eq!(status, 503);
        assert_eq!(headers.get("retry-after").map(String::as_str), Some("1"));

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    }

    #[test]
    fn bad_query_gets_422() {
        let server = DpuHttpServer::new(|_q: &SkimQuery, _tl: &Timeline| {
            unreachable!("handler must not run for invalid queries")
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = server.serve(listener, stop.clone());

        let (status, _, body) = post_skim(&addr, "{not json").unwrap();
        assert_eq!(status, 422);
        assert!(String::from_utf8_lossy(&body).contains("error"));

        crate::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    }
}
