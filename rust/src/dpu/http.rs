//! Minimal HTTP/1.1 service for the DPU's separated-host endpoint.
//!
//! Users interact with SkimROOT exactly as the paper describes: an
//! HTTP POST with the JSON selection payload (`curl -d @query.json
//! http://<dpu>/skim`). The response body is the filtered troot file;
//! job statistics come back in `X-Skim-*` headers.
//!
//! Hand-rolled request/response parsing (no HTTP crates offline):
//! request line + headers + `Content-Length` body; responses are
//! always `Connection: close`.

use crate::coordinator::Deployment;
use crate::job::SkimJob;
use crate::metrics::Timeline;
use crate::query::SkimQuery;
use crate::runtime::SkimRuntime;
use crate::{Error, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

pub const MAX_BODY: usize = 64 * 1024 * 1024;

/// A parsed HTTP request.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

/// Parse one HTTP/1.1 request from a stream.
pub fn read_request(stream: &mut impl Read) -> Result<HttpRequest> {
    // Read until CRLFCRLF (header terminator).
    let mut buf = Vec::with_capacity(1024);
    let mut byte = [0u8; 1];
    while !buf.ends_with(b"\r\n\r\n") {
        if buf.len() > 64 * 1024 {
            return Err(Error::protocol("http: header section too large"));
        }
        let n = stream.read(&mut byte)?;
        if n == 0 {
            return Err(Error::protocol("http: connection closed mid-header"));
        }
        buf.push(byte[0]);
    }
    let head = std::str::from_utf8(&buf[..buf.len() - 4])
        .map_err(|_| Error::protocol("http: non-utf8 header"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().ok_or_else(|| Error::protocol("http: empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| Error::protocol("http: no method"))?.to_string();
    let path = parts.next().ok_or_else(|| Error::protocol("http: no path"))?.to_string();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(Error::protocol(format!("http: unsupported version '{version}'")));
    }

    let mut headers = HashMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    let body_len: usize = headers
        .get("content-length")
        .map(|v| v.parse().map_err(|_| Error::protocol("http: bad content-length")))
        .transpose()?
        .unwrap_or(0);
    if body_len > MAX_BODY {
        return Err(Error::protocol("http: body too large"));
    }
    let mut body = vec![0u8; body_len];
    stream.read_exact(&mut body)?;
    Ok(HttpRequest { method, path, headers, body })
}

/// Write an HTTP/1.1 response.
pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    reason: &str,
    headers: &[(&str, String)],
    body: &[u8],
) -> Result<()> {
    write!(stream, "HTTP/1.1 {status} {reason}\r\n")?;
    for (k, v) in headers {
        write!(stream, "{k}: {v}\r\n")?;
    }
    write!(stream, "Content-Length: {}\r\nConnection: close\r\n\r\n", body.len())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// The DPU's HTTP front-end, generic over the job executor so the
/// in-process node model and tests can plug in.
pub struct DpuHttpServer<F> {
    handler: Arc<F>,
}

/// What the executor returns: the filtered file plus summary stats.
pub struct SkimHttpOutput {
    pub output: Vec<u8>,
    pub n_events: u64,
    pub n_pass: u64,
    pub elapsed: f64,
}

impl<F> DpuHttpServer<F>
where
    F: Fn(&SkimQuery, &Timeline) -> Result<SkimHttpOutput> + Send + Sync + 'static,
{
    pub fn new(handler: F) -> Self {
        DpuHttpServer { handler: Arc::new(handler) }
    }

    /// Serve until `stop`; one thread per connection (the DPU has 16
    /// ARM cores; connection handling is not the bottleneck).
    pub fn serve(
        &self,
        listener: TcpListener,
        stop: Arc<AtomicBool>,
    ) -> std::thread::JoinHandle<()> {
        let handler = self.handler.clone();
        listener.set_nonblocking(true).expect("set_nonblocking");
        std::thread::spawn(move || {
            let mut conns = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let handler = handler.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_connection(stream, &*handler);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        })
    }
}

fn handle_connection<F>(mut stream: TcpStream, handler: &F) -> Result<()>
where
    F: Fn(&SkimQuery, &Timeline) -> Result<SkimHttpOutput>,
{
    stream.set_nodelay(true).ok();
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let msg = format!("{{\"error\": \"{e}\"}}");
            return write_response(&mut stream, 400, "Bad Request", &[], msg.as_bytes());
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => write_response(
            &mut stream,
            200,
            "OK",
            &[("Content-Type", "application/json".into())],
            b"{\"status\": \"ok\"}",
        ),
        ("POST", "/skim") => {
            let text = match std::str::from_utf8(&req.body) {
                Ok(t) => t,
                Err(_) => {
                    return write_response(&mut stream, 400, "Bad Request", &[], b"non-utf8 body")
                }
            };
            let query = match SkimQuery::from_json_text(text) {
                Ok(q) => q,
                Err(e) => {
                    let msg = format!("{{\"error\": \"{e}\"}}");
                    return write_response(
                        &mut stream,
                        422,
                        "Unprocessable Entity",
                        &[("Content-Type", "application/json".into())],
                        msg.as_bytes(),
                    );
                }
            };
            let timeline = Timeline::new();
            match handler(&query, &timeline) {
                Ok(out) => write_response(
                    &mut stream,
                    200,
                    "OK",
                    &[
                        ("Content-Type", "application/octet-stream".into()),
                        ("X-Skim-Events", out.n_events.to_string()),
                        ("X-Skim-Pass", out.n_pass.to_string()),
                        ("X-Skim-Elapsed-Secs", format!("{:.6}", out.elapsed)),
                    ],
                    &out.output,
                ),
                Err(e) => {
                    let msg = format!("{{\"error\": \"{e}\"}}");
                    write_response(
                        &mut stream,
                        500,
                        "Internal Server Error",
                        &[("Content-Type", "application/json".into())],
                        msg.as_bytes(),
                    )
                }
            }
        }
        _ => write_response(&mut stream, 404, "Not Found", &[], b"not found"),
    }
}

/// The standard separated-host executor: each `POST /skim` runs a
/// [`SkimJob`] under `deployment` against the `root` catalog — the
/// same facade the CLI and examples use, so HTTP-served skims and
/// in-process skims share one code path. A deployment with
/// `fan_out > 1` shards each request across a
/// [`crate::dpu::DpuCluster`].
///
/// Callers typically pass a DPU placement over
/// [`crate::net::LinkModel::local`] — the HTTP response *is* the real
/// output transfer, so no virtual output-transfer time should be
/// charged.
///
/// Concurrent requests are isolated: each one works in its own
/// subdirectory of `work_dir` (the server is thread-per-connection,
/// and two requests naming the same `output` must not race on one
/// file).
pub fn storage_handler(
    root: impl Into<PathBuf>,
    work_dir: impl Into<PathBuf>,
    runtime: Option<&'static SkimRuntime>,
    deployment: Deployment,
) -> impl Fn(&SkimQuery, &Timeline) -> Result<SkimHttpOutput> + Send + Sync + 'static {
    let root = root.into();
    let work = work_dir.into();
    let seq = AtomicU64::new(0);
    move |query: &SkimQuery, _timeline: &Timeline| {
        let req_dir = work.join(format!("req{}", seq.fetch_add(1, Ordering::Relaxed)));
        let report = SkimJob::new(query.clone())
            .storage(&root)
            .client_dir(&req_dir)
            .runtime(runtime)
            .deployment(deployment.clone())
            .run()?;
        let output = std::fs::read(&report.result.output_path)?;
        // The response body is the only product; a long-running service
        // must not accumulate one filtered file per request.
        let _ = std::fs::remove_dir_all(&req_dir);
        Ok(SkimHttpOutput {
            n_events: report.result.n_events,
            n_pass: report.result.n_pass,
            elapsed: report.latency,
            output,
        })
    }
}

/// Minimal HTTP client for posting skim queries (what `curl` does).
pub fn post_skim(addr: &str, query_json: &str) -> Result<(u16, HashMap<String, String>, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Error::protocol(format!("connect {addr}: {e}")))?;
    stream.set_nodelay(true).ok();
    write!(
        stream,
        "POST /skim HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
        query_json.len()
    )?;
    stream.write_all(query_json.as_bytes())?;
    stream.flush()?;

    // Parse response: status line, headers, body per Content-Length.
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    while !buf.ends_with(b"\r\n\r\n") {
        let n = stream.read(&mut byte)?;
        if n == 0 {
            return Err(Error::protocol("http: closed mid-response"));
        }
        buf.push(byte[0]);
    }
    let head = std::str::from_utf8(&buf[..buf.len() - 4])
        .map_err(|_| Error::protocol("http: non-utf8 response"))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::protocol("http: bad status line"))?;
    let mut headers = HashMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers.get("content-length").and_then(|v| v.parse().ok()).unwrap_or(0);
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok((status, headers, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_query_json() -> String {
        r#"{"input": "f.troot", "output": "o.troot", "branches": ["*"]}"#.to_string()
    }

    #[test]
    fn request_roundtrip() {
        let raw = b"POST /skim HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nbody";
        let req = read_request(&mut &raw[..]).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/skim");
        assert_eq!(req.body, b"body");
        assert_eq!(req.headers["host"], "x");
    }

    #[test]
    fn rejects_malformed_requests() {
        for raw in [
            &b"GARBAGE\r\n\r\n"[..],
            &b"GET /x SPDY/3\r\n\r\n"[..],
            &b"POST / HTTP/1.1\r\nContent-Length: zzz\r\n\r\n"[..],
        ] {
            assert!(read_request(&mut &raw[..]).is_err(), "{:?}", String::from_utf8_lossy(raw));
        }
    }

    #[test]
    fn response_format() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "OK", &[("X-Test", "1".into())], b"hi").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("X-Test: 1\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.ends_with("\r\n\r\nhi"));
    }

    #[test]
    fn end_to_end_post_skim() {
        let server = DpuHttpServer::new(|q: &SkimQuery, _tl: &Timeline| {
            assert_eq!(q.input, "f.troot");
            Ok(SkimHttpOutput {
                output: vec![1, 2, 3],
                n_events: 100,
                n_pass: 7,
                elapsed: 0.5,
            })
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = server.serve(listener, stop.clone());

        let (status, headers, body) = post_skim(&addr, &sample_query_json()).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, vec![1, 2, 3]);
        assert_eq!(headers["x-skim-pass"], "7");
        assert_eq!(headers["x-skim-events"], "100");

        stop.store(true, Ordering::Relaxed);
        handle.join().unwrap();
    }

    #[test]
    fn bad_query_gets_422() {
        let server = DpuHttpServer::new(|_q: &SkimQuery, _tl: &Timeline| {
            unreachable!("handler must not run for invalid queries")
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = server.serve(listener, stop.clone());

        let (status, _, body) = post_skim(&addr, "{not json").unwrap();
        assert_eq!(status, 422);
        assert!(String::from_utf8_lossy(&body).contains("error"));

        stop.store(true, Ordering::Relaxed);
        handle.join().unwrap();
    }
}
